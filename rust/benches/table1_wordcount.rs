//! Bench: Table I(a) — Wordcount sweep regeneration.

use bass::bench_harness::Bencher;
use bass::experiments::{run_cell_for_bench, run_table1, Table1Config};
use bass::runtime::CostModel;
use bass::trace;
use bass::workload::JobKind;

fn main() {
    let cost = CostModel::rust_only();
    let mut cfg = Table1Config::paper(JobKind::Wordcount);
    cfg.sizes_mb = vec![150.0, 300.0, 600.0];
    let b = Bencher::quick();
    println!("# bench: table1(a) wordcount");
    b.bench("table1a/sweep_150_300_600_x3sched", || run_table1(&cfg, &cost));
    for &size in &cfg.sizes_mb {
        b.bench(&format!("table1a/cell/bass/{}MB", size), || {
            run_cell_for_bench(&cfg, size, &cost)
        });
    }
    let rows = run_table1(&cfg, &cost);
    print!("{}", trace::table1_markdown(&rows));
}
