//! Bench: Example 1 / Fig 3 / Fig 4 — full 4-scheduler walk-through,
//! plus per-scheduler scheduling latency on the 9-task fixture.

use bass::bench_harness::Bencher;
use bass::experiments::{example1_fixture, run_example1, run_one, SchedulerKind};
use bass::runtime::CostModel;
use bass::sched::SchedCtx;
use bass::util::Secs;

fn main() {
    let cost = CostModel::rust_only();
    let b = Bencher::default();
    println!("# bench: example1 (Fig 3 / Fig 4 regeneration)");
    b.bench("example1/all_four_schedulers+execution", || run_example1(&cost));
    for kind in SchedulerKind::ALL {
        b.bench(&format!("example1/schedule_only/{}", kind.label()), || {
            let mut fx = example1_fixture();
            let mut s = kind.make();
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut fx.ctrl,
                namenode: &fx.nn,
                ledger: &mut fx.ledger,
                authorized: fx.nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            s.schedule(&fx.tasks, None, &mut ctx)
        });
        b.bench(&format!("example1/schedule+execute/{}", kind.label()), || {
            run_one(kind, &cost)
        });
    }
    // regenerate the figure values once for the log
    for o in run_example1(&cost) {
        println!("  fig4 row: {:<9} JT {:.0}s", o.scheduler, o.executed_jt);
    }
}
