//! Bench: the AOT XLA request path — artifact compile (cold) and
//! execute (hot), vs the pure-Rust mirror. §Perf L1/L2 evidence.

use bass::bench_harness::Bencher;
use bass::runtime::{CostInputs, CostModel};
use bass::util::XorShift;

fn inputs(m: usize, n: usize, seed: u64) -> CostInputs {
    let mut r = XorShift::new(seed);
    CostInputs {
        m,
        n,
        sz: (0..m).map(|_| r.uniform(1.0, 5000.0) as f32).collect(),
        bw: (0..m * n).map(|_| r.uniform(0.5, 120.0) as f32).collect(),
        tp: (0..m * n).map(|_| r.uniform(1.0, 900.0) as f32).collect(),
        local: (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect(),
        idle: (0..n).map(|_| r.uniform(0.0, 200.0) as f32).collect(),
        ts: 1.0,
    }
}

fn main() {
    let model = CostModel::auto();
    let b = Bencher::default();
    println!("# bench: runtime xla path");
    if model.backend_for(16, 8) != bass::runtime::exec::Backend::Xla {
        println!("no artifacts found — run `make artifacts`; skipping XLA benches");
        return;
    }
    for (m, n) in [(9usize, 4usize), (16, 8), (64, 16), (256, 64)] {
        let inp = inputs(m, n, 1);
        b.bench(&format!("xla/eval/{m}x{n}"), || model.eval(&inp).unwrap());
        b.bench(&format!("rust/eval/{m}x{n}"), || CostModel::eval_rust(&inp));
    }
}
