//! Microbenches: the L3 hot paths — scheduler decision latency at scale,
//! slot-calendar ops, flow-network recomputation, XLA cost-model calls.
//! This is the §Perf driver (EXPERIMENTS.md).

use bass::bench_harness::{Bencher, Stats};
use bass::cluster::Ledger;
use bass::sdn::SlotCalendar;
use bass::hdfs::{Namenode, PlacementPolicy};
use bass::mapreduce::TaskSpec;
use bass::runtime::{CostInputs, CostModel};
use bass::sched::{Bass, Hds, SchedCtx, Scheduler};
use bass::sdn::{Controller, TrafficClass};
use bass::sim::FlowNet;
use bass::topology::builders::tree_cluster;
use bass::topology::LinkId;
use bass::util::{Secs, XorShift, BLOCK_MB};

fn big_cluster(n_sw: usize, per_sw: usize, m_tasks: usize) -> (Controller, Namenode, Vec<bass::topology::NodeId>, Vec<TaskSpec>) {
    let (topo, nodes) = tree_cluster(n_sw, per_sw, 100.0, 1000.0);
    let ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(7);
    let blocks = PlacementPolicy::RandomDistinct.place(&mut nn, &nodes, m_tasks, BLOCK_MB, 3, &mut rng);
    let tasks = blocks
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(20.0), 16.0))
        .collect();
    (ctrl, nn, nodes, tasks)
}

fn main() {
    let b = Bencher::default();
    println!("# bench: scheduler micro (L3 hot paths)");

    for (m, n_sw, per_sw) in [(64usize, 4usize, 8usize), (256, 8, 8)] {
        let n = n_sw * per_sw;
        // setup is hoisted out; each sample clones the pristine state so
        // the timing isolates the scheduling decision path
        let (ctrl0, nn, nodes, tasks) = big_cluster(n_sw, per_sw, m);
        for which in ["bass", "hds"] {
            b.bench(&format!("schedule/{which}/{m}tasks_{n}nodes"), || {
                let mut ctrl = ctrl0.clone();
                let cost = CostModel::rust_only();
                let mut ledger = Ledger::new(nodes.len());
                let mut ctx = SchedCtx {
                    controller: &mut ctrl,
                    namenode: &nn,
                    ledger: &mut ledger,
                    authorized: nodes.clone(),
                    now: Secs::ZERO,
                    cost: &cost,
            node_speed: Vec::new(),
                };
                if which == "bass" {
                    Bass::new().schedule(&tasks, None, &mut ctx)
                } else {
                    Hds::new().schedule(&tasks, None, &mut ctx)
                }
            });
        }
    }

    // cost model backends
    let mk_inputs = |m: usize, n: usize| -> CostInputs {
        let mut r = XorShift::new(3);
        CostInputs {
            m,
            n,
            sz: (0..m).map(|_| r.uniform(1.0, 5000.0) as f32).collect(),
            bw: (0..m * n).map(|_| r.uniform(0.5, 120.0) as f32).collect(),
            tp: (0..m * n).map(|_| r.uniform(1.0, 900.0) as f32).collect(),
            local: (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect(),
            idle: (0..n).map(|_| r.uniform(0.0, 200.0) as f32).collect(),
            ts: 1.0,
        }
    };
    let auto = CostModel::auto();
    for (m, n) in [(16usize, 8usize), (64, 16), (256, 64)] {
        let inp = mk_inputs(m, n);
        b.bench(&format!("cost/rust/{m}x{n}"), || CostModel::eval_rust(&inp));
        if auto.backend_for(m, n) == bass::runtime::exec::Backend::Xla {
            b.bench(&format!("cost/xla/{m}x{n}"), || auto.eval(&inp).unwrap());
        }
    }

    // slot calendar ops
    b.bench("calendar/plan+reserve+release_64slots", || {
        let mut ctrl = {
            let (topo, _) = tree_cluster(2, 3, 100.0, 100.0);
            Controller::new(topo, 1.0)
        };
        let nodes = ctrl.topo().hosts.clone();
        let mut out = 0usize;
        for i in 0..64 {
            let plan = ctrl
                .plan_transfer(nodes[i % 3], nodes[3 + i % 3], 64.0, Secs(i as f64))
                .unwrap();
            let t = ctrl
                .commit_transfer(nodes[i % 3], nodes[3 + i % 3], TrafficClass::HadoopOther, plan, Secs(i as f64))
                .unwrap();
            out += t.reservation.n_slots;
            ctrl.complete_transfer(&t, 64.0);
        }
        out
    });

    // flow network recompute at scale
    b.bench("flownet/200flows_recompute", || {
        let caps: Vec<f64> = (0..64).map(|_| 100.0).collect();
        let mut net = FlowNet::new(&caps);
        let mut r = XorShift::new(5);
        for _ in 0..200 {
            let a = r.below(64);
            let b2 = r.below(64);
            net.add_flow(vec![LinkId(a), LinkId(b2)], 64.0, TrafficClass::HadoopOther);
        }
        net.n_flows()
    });

    // sparse calendar: reserve/release throughput vs horizon length. The
    // seed's dense Vec<f64>-per-slot calendar allocated and walked arrays
    // proportional to the absolute slot index, so the 1M-slot horizon was
    // ~100x the 10k one; the interval calendar costs O(log segments) per
    // op at any horizon. Results land in BENCH_calendar.json.
    let calendar_case = |horizon_slots: usize| {
        move || {
            let mut cal = SlotCalendar::new(8, 1.0);
            let mut r = XorShift::new(11);
            let mut grants = Vec::with_capacity(256);
            for _ in 0..256 {
                let links = [LinkId(r.below(8)), LinkId(r.below(8))];
                let start = r.below(horizon_slots);
                let frac = r.uniform(0.05, 0.45);
                if let Ok(g) = cal.reserve_path(&links, start, 1 + r.below(16), frac) {
                    grants.push(g);
                }
            }
            let segs = cal.n_segments();
            for g in &grants {
                cal.release(g);
            }
            segs
        }
    };
    let s10k = b.bench("calendar_sparse/reserve_release_10k_horizon", calendar_case(10_000));
    let s1m = b.bench("calendar_sparse/reserve_release_1M_horizon", calendar_case(1_000_000));
    write_calendar_json(&s10k, &s1m);
}

/// Record the calendar bench (schema consumed by BENCH_calendar.json at
/// the repo root; regenerate with `cargo bench --bench scheduler_micro`).
fn write_calendar_json(s10k: &Stats, s1m: &Stats) {
    let row = |name: &str, s: &Stats| {
        format!(
            "    {{\"case\": \"{name}\", \"mean_s\": {:.9}, \"p50_s\": {:.9}, \"p99_s\": {:.9}, \"min_s\": {:.9}, \"samples\": {}}}",
            s.mean, s.p50, s.p99, s.min, s.samples
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"calendar_sparse\",\n  \"measured\": true,\n  \"workload\": \"256 two-link reservations (1-16 slots, frac 0.05-0.45) + full release on an 8-link calendar\",\n  \"note\": \"sparse interval calendar: horizon-independent cost; the dense seed scaled with the absolute slot index\",\n  \"ratio_1M_over_10k_mean\": {:.3},\n  \"cases\": [\n{},\n{}\n  ]\n}}\n",
        s1m.mean / s10k.mean,
        row("reserve_release_10k_horizon", s10k),
        row("reserve_release_1M_horizon", s1m)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_calendar.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
