//! Microbenches: the L3/L4 hot paths — scheduler decision latency at
//! scale, slot-calendar ops, flow-network churn, engine replay, XLA
//! cost-model calls. This is the §Perf driver (EXPERIMENTS.md).
//!
//! Measured results land in `BENCH_calendar.json`, `BENCH_flownet.json`,
//! `BENCH_sched.json`, `BENCH_scale.json` and `BENCH_stream.json` at the
//! repo root; the CI bench-smoke job runs
//! this binary with `BASS_BENCH_QUICK=1` and fails on >2x regressions
//! against the committed baselines (tools/check_bench_regression.py).

use bass::bench_harness::{Bencher, Stats};
use bass::cluster::Ledger;
use bass::experiments::{fat_scale_spec, scale_spec, stream_cluster};
use bass::hdfs::{Namenode, PlacementPolicy};
use bass::mapreduce::TaskSpec;
use bass::runtime::{CostInputs, CostModel};
use bass::scenario::{
    checkpoint_soak, resume_soak, AdmissionPolicy, SimSession, SoakConfig, Submission,
};
use bass::sched::cost::eval_batch;
use bass::sched::{Bass, Hds, SchedCtx, Scheduler, SchedulerKind};
use bass::sdn::{Controller, SlotCalendar, TrafficClass};
use bass::sim::FlowNet;
use bass::topology::builders::{fat_tree, tree_cluster};
use bass::topology::{LinkId, NodeId, PathCache};
use bass::util::{Secs, XorShift, BLOCK_MB};
use bass::workload::{LoadShape, LoadStage, SizeDist};

fn big_cluster(
    n_sw: usize,
    per_sw: usize,
    m_tasks: usize,
) -> (Controller, Namenode, Vec<NodeId>, Vec<TaskSpec>) {
    let (topo, nodes) = tree_cluster(n_sw, per_sw, 100.0, 1000.0);
    let ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(7);
    let blocks = PlacementPolicy::RandomDistinct
        .place(&mut nn, &nodes, &[], m_tasks, BLOCK_MB, 3, &mut rng);
    let tasks = blocks
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(20.0), 16.0))
        .collect();
    (ctrl, nn, nodes, tasks)
}

/// The ISSUE-2 churn workload: 50 capped background + 200 finite flows
/// over the 64 links of an 8x7 tree, then a full drain through
/// `next_completion`/`settle`/`finished_into`/`remove_flow` — the exact
/// op mix the DES engine drives. Paths are resolved outside the timer.
fn flownet_churn_paths() -> (Vec<Vec<LinkId>>, Vec<Vec<LinkId>>) {
    let (topo, nodes) = tree_cluster(8, 7, 100.0, 1000.0); // 56 + 8 = 64 links
    assert_eq!(topo.n_links(), 64);
    let mut rng = XorShift::new(13);
    let mut pick_path = |rng: &mut XorShift| -> Vec<LinkId> {
        loop {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            if a != b {
                return topo.route(a, b).expect("tree is connected");
            }
        }
    };
    let bg: Vec<Vec<LinkId>> = (0..50).map(|_| pick_path(&mut rng)).collect();
    let fg: Vec<Vec<LinkId>> = (0..200).map(|_| pick_path(&mut rng)).collect();
    (bg, fg)
}

fn flownet_churn_cycle(bg: &[Vec<LinkId>], fg: &[Vec<LinkId>]) -> f64 {
    let caps = vec![100.0f64; 64];
    let mut net = FlowNet::new(&caps);
    for p in bg {
        net.add_background_capped(p.clone(), TrafficClass::Background, 4.0);
    }
    for p in fg {
        net.add_flow_slice(p, 64.0, TrafficClass::HadoopOther);
    }
    let mut done = 0usize;
    let mut buf = Vec::new();
    while done < fg.len() {
        let (t, _) = net.next_completion().expect("finite flows must finish");
        net.settle(t.max(net.clock()));
        net.finished_into(&mut buf);
        for &id in &buf {
            net.remove_flow(id);
            done += 1;
        }
    }
    net.clock().0
}

fn main() {
    // CI smoke runs with short sample counts
    let b = if std::env::var_os("BASS_BENCH_QUICK").is_some() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    println!("# bench: scheduler micro (L3/L4 hot paths)");

    let mut sched_cases: Vec<(String, Stats)> = Vec::new();
    for (m, n_sw, per_sw) in [(64usize, 4usize, 8usize), (256, 8, 8)] {
        let n = n_sw * per_sw;
        // setup is hoisted out; each sample clones the pristine state so
        // the timing isolates the scheduling decision path
        let (ctrl0, nn, nodes, tasks) = big_cluster(n_sw, per_sw, m);
        for which in ["bass", "hds"] {
            let stats = b.bench(&format!("schedule/{which}/{m}tasks_{n}nodes"), || {
                let mut ctrl = ctrl0.clone();
                let cost = CostModel::rust_only();
                let mut ledger = Ledger::new(nodes.len());
                let mut ctx = SchedCtx {
                    view: &bass::sdn::Oracle,
                    controller: &mut ctrl,
                    namenode: &nn,
                    ledger: &mut ledger,
                    authorized: nodes.clone(),
                    now: Secs::ZERO,
                    cost: &cost,
                    node_speed: Vec::new(),
                    down: Vec::new(),
                    bw_aware_sources: true,
                };
                if which == "bass" {
                    Bass::new().schedule(&tasks, None, &mut ctx)
                } else {
                    Hds::new().schedule(&tasks, None, &mut ctx)
                }
            });
            if m == 256 {
                let label = if which == "bass" { "bass_round" } else { "hds_round" };
                sched_cases.push((label.to_string(), stats));
            }
        }
    }

    // engine replay: schedule once, then time pure DES execution (flow
    // churn included) of the 32-node shared-cluster scale point
    {
        let mut sess = SimSession::new(&scale_spec(4, SchedulerKind::Hds));
        let tasks = sess.tasks.clone();
        let cost = CostModel::rust_only();
        let a = sess.schedule(&tasks, None, Secs::ZERO, &cost);
        let stats = b.bench("engine_replay/hds_64tasks_32nodes", || sess.execute(&a));
        sched_cases.push(("engine_replay".to_string(), stats));
    }
    // fat-tree construction + one BASS round at the 128-node point keeps
    // the thousand-node path honest without minutes of CI time
    {
        let spec = fat_scale_spec(16, SchedulerKind::Bass);
        let cost = CostModel::rust_only();
        let stats = b.bench("bass_round/fat_tree_128nodes_build+schedule", || {
            let mut sess = SimSession::new(&spec);
            let tasks = sess.tasks.clone();
            sess.schedule(&tasks, None, Secs::ZERO, &cost)
        });
        sched_cases.push(("bass_round_fat128".to_string(), stats));
    }
    write_json(
        "BENCH_sched.json",
        "scheduler_micro",
        "BASS/HDS rounds at 256 tasks x 64 nodes; HDS engine replay at 64 tasks x 32 nodes; fat-tree BASS point at 128 nodes",
        "Perf L4 scheduler inner loops: IdleHeap min-idle, per-node local queues, hoisted speed factors, contiguous TM rows",
        &sched_cases,
    );

    // cost model backends
    let mk_inputs = |m: usize, n: usize| -> CostInputs {
        let mut r = XorShift::new(3);
        CostInputs {
            m,
            n,
            sz: (0..m).map(|_| r.uniform(1.0, 5000.0) as f32).collect(),
            bw: (0..m * n).map(|_| r.uniform(0.5, 120.0) as f32).collect(),
            tp: (0..m * n).map(|_| r.uniform(1.0, 900.0) as f32).collect(),
            local: (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect(),
            idle: (0..n).map(|_| r.uniform(0.0, 200.0) as f32).collect(),
            ts: 1.0,
        }
    };
    let auto = CostModel::auto();
    for (m, n) in [(16usize, 8usize), (64, 16), (256, 64)] {
        let inp = mk_inputs(m, n);
        b.bench(&format!("cost/rust/{m}x{n}"), || CostModel::eval_rust(&inp));
        if auto.backend_for(m, n) == bass::runtime::exec::Backend::Xla {
            b.bench(&format!("cost/xla/{m}x{n}"), || auto.eval(&inp).unwrap());
        }
    }

    // slot calendar ops
    b.bench("calendar/plan+reserve+release_64slots", || {
        let mut ctrl = {
            let (topo, _) = tree_cluster(2, 3, 100.0, 100.0);
            Controller::new(topo, 1.0)
        };
        let nodes = ctrl.topo().hosts.clone();
        let mut out = 0usize;
        for i in 0..64 {
            let plan = ctrl
                .plan_transfer(nodes[i % 3], nodes[3 + i % 3], 64.0, Secs(i as f64))
                .unwrap();
            let t = ctrl
                .commit_transfer(
                    nodes[i % 3],
                    nodes[3 + i % 3],
                    TrafficClass::HadoopOther,
                    plan,
                    Secs(i as f64),
                )
                .unwrap();
            out += t.reservation.n_slots;
            ctrl.complete_transfer(&t, 64.0);
        }
        out
    });

    // flow network: incremental churn (the ISSUE-2 acceptance case) and
    // the legacy 200-flow add-storm
    let (bg, fg) = flownet_churn_paths();
    let churn = b.bench("flownet_churn/200finite+50bg_64link_tree", || {
        flownet_churn_cycle(&bg, &fg)
    });
    let storm = b.bench("flownet/200flows_recompute", || {
        let caps: Vec<f64> = (0..64).map(|_| 100.0).collect();
        let mut net = FlowNet::new(&caps);
        let mut r = XorShift::new(5);
        for _ in 0..200 {
            let a = r.below(64);
            let b2 = r.below(64);
            net.add_flow(vec![LinkId(a), LinkId(b2)], 64.0, TrafficClass::HadoopOther);
        }
        // lazy refill: force the recompute the seed ran eagerly
        net.settle(Secs(0.0));
        net.n_flows()
    });
    write_json(
        "BENCH_flownet.json",
        "flownet_churn",
        "full add/drain cycle: 200 finite (64MB) + 50 background (4MB/s cap) flows over a 64-link 8x7 tree; plus a 200-flow add storm",
        "Perf L4 incremental flow network: slab arena + per-link index + lazy component refill + completion heap (seed: from-scratch O(F*L) per add/remove)",
        &[("flownet_churn".to_string(), churn), ("add_storm_200flows".to_string(), storm)],
    );

    // sparse calendar: reserve/release throughput vs horizon length. The
    // seed's dense Vec<f64>-per-slot calendar allocated and walked arrays
    // proportional to the absolute slot index, so the 1M-slot horizon was
    // ~100x the 10k one; the interval calendar costs O(log segments) per
    // op at any horizon. Results land in BENCH_calendar.json.
    let calendar_case = |horizon_slots: usize| {
        move || {
            let mut cal = SlotCalendar::new(8, 1.0);
            let mut r = XorShift::new(11);
            let mut grants = Vec::with_capacity(256);
            for _ in 0..256 {
                let links = [LinkId(r.below(8)), LinkId(r.below(8))];
                let start = r.below(horizon_slots);
                let frac = r.uniform(0.05, 0.45);
                if let Ok(g) = cal.reserve_path(&links, start, 1 + r.below(16), frac) {
                    grants.push(g);
                }
            }
            let segs = cal.n_segments();
            for g in &grants {
                cal.release(g);
            }
            segs
        }
    };
    let s10k = b.bench("calendar_sparse/reserve_release_10k_horizon", calendar_case(10_000));
    let s1m = b.bench("calendar_sparse/reserve_release_1M_horizon", calendar_case(1_000_000));
    write_calendar_json(&s10k, &s1m);

    // ten-kilonode tier (BENCH_scale.json): the kilonode sharded BASS
    // point, the batched cost kernel, and hierarchical path-cache
    // construction — the three hot paths the sharded stack rebuilds
    let mut scale_cases: Vec<(String, Stats)> = Vec::new();
    {
        // kilonode sharded point: session build + one BASS round at 1024
        // hosts / 2048 tasks. Per-rack ShardedIdleHeaps and the
        // shard-grouped minnow scan run under the hood; the property
        // pins guarantee the schedule matches the flat path bitwise.
        let spec = fat_scale_spec(128, SchedulerKind::Bass);
        let cost = CostModel::rust_only();
        let stats = b.bench("scale_shard/fat_tree_1024hosts_build+schedule", || {
            let mut sess = SimSession::new(&spec);
            let tasks = sess.tasks.clone();
            sess.schedule(&tasks, None, Secs::ZERO, &cost)
        });
        scale_cases.push(("scale_shard".to_string(), stats));
    }
    {
        // batched cost kernel: blocked build_inputs (per-holder bandwidth
        // rows reused across tasks sharing a block) + evaluation of one
        // 2048 x 512 matrix
        let (mut ctrl, nn, nodes, tasks) = big_cluster(8, 64, 2048);
        let cost = CostModel::rust_only();
        let stats = b.bench("cost_batch/build+eval_2048x512", || {
            let mut ledger = Ledger::new(nodes.len());
            let ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            eval_batch(&tasks, &ctx)
        });
        scale_cases.push(("cost_batch".to_string(), stats));
    }
    {
        // hierarchical path cache: pod-level two-tier build on the
        // 1024-host fat tree (the flat per-source table this replaces
        // held one BFS result per host pair)
        let (topo, _) = fat_tree(8, 128, 4, 100.0, 10_000.0);
        let stats = b.bench("pathcache_hier/build_fat_1024hosts", || PathCache::build(&topo));
        scale_cases.push(("pathcache_hier".to_string(), stats));
    }
    write_json(
        "BENCH_scale.json",
        "scale_shard",
        "kilonode fat-tree BASS round (1024 hosts / 2048 tasks, per-rack shards); batched cost kernel on a 2048x512 matrix; hierarchical PathCache build at 1024 hosts",
        "Perf ten-kilonode tier: sharded idle heaps + shard-grouped scans, blocked build_inputs with shared row memo + row-chunked eval, pod-level two-tier path cache",
        &scale_cases,
    );

    // soak-stream tier (BENCH_stream.json): a shaped 24-job trace —
    // ramp in, burst, steady soak — through the bounded-memory soak
    // driver (drain + arena compaction + calendar GC on the hot path),
    // plus the mid-trace checkpoint/resume round trip
    let mut stream_cases: Vec<(String, Stats)> = Vec::new();
    let soak_shape = LoadShape::new(
        vec![
            LoadStage::ramp(8, 40.0, 20.0),
            LoadStage::spike(4, 20.0, 3.0),
            LoadStage::soak(12, 25.0),
        ],
        SizeDist::Menu(vec![150.0, 300.0]),
        None,
    )
    .expect("bench load shape is valid");
    let soak_subs: Vec<Submission> = {
        let mut rng = XorShift::new(4242);
        soak_shape.generate(&mut rng).into_iter().map(Submission::from).collect()
    };
    let soak_spec = stream_cluster(SchedulerKind::Bass);
    let soak_policy = AdmissionPolicy { max_active: 6, min_free_slots: 0 };
    let soak_cfg =
        SoakConfig { target_p95_slowdown: 2.0, sketch_cap: 256, gc_period_secs: 120.0 };
    {
        let cost = CostModel::rust_only();
        let stats = b.bench("stream_soak/24jobs_shaped_bass_drain", || {
            let mut sess = SimSession::new(&soak_spec);
            sess.run_soak(soak_subs.clone(), soak_policy, &cost, soak_cfg).jobs
        });
        stream_cases.push(("stream_soak".to_string(), stats));
    }
    {
        let cost = CostModel::rust_only();
        let half = soak_subs.len() / 2;
        let stats = b.bench("soak_checkpoint/snapshot+resume_mid_trace", || {
            let mut sess = SimSession::new(&soak_spec);
            let ckpt =
                checkpoint_soak(&mut sess, &soak_subs, half, soak_policy, &cost, soak_cfg);
            let mut resumed = SimSession::new(&soak_spec);
            resume_soak(&mut resumed, ckpt, soak_subs[half..].to_vec(), &cost).jobs
        });
        stream_cases.push(("soak_checkpoint".to_string(), stats));
    }
    write_json(
        "BENCH_stream.json",
        "stream_soak",
        "full soak drain of a shaped 24-job trace (ramp 8, spike 4, soak 12; max_active 6) on the 12-host stream cluster; mid-trace checkpoint + resume of the same trace",
        "Perf soak tier: bounded-memory drain (finished-record forgetting, placement-arena compaction, calendar GC) and the snapshot/resume path that replays no completed work",
        &stream_cases,
    );
}

fn case_row(name: &str, s: &Stats) -> String {
    format!(
        "    {{\"case\": \"{name}\", \"mean_s\": {:.9}, \"p50_s\": {:.9}, \"p99_s\": {:.9}, \"min_s\": {:.9}, \"samples\": {}}}",
        s.mean, s.p50, s.p99, s.min, s.samples
    )
}

/// Write one BENCH_*.json at the repo root (schema shared with the CI
/// regression check, tools/check_bench_regression.py).
fn write_json(file: &str, bench: &str, workload: &str, note: &str, cases: &[(String, Stats)]) {
    let rows: Vec<String> = cases.iter().map(|(name, s)| case_row(name, s)).collect();
    let json = format!(
        "{{\n  \"bench\": \"{bench}\",\n  \"measured\": true,\n  \"workload\": \"{workload}\",\n  \"note\": \"{note}\",\n  \"cases\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let path = format!("{}/../{file}", env!("CARGO_MANIFEST_DIR"));
    match std::fs::write(&path, json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

/// Record the calendar bench (schema consumed by BENCH_calendar.json at
/// the repo root; regenerate with `cargo bench --bench scheduler_micro`).
fn write_calendar_json(s10k: &Stats, s1m: &Stats) {
    let json = format!(
        "{{\n  \"bench\": \"calendar_sparse\",\n  \"measured\": true,\n  \"workload\": \"256 two-link reservations (1-16 slots, frac 0.05-0.45) + full release on an 8-link calendar\",\n  \"note\": \"sparse interval calendar: horizon-independent cost; the dense seed scaled with the absolute slot index\",\n  \"ratio_1M_over_10k_mean\": {:.3},\n  \"cases\": [\n{},\n{}\n  ]\n}}\n",
        s1m.mean / s10k.mean,
        case_row("reserve_release_10k_horizon", s10k),
        case_row("reserve_release_1M_horizon", s1m)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_calendar.json");
    match std::fs::write(path, json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
