//! Bench: Fig 5 — both JT-vs-size panels.

use bass::bench_harness::Bencher;
use bass::experiments::run_fig5;
use bass::runtime::CostModel;

fn main() {
    let cost = CostModel::rust_only();
    let b = Bencher::quick();
    println!("# bench: fig5 (both panels)");
    b.bench("fig5/both_panels_150_600", || {
        run_fig5(&cost, Some(vec![150.0, 600.0]), 1)
    });
    b.bench("fig5/both_panels_150_600/threads4", || {
        run_fig5(&cost, Some(vec![150.0, 600.0]), 4)
    });
    for p in run_fig5(&cost, Some(vec![150.0, 300.0, 600.0]), 4) {
        println!("  panel {}:", p.job);
        for (name, jts) in &p.series {
            println!("    {:<8} {:?}", name, jts.iter().map(|x| x.round()).collect::<Vec<_>>());
        }
    }
}
