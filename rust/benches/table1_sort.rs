//! Bench: Table I(b) — Sort sweep regeneration.

use bass::bench_harness::Bencher;
use bass::experiments::{run_table1, Table1Config};
use bass::runtime::CostModel;
use bass::trace;
use bass::workload::JobKind;

fn main() {
    let cost = CostModel::rust_only();
    let mut cfg = Table1Config::paper(JobKind::Sort);
    cfg.sizes_mb = vec![150.0, 300.0, 600.0];
    let b = Bencher::quick();
    println!("# bench: table1(b) sort");
    b.bench("table1b/sweep_150_300_600_x3sched", || run_table1(&cfg, &cost));
    let rows = run_table1(&cfg, &cost);
    print!("{}", trace::table1_markdown(&rows));
}
