//! Bench: ablations over the paper's tunables (slot duration, background
//! intensity, replication, heterogeneity) + the future-work scale sweep.

use bass::bench_harness::Bencher;
use bass::experiments::{
    ablate_background, ablate_heterogeneity, ablate_replication, ablate_slot_duration,
    run_scale,
};
use bass::runtime::CostModel;

fn main() {
    let cost = CostModel::rust_only();
    let b = Bencher::quick();
    println!("# bench: ablations + scale");
    b.bench("ablate/slot_duration_4pts", || {
        ablate_slot_duration(&[0.25, 1.0, 2.0, 4.0], &cost)
    });
    b.bench("ablate/background_4pts", || ablate_background(&[0, 2, 4, 8], &cost));
    b.bench("ablate/replication_3pts", || ablate_replication(&[1, 2, 3], &cost));
    b.bench("ablate/heterogeneity_3x", || ablate_heterogeneity(3.0, &cost));
    b.bench("scale/8sw_x2..4", || run_scale(&[2, 4], &cost, 1));
    // fan the same grid across 4 workers: identical metrics, less wall
    b.bench("scale/8sw_x2..4/threads4", || run_scale(&[2, 4], &cost, 4));

    println!("\nablation values:");
    for p in ablate_slot_duration(&[0.25, 1.0, 2.0, 4.0], &cost) {
        println!("  ts={:<5} {:<5} JT {:.1}s", p.x, p.scheduler, p.jt);
    }
    for p in ablate_background(&[0, 2, 4, 8], &cost) {
        println!("  bg={:<5} {:<5} JT {:.1}s", p.x, p.scheduler, p.jt);
    }
    for (s, jt) in ablate_heterogeneity(3.0, &cost) {
        println!("  hetero3x {:<5} JT {:.1}s", s, jt);
    }
    for p in run_scale(&[2, 4, 8, 16], &cost, 4) {
        println!(
            "  scale n={:<4} m={:<4} {:<5} sched {:.1}ms makespan {:.0}s",
            p.nodes,
            p.tasks,
            p.scheduler,
            p.sched_secs * 1e3,
            p.makespan
        );
    }
}
