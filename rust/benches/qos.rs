//! Bench: Example 3 — QoS queue comparison.

use bass::bench_harness::Bencher;
use bass::experiments::run_example3;

fn main() {
    let b = Bencher::default();
    println!("# bench: example3 qos");
    b.bench("qos/shared_vs_queued_5bg", || run_example3(5));
    for bg in [0usize, 5, 10] {
        let o = run_example3(bg);
        println!("  bg={bg}: shared {:.1}s queued {:.1}s speedup {:.2}x", o.shared_secs, o.queued_secs, o.speedup);
    }
}
