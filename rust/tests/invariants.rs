//! Invariant-oracle suite: random `DynamicsSpec`s through HDS, BAR and
//! BASS, with `testkit::oracles` asserting the global safety properties
//! after every run — no task on a down node, exactly-once completion,
//! reservations within (time-varying) capacity, makespan lower bounds.
//!
//! `BASS_BENCH_QUICK=1` shrinks the case count for CI smoke runs; every
//! failure replays exactly from the printed (seed, case) pair.

//! The second half drives the same idea through the **concurrent
//! stream** layer (`scenario::online`): random Poisson job storms with
//! admission caps, for HDS/BAR/BASS, checked against the concurrency
//! oracles — per-job exactly-once completion, no slot double-booking
//! across jobs, cross-job reservation sums within capacity, and the
//! stream makespan lower bounds.

use bass::runtime::CostModel;
use bass::scenario::{
    BackgroundSpec, DynamicsSpec, InitialLoad, MitigationSpec, ScenarioSpec, SimSession,
    StreamSpec, TenancySpec, TenantClass, TenantSpec, TopologyShape, WorkloadSpec,
};
use bass::sched::SchedulerKind;
use bass::testkit::{forall, oracles};
use bass::util::XorShift;

#[derive(Debug)]
struct Case {
    spec_seed: u64,
    switches: usize,
    hosts_per_switch: usize,
    tasks: usize,
    dynamics: DynamicsSpec,
}

fn gen_case(r: &mut XorShift) -> Case {
    let switches = 2 + r.below(2); // 2..=3
    let hosts_per_switch = 2 + r.below(2); // 2..=3
    let n_nodes = switches * hosts_per_switch;
    let dynamics = DynamicsSpec {
        node_failures: r.below(n_nodes.min(4)),
        mttr_secs: 10.0 + r.uniform(0.0, 30.0),
        link_degradations: r.below(3),
        degrade_floor: 0.2 + r.uniform(0.0, 0.5),
        degrade_secs: 10.0 + r.uniform(0.0, 25.0),
        stragglers: r.below(3),
        straggle_factor: 1.0 + r.uniform(0.0, 2.0),
        straggle_secs: 10.0 + r.uniform(0.0, 20.0),
        cross_flows: r.below(3),
        cross_rate_mb_s: 1.0 + r.uniform(0.0, 5.0),
        cross_secs: 10.0 + r.uniform(0.0, 30.0),
        horizon_secs: 40.0 + r.uniform(0.0, 60.0),
        seed: r.next_u64(),
    };
    Case {
        spec_seed: r.next_u64(),
        switches,
        hosts_per_switch,
        tasks: 4 + r.below(9),
        dynamics,
    }
}

fn spec_for(case: &Case, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        "invariant-case",
        TopologyShape::Tree {
            switches: case.switches,
            hosts_per_switch: case.hosts_per_switch,
            edge_mbps: 100.0,
            uplink_mbps: 400.0,
        },
        WorkloadSpec::MapWave { tasks: case.tasks, compute_secs: 12.0, output_mb: 4.0 },
    );
    s.scheduler = kind;
    s.replication = 2;
    s.seed = case.spec_seed;
    s.initial = InitialLoad::Sampled { max_secs: 10.0 };
    s.background = BackgroundSpec { flows: 2, rate_mb_s: 2.0 };
    s.dynamics = Some(case.dynamics.clone());
    s
}

/// `BASS_BENCH_QUICK=1` (the CI smoke knob) shrinks the case budget.
fn iters(default: usize) -> usize {
    match std::env::var("BASS_BENCH_QUICK") {
        Ok(_) => (default / 4).max(2),
        Err(_) => default,
    }
}

const ALL: [SchedulerKind; 3] = [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass];

#[test]
fn oracles_hold_for_all_schedulers_under_random_dynamics() {
    let cost = CostModel::rust_only();
    forall(0xD15EA5E, iters(16), gen_case, |case| {
        for kind in ALL {
            let sess = SimSession::new(&spec_for(case, kind));
            let tasks = sess.tasks.clone();
            let out = sess.run_dynamic(&cost);
            oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
        }
        Ok(())
    });
}

#[test]
fn oracles_hold_on_the_static_degenerate_case() {
    // all-zero churn must pass the same oracles (and run one round)
    let cost = CostModel::rust_only();
    forall(0xBA55, iters(6), gen_case, |case| {
        let mut quiet = case.dynamics.clone();
        quiet.node_failures = 0;
        quiet.link_degradations = 0;
        quiet.stragglers = 0;
        quiet.cross_flows = 0;
        for kind in ALL {
            let mut spec = spec_for(case, kind);
            spec.dynamics = Some(quiet.clone());
            let sess = SimSession::new(&spec);
            let tasks = sess.tasks.clone();
            let out = sess.run_dynamic(&cost);
            if out.rounds != 1 || out.reassignments != 0 {
                return Err(format!(
                    "{}: static case took {} rounds / {} reassignments",
                    kind.label(),
                    out.rounds,
                    out.reassignments
                ));
            }
            oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
        }
        Ok(())
    });
}

// ---- concurrent multi-job streams ----

#[derive(Debug)]
struct StreamCase {
    spec_seed: u64,
    switches: usize,
    hosts_per_switch: usize,
    jobs: usize,
    mean_gap: f64,
    max_active: usize,
    min_free_slots: usize,
    trace_seed: u64,
}

fn gen_stream_case(r: &mut XorShift) -> StreamCase {
    StreamCase {
        spec_seed: r.next_u64(),
        switches: 2 + r.below(2),        // 2..=3
        hosts_per_switch: 2 + r.below(2), // 2..=3
        jobs: 3 + r.below(5),            // 3..=7
        mean_gap: 5.0 + r.uniform(0.0, 40.0),
        max_active: 1 + r.below(4),      // exercises FIFO queueing
        min_free_slots: r.below(3),      // exercises the slot gate
        trace_seed: r.next_u64(),
    }
}

fn stream_case_spec(case: &StreamCase, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        "stream-invariant-case",
        TopologyShape::Tree {
            switches: case.switches,
            hosts_per_switch: case.hosts_per_switch,
            edge_mbps: 100.0,
            uplink_mbps: 400.0,
        },
        WorkloadSpec::None,
    );
    s.scheduler = kind;
    s.replication = 2;
    s.reduces = 2;
    s.seed = case.spec_seed;
    s.initial = InitialLoad::Sampled { max_secs: 10.0 };
    s.background = BackgroundSpec { flows: 2, rate_mb_s: 2.0 };
    s
}

fn stream_spec_for(case: &StreamCase) -> StreamSpec {
    StreamSpec {
        jobs: case.jobs,
        mean_interarrival_secs: case.mean_gap,
        sizes_mb: vec![150.0, 300.0],
        max_active: case.max_active,
        min_free_slots: case.min_free_slots,
        seed: case.trace_seed,
    }
}

#[test]
fn stream_oracles_hold_for_all_schedulers_under_random_arrival_storms() {
    let cost = CostModel::rust_only();
    forall(0x57E4A1, iters(12), gen_stream_case, |case| {
        let spec = stream_spec_for(case);
        for kind in ALL {
            let mut sess = SimSession::new(&stream_case_spec(case, kind));
            let out = sess.run_stream(spec.submissions(), spec.policy(), &cost);
            oracles::check_stream(&out, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
            if out.jobs.len() != case.jobs {
                return Err(format!(
                    "{}: {} of {} jobs completed",
                    kind.label(),
                    out.jobs.len(),
                    case.jobs
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_streams_never_slow_jobs_down() {
    // inter-arrival gaps deterministically beyond any makespan: jobs
    // cannot contend, so the oracles hold and no job runs slower than
    // its isolated self
    use bass::scenario::{AdmissionPolicy, Submission, SubmissionBody};
    use bass::workload::JobKind;
    let cost = CostModel::rust_only();
    forall(0x5A4553, iters(6), gen_stream_case, |case| {
        let subs: Vec<Submission> = (0..case.jobs)
            .map(|i| Submission {
                at_secs: 10.0 + i as f64 * 50_000.0,
                body: SubmissionBody::Generated {
                    kind: if i % 2 == 0 { JobKind::Sort } else { JobKind::Wordcount },
                    data_mb: if i % 3 == 0 { 300.0 } else { 150.0 },
                },
                tenant: None,
            })
            .collect();
        for kind in ALL {
            let mut sess = SimSession::new(&stream_case_spec(case, kind));
            let out = sess.run_stream(subs.clone(), AdmissionPolicy::default(), &cost);
            oracles::check_stream(&out, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
            if out.queued_jobs != 0 {
                return Err(format!("{}: sparse stream queued jobs", kind.label()));
            }
            for j in &out.jobs {
                if j.slowdown < 1.0 - 1e-9 {
                    return Err(format!(
                        "{}: job {} ran faster than its isolated self ({})",
                        kind.label(),
                        j.name,
                        j.slowdown
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn deterministic_burst_contends_and_satisfies_the_oracles() {
    // a fixed storm: all arrivals within seconds, an admission cap of 2
    let cost = CostModel::rust_only();
    let case = StreamCase {
        spec_seed: 2014,
        switches: 2,
        hosts_per_switch: 3,
        jobs: 6,
        mean_gap: 3.0,
        max_active: 2,
        min_free_slots: 1,
        trace_seed: 7,
    };
    let spec = stream_spec_for(&case);
    for kind in ALL {
        let mut sess = SimSession::new(&stream_case_spec(&case, kind));
        let out = sess.run_stream(spec.submissions(), spec.policy(), &cost);
        oracles::check_stream(&out, &sess.nodes, &sess.spec.node_speed)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        assert!(
            out.stats.mean_slowdown > 1.0,
            "{}: a storm must contend (mean slowdown {})",
            kind.label(),
            out.stats.mean_slowdown
        );
        assert!(out.queued_jobs > 0, "{}: the admission cap must bite", kind.label());
    }
}

// ---- multi-tenant streams ----

#[derive(Debug)]
struct TenancyCase {
    stream: StreamCase,
    tenants: TenancySpec,
}

fn gen_tenancy_case(r: &mut XorShift) -> TenancyCase {
    let n_tenants = 2 + r.below(2); // 2..=3
    let tenants = (0..n_tenants)
        .map(|i| {
            let mut t = TenantSpec::named(format!("t{i}"));
            t.weight = 1.0 + r.uniform(0.0, 3.0);
            if r.below(2) == 0 {
                t.slot_quota = 4 + r.below(40);
            }
            if r.below(2) == 0 {
                t.bw_quota = 50.0 + r.uniform(0.0, 400.0);
            }
            if r.below(2) == 0 {
                t.class = TenantClass::Guaranteed;
                if r.below(2) == 0 {
                    t.deadline_secs = Some(120.0 + r.uniform(0.0, 600.0));
                }
            }
            t
        })
        .collect();
    TenancyCase { stream: gen_stream_case(r), tenants: TenancySpec { tenants } }
}

#[test]
fn tenancy_oracles_hold_for_all_schedulers_under_multitenant_storms() {
    // random tenant mixes (weights, quotas, classes, deadlines) over
    // random arrival storms: the stream oracles AND the tenancy oracles
    // (quota caps, exactly-once preempted completion, no guaranteed
    // preemption, reproducible DRF order) must all hold; every job is
    // accounted for as completed or rejected
    let cost = CostModel::rust_only();
    forall(0x7E1A17, iters(10), gen_tenancy_case, |case| {
        let spec = stream_spec_for(&case.stream);
        for kind in ALL {
            let mut scen = stream_case_spec(&case.stream, kind);
            scen.tenants = Some(case.tenants.clone());
            let mut sess = SimSession::new(&scen);
            let out = sess.run_stream(spec.submissions(), spec.policy(), &cost);
            oracles::check_stream(&out, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
            oracles::check_tenancy(&out).map_err(|e| format!("{}: {e}", kind.label()))?;
            if out.jobs.len() != case.stream.jobs {
                return Err(format!(
                    "{}: {} of {} jobs accounted for",
                    kind.label(),
                    out.jobs.len(),
                    case.stream.jobs
                ));
            }
            for j in &out.jobs {
                if j.tenant.is_none() {
                    return Err(format!("{}: job {} has no tenant", kind.label(), j.name));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn single_default_tenant_is_bitwise_identical_to_the_fifo_stream() {
    // the differential pin: one default-weight tenant (and, by
    // construction, an absent `[tenants]` table) must reproduce the FIFO
    // stream path exactly — same records, same float bits — for every
    // scheduler under random arrival storms
    let cost = CostModel::rust_only();
    forall(0x7E4A47, iters(6), gen_stream_case, |case| {
        let spec = stream_spec_for(case);
        for kind in ALL {
            let mut fifo_sess = SimSession::new(&stream_case_spec(case, kind));
            let fifo = fifo_sess.run_stream(spec.submissions(), spec.policy(), &cost);
            let mut scen = stream_case_spec(case, kind);
            scen.tenants = Some(TenancySpec::single_default());
            let mut sess = SimSession::new(&scen);
            let tn = sess.run_stream(spec.submissions(), spec.policy(), &cost);
            if fifo.makespan.to_bits() != tn.makespan.to_bits()
                || fifo.last_finish.to_bits() != tn.last_finish.to_bits()
                || fifo.queued_jobs != tn.queued_jobs
                || fifo.records.len() != tn.records.len()
                || fifo.jobs.len() != tn.jobs.len()
                || !tn.preemptions.is_empty()
                || tn.rejected_jobs != 0
            {
                return Err(format!("{}: single-tenant run diverged from FIFO", kind.label()));
            }
            for ((ja, a), (jb, b)) in fifo.records.iter().zip(&tn.records) {
                if ja != jb || a.task != b.task || a.node != b.node || a.finish != b.finish {
                    return Err(format!(
                        "{}: single-tenant record for {:?} diverged",
                        kind.label(),
                        a.task
                    ));
                }
            }
            for (a, b) in fifo.jobs.iter().zip(&tn.jobs) {
                if a.admitted_at.to_bits() != b.admitted_at.to_bits()
                    || a.metrics.jt.to_bits() != b.metrics.jt.to_bits()
                {
                    return Err(format!(
                        "{}: single-tenant job {} timing diverged",
                        kind.label(),
                        a.name
                    ));
                }
                if b.tenant.as_deref() != Some("default") {
                    return Err(format!(
                        "{}: job {} not attributed to the default tenant",
                        kind.label(),
                        a.name
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn single_replica_crashes_defer_instead_of_pulling_from_down_nodes() {
    // replication 1: a crashed node's blocks have no surviving holder, so
    // their tasks must defer to the recovery instant — never pull from
    // the dead holder (oracle 9) — and still complete exactly once. The
    // seed picked the crashed holder as a transfer source here.
    let cost = CostModel::rust_only();
    let dynamics = DynamicsSpec {
        node_failures: 2,
        mttr_secs: 60.0,
        horizon_secs: 15.0, // crash early, while the wave is in flight
        ..DynamicsSpec::none()
    };
    for kind in ALL {
        let mut spec = spec_for(
            &Case {
                spec_seed: 77,
                switches: 2,
                hosts_per_switch: 3,
                tasks: 12,
                dynamics: dynamics.clone(),
            },
            kind,
        );
        spec.replication = 1;
        let sess = SimSession::new(&spec);
        let tasks = sess.tasks.clone();
        let out = sess.run_dynamic(&cost);
        assert_eq!(out.records.len(), out.submitted.len(), "{}", kind.label());
        oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        // a deferral means some block had no readable holder at a round
        // start — the namenode's under-replication view must have
        // surfaced it, and the run must have taken extra rounds
        if out.deferrals > 0 {
            assert!(out.under_replicated_peak > 0, "{}", kind.label());
            assert!(out.rounds > 1, "{}", kind.label());
        }
    }
}

// ---- straggler mitigation ----

#[test]
fn mitigation_oracles_hold_for_all_schedulers_under_random_dynamics() {
    // the full oracle suite — including the no-leaked-grant check over
    // the duel audit log — with speculation and eviction active
    let cost = CostModel::rust_only();
    forall(0x517A66, iters(8), gen_case, |case| {
        for kind in ALL {
            for mit in [MitigationSpec::late(), MitigationSpec::bw_aware()] {
                let mut spec = spec_for(case, kind);
                spec.mitigation = Some(mit.clone());
                let sess = SimSession::new(&spec);
                let tasks = sess.tasks.clone();
                let out = sess.run_mitigated(&cost);
                oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
                    .map_err(|e| {
                        format!("{} + {}: {e}", kind.label(), mit.speculation.label())
                    })?;
            }
        }
        Ok(())
    });
}

#[test]
fn off_mitigation_is_bitwise_identical_to_run_dynamic() {
    // `speculation = "off"` (the inert spec) must reproduce today's
    // run_dynamic exactly — same records, same float bits — for every
    // scheduler under random churn
    let cost = CostModel::rust_only();
    forall(0x0FF1CE, iters(6), gen_case, |case| {
        for kind in ALL {
            let plain = SimSession::new(&spec_for(case, kind)).run_dynamic(&cost);
            let mut spec = spec_for(case, kind);
            spec.mitigation = Some(MitigationSpec::off());
            let mit = SimSession::new(&spec).run_mitigated(&cost);
            if plain.makespan.to_bits() != mit.makespan.to_bits()
                || plain.rounds != mit.rounds
                || plain.reassignments != mit.reassignments
                || plain.records.len() != mit.records.len()
            {
                return Err(format!("{}: off-mode diverged from run_dynamic", kind.label()));
            }
            for (a, b) in plain.records.iter().zip(&mit.records) {
                if a.task != b.task || a.node != b.node || a.finish != b.finish {
                    return Err(format!(
                        "{}: off-mode record for {:?} diverged",
                        kind.label(),
                        a.task
                    ));
                }
            }
            if mit.speculated != 0 || mit.evictions != 0 {
                return Err(format!("{}: inert spec took mitigation actions", kind.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn crash_storm_with_speculation_still_completes_every_task() {
    // replication 1 + early crashes + stragglers, speculation on: a task
    // can lose BOTH its original and its duplicate to one crash wave.
    // The silent-tail hazard is that the duel machinery swallows the
    // loss — every task must instead re-enter the orphan carry and
    // complete exactly once (checked by oracle 2 in check_dynamics).
    let cost = CostModel::rust_only();
    let dynamics = DynamicsSpec {
        node_failures: 2,
        mttr_secs: 60.0,
        stragglers: 2,
        straggle_factor: 5.0,
        straggle_secs: 300.0,
        horizon_secs: 15.0, // crash while originals AND duplicates run
        ..DynamicsSpec::none()
    };
    for kind in ALL {
        for mit in [MitigationSpec::late(), MitigationSpec::bw_aware()] {
            let mut spec = spec_for(
                &Case {
                    spec_seed: 77,
                    switches: 2,
                    hosts_per_switch: 3,
                    tasks: 12,
                    dynamics: dynamics.clone(),
                },
                kind,
            );
            spec.replication = 1;
            spec.mitigation = Some(mit.clone());
            let sess = SimSession::new(&spec);
            let tasks = sess.tasks.clone();
            let out = sess.run_mitigated(&cost);
            assert_eq!(
                out.records.len(),
                out.submitted.len(),
                "{} + {}: task lost in the crash storm",
                kind.label(),
                mit.speculation.label()
            );
            oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
                .unwrap_or_else(|e| {
                    panic!("{} + {}: {e}", kind.label(), mit.speculation.label())
                });
        }
    }
}

#[test]
fn bw_aware_speculation_strictly_beats_off_on_a_straggler_heavy_cluster() {
    // the headline claim: on a cluster where stragglers dominate,
    // reservation-gated duplicates buy BASS a strictly better makespan
    // than no mitigation at all — and the run still passes every oracle
    let cost = CostModel::rust_only();
    let dynamics = DynamicsSpec {
        stragglers: 5,
        straggle_factor: 6.0,
        straggle_secs: 500.0,
        horizon_secs: 2.0, // stragglers hit while the first wave runs
        ..DynamicsSpec::none()
    };
    let case = Case {
        spec_seed: 2014,
        switches: 2,
        hosts_per_switch: 3,
        tasks: 10,
        dynamics,
    };
    let off = SimSession::new(&spec_for(&case, SchedulerKind::Bass)).run_dynamic(&cost);
    let mut spec = spec_for(&case, SchedulerKind::Bass);
    spec.mitigation = Some(MitigationSpec::bw_aware());
    let sess = SimSession::new(&spec);
    let tasks = sess.tasks.clone();
    let on = sess.run_mitigated(&cost);
    oracles::check_dynamics(&on, &tasks, &sess.nodes, &sess.spec.node_speed)
        .unwrap_or_else(|e| panic!("bw_aware: {e}"));
    assert!(on.speculated > 0, "stragglers this heavy must trigger duplicates");
    assert!(
        on.makespan < off.makespan,
        "bw_aware makespan {} must strictly beat off {}",
        on.makespan,
        off.makespan
    );
}

#[test]
fn heavy_forced_churn_still_satisfies_the_oracles() {
    // deterministic worst case: early crashes with long repairs, on top
    // of degradation + stragglers + cross traffic, for every scheduler
    let cost = CostModel::rust_only();
    let dynamics = DynamicsSpec {
        node_failures: 3,
        mttr_secs: 120.0,
        link_degradations: 2,
        degrade_floor: 0.2,
        degrade_secs: 60.0,
        stragglers: 2,
        straggle_factor: 3.0,
        straggle_secs: 50.0,
        cross_flows: 3,
        cross_rate_mb_s: 6.0,
        cross_secs: 80.0,
        horizon_secs: 30.0, // everything hits while work is in flight
        seed: 7,
    };
    for kind in ALL {
        let case = Case {
            spec_seed: 2014,
            switches: 2,
            hosts_per_switch: 3,
            tasks: 12,
            dynamics: dynamics.clone(),
        };
        let sess = SimSession::new(&spec_for(&case, kind));
        let tasks = sess.tasks.clone();
        let out = sess.run_dynamic(&cost);
        assert_eq!(out.records.len(), out.submitted.len(), "{}", kind.label());
        oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
    }
}
