//! Invariant-oracle suite: random `DynamicsSpec`s through HDS, BAR and
//! BASS, with `testkit::oracles` asserting the global safety properties
//! after every run — no task on a down node, exactly-once completion,
//! reservations within (time-varying) capacity, makespan lower bounds.
//!
//! `BASS_BENCH_QUICK=1` shrinks the case count for CI smoke runs; every
//! failure replays exactly from the printed (seed, case) pair.

use bass::runtime::CostModel;
use bass::scenario::{
    BackgroundSpec, DynamicsSpec, InitialLoad, ScenarioSpec, SimSession, TopologyShape,
    WorkloadSpec,
};
use bass::sched::SchedulerKind;
use bass::testkit::{forall, oracles};
use bass::util::XorShift;

#[derive(Debug)]
struct Case {
    spec_seed: u64,
    switches: usize,
    hosts_per_switch: usize,
    tasks: usize,
    dynamics: DynamicsSpec,
}

fn gen_case(r: &mut XorShift) -> Case {
    let switches = 2 + r.below(2); // 2..=3
    let hosts_per_switch = 2 + r.below(2); // 2..=3
    let n_nodes = switches * hosts_per_switch;
    let dynamics = DynamicsSpec {
        node_failures: r.below(n_nodes.min(4)),
        mttr_secs: 10.0 + r.uniform(0.0, 30.0),
        link_degradations: r.below(3),
        degrade_floor: 0.2 + r.uniform(0.0, 0.5),
        degrade_secs: 10.0 + r.uniform(0.0, 25.0),
        stragglers: r.below(3),
        straggle_factor: 1.0 + r.uniform(0.0, 2.0),
        straggle_secs: 10.0 + r.uniform(0.0, 20.0),
        cross_flows: r.below(3),
        cross_rate_mb_s: 1.0 + r.uniform(0.0, 5.0),
        cross_secs: 10.0 + r.uniform(0.0, 30.0),
        horizon_secs: 40.0 + r.uniform(0.0, 60.0),
        seed: r.next_u64(),
    };
    Case {
        spec_seed: r.next_u64(),
        switches,
        hosts_per_switch,
        tasks: 4 + r.below(9),
        dynamics,
    }
}

fn spec_for(case: &Case, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        "invariant-case",
        TopologyShape::Tree {
            switches: case.switches,
            hosts_per_switch: case.hosts_per_switch,
            edge_mbps: 100.0,
            uplink_mbps: 400.0,
        },
        WorkloadSpec::MapWave { tasks: case.tasks, compute_secs: 12.0, output_mb: 4.0 },
    );
    s.scheduler = kind;
    s.replication = 2;
    s.seed = case.spec_seed;
    s.initial = InitialLoad::Sampled { max_secs: 10.0 };
    s.background = BackgroundSpec { flows: 2, rate_mb_s: 2.0 };
    s.dynamics = Some(case.dynamics.clone());
    s
}

/// `BASS_BENCH_QUICK=1` (the CI smoke knob) shrinks the case budget.
fn iters(default: usize) -> usize {
    match std::env::var("BASS_BENCH_QUICK") {
        Ok(_) => (default / 4).max(2),
        Err(_) => default,
    }
}

const ALL: [SchedulerKind; 3] = [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass];

#[test]
fn oracles_hold_for_all_schedulers_under_random_dynamics() {
    let cost = CostModel::rust_only();
    forall(0xD15EA5E, iters(16), gen_case, |case| {
        for kind in ALL {
            let sess = SimSession::new(&spec_for(case, kind));
            let tasks = sess.tasks.clone();
            let out = sess.run_dynamic(&cost);
            oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
        }
        Ok(())
    });
}

#[test]
fn oracles_hold_on_the_static_degenerate_case() {
    // all-zero churn must pass the same oracles (and run one round)
    let cost = CostModel::rust_only();
    forall(0xBA55, iters(6), gen_case, |case| {
        let mut quiet = case.dynamics.clone();
        quiet.node_failures = 0;
        quiet.link_degradations = 0;
        quiet.stragglers = 0;
        quiet.cross_flows = 0;
        for kind in ALL {
            let mut spec = spec_for(case, kind);
            spec.dynamics = Some(quiet.clone());
            let sess = SimSession::new(&spec);
            let tasks = sess.tasks.clone();
            let out = sess.run_dynamic(&cost);
            if out.rounds != 1 || out.reassignments != 0 {
                return Err(format!(
                    "{}: static case took {} rounds / {} reassignments",
                    kind.label(),
                    out.rounds,
                    out.reassignments
                ));
            }
            oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
                .map_err(|e| format!("{}: {e}", kind.label()))?;
        }
        Ok(())
    });
}

#[test]
fn heavy_forced_churn_still_satisfies_the_oracles() {
    // deterministic worst case: early crashes with long repairs, on top
    // of degradation + stragglers + cross traffic, for every scheduler
    let cost = CostModel::rust_only();
    let dynamics = DynamicsSpec {
        node_failures: 3,
        mttr_secs: 120.0,
        link_degradations: 2,
        degrade_floor: 0.2,
        degrade_secs: 60.0,
        stragglers: 2,
        straggle_factor: 3.0,
        straggle_secs: 50.0,
        cross_flows: 3,
        cross_rate_mb_s: 6.0,
        cross_secs: 80.0,
        horizon_secs: 30.0, // everything hits while work is in flight
        seed: 7,
    };
    for kind in ALL {
        let case = Case {
            spec_seed: 2014,
            switches: 2,
            hosts_per_switch: 3,
            tasks: 12,
            dynamics: dynamics.clone(),
        };
        let sess = SimSession::new(&spec_for(&case, kind));
        let tasks = sess.tasks.clone();
        let out = sess.run_dynamic(&cost);
        assert_eq!(out.records.len(), out.submitted.len(), "{}", kind.label());
        oracles::check_dynamics(&out, &tasks, &sess.nodes, &sess.spec.node_speed)
            .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
    }
}
