//! Property-based tests over coordinator/substrate invariants, driven by
//! the deterministic `bass::testkit` runner (proptest substitute — see
//! DESIGN.md toolchain notes).

use bass::cluster::{Ledger, ShardPlan};
use bass::hdfs::{Namenode, PlacementPolicy};
use bass::mapreduce::TaskSpec;
use bass::runtime::{CostInputs, CostModel};
use bass::sched::{cost, Bar, Bass, Hds, SchedCtx, Scheduler};
use bass::sdn::{Controller, Reservation, SlotCalendar};
use bass::sim::{Assignment, Engine, FlowNet, TransferPlan};
use bass::testkit::forall;
use bass::topology::builders::{fat_tree, tree_cluster};
use bass::topology::{LinkId, NodeId, PathCache};
use bass::util::{Secs, XorShift, BLOCK_MB};

/// A random scheduling scenario over a random tree cluster.
#[derive(Debug)]
struct Scenario {
    n_switches: usize,
    per_switch: usize,
    m_tasks: usize,
    replication: usize,
    seed: u64,
}

fn gen_scenario(r: &mut XorShift) -> Scenario {
    let n_switches = 1 + r.below(3);
    let per_switch = 2 + r.below(3);
    Scenario {
        n_switches,
        per_switch,
        m_tasks: 1 + r.below(24),
        replication: 1 + r.below((n_switches * per_switch).min(3)),
        seed: r.next_u64(),
    }
}

fn build(s: &Scenario) -> (Controller, Namenode, Vec<NodeId>, Vec<TaskSpec>, Vec<f64>) {
    let (topo, nodes) = tree_cluster(s.n_switches, s.per_switch, 100.0, 100.0);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
    let ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(s.seed);
    let blocks = PlacementPolicy::RandomDistinct.place(
        &mut nn,
        &nodes,
        &[],
        s.m_tasks,
        BLOCK_MB,
        s.replication,
        &mut rng,
    );
    let tasks = blocks
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(5.0 + (i % 7) as f64), 8.0))
        .collect();
    (ctrl, nn, nodes, tasks, caps)
}

/// Every scheduler must place every task exactly once, on an authorized
/// node, and local placements must actually be replica holders.
#[test]
fn prop_schedulers_place_each_task_once_and_validly() {
    forall(0xA11, 60, gen_scenario, |s| {
        let schedulers: Vec<Box<dyn Scheduler>> =
            vec![Box::new(Hds::new()), Box::new(Bar::new()), Box::new(Bass::new())];
        for mut sched in schedulers {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            let a = sched.schedule(&tasks, None, &mut ctx);
            if a.placements.len() != tasks.len() {
                return Err(format!(
                    "{}: {} placements for {} tasks",
                    sched.name(),
                    a.placements.len(),
                    tasks.len()
                ));
            }
            let mut seen = vec![false; tasks.len()];
            for p in &a.placements {
                if seen[p.task.0] {
                    return Err(format!("{}: task {} placed twice", sched.name(), p.task.0));
                }
                seen[p.task.0] = true;
                if !nodes.contains(&p.node) {
                    return Err(format!("{}: unauthorized node {:?}", sched.name(), p.node));
                }
                if p.is_local {
                    let b = tasks[p.task.0].input.unwrap();
                    if !nn.is_local(b, p.node) {
                        return Err(format!(
                            "{}: fake locality for task {}",
                            sched.name(),
                            p.task.0
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// BASS's ledger estimate must equal DES execution exactly (reservations
/// make its world deterministic), and execution must finish all tasks.
#[test]
fn prop_bass_estimate_matches_execution() {
    forall(0xB0B, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, caps) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let a = {
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            Bass::new().schedule(&tasks, None, &mut ctx)
        };
        let est = nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max);
        let mut engine = Engine::new(FlowNet::new(&caps), vec![Secs::ZERO; nodes.len()]);
        engine.load(&a);
        let records = engine.run();
        if records.len() != tasks.len() {
            return Err(format!("{} records for {} tasks", records.len(), tasks.len()));
        }
        let exe = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        if (est - exe).abs() > 1e-6 {
            return Err(format!("estimate {est} != execution {exe}"));
        }
        Ok(())
    });
}

/// The slot calendar never oversubscribes: after any random sequence of
/// successful reservations, every (link, slot) stays within capacity;
/// releases restore exactly.
#[test]
fn prop_calendar_never_oversubscribes() {
    #[derive(Debug)]
    struct Ops {
        n_links: usize,
        ops: Vec<(usize, usize, usize, f64)>, // link, start, len, frac
    }
    forall(
        0xCA1,
        120,
        |r| {
            let n_links = 1 + r.below(6);
            let ops = (0..24)
                .map(|_| (r.below(n_links), r.below(40), 1 + r.below(10), r.uniform(0.05, 1.0)))
                .collect();
            Ops { n_links, ops }
        },
        |case| {
            let mut cal = SlotCalendar::new(case.n_links, 1.0);
            let mut grants = Vec::new();
            for &(l, start, len, frac) in &case.ops {
                if let Ok(res) = cal.reserve_path(&[LinkId(l)], start, len, frac) {
                    grants.push(res);
                }
                for link in 0..case.n_links {
                    for slot in 0..60 {
                        let r = cal.reserved_frac(LinkId(link), slot);
                        if r > 1.0 + 1e-9 {
                            return Err(format!("link {link} slot {slot} oversubscribed: {r}"));
                        }
                    }
                }
            }
            for g in &grants {
                cal.release(g);
            }
            for link in 0..case.n_links {
                for slot in 0..60 {
                    let r = cal.reserved_frac(LinkId(link), slot);
                    if r > 1e-9 {
                        return Err(format!("leak on link {link} slot {slot}: {r}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Max-min rates: per-link sums never exceed capacity, every flow gets a
/// positive rate, and rates are deterministic.
#[test]
fn prop_flownet_rates_feasible() {
    #[derive(Debug)]
    struct Net {
        n_links: usize,
        flows: Vec<Vec<usize>>,
    }
    forall(
        0xF10,
        100,
        |r| {
            let n_links = 1 + r.below(8);
            let flows = (0..1 + r.below(20))
                .map(|_| {
                    let len = 1 + r.below(3.min(n_links));
                    r.distinct(n_links, len)
                })
                .collect();
            Net { n_links, flows }
        },
        |case| {
            let caps: Vec<f64> = (0..case.n_links).map(|_| 80.0).collect();
            let mut net = FlowNet::new(&caps);
            let ids: Vec<_> = case
                .flows
                .iter()
                .map(|p| {
                    net.add_flow(
                        p.iter().map(|&l| LinkId(l)).collect(),
                        100.0,
                        bass::sdn::TrafficClass::HadoopOther,
                    )
                })
                .collect();
            let mut per_link = vec![0.0f64; case.n_links];
            for (i, id) in ids.iter().enumerate() {
                let rate = net.rate_of(*id).ok_or("missing flow")?;
                if rate <= 0.0 {
                    return Err(format!("flow {i} starved: {rate}"));
                }
                for &l in &case.flows[i] {
                    per_link[l] += rate;
                }
            }
            for (l, &sum) in per_link.iter().enumerate() {
                if sum > 10.0 + 1e-6 {
                    return Err(format!("link {l} oversubscribed: {sum} MB/s of 10"));
                }
            }
            Ok(())
        },
    );
}

/// XLA artifact output == Rust mirror, bit for bit, on random batches.
#[test]
fn prop_xla_matches_rust_mirror() {
    let model = CostModel::auto();
    if model.backend_for(16, 8) != bass::runtime::exec::Backend::Xla {
        eprintln!("skipping: artifacts not built");
        return;
    }
    forall(
        0x71A,
        30,
        |r| {
            let m = 1 + r.below(16);
            let n = 1 + r.below(8);
            fn mk(r: &mut XorShift, k: usize, lo: f64, hi: f64) -> Vec<f32> {
                (0..k).map(|_| r.uniform(lo, hi) as f32).collect()
            }
            let sz = mk(r, m, 0.0, 5000.0);
            let bw = mk(r, m * n, -5.0, 120.0);
            let tp = mk(r, m * n, 0.0, 900.0);
            let local = (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect();
            let idle = mk(r, n, 0.0, 200.0);
            CostInputs { m, n, sz, bw, tp, local, idle, ts: 1.0 }
        },
        |inp| {
            let x = model.eval(inp).map_err(|e| e.to_string())?;
            let y = CostModel::eval_rust(inp);
            if x.yc != y.yc || x.tm != y.tm || x.slots != y.slots
                || x.best_idx != y.best_idx || x.best_cost != y.best_cost
            {
                return Err("backend divergence".into());
            }
            Ok(())
        },
    );
}

/// Engine conservation: records == placements, finishes are monotone per
/// node, and no record finishes before its compute start.
#[test]
fn prop_engine_records_consistent() {
    forall(0xE46, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, caps) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let a = {
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            Hds::new().schedule(&tasks, None, &mut ctx)
        };
        let remote = a
            .placements
            .iter()
            .filter(|p| matches!(p.transfer, TransferPlan::FairShare { .. }))
            .count();
        let mut engine = Engine::new(FlowNet::new(&caps), vec![Secs::ZERO; nodes.len()]);
        engine.load(&a);
        let records = engine.run();
        if records.len() != tasks.len() {
            return Err(format!(
                "{} records for {} tasks (remote={remote})",
                records.len(),
                tasks.len()
            ));
        }
        let mut per_node: Vec<Vec<f64>> = vec![Vec::new(); nodes.len()];
        for r in &records {
            if r.finish < r.compute_start || r.compute_start < r.picked_at {
                return Err(format!("time travel in record {:?}", r));
            }
            per_node[r.node.0].push(r.finish.0);
        }
        Ok(())
    });
}

/// Pre-BASS invariant: prefetch never makes any transfer arrive later
/// than BASS's on-demand plan for the same (task, node) placement.
#[test]
fn prop_prefetch_never_later() {
    use bass::sched::PreBass;
    forall(0x9F3, 40, gen_scenario, |s| {
        let run = |pre: bool| -> Vec<(usize, f64)> {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            let a = if pre {
                PreBass::new().schedule(&tasks, None, &mut ctx)
            } else {
                Bass::new().schedule(&tasks, None, &mut ctx)
            };
            a.placements
                .iter()
                .filter_map(|p| match &p.transfer {
                    TransferPlan::Reserved(t) => Some((p.task.0, t.arrival.0)),
                    TransferPlan::Prefetched(t) => Some((p.task.0, t.arrival.0)),
                    _ => None,
                })
                .collect()
        };
        let bass = run(false);
        let pre = run(true);
        for (task, arr_pre) in &pre {
            if let Some((_, arr_bass)) = bass.iter().find(|(t, _)| t == task) {
                if *arr_pre > arr_bass + 1e-9 {
                    return Err(format!(
                        "task {task}: prefetch arrival {arr_pre} later than on-demand {arr_bass}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Controller reserve/complete cycles never leak calendar capacity.
#[test]
fn prop_controller_transfer_lifecycle_leak_free() {
    use bass::sdn::TrafficClass;
    forall(0x1EA, 60, gen_scenario, |s| {
        let (mut ctrl, _nn, nodes, _tasks, _) = build(s);
        let mut rng = XorShift::new(s.seed ^ 0xDEAD);
        let mut live = Vec::new();
        for i in 0..20 {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            if a == b {
                continue;
            }
            if let Some(plan) = ctrl.plan_transfer(a, b, 32.0, Secs(i as f64)) {
                let t = ctrl
                    .commit_transfer(a, b, TrafficClass::HadoopOther, plan, Secs(i as f64))
                    .map_err(|e| e.to_string())?;
                live.push(t);
            }
            // randomly complete some
            if !live.is_empty() && rng.chance(0.5) {
                let t = live.swap_remove(rng.below(live.len()));
                ctrl.complete_transfer(&t, 32.0);
            }
        }
        for t in live.drain(..) {
            ctrl.complete_transfer(&t, 32.0);
        }
        // all slots must be fully free again
        for l in 0..ctrl.topo().n_links() {
            for slot in 0..200 {
                let r = ctrl.calendar.reserved_frac(bass::topology::LinkId(l), slot);
                if r > 1e-9 {
                    return Err(format!("leak: link {l} slot {slot} frac {r}"));
                }
            }
        }
        if !ctrl.flows.is_empty() {
            return Err(format!("{} flow entries leaked", ctrl.flows.len()));
        }
        Ok(())
    });
}

/// Reference implementation for the calendar-equivalence property: the
/// seed's dense per-slot `Vec<f64>` calendar, ported verbatim (including
/// its `MAX_SEARCH_SLOTS` cap, which the scenarios below never hit).
/// The sparse interval calendar must be observationally equivalent.
mod dense_reference {
    use bass::sdn::Reservation;
    use bass::topology::LinkId;
    use bass::util::Secs;

    const MAX_SEARCH_SLOTS: usize = 4_000_000;

    pub struct DenseCalendar {
        slot_secs: f64,
        reserved: Vec<Vec<f64>>,
    }

    impl DenseCalendar {
        pub fn new(n_links: usize, slot_secs: f64) -> Self {
            Self { slot_secs, reserved: vec![Vec::new(); n_links] }
        }

        pub fn slot_of(&self, t: Secs) -> usize {
            (t.0 / self.slot_secs).floor() as usize
        }

        pub fn slots_for(&self, size_mb: f64, rate_mb_s: f64) -> usize {
            ((size_mb / rate_mb_s) / self.slot_secs).ceil().max(0.0) as usize
        }

        pub fn reserved_frac(&self, link: LinkId, slot: usize) -> f64 {
            self.reserved[link.0].get(slot).copied().unwrap_or(0.0)
        }

        pub fn residual_frac(&self, link: LinkId, slot: usize) -> f64 {
            (1.0 - self.reserved_frac(link, slot)).max(0.0)
        }

        pub fn path_residual(&self, links: &[LinkId], start: usize, n: usize) -> f64 {
            let mut min = 1.0f64;
            for &l in links {
                for s in start..start + n {
                    min = min.min(self.residual_frac(l, s));
                    if min <= 0.0 {
                        return 0.0;
                    }
                }
            }
            min
        }

        fn ensure_len(&mut self, link: LinkId, upto: usize) {
            let v = &mut self.reserved[link.0];
            if v.len() < upto {
                v.resize(upto, 0.0);
            }
        }

        pub fn reserve_path(
            &mut self,
            links: &[LinkId],
            start: usize,
            n: usize,
            frac: f64,
        ) -> Result<Reservation, String> {
            if !(frac > 0.0 && frac <= 1.0) || n == 0 {
                return Err("invalid".into());
            }
            const EPS: f64 = 1e-9;
            if self.path_residual(links, start, n) + EPS < frac {
                return Err("insufficient".into());
            }
            for &l in links {
                self.ensure_len(l, start + n);
                for s in start..start + n {
                    self.reserved[l.0][s] = (self.reserved[l.0][s] + frac).min(1.0);
                }
            }
            Ok(Reservation { links: links.to_vec(), start_slot: start, n_slots: n, frac })
        }

        pub fn release(&mut self, r: &Reservation) {
            for &l in &r.links {
                for s in r.start_slot..r.start_slot + r.n_slots {
                    if let Some(x) = self.reserved[l.0].get_mut(s) {
                        *x = (*x - r.frac).max(0.0);
                    }
                }
            }
        }

        pub fn find_window(
            &self,
            links: &[LinkId],
            earliest: usize,
            n: usize,
            frac: f64,
        ) -> Option<usize> {
            const EPS: f64 = 1e-9;
            let mut s = earliest;
            while s < earliest + MAX_SEARCH_SLOTS {
                let mut ok = true;
                'outer: for off in 0..n {
                    for &l in links {
                        if self.residual_frac(l, s + off) + EPS < frac {
                            s = s + off + 1;
                            ok = false;
                            break 'outer;
                        }
                    }
                }
                if ok {
                    return Some(s);
                }
            }
            None
        }

        pub fn plan_transfer(
            &self,
            links: &[LinkId],
            earliest: Secs,
            size_mb: f64,
            capacity_mb_s: f64,
            min_frac: f64,
        ) -> Option<Reservation> {
            if size_mb == 0.0 || links.is_empty() {
                return Some(Reservation {
                    links: links.to_vec(),
                    start_slot: self.slot_of(earliest),
                    n_slots: 0,
                    frac: 0.0,
                });
            }
            let mut start = self.slot_of(earliest);
            for _ in 0..MAX_SEARCH_SLOTS {
                let f0 = links
                    .iter()
                    .map(|&l| self.residual_frac(l, start))
                    .fold(1.0f64, f64::min);
                if f0 < min_frac || f0 <= 0.0 {
                    start += 1;
                    continue;
                }
                let mut frac = f0;
                let mut n = self.slots_for(size_mb, frac * capacity_mb_s);
                loop {
                    let avail = self.path_residual(links, start, n.max(1));
                    if avail + 1e-9 >= frac {
                        return Some(Reservation {
                            links: links.to_vec(),
                            start_slot: start,
                            n_slots: n.max(1),
                            frac,
                        });
                    }
                    if avail < min_frac || avail <= 0.0 {
                        break;
                    }
                    frac = avail;
                    n = self.slots_for(size_mb, frac * capacity_mb_s);
                }
                start += 1;
            }
            None
        }
    }
}

/// One randomized calendar interaction.
#[derive(Debug, Clone)]
enum CalOp {
    Reserve { links: Vec<usize>, start: usize, n: usize, frac: f64 },
    Release { pick: usize },
    FindWindow { links: Vec<usize>, earliest: usize, n: usize, frac: f64 },
    Plan { links: Vec<usize>, earliest: usize, size_mb: f64, min_frac: f64 },
}

#[derive(Debug)]
struct CalCase {
    n_links: usize,
    ops: Vec<CalOp>,
}

fn gen_cal_case(r: &mut XorShift) -> CalCase {
    let n_links = 1 + r.below(5);
    let pick_links = |r: &mut XorShift, n_links: usize| -> Vec<usize> {
        let k = 1 + r.below(3.min(n_links));
        r.distinct(n_links, k)
    };
    let ops = (0..32)
        .map(|_| match r.below(6) {
            0 | 1 | 2 => CalOp::Reserve {
                links: pick_links(r, n_links),
                start: r.below(50),
                n: 1 + r.below(12),
                // mix exact full-rate grabs with fractional ones
                frac: if r.chance(0.25) { 1.0 } else { r.uniform(0.05, 1.0) },
            },
            3 => CalOp::Release { pick: r.below(64) },
            4 => CalOp::FindWindow {
                links: pick_links(r, n_links),
                earliest: r.below(40),
                n: 1 + r.below(10),
                frac: if r.chance(0.25) { 1.0 } else { r.uniform(0.05, 1.0) },
            },
            _ => CalOp::Plan {
                links: pick_links(r, n_links),
                earliest: r.below(40),
                size_mb: r.uniform(1.0, 400.0),
                min_frac: r.uniform(0.01, 0.3),
            },
        })
        .collect();
    CalCase { n_links, ops }
}

/// The sparse interval calendar is observationally equivalent to the
/// seed's dense per-slot implementation: identical `reserve_path` /
/// `release` / `find_window` / `plan_transfer` outcomes and per-slot
/// occupancy matching within dust (the sparse calendar snaps sub-1e-12
/// f64 residue so released segments coalesce away; the decision
/// tolerance is 1e-9, so behavior is unaffected) — and it never
/// oversubscribes a link.
#[test]
fn prop_sparse_calendar_matches_dense_reference() {
    use dense_reference::DenseCalendar;
    const TOL: f64 = 1e-9;
    let res_close = |x: &Reservation, y: &Reservation| -> bool {
        x.links == y.links
            && x.start_slot == y.start_slot
            && x.n_slots == y.n_slots
            && (x.frac - y.frac).abs() <= TOL
    };
    forall(0x5AC, 120, gen_cal_case, |case| {
        let mut sparse = SlotCalendar::new(case.n_links, 1.0);
        let mut dense = DenseCalendar::new(case.n_links, 1.0);
        let mut grants: Vec<Reservation> = Vec::new();
        for (step, op) in case.ops.iter().enumerate() {
            let ids = |v: &[usize]| -> Vec<LinkId> { v.iter().map(|&l| LinkId(l)).collect() };
            match op {
                CalOp::Reserve { links, start, n, frac } => {
                    let links = ids(links);
                    let a = sparse.reserve_path(&links, *start, *n, *frac);
                    let b = dense.reserve_path(&links, *start, *n, *frac);
                    match (a, b) {
                        (Ok(x), Ok(y)) => {
                            if !res_close(&x, &y) {
                                return Err(format!("step {step}: grants differ {x:?} vs {y:?}"));
                            }
                            grants.push(x);
                        }
                        (Err(_), Err(_)) => {}
                        (a, b) => {
                            return Err(format!(
                                "step {step}: outcome mismatch sparse={:?} dense={:?}",
                                a.is_ok(),
                                b.is_ok()
                            ));
                        }
                    }
                }
                CalOp::Release { pick } => {
                    if !grants.is_empty() {
                        let r = grants.swap_remove(pick % grants.len());
                        sparse.release(&r);
                        dense.release(&r);
                    }
                }
                CalOp::FindWindow { links, earliest, n, frac } => {
                    let links = ids(links);
                    let a = sparse.find_window(&links, *earliest, *n, *frac);
                    let b = dense.find_window(&links, *earliest, *n, *frac);
                    if a != b {
                        return Err(format!("step {step}: find_window {a:?} vs {b:?}"));
                    }
                }
                CalOp::Plan { links, earliest, size_mb, min_frac } => {
                    let links = ids(links);
                    let a = sparse.plan_transfer(
                        &links,
                        Secs(*earliest as f64),
                        *size_mb,
                        12.5,
                        *min_frac,
                    );
                    let b = dense.plan_transfer(
                        &links,
                        Secs(*earliest as f64),
                        *size_mb,
                        12.5,
                        *min_frac,
                    );
                    let same = match (&a, &b) {
                        (Some(x), Some(y)) => res_close(x, y),
                        (None, None) => true,
                        _ => false,
                    };
                    if !same {
                        return Err(format!("step {step}: plan {a:?} vs {b:?}"));
                    }
                }
            }
            // occupancy must agree within dust and never oversubscribe
            for l in 0..case.n_links {
                for slot in [0usize, 1, 3, 7, 17, 29, 43, 59, 71, 97, 131] {
                    let s = sparse.reserved_frac(LinkId(l), slot);
                    let d = dense.reserved_frac(LinkId(l), slot);
                    if (s - d).abs() > TOL {
                        return Err(format!(
                            "step {step}: link {l} slot {slot}: sparse {s} != dense {d}"
                        ));
                    }
                    if s > 1.0 + 1e-9 {
                        return Err(format!("step {step}: link {l} slot {slot} oversubscribed {s}"));
                    }
                }
                // window minima agree too (path_residual drives planning)
                let pr_s = sparse.path_residual(&[LinkId(l)], 0, 80);
                let pr_d = dense.path_residual(&[LinkId(l)], 0, 80);
                if (pr_s - pr_d).abs() > TOL {
                    return Err(format!("step {step}: path_residual {pr_s} != {pr_d}"));
                }
            }
        }
        // drain everything: both must come back (dust-)free; the sparse
        // calendar additionally guarantees zero retained segments
        for r in grants.drain(..) {
            sparse.release(&r);
            dense.release(&r);
        }
        for l in 0..case.n_links {
            for slot in 0..80 {
                let s = sparse.reserved_frac(LinkId(l), slot);
                if (s - dense.reserved_frac(LinkId(l), slot)).abs() > TOL {
                    return Err(format!("post-drain mismatch link {l} slot {slot}"));
                }
                if s > 1e-9 {
                    return Err(format!("leak on link {l} slot {slot}: {s}"));
                }
            }
        }
        if sparse.n_segments() != 0 {
            return Err(format!(
                "post-drain segment leak: {} boundaries retained",
                sparse.n_segments()
            ));
        }
        Ok(())
    });
}

/// Heterogeneity invariant: scaling every node's speed by the same
/// factor scales every scheduler's makespan estimate consistently
/// (no hidden homogeneity assumptions).
#[test]
fn prop_uniform_speed_scaling() {
    forall(0x5CA, 30, gen_scenario, |s| {
        let jt_with = |speed: f64| -> f64 {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: vec![speed; nodes.len()],
                down: Vec::new(),
                bw_aware_sources: true,
            };
            Bass::new().schedule(&tasks, None, &mut ctx);
            nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max)
        };
        let base = jt_with(1.0);
        let double = jt_with(2.0);
        // all-compute lower bound: doubling TP at least doesn't shrink JT
        if double + 1e-9 < base {
            return Err(format!("doubling compute time shrank JT: {base} -> {double}"));
        }
        Ok(())
    });
}

/// Reference implementation for the Perf-L4 equivalence properties: the
/// seed's `FlowNet` (HashMap storage, eager from-scratch max-min fill on
/// every membership change), ported verbatim. The incremental slab/
/// component/heap implementation must be observationally equivalent.
mod flownet_reference {
    use std::collections::HashMap;

    use bass::sdn::{QosPolicy, TrafficClass};
    use bass::topology::LinkId;
    use bass::util::{mbps_to_mb_per_s, Secs};

    #[derive(Debug, Clone)]
    struct Flow {
        path: Vec<LinkId>,
        remaining_mb: f64,
        class: TrafficClass,
        rate_mb_s: f64,
        max_rate_mb_s: f64,
    }

    #[derive(Debug, Clone)]
    pub struct RefNet {
        link_cap_mb_s: Vec<f64>,
        qos: Option<QosPolicy>,
        flows: HashMap<u64, Flow>,
        next_id: u64,
        clock: Secs,
    }

    impl RefNet {
        pub fn new(link_caps_mbps: &[f64]) -> Self {
            Self {
                link_cap_mb_s: link_caps_mbps.iter().map(|&c| mbps_to_mb_per_s(c)).collect(),
                qos: None,
                flows: HashMap::new(),
                next_id: 0,
                clock: Secs::ZERO,
            }
        }

        pub fn set_qos(&mut self, policy: QosPolicy) {
            self.qos = Some(policy);
            self.recompute();
        }

        pub fn clock(&self) -> Secs {
            self.clock
        }

        pub fn n_flows(&self) -> usize {
            self.flows.len()
        }

        pub fn rate_of(&self, id: u64) -> Option<f64> {
            self.flows.get(&id).map(|f| f.rate_mb_s)
        }

        pub fn remaining_of(&self, id: u64) -> Option<f64> {
            self.flows.get(&id).map(|f| f.remaining_mb)
        }

        pub fn settle(&mut self, now: Secs) {
            assert!(now >= self.clock, "time went backwards");
            let dt = (now - self.clock).0;
            if dt > 0.0 {
                for f in self.flows.values_mut() {
                    if f.remaining_mb.is_finite() {
                        f.remaining_mb = (f.remaining_mb - f.rate_mb_s * dt).max(0.0);
                        if f.remaining_mb < 1e-6 {
                            f.remaining_mb = 0.0;
                        }
                    }
                }
            }
            self.clock = now;
        }

        pub fn add_flow(&mut self, path: Vec<LinkId>, size_mb: f64, class: TrafficClass) -> u64 {
            self.add_flow_capped(path, size_mb, class, f64::INFINITY)
        }

        pub fn add_flow_capped(
            &mut self,
            path: Vec<LinkId>,
            size_mb: f64,
            class: TrafficClass,
            max_rate_mb_s: f64,
        ) -> u64 {
            let id = self.next_id;
            self.next_id += 1;
            self.flows.insert(
                id,
                Flow { path, remaining_mb: size_mb, class, rate_mb_s: 0.0, max_rate_mb_s },
            );
            self.recompute();
            id
        }

        pub fn remove_flow(&mut self, id: u64) -> Option<f64> {
            let f = self.flows.remove(&id)?;
            self.recompute();
            Some(f.remaining_mb)
        }

        pub fn finished(&self) -> Vec<u64> {
            let mut v: Vec<u64> = self
                .flows
                .iter()
                .filter(|(_, f)| f.remaining_mb <= 0.0)
                .map(|(&id, _)| id)
                .collect();
            v.sort_unstable();
            v
        }

        pub fn next_completion(&self) -> Option<(Secs, u64)> {
            let mut best: Option<(Secs, u64)> = None;
            for (&id, f) in &self.flows {
                if !f.remaining_mb.is_finite() || f.rate_mb_s <= 0.0 {
                    continue;
                }
                let t = Secs(self.clock.0 + f.remaining_mb / f.rate_mb_s);
                best = match best {
                    None => Some((t, id)),
                    Some((bt, bid)) => {
                        if t < bt || (t == bt && id < bid) {
                            Some((t, id))
                        } else {
                            Some((bt, bid))
                        }
                    }
                };
            }
            best
        }

        fn recompute(&mut self) {
            match self.qos.clone() {
                None => {
                    let caps = self.link_cap_mb_s.clone();
                    let ids: Vec<u64> = self.flows.keys().copied().collect();
                    self.fill(&ids, &caps);
                }
                Some(policy) => {
                    for class in [
                        TrafficClass::Shuffle,
                        TrafficClass::HadoopOther,
                        TrafficClass::Background,
                    ] {
                        let qrate = policy
                            .classify(class)
                            .map(|qid| mbps_to_mb_per_s(policy.queues[qid.0].rate_mbps));
                        let caps: Vec<f64> = self
                            .link_cap_mb_s
                            .iter()
                            .map(|&c| qrate.map_or(c, |q| q.min(c)))
                            .collect();
                        let ids: Vec<u64> = self
                            .flows
                            .iter()
                            .filter(|(_, f)| f.class == class)
                            .map(|(&id, _)| id)
                            .collect();
                        self.fill(&ids, &caps);
                    }
                }
            }
        }

        fn fill(&mut self, ids: &[u64], caps: &[f64]) {
            let mut order: Vec<u64> = ids.to_vec();
            order.sort_unstable();
            let mut snap: Vec<(u64, Vec<LinkId>, f64, f64)> = order
                .iter()
                .map(|id| {
                    let f = &self.flows[id];
                    (*id, f.path.clone(), f.max_rate_mb_s, 0.0)
                })
                .collect();
            let mut active: Vec<usize> = Vec::with_capacity(snap.len());
            for (i, e) in snap.iter_mut().enumerate() {
                if e.1.is_empty() {
                    e.3 = f64::INFINITY;
                } else {
                    active.push(i);
                }
            }
            let mut remaining_cap = caps.to_vec();
            let mut count = vec![0usize; caps.len()];
            while !active.is_empty() {
                count.iter_mut().for_each(|c| *c = 0);
                for &i in &active {
                    for l in &snap[i].1 {
                        count[l.0] += 1;
                    }
                }
                let mut bottleneck: Option<(f64, usize)> = None;
                for (l, &c) in count.iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    let share = remaining_cap[l] / c as f64;
                    if bottleneck.map_or(true, |(s, _)| share < s) {
                        bottleneck = Some((share, l));
                    }
                }
                let Some((share, bl)) = bottleneck else { break };
                let any_capped = active.iter().any(|&i| snap[i].2 < share);
                let mut still_active = Vec::with_capacity(active.len());
                for &i in &active {
                    let freeze = if any_capped {
                        snap[i].2 < share
                    } else {
                        snap[i].1.contains(&LinkId(bl))
                    };
                    if freeze {
                        let rate = if any_capped { snap[i].2 } else { share };
                        snap[i].3 = rate;
                        for l in &snap[i].1 {
                            remaining_cap[l.0] = (remaining_cap[l.0] - rate).max(0.0);
                        }
                    } else {
                        still_active.push(i);
                    }
                }
                active = still_active;
            }
            for (id, _, _, rate) in snap {
                self.flows.get_mut(&id).unwrap().rate_mb_s = rate;
            }
        }
    }
}

/// One randomized flow-network interaction.
#[derive(Debug, Clone)]
enum NetOp {
    Add { path: Vec<usize>, size_mb: f64, class: usize, cap: f64 },
    AddBg { path: Vec<usize>, class: usize, cap: f64 },
    Remove { pick: usize },
    SettleNext,
    Settle { dt: f64 },
    InstallQos,
    Drain,
}

#[derive(Debug)]
struct NetCase {
    caps_mbps: Vec<f64>,
    ops: Vec<NetOp>,
}

fn gen_net_case(r: &mut XorShift, qos_mode: bool) -> NetCase {
    let n_links = 1 + r.below(10);
    let caps_mbps: Vec<f64> =
        (0..n_links).map(|_| [80.0, 100.0, 64.0, 40.0][r.below(4)]).collect();
    let pick_path = |r: &mut XorShift, min_len: usize| -> Vec<usize> {
        let len = min_len + r.below(3.min(n_links) + 1 - min_len);
        r.distinct(n_links, len.min(n_links))
    };
    let ops = (0..80)
        .map(|_| match r.below(20) {
            0..=6 => NetOp::Add {
                path: pick_path(r, 0),
                size_mb: [8.0, 16.0, 64.0, 100.0, 0.0][r.below(5)],
                class: r.below(3),
                cap: [f64::INFINITY, f64::INFINITY, 4.0, 2.0][r.below(4)],
            },
            7..=8 => NetOp::AddBg {
                path: pick_path(r, 1),
                class: r.below(3),
                cap: [f64::INFINITY, 4.0, 2.0][r.below(3)],
            },
            9..=12 => NetOp::Remove { pick: r.below(64) },
            13..=15 => NetOp::SettleNext,
            16..=17 => NetOp::Settle { dt: [0.0, 0.5, 1.0, 3.0][r.below(4)] },
            18 => {
                if qos_mode {
                    NetOp::InstallQos
                } else {
                    NetOp::SettleNext
                }
            }
            _ => NetOp::Drain,
        })
        .collect();
    NetCase { caps_mbps, ops }
}

fn class_of(i: usize) -> bass::sdn::TrafficClass {
    use bass::sdn::TrafficClass::*;
    [Shuffle, HadoopOther, Background][i]
}

/// The incremental FlowNet (slab arena + per-link index + lazy component
/// refill + completion heap) is observationally equivalent to the seed's
/// from-scratch implementation under arbitrary add/settle/remove churn —
/// rates, finished sets, completion predictions and drained volumes all
/// match within f64 dust, in shared mode and with rate caps in play.
#[test]
fn prop_flownet_incremental_matches_scratch_shared() {
    flownet_equivalence(0xF0A, false);
}

/// Same property with the Example 3 QoS queues installed mid-sequence
/// (per-class partitions + background rate caps interacting with churn).
#[test]
fn prop_flownet_incremental_matches_scratch_qos() {
    flownet_equivalence(0xF0B, true);
}

fn flownet_equivalence(seed: u64, qos_mode: bool) {
    use bass::sdn::QosPolicy;
    use flownet_reference::RefNet;
    const TOL: f64 = 1e-9;
    forall(
        seed,
        80,
        |r| gen_net_case(r, qos_mode),
        |case| {
            let mut reference = RefNet::new(&case.caps_mbps);
            let mut incr = bass::sim::FlowNet::new(&case.caps_mbps);
            let mut live: Vec<(u64, bass::sim::FlowId)> = Vec::new();
            let mut map: std::collections::HashMap<u64, bass::sim::FlowId> =
                std::collections::HashMap::new();
            let close = |a: f64, b: f64| -> bool {
                (a == b) || (a - b).abs() <= TOL || (a.is_infinite() && b.is_infinite())
            };
            for (step, op) in case.ops.iter().enumerate() {
                match op {
                    NetOp::Add { path, size_mb, class, cap } => {
                        let p: Vec<LinkId> = path.iter().map(|&l| LinkId(l)).collect();
                        let a = reference.add_flow_capped(
                            p.clone(),
                            *size_mb,
                            class_of(*class),
                            *cap,
                        );
                        let b = incr.add_flow_capped(p, *size_mb, class_of(*class), *cap);
                        map.insert(a, b);
                        live.push((a, b));
                    }
                    NetOp::AddBg { path, class, cap } => {
                        let p: Vec<LinkId> = path.iter().map(|&l| LinkId(l)).collect();
                        let a = reference.add_flow_capped(
                            p.clone(),
                            f64::INFINITY,
                            class_of(*class),
                            *cap,
                        );
                        let b =
                            incr.add_flow_capped(p, f64::INFINITY, class_of(*class), *cap);
                        map.insert(a, b);
                        live.push((a, b));
                    }
                    NetOp::Remove { pick } => {
                        if !live.is_empty() {
                            let (a, b) = live.swap_remove(pick % live.len());
                            let ra = reference.remove_flow(a);
                            let rb = incr.remove_flow(b);
                            match (ra, rb) {
                                (Some(x), Some(y)) if close(x, y) => {}
                                other => {
                                    return Err(format!(
                                        "step {step}: remove returns diverged {other:?}"
                                    ))
                                }
                            }
                        }
                    }
                    NetOp::SettleNext => {
                        if let Some((t, _)) = reference.next_completion() {
                            let to = t.max(reference.clock());
                            reference.settle(to);
                            incr.settle(to);
                        }
                    }
                    NetOp::Settle { dt } => {
                        let to = Secs(reference.clock().0 + dt);
                        reference.settle(to);
                        incr.settle(to);
                    }
                    NetOp::InstallQos => {
                        reference.set_qos(QosPolicy::example3());
                        incr.set_qos(QosPolicy::example3());
                    }
                    NetOp::Drain => {
                        for a in reference.finished() {
                            let b = map[&a];
                            reference.remove_flow(a);
                            incr.remove_flow(b);
                            live.retain(|&(x, _)| x != a);
                        }
                    }
                }
                // full observational comparison after every op
                if reference.n_flows() != incr.n_flows() {
                    return Err(format!(
                        "step {step}: flow counts {} != {}",
                        reference.n_flows(),
                        incr.n_flows()
                    ));
                }
                for &(a, b) in &live {
                    let (ra, rb) = (reference.rate_of(a), incr.rate_of(b));
                    match (ra, rb) {
                        (Some(x), Some(y)) if close(x, y) => {}
                        other => {
                            return Err(format!("step {step}: rate diverged {other:?}"))
                        }
                    }
                    let (ma, mb) = (reference.remaining_of(a), incr.remaining_of(b));
                    match (ma, mb) {
                        (Some(x), Some(y)) if close(x, y) => {}
                        other => {
                            return Err(format!("step {step}: remaining diverged {other:?}"))
                        }
                    }
                }
                let fa: Vec<bass::sim::FlowId> =
                    reference.finished().iter().map(|id| map[id]).collect();
                let fb = incr.finished();
                if fa != fb {
                    return Err(format!("step {step}: finished diverged {fa:?} vs {fb:?}"));
                }
                match (reference.next_completion(), incr.next_completion()) {
                    (None, None) => {}
                    (Some((ta, ia)), Some((tb, ib))) => {
                        if !close(ta.0, tb.0) {
                            return Err(format!(
                                "step {step}: completion time {ta} vs {tb}"
                            ));
                        }
                        if map[&ia] != ib {
                            // ulp ties: the incremental side may argmin a
                            // different flow whose completion is within
                            // dust of the reference minimum — accept it
                            // iff the reference also predicts that flow
                            // completing at (dust-)the same instant
                            let alt = live.iter().find(|&&(_, b)| b == ib).map(|&(a, _)| a);
                            let alt_t = alt.and_then(|a| {
                                let rem = reference.remaining_of(a)?;
                                let rate = reference.rate_of(a)?;
                                (rate > 0.0 && rem.is_finite())
                                    .then(|| reference.clock().0 + rem / rate)
                            });
                            match alt_t {
                                Some(t) if close(t, ta.0) => {}
                                _ => {
                                    return Err(format!(
                                        "step {step}: completion flow {ia} vs {ib:?}"
                                    ))
                                }
                            }
                        }
                    }
                    other => {
                        return Err(format!("step {step}: completion diverged {other:?}"))
                    }
                }
            }
            Ok(())
        },
    );
}

/// Reference executor: the seed's engine (per-event settle, per-flow
/// remove + reschedule, cloned placements) ported verbatim on top of the
/// reference flow network.
mod engine_reference {
    use std::cmp::Reverse;
    use std::collections::{BinaryHeap, HashMap, VecDeque};

    use bass::sim::{Assignment, Placement, TaskRecord, TransferPlan};
    use bass::util::Secs;

    use super::flownet_reference::RefNet;

    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    enum EvKind {
        NodeReady(usize),
        FlowCheck(u64),
    }

    pub struct RefEngine {
        pub net: RefNet,
        now: Secs,
        seq: u64,
        events: BinaryHeap<Reverse<(Secs, u64, EvKind)>>,
        queues: Vec<VecDeque<Placement>>,
        node_free: Vec<Secs>,
        blocked: Vec<bool>,
        waiting: HashMap<u64, (usize, Placement, Secs)>,
        records: Vec<TaskRecord>,
        flow_gen: u64,
    }

    impl RefEngine {
        pub fn new(net: RefNet, initial_free: Vec<Secs>) -> Self {
            let n = initial_free.len();
            Self {
                net,
                now: Secs::ZERO,
                seq: 0,
                events: BinaryHeap::new(),
                queues: vec![VecDeque::new(); n],
                node_free: initial_free,
                blocked: vec![false; n],
                waiting: HashMap::new(),
                records: Vec::new(),
                flow_gen: 0,
            }
        }

        fn push(&mut self, at: Secs, kind: EvKind) {
            self.seq += 1;
            self.events.push(Reverse((at, self.seq, kind)));
        }

        pub fn load(&mut self, a: &Assignment) {
            for p in &a.placements {
                self.queues[p.node.0].push_back(p.clone());
            }
            for j in 0..self.queues.len() {
                let at = self.node_free[j].max(self.now);
                self.push(at, EvKind::NodeReady(j));
            }
        }

        fn reschedule_flow_check(&mut self) {
            if let Some((t, _)) = self.net.next_completion() {
                self.flow_gen += 1;
                self.push(t.max(self.now), EvKind::FlowCheck(self.flow_gen));
            }
        }

        pub fn run(&mut self) -> Vec<TaskRecord> {
            while let Some(Reverse((at, _, kind))) = self.events.pop() {
                self.now = self.now.max(at);
                self.net.settle(self.now);
                match kind {
                    EvKind::NodeReady(j) => self.node_ready(j),
                    EvKind::FlowCheck(gen) => {
                        if gen == self.flow_gen {
                            self.flow_check();
                        }
                    }
                }
            }
            assert!(self.waiting.is_empty() && self.queues.iter().all(|q| q.is_empty()));
            let mut recs = std::mem::take(&mut self.records);
            recs.sort_by_key(|r| r.task);
            recs
        }

        fn node_ready(&mut self, j: usize) {
            if self.blocked[j] {
                return;
            }
            if self.node_free[j] > self.now {
                let at = self.node_free[j];
                self.push(at, EvKind::NodeReady(j));
                return;
            }
            let Some(p) = self.queues[j].front().cloned() else { return };
            if let Some(g) = p.gate {
                if g > self.now {
                    self.push(g, EvKind::NodeReady(j));
                    return;
                }
            }
            self.queues[j].pop_front();
            let picked = self.now;
            match p.transfer.clone() {
                TransferPlan::None => self.finish_compute(j, &p, picked, picked, picked),
                TransferPlan::Reserved(t) => {
                    let ready = t.arrival.max(picked);
                    self.finish_compute(j, &p, picked, ready, ready);
                }
                TransferPlan::Prefetched(t) => {
                    let ready = t.arrival;
                    let start = ready.max(picked);
                    self.finish_compute(j, &p, picked, ready, start);
                }
                TransferPlan::FairShare { path, size_mb, class } => {
                    if size_mb <= 0.0 || path.is_empty() {
                        self.finish_compute(j, &p, picked, picked, picked);
                    } else {
                        let id = self.net.add_flow(path, size_mb, class);
                        self.blocked[j] = true;
                        self.waiting.insert(id, (j, p, picked));
                        self.reschedule_flow_check();
                    }
                }
            }
        }

        fn finish_compute(
            &mut self,
            j: usize,
            p: &Placement,
            picked: Secs,
            ready: Secs,
            start: Secs,
        ) {
            let finish = start + p.compute;
            self.node_free[j] = finish;
            self.records.push(TaskRecord {
                task: p.task,
                node: p.node,
                picked_at: picked,
                input_ready: ready,
                compute_start: start,
                finish,
                source: p.source,
                is_local: p.is_local,
                is_map: p.is_map,
            });
            self.push(finish, EvKind::NodeReady(j));
        }

        fn flow_check(&mut self) {
            for id in self.net.finished() {
                self.net.remove_flow(id);
                if let Some((j, p, picked)) = self.waiting.remove(&id) {
                    self.blocked[j] = false;
                    self.node_free[j] = self.now;
                    self.finish_compute(j, &p, picked, self.now, self.now);
                }
            }
            self.reschedule_flow_check();
        }
    }
}

/// A randomized assignment over a small cluster for the engine property.
#[derive(Debug)]
struct EngineCase {
    caps_mbps: Vec<f64>,
    initial: Vec<f64>,
    placements: Vec<(usize, usize, f64, u8, Vec<usize>, f64, f64, Option<f64>)>,
    background: Vec<(Vec<usize>, f64)>,
}

fn gen_engine_case(r: &mut XorShift) -> EngineCase {
    let n_links = 1 + r.below(8);
    let caps_mbps: Vec<f64> = (0..n_links).map(|_| [80.0, 100.0, 64.0][r.below(3)]).collect();
    let n_nodes = 1 + r.below(6);
    let initial: Vec<f64> = (0..n_nodes).map(|_| [0.0, 1.0, 3.0, 7.0][r.below(4)]).collect();
    let m = 1 + r.below(24);
    let placements = (0..m)
        .map(|t| {
            let node = r.below(n_nodes);
            let compute = [1.0, 2.0, 5.0, 9.0][r.below(4)];
            // kind: 0/1 = local, 2 = reserved, 3 = prefetched, else fair
            let kind = r.below(8) as u8;
            let path = {
                let len = r.below(3.min(n_links) + 1);
                r.distinct(n_links, len)
            };
            let size = [0.0, 16.0, 50.0, 64.0][r.below(4)];
            let arrival = [2.0, 5.0, 8.0][r.below(3)];
            let gate = if r.chance(0.25) { Some([4.0, 10.0][r.below(2)]) } else { None };
            (t, node, compute, kind, path, size, arrival, gate)
        })
        .collect();
    let background = (0..r.below(4))
        .map(|_| {
            let len = 1 + r.below(2.min(n_links));
            (r.distinct(n_links, len), [f64::INFINITY, 4.0][r.below(2)])
        })
        .collect();
    EngineCase { caps_mbps, initial, placements, background }
}

fn engine_case_assignment(case: &EngineCase) -> Assignment {
    use bass::mapreduce::TaskId;
    use bass::sdn::calendar::Reservation;
    use bass::sdn::controller::Transfer;
    use bass::sim::Placement;
    use bass::topology::NodeId;

    let placements = case
        .placements
        .iter()
        .map(|&(t, node, compute, kind, ref path, size, arrival, gate)| {
            let reserved = |at: f64| Transfer {
                flow_id: 0,
                reservation: Reservation { links: vec![], start_slot: 0, n_slots: 0, frac: 1.0 },
                rate_mb_s: 12.8,
                arrival: Secs(at),
                start: Secs(at - 1.0),
            };
            let transfer = match kind {
                0 | 1 => TransferPlan::None,
                2 => TransferPlan::Reserved(reserved(arrival)),
                3 => TransferPlan::Prefetched(reserved(arrival)),
                _ => TransferPlan::FairShare {
                    path: path.iter().map(|&l| LinkId(l)).collect(),
                    size_mb: size,
                    class: bass::sdn::TrafficClass::HadoopOther,
                },
            };
            let is_local = matches!(transfer, TransferPlan::None);
            Placement {
                task: TaskId(t),
                node: NodeId(node),
                compute: Secs(compute),
                transfer,
                gate: gate.map(Secs),
                source: None,
                is_local,
                is_map: true,
            }
        })
        .collect();
    Assignment { placements }
}

/// The batched engine (same-instant event draining, index queues, lazy
/// flow net) produces the same records as the seed's per-event engine on
/// random assignments with contended fair-share transfers, reservations,
/// gates and background flows.
#[test]
fn prop_engine_batched_matches_reference() {
    const TOL: f64 = 1e-9;
    forall(0xE55, 80, gen_engine_case, |case| {
        let a = engine_case_assignment(case);
        let initial: Vec<Secs> = case.initial.iter().map(|&t| Secs(t)).collect();

        let mut ref_net = flownet_reference::RefNet::new(&case.caps_mbps);
        let mut new_net = FlowNet::new(&case.caps_mbps);
        for (path, cap) in &case.background {
            let p: Vec<LinkId> = path.iter().map(|&l| LinkId(l)).collect();
            ref_net.add_flow_capped(
                p.clone(),
                f64::INFINITY,
                bass::sdn::TrafficClass::Background,
                *cap,
            );
            new_net.add_flow_capped(
                p,
                f64::INFINITY,
                bass::sdn::TrafficClass::Background,
                *cap,
            );
        }

        let mut reference = engine_reference::RefEngine::new(ref_net, initial.clone());
        reference.load(&a);
        let want = reference.run();

        let mut engine = Engine::new(new_net, initial);
        engine.load(&a);
        let got = engine.run();

        if want.len() != got.len() {
            return Err(format!("record counts {} != {}", want.len(), got.len()));
        }
        for (w, g) in want.iter().zip(&got) {
            if w.task != g.task || w.node != g.node || w.is_local != g.is_local {
                return Err(format!("record identity diverged: {w:?} vs {g:?}"));
            }
            for (x, y) in [
                (w.picked_at, g.picked_at),
                (w.input_ready, g.input_ready),
                (w.compute_start, g.compute_start),
                (w.finish, g.finish),
            ] {
                if (x.0 - y.0).abs() > TOL {
                    return Err(format!("record times diverged: {w:?} vs {g:?}"));
                }
            }
        }
        Ok(())
    });
}

/// Reference schedulers: the seed's HDS loop (O(m·n) ledger scans +
/// O(m²) locality probes) and BASS round (per-(task,node) cost
/// resolution, linear minnow scan), ported verbatim. The rewritten
/// inner loops must reproduce their picks bit for bit.
mod sched_reference {
    use bass::mapreduce::TaskSpec;
    use bass::sched::{cost, SchedCtx};
    use bass::sdn::TrafficClass;
    use bass::sim::{Assignment, Placement, TransferPlan};
    use bass::util::Secs;

    pub fn hds_schedule(
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment {
        let mut pending: Vec<usize> = (0..tasks.len()).collect();
        let mut placements = Vec::with_capacity(tasks.len());
        let floor = gate.unwrap_or(ctx.now).max(ctx.now);
        while !pending.is_empty() {
            let (j, idle) = ctx
                .ledger
                .min_idle_among(ctx.authorized.iter().copied())
                .expect("no authorized nodes");
            let t0 = idle.max(floor);
            let local_pick =
                pending.iter().copied().find(|&i| ctx.local_nodes(&tasks[i]).contains(&j));
            let (i, is_local) = match local_pick {
                Some(i) => (i, true),
                None => (pending[0], false),
            };
            pending.retain(|&x| x != i);
            let t = &tasks[i];
            let tp = ctx.effective_compute(t, j);
            if is_local || t.input_mb <= 0.0 {
                let finish = t0 + tp;
                ctx.ledger.occupy_until(j, finish);
                placements.push(Placement {
                    task: t.id,
                    node: j,
                    compute: tp,
                    transfer: TransferPlan::None,
                    gate,
                    source: None,
                    is_local,
                    is_map: t.is_map(),
                });
            } else {
                let src =
                    ctx.transfer_source_for(t, j).expect("remote task needs a readable source");
                let tm = ctx.tm_estimate(src, j, t.input_mb).unwrap_or(Secs::INF);
                let finish = t0 + tm + tp;
                ctx.ledger.occupy_until(j, finish);
                let path =
                    ctx.controller.path(src, j).map(|p| p.to_vec()).unwrap_or_default();
                let class =
                    if t.is_map() { TrafficClass::HadoopOther } else { TrafficClass::Shuffle };
                placements.push(Placement {
                    task: t.id,
                    node: j,
                    compute: tp,
                    transfer: TransferPlan::FairShare { path, size_mb: t.input_mb, class },
                    gate,
                    source: Some(src),
                    is_local: false,
                    is_map: t.is_map(),
                });
            }
        }
        Assignment { placements }
    }

    pub fn bass_schedule(
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> (Assignment, usize) {
        let mut remote_assignments = 0usize;
        let floor = gate.unwrap_or(ctx.now).max(ctx.now);
        let batch = cost::eval_batch(tasks, ctx);
        let mut placements = Vec::with_capacity(tasks.len());
        for (i, t) in tasks.iter().enumerate() {
            let class =
                if t.is_map() { TrafficClass::HadoopOther } else { TrafficClass::Shuffle };
            let locals = ctx.local_nodes(t);
            let (minnow, yi_minnow) = {
                let mut best: Option<(bass::topology::NodeId, f64)> = None;
                for (j, &nd) in ctx.authorized.iter().enumerate() {
                    let tm = batch.tm_at(i, j) as f64;
                    let score = tm + ctx.ledger.idle(nd).0 + ctx.effective_compute(t, nd).0;
                    if best.map_or(true, |(_, b)| score < b) {
                        best = Some((nd, score));
                    }
                }
                let (nd, _) = best.expect("no authorized nodes");
                (nd, ctx.ledger.idle(nd))
            };
            let loc = ctx.ledger.min_idle_among(locals.iter().copied());

            let assign_local = |ctx: &mut SchedCtx, placements: &mut Vec<Placement>| {
                let (loc_nd, yi_loc) = loc.unwrap();
                let start = yi_loc.max(floor);
                let tp = ctx.effective_compute(t, loc_nd);
                ctx.ledger.occupy_until(loc_nd, start + tp);
                placements.push(Placement {
                    task: t.id,
                    node: loc_nd,
                    compute: tp,
                    transfer: TransferPlan::None,
                    gate,
                    source: None,
                    is_local: true,
                    is_map: t.is_map(),
                });
            };

            match loc {
                Some((loc_nd, yi_loc)) => {
                    if loc_nd == minnow || yi_loc <= yi_minnow {
                        assign_local(ctx, &mut placements);
                        continue;
                    }
                    let mcol = cost::col_of(ctx, minnow);
                    if batch.tm_at(i, mcol) >= bass::runtime::exec::INF {
                        assign_local(ctx, &mut placements);
                        continue;
                    }
                    let src = match ctx.transfer_source_for(t, minnow) {
                        Some(s) => s,
                        None => {
                            assign_local(ctx, &mut placements);
                            continue;
                        }
                    };
                    let earliest = yi_minnow.max(floor);
                    let plan =
                        ctx.controller.plan_transfer(src, minnow, t.input_mb, earliest);
                    let tp_loc = ctx.effective_compute(t, loc_nd);
                    let tp_min = ctx.effective_compute(t, minnow);
                    let yc_loc = yi_loc.max(floor) + tp_loc;
                    match plan {
                        Some(p) if p.2 + tp_min < yc_loc => {
                            let tr = ctx
                                .controller
                                .commit_transfer(src, minnow, class, p, ctx.now)
                                .expect("planned reservation must commit");
                            ctx.ledger.occupy_until(minnow, tr.arrival + tp_min);
                            remote_assignments += 1;
                            placements.push(Placement {
                                task: t.id,
                                node: minnow,
                                compute: tp_min,
                                transfer: TransferPlan::Reserved(tr),
                                gate,
                                source: Some(src),
                                is_local: false,
                                is_map: t.is_map(),
                            });
                        }
                        _ => assign_local(ctx, &mut placements),
                    }
                }
                None => {
                    let start = yi_minnow.max(floor);
                    let tp_min = ctx.effective_compute(t, minnow);
                    match ctx.transfer_source_for(t, minnow).filter(|_| t.input_mb > 0.0) {
                        None => {
                            ctx.ledger.occupy_until(minnow, start + tp_min);
                            placements.push(Placement {
                                task: t.id,
                                node: minnow,
                                compute: tp_min,
                                transfer: TransferPlan::None,
                                gate,
                                source: None,
                                is_local: false,
                                is_map: t.is_map(),
                            });
                        }
                        Some(src) => {
                            match ctx.controller.plan_transfer(src, minnow, t.input_mb, start)
                            {
                                Some(p) => {
                                    let tr = ctx
                                        .controller
                                        .commit_transfer(src, minnow, class, p, ctx.now)
                                        .expect("planned reservation must commit");
                                    ctx.ledger.occupy_until(minnow, tr.arrival + tp_min);
                                    remote_assignments += 1;
                                    placements.push(Placement {
                                        task: t.id,
                                        node: minnow,
                                        compute: tp_min,
                                        transfer: TransferPlan::Reserved(tr),
                                        gate,
                                        source: Some(src),
                                        is_local: false,
                                        is_map: t.is_map(),
                                    });
                                }
                                None => {
                                    let path = ctx
                                        .controller
                                        .path(src, minnow)
                                        .map(|p| p.to_vec())
                                        .unwrap_or_default();
                                    let tm = ctx
                                        .tm_estimate(src, minnow, t.input_mb)
                                        .unwrap_or(Secs::INF);
                                    ctx.ledger.occupy_until(minnow, start + tm + tp_min);
                                    placements.push(Placement {
                                        task: t.id,
                                        node: minnow,
                                        compute: tp_min,
                                        transfer: TransferPlan::FairShare {
                                            path,
                                            size_mb: t.input_mb,
                                            class,
                                        },
                                        gate,
                                        source: Some(src),
                                        is_local: false,
                                        is_map: t.is_map(),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        (Assignment { placements }, remote_assignments)
    }
}

/// Bitwise placement comparison (schedule decisions, compute times,
/// transfer plans, reservation geometry).
fn assignments_equal(want: &Assignment, got: &Assignment) -> Result<(), String> {
    if want.placements.len() != got.placements.len() {
        return Err(format!(
            "placement counts {} != {}",
            want.placements.len(),
            got.placements.len()
        ));
    }
    for (k, (w, g)) in want.placements.iter().zip(&got.placements).enumerate() {
        if w.task != g.task
            || w.node != g.node
            || w.compute != g.compute
            || w.gate != g.gate
            || w.source != g.source
            || w.is_local != g.is_local
            || w.is_map != g.is_map
        {
            return Err(format!("placement {k} diverged: {w:?} vs {g:?}"));
        }
        let same = match (&w.transfer, &g.transfer) {
            (TransferPlan::None, TransferPlan::None) => true,
            (TransferPlan::Reserved(a), TransferPlan::Reserved(b))
            | (TransferPlan::Prefetched(a), TransferPlan::Prefetched(b)) => {
                a.reservation.links == b.reservation.links
                    && a.reservation.start_slot == b.reservation.start_slot
                    && a.reservation.n_slots == b.reservation.n_slots
                    && a.reservation.frac == b.reservation.frac
                    && a.rate_mb_s == b.rate_mb_s
                    && a.arrival == b.arrival
                    && a.start == b.start
            }
            (
                TransferPlan::FairShare { path: pa, size_mb: sa, class: ca },
                TransferPlan::FairShare { path: pb, size_mb: sb, class: cb },
            ) => pa == pb && sa == sb && ca == cb,
            _ => false,
        };
        if !same {
            return Err(format!("transfer {k} diverged: {:?} vs {:?}", w.transfer, g.transfer));
        }
    }
    Ok(())
}

/// A scheduling scenario with the knobs the rewritten inner loops touch:
/// gates (reduce floors) and heterogeneous per-node speed factors.
#[derive(Debug)]
struct SchedCase {
    scenario: Scenario,
    gate: Option<f64>,
    speeds: Vec<f64>,
}

fn gen_sched_case(r: &mut XorShift) -> SchedCase {
    let scenario = gen_scenario(r);
    let n = scenario.n_switches * scenario.per_switch;
    let gate = if r.chance(0.3) { Some([5.0, 20.0][r.below(2)]) } else { None };
    let speeds = if r.chance(0.4) {
        (0..n).map(|_| [0.5, 1.0, 2.0, 3.0][r.below(4)]).collect()
    } else {
        Vec::new()
    };
    SchedCase { scenario, gate, speeds }
}

/// The heap/queue-based HDS reproduces the seed's pick order, transfer
/// plans and ledger bit for bit on random clusters, gates and
/// heterogeneous speed tables.
#[test]
fn prop_hds_matches_reference() {
    forall(0x4D5, 80, gen_sched_case, |case| {
        let run = |use_reference: bool| -> (Assignment, Ledger) {
            let (mut ctrl, nn, nodes, tasks, _) = build(&case.scenario);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: case.speeds.clone(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            let gate = case.gate.map(Secs);
            let a = if use_reference {
                sched_reference::hds_schedule(&tasks, gate, &mut ctx)
            } else {
                Hds::new().schedule(&tasks, gate, &mut ctx)
            };
            (a, ledger)
        };
        let (want, ledger_want) = run(true);
        let (got, ledger_got) = run(false);
        assignments_equal(&want, &got)?;
        if ledger_want != ledger_got {
            return Err("ledger diverged".into());
        }
        Ok(())
    });
}

/// The hoisted/pruned BASS round (speed-factor tables, contiguous TM
/// rows, idle-bound minnow prune) reproduces the seed's decisions,
/// reservations and ledger bit for bit.
#[test]
fn prop_bass_matches_reference() {
    forall(0xBA55, 80, gen_sched_case, |case| {
        let run = |use_reference: bool| -> (Assignment, usize, Ledger) {
            let (mut ctrl, nn, nodes, tasks, _) = build(&case.scenario);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: case.speeds.clone(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            let gate = case.gate.map(Secs);
            if use_reference {
                let (a, remote) = sched_reference::bass_schedule(&tasks, gate, &mut ctx);
                (a, remote, ledger)
            } else {
                let mut b = Bass::new();
                let a = b.schedule(&tasks, gate, &mut ctx);
                (a, b.remote_assignments, ledger)
            }
        };
        let (want, remote_want, ledger_want) = run(true);
        let (got, remote_got, ledger_got) = run(false);
        assignments_equal(&want, &got)?;
        if remote_want != remote_got {
            return Err(format!("remote counts {remote_want} != {remote_got}"));
        }
        if ledger_want != ledger_got {
            return Err("ledger diverged".into());
        }
        Ok(())
    });
}

// ---- replica-selection equivalence + bandwidth-row properties ----

/// With every block at replication 1, the bandwidth-aware source rule
/// and the legacy idle-only rule are the *same function* — placements,
/// transfer plans, sources and ledgers must match bit for bit for every
/// scheduler. This pins the fix's backward-compatibility half: sparse
/// layouts behave exactly as the seed did.
#[test]
fn prop_single_replica_source_rules_coincide() {
    forall(0x1A5B, 60, gen_sched_case, |case| {
        let s = &case.scenario;
        let single = Scenario {
            n_switches: s.n_switches,
            per_switch: s.per_switch,
            m_tasks: s.m_tasks,
            replication: 1,
            seed: s.seed,
        };
        for kind in ["hds", "bar", "bass"] {
            let run = |bw_aware: bool| -> (Assignment, Ledger) {
                let (mut ctrl, nn, nodes, tasks, _) = build(&single);
                let cost = CostModel::rust_only();
                let mut ledger = Ledger::new(nodes.len());
                let mut ctx = SchedCtx {
                    view: &bass::sdn::Oracle,
                    controller: &mut ctrl,
                    namenode: &nn,
                    ledger: &mut ledger,
                    authorized: nodes.clone(),
                    now: Secs::ZERO,
                    cost: &cost,
                    node_speed: case.speeds.clone(),
                    down: Vec::new(),
                    bw_aware_sources: bw_aware,
                };
                let gate = case.gate.map(Secs);
                let a = match kind {
                    "hds" => Hds::new().schedule(&tasks, gate, &mut ctx),
                    "bar" => Bar::new().schedule(&tasks, gate, &mut ctx),
                    _ => Bass::new().schedule(&tasks, gate, &mut ctx),
                };
                (a, ledger)
            };
            let (want, ledger_want) = run(false);
            let (got, ledger_got) = run(true);
            assignments_equal(&want, &got).map_err(|e| format!("{kind}: {e}"))?;
            if ledger_want != ledger_got {
                return Err(format!("{kind}: ledger diverged at replication 1"));
            }
        }
        Ok(())
    });
}

/// The batched bandwidth rows are the element-wise best over every
/// readable holder — re-derived here cell by cell against the
/// controller, independently of `build_inputs`' memoization.
#[test]
fn prop_bw_rows_are_elementwise_best() {
    use bass::runtime::exec::BW_SENTINEL_MB_S;
    forall(0xBE57, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, _) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let ctx = SchedCtx {
            view: &bass::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let inp = bass::sched::cost::build_inputs(&tasks, &ctx);
        for (i, t) in tasks.iter().enumerate() {
            let b = t.input.expect("map tasks");
            for (j, &nd) in nodes.iter().enumerate() {
                let want = nn
                    .block(b)
                    .replicas
                    .iter()
                    .map(|&r| {
                        let bw = ctx.controller.path_bw_mb_s(r, nd, Secs::ZERO);
                        if bw.is_infinite() {
                            BW_SENTINEL_MB_S
                        } else {
                            bw as f32
                        }
                    })
                    .fold(0.0f32, f32::max);
                let got = inp.bw[i * nodes.len() + j];
                if (want - got).abs() > 1e-6 * want.max(1.0) {
                    return Err(format!(
                        "task {i} node {j}: bw {got} != element-wise best {want}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Saturation contract of the centralized bandwidth sentinel
/// (`runtime::exec::BW_SENTINEL_MB_S`): an infinite-bandwidth (local)
/// cell always yields a strictly smaller TM — and, at equal TP and idle,
/// a strictly smaller ΥC — than any remote cell at a physical bandwidth,
/// and nothing overflows to f32 infinity on the way.
#[test]
fn prop_local_sentinel_cells_always_beat_remote() {
    use bass::runtime::exec::{BW_SENTINEL_MB_S, INF};
    use bass::runtime::{CostInputs, CostModel};
    #[derive(Debug)]
    struct SentinelCase {
        sz: f32,
        tp: f32,
        idle: f32,
        remote_bw: f32,
        masked: bool,
    }
    let gen = |r: &mut XorShift| SentinelCase {
        sz: r.uniform(0.1, 10_000.0) as f32,
        tp: r.uniform(0.0, 900.0) as f32,
        idle: r.uniform(0.0, 500.0) as f32,
        // up to 1e6 MB/s: far beyond any physical link, far below the cap
        remote_bw: r.uniform(1e-3, 1e6) as f32,
        masked: r.chance(0.5),
    };
    forall(0x5E47, 300, gen, |c| {
        // column 0: the "local" cell (sentinel bw; optionally the replica
        // mask on top, as build_inputs emits for holder columns);
        // column 1: a remote cell at a physical bandwidth
        let inp = CostInputs {
            m: 1,
            n: 2,
            sz: vec![c.sz],
            bw: vec![BW_SENTINEL_MB_S, c.remote_bw],
            tp: vec![c.tp; 2],
            local: vec![if c.masked { 1.0 } else { 0.0 }, 0.0],
            idle: vec![c.idle; 2],
            ts: 1.0,
        };
        let out = CostModel::eval_rust(&inp);
        let (tm_local, tm_remote) = (out.tm_at(0, 0), out.tm_at(0, 1));
        if c.masked && tm_local != 0.0 {
            return Err(format!("masked local TM must be exactly 0, got {tm_local}"));
        }
        if tm_local >= tm_remote {
            return Err(format!(
                "sentinel TM {tm_local} not below remote TM {tm_remote} (bw {})",
                c.remote_bw
            ));
        }
        // ΥC adds TP + idle on top; a microscopic remote TM can round
        // into the same f32 as the local sum, so the guarantee is
        // "never worse, and the argmin keeps the local column on ties"
        if out.yc_at(0, 0) > out.yc_at(0, 1) {
            return Err(format!(
                "local ΥC {} above remote ΥC {} at equal TP/idle",
                out.yc_at(0, 0),
                out.yc_at(0, 1)
            ));
        }
        for v in [out.yc_at(0, 0), out.yc_at(0, 1), tm_local, tm_remote] {
            if !v.is_finite() || v >= INF {
                return Err(format!("sentinel arithmetic saturated: {v}"));
            }
        }
        if out.best_idx[0] != 0 {
            return Err("argmin must pick the local column".into());
        }
        Ok(())
    });
}

// ---- online stream vs the static single-job path (differential pins) ----
//
// The concurrent stream (`scenario::online`) must degenerate to the
// existing static path bit-for-bit when jobs cannot overlap: a 1-job
// stream, and an N-job stream whose inter-arrival gaps exceed every
// job's makespan, are pinned against the sequential run-to-completion
// reference. Two pins cover the two equivalence domains (see the
// `scenario::online` module docs):
//
// * explicit jobs at `slowstart = 1.0` — the shared-engine and
//   phase-split models provably coincide for every scheduler, so HDS,
//   BAR and BASS are all pinned at full record granularity;
// * generated Wordcount/Sort jobs at the default slowstart through the
//   real `Coordinator::handle` path — BASS's transfers are
//   calendar-reserved (they never touch the shared flow network), so
//   the pin holds there at the default gate too.

use bass::coordinator::{ClusterSetup, Coordinator, JobRequest};
use bass::mapreduce::TaskId;
use bass::scenario::{
    shuffle_majority_node, slowstart_gate, AdmissionPolicy, BackgroundSpec, InitialLoad,
    ScenarioSpec, SimSession, Submission, SubmissionBody, TopologyShape, WorkloadSpec,
};
use bass::sched::SchedulerKind;
use bass::sim::TaskRecord;
use bass::workload::{JobArrival, JobKind};

#[derive(Debug, Clone)]
struct PinShape {
    maps: usize,
    reduces: usize,
    map_secs: f64,
    out_mb: f64,
    red_secs: f64,
}

#[derive(Debug)]
struct ExplicitPinCase {
    cluster_seed: u64,
    layout_seed: u64,
    switches: usize,
    per_switch: usize,
    shapes: Vec<PinShape>,
}

fn gen_explicit_pin_case(r: &mut XorShift) -> ExplicitPinCase {
    let n_jobs = 1 + r.below(3);
    ExplicitPinCase {
        cluster_seed: r.next_u64(),
        layout_seed: r.next_u64(),
        switches: 2 + r.below(2),
        per_switch: 2 + r.below(2),
        shapes: (0..n_jobs)
            .map(|_| PinShape {
                maps: 1 + r.below(6),
                reduces: r.below(3),
                map_secs: 4.0 + r.uniform(0.0, 18.0),
                out_mb: r.uniform(0.0, 24.0),
                red_secs: 3.0 + r.uniform(0.0, 15.0),
            })
            .collect(),
    }
}

fn pin_cluster_spec(case: &ExplicitPinCase, kind: SchedulerKind) -> ScenarioSpec {
    let mut s = ScenarioSpec::new(
        "stream-pin",
        TopologyShape::Tree {
            switches: case.switches,
            hosts_per_switch: case.per_switch,
            edge_mbps: 100.0,
            uplink_mbps: 100.0,
        },
        WorkloadSpec::None,
    );
    s.scheduler = kind;
    s.seed = case.cluster_seed;
    s.initial = InitialLoad::Sampled { max_secs: 8.0 };
    s.background = BackgroundSpec { flows: 2, rate_mb_s: 2.0 };
    s
}

/// Place the case's blocks into a fresh session's namenode and build the
/// explicit task sets. Called once per session with its own RNG, so the
/// static and stream sides see byte-identical layouts.
fn build_explicit_jobs(
    sess: &mut SimSession,
    case: &ExplicitPinCase,
) -> Vec<(f64, Vec<TaskSpec>)> {
    let mut rng = XorShift::new(case.layout_seed);
    case.shapes
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            let blocks = PlacementPolicy::RandomDistinct.place(
                &mut sess.nn,
                &sess.nodes,
                &[],
                sh.maps,
                BLOCK_MB,
                2.min(sess.nodes.len()),
                &mut rng,
            );
            let mut tasks: Vec<TaskSpec> = blocks
                .iter()
                .enumerate()
                .map(|(j, &b)| TaskSpec::map(j, b, BLOCK_MB, Secs(sh.map_secs), sh.out_mb))
                .collect();
            let shuffle = sh.out_mb * sh.maps as f64;
            for q in 0..sh.reduces {
                tasks.push(TaskSpec::reduce(
                    sh.maps + q,
                    shuffle / sh.reduces as f64,
                    Secs(sh.red_secs),
                ));
            }
            // inter-arrival gaps far beyond any possible makespan
            (10.0 + i as f64 * 50_000.0, tasks)
        })
        .collect()
}

/// The static sequential reference: `Coordinator::handle` semantics
/// (carried node availability, fresh ledger and pristine-net engine per
/// phase, jobs run to completion in arrival order) at `slowstart = 1.0`
/// over explicit task sets.
fn static_chain(case: &ExplicitPinCase, kind: SchedulerKind) -> Vec<Vec<TaskRecord>> {
    let cost = CostModel::rust_only();
    let mut sess = SimSession::new(&pin_cluster_spec(case, kind));
    let jobs = build_explicit_jobs(&mut sess, case);
    let n_hosts = sess.engine_init.len();
    let mut node_free = sess.engine_init.clone();
    let mut out = Vec::new();
    for (at, tasks) in jobs {
        let at = Secs(at);
        let init: Vec<Secs> = node_free.iter().map(|&f| f.max(at)).collect();
        let maps: Vec<TaskSpec> = tasks.iter().filter(|t| t.is_map()).cloned().collect();
        let mut reduces: Vec<TaskSpec> =
            tasks.iter().filter(|t| !t.is_map()).cloned().collect();
        let mut ledger_init = vec![Secs::INF; n_hosts];
        for &nd in &sess.nodes {
            ledger_init[nd.0] = init[nd.0];
        }
        sess.ledger = Ledger::with_initial(ledger_init);
        let a = sess.schedule(&maps, Some(at), at, &cost);
        let mut engine = Engine::new(sess.net.clone(), init.clone());
        engine.load(&a);
        let map_records = engine.run();
        let gate = slowstart_gate(&map_records, 1.0).max(at);
        let hint = shuffle_majority_node(&map_records, &maps, n_hosts);
        for r in &mut reduces {
            r.src_hint = Some(hint);
        }
        let mut all = map_records;
        if !reduces.is_empty() {
            let mut reduce_init = init;
            for r in &all {
                if reduce_init[r.node.0] < r.finish {
                    reduce_init[r.node.0] = r.finish;
                }
            }
            let mut l2 = vec![Secs::INF; n_hosts];
            for &nd in &sess.nodes {
                l2[nd.0] = reduce_init[nd.0];
            }
            sess.ledger = Ledger::with_initial(l2);
            let a2 = sess.schedule(&reduces, Some(gate), gate, &cost);
            let mut e2 = Engine::new(sess.net.clone(), reduce_init);
            e2.load(&a2);
            all.extend(e2.run());
        }
        for r in &all {
            if node_free[r.node.0] < r.finish {
                node_free[r.node.0] = r.finish;
            }
        }
        out.push(all);
    }
    out
}

/// The same jobs through the online stream, split back per job with the
/// stream-global id offsets removed.
fn stream_chain(case: &ExplicitPinCase, kind: SchedulerKind) -> Vec<Vec<TaskRecord>> {
    let cost = CostModel::rust_only();
    let mut sess = SimSession::new(&pin_cluster_spec(case, kind));
    let jobs = build_explicit_jobs(&mut sess, case);
    let mut base = Vec::with_capacity(jobs.len());
    let mut acc = 0usize;
    for (_, tasks) in &jobs {
        base.push(acc);
        acc += tasks.len();
    }
    let subs: Vec<Submission> = jobs
        .iter()
        .enumerate()
        .map(|(i, (at, tasks))| Submission {
            at_secs: *at,
            body: SubmissionBody::Explicit {
                name: format!("pin-{i}"),
                tasks: tasks.clone(),
                slowstart: 1.0,
            },
            tenant: None,
        })
        .collect();
    let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
    let mut per: Vec<Vec<TaskRecord>> = vec![Vec::new(); jobs.len()];
    for (job, r) in &out.records {
        let mut r = r.clone();
        r.task = TaskId(r.task.0 - base[job.0]);
        per[job.0].push(r);
    }
    per
}

fn records_equal(want: &[TaskRecord], got: &[TaskRecord]) -> Result<(), String> {
    if want.len() != got.len() {
        return Err(format!("{} records vs {}", want.len(), got.len()));
    }
    for (w, g) in want.iter().zip(got) {
        if w.task != g.task
            || w.node != g.node
            || w.picked_at != g.picked_at
            || w.input_ready != g.input_ready
            || w.compute_start != g.compute_start
            || w.finish != g.finish
            || w.is_local != g.is_local
            || w.is_map != g.is_map
        {
            return Err(format!("record diverged:\n  want {w:?}\n  got  {g:?}"));
        }
    }
    Ok(())
}

/// 1-job and sparse N-job streams are bit-identical to the static
/// sequential path, for HDS, BAR and BASS, at full record granularity.
#[test]
fn prop_sparse_stream_matches_static_path_all_schedulers() {
    let iters = match std::env::var("BASS_BENCH_QUICK") {
        Ok(_) => 4,
        Err(_) => 14,
    };
    forall(0x051_1EA4, iters, gen_explicit_pin_case, |case| {
        for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
            let want = static_chain(case, kind);
            let got = stream_chain(case, kind);
            if want.len() != got.len() {
                return Err(format!("{}: job counts differ", kind.label()));
            }
            for (j, (w, g)) in want.iter().zip(&got).enumerate() {
                records_equal(w, g)
                    .map_err(|e| format!("{} job {j}: {e}", kind.label()))?;
            }
        }
        Ok(())
    });
}

#[derive(Debug)]
struct CoordPinCase {
    cluster_seed: u64,
    jobs: Vec<(bool, f64)>,
}

fn gen_coord_pin_case(r: &mut XorShift) -> CoordPinCase {
    let n = 1 + r.below(3);
    CoordPinCase {
        cluster_seed: 1 + r.next_u64() % 100_000,
        jobs: (0..n).map(|_| (r.chance(0.5), [150.0, 300.0][r.below(2)])).collect(),
    }
}

/// The real coordinator path: sparse generated Wordcount/Sort traces
/// through `run_trace` (online) match `handle` (static) bit-for-bit for
/// BASS — reserved transfers never touch the shared flow network, so
/// the equivalence holds at the default slowstart too.
#[test]
fn prop_sparse_coordinator_stream_matches_handle_bass() {
    let iters = match std::env::var("BASS_BENCH_QUICK") {
        Ok(_) => 3,
        Err(_) => 10,
    };
    forall(0xC00D, iters, gen_coord_pin_case, |case| {
        let setup = ClusterSetup { seed: case.cluster_seed, ..ClusterSetup::default() };
        let arrivals: Vec<JobArrival> = case
            .jobs
            .iter()
            .enumerate()
            .map(|(i, &(sort, mb))| JobArrival {
                at_secs: 5.0 + i as f64 * 50_000.0,
                kind: if sort { JobKind::Sort } else { JobKind::Wordcount },
                data_mb: mb,
            })
            .collect();
        // static reference: the existing sequential handle path
        let mut coord =
            Coordinator::new(setup.clone(), SchedulerKind::Bass, CostModel::rust_only());
        let want: Vec<_> = arrivals
            .iter()
            .enumerate()
            .map(|(id, a)| coord.handle_with_records(&JobRequest { arrival: a.clone(), id }))
            .collect();
        // online stream over the identical trace
        let out = Coordinator::new(setup, SchedulerKind::Bass, CostModel::rust_only())
            .run_stream(arrivals)
            .map_err(|e| e.to_string())?;
        if out.jobs.len() != want.len() {
            return Err("job counts differ".into());
        }
        let mut bases = Vec::with_capacity(want.len());
        let mut acc = 0usize;
        for (_, recs) in &want {
            bases.push(acc);
            acc += recs.len();
        }
        for (j, ((want_res, want_recs), got)) in want.iter().zip(&out.jobs).enumerate() {
            if want_res.metrics != got.metrics {
                return Err(format!(
                    "job {j}: metrics diverged {:?} vs {:?}",
                    want_res.metrics, got.metrics
                ));
            }
            if want_res.submitted_at != got.submitted_at {
                return Err(format!("job {j}: submit times diverged"));
            }
            let got_recs: Vec<TaskRecord> = out
                .records
                .iter()
                .filter(|(job, _)| job.0 == j)
                .map(|(_, r)| {
                    let mut r = r.clone();
                    r.task = TaskId(r.task.0 - bases[j]);
                    r
                })
                .collect();
            records_equal(want_recs, &got_recs).map_err(|e| format!("job {j}: {e}"))?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Sharded scheduler state, batched cost kernel, two-tier path cache
// ---------------------------------------------------------------------

/// A sharding scenario: a [`SchedCase`] plus the topology family — the
/// rack-per-switch tree or a multipath fat tree (where the controller's
/// two-tier path cache engages).
#[derive(Debug)]
struct ShardCase {
    sched: SchedCase,
    fat: bool,
    cores: usize,
}

fn gen_shard_case(r: &mut XorShift) -> ShardCase {
    ShardCase { sched: gen_sched_case(r), fat: r.chance(0.5), cores: 1 + r.below(3) }
}

fn build_shard_cluster(case: &ShardCase) -> (Controller, Namenode, Vec<NodeId>, Vec<TaskSpec>) {
    let s = &case.sched.scenario;
    let (topo, nodes) = if case.fat {
        fat_tree(1 + s.n_switches, s.per_switch, case.cores, 100.0, 1000.0)
    } else {
        tree_cluster(s.n_switches, s.per_switch, 100.0, 100.0)
    };
    let ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(s.seed);
    let blocks = PlacementPolicy::RandomDistinct.place(
        &mut nn,
        &nodes,
        &[],
        s.m_tasks,
        BLOCK_MB,
        s.replication,
        &mut rng,
    );
    let tasks = blocks
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(5.0 + (i % 7) as f64), 8.0))
        .collect();
    (ctrl, nn, nodes, tasks)
}

/// Stretch a [`SchedCase`] speed table (sized for the tree cluster) to
/// `n` nodes by cycling; empty stays empty (homogeneous).
fn cycle_speeds(speeds: &[f64], n: usize) -> Vec<f64> {
    if speeds.is_empty() {
        Vec::new()
    } else {
        (0..n).map(|i| speeds[i % speeds.len()]).collect()
    }
}

/// The tentpole pin: sharding the scheduler's mutable state (per-rack
/// idle heaps, shard-grouped candidate scans) is invisible at the
/// decision level. HDS, BAR and BASS must produce bitwise-identical
/// assignments, reservations and ledgers under the flat single-shard
/// plan, the default per-rack plan and a folded two-shard plan.
#[test]
fn prop_sharded_state_matches_flat_all_schedulers() {
    forall(0x5A4D, 60, gen_shard_case, |case| {
        for which in ["hds", "bar", "bass"] {
            let run = |plan: usize| -> (Assignment, Ledger) {
                let (mut ctrl, nn, nodes, tasks) = build_shard_cluster(case);
                match plan {
                    0 => ctrl.set_shard_plan(ShardPlan::single(nodes.len())),
                    1 => {} // the default per-rack plan
                    _ => ctrl.set_max_shards(2),
                }
                let model = CostModel::rust_only();
                let mut ledger = Ledger::new(nodes.len());
                let mut ctx = SchedCtx {
                    view: &bass::sdn::Oracle,
                    controller: &mut ctrl,
                    namenode: &nn,
                    ledger: &mut ledger,
                    authorized: nodes.clone(),
                    now: Secs::ZERO,
                    cost: &model,
                    node_speed: cycle_speeds(&case.sched.speeds, nodes.len()),
                    down: Vec::new(),
                    bw_aware_sources: true,
                };
                let gate = case.sched.gate.map(Secs);
                let a = match which {
                    "hds" => Hds::new().schedule(&tasks, gate, &mut ctx),
                    "bar" => Bar::new().schedule(&tasks, gate, &mut ctx),
                    _ => Bass::new().schedule(&tasks, gate, &mut ctx),
                };
                (a, ledger)
            };
            let (want, ledger_want) = run(0);
            for plan in [1usize, 2] {
                let (got, ledger_got) = run(plan);
                assignments_equal(&want, &got)
                    .map_err(|e| format!("{which}, plan {plan}: {e}"))?;
                if ledger_want != ledger_got {
                    return Err(format!("{which}, plan {plan}: ledger diverged"));
                }
            }
        }
        Ok(())
    });
}

/// The blocked batch kernel (one flat row-major fill, per-holder
/// bandwidth rows shared across tasks of a block) reproduces the exact
/// bytes of the seed's per-task row loop, and the row-chunked evaluator
/// concatenates to the monolithic outputs bitwise — across random tree
/// and fat-tree clusters, down replicas and both source-selection modes.
#[test]
fn prop_batched_cost_kernel_matches_rowwise() {
    forall(0xBA7C, 80, gen_shard_case, |case| {
        let (mut ctrl, nn, nodes, tasks) = build_shard_cluster(case);
        let mut rng = XorShift::new(case.sched.scenario.seed ^ 0x00C0_FFEE);
        let down: Vec<bool> = nodes.iter().map(|_| rng.chance(0.15)).collect();
        let model = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        for (i, &nd) in nodes.iter().enumerate() {
            ledger.occupy_until(nd, Secs((i % 5) as f64 * 3.0));
        }
        let ctx = SchedCtx {
            view: &bass::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs(2.0),
            cost: &model,
            node_speed: cycle_speeds(&case.sched.speeds, nodes.len()),
            down,
            bw_aware_sources: rng.chance(0.5),
        };
        let batched = cost::build_inputs(&tasks, &ctx);
        let rowwise = cost::build_inputs_rowwise(&tasks, &ctx);
        if batched.m != rowwise.m
            || batched.n != rowwise.n
            || batched.sz != rowwise.sz
            || batched.bw != rowwise.bw
            || batched.tp != rowwise.tp
            || batched.local != rowwise.local
            || batched.idle != rowwise.idle
            || batched.ts != rowwise.ts
        {
            return Err("batched inputs diverged from the rowwise reference".into());
        }
        let mono = cost::eval_batch(&tasks, &ctx);
        let rows = 1 + (case.sched.scenario.seed as usize) % tasks.len();
        let chunked = cost::eval_batch_chunked(&tasks, &ctx, rows);
        if mono.yc != chunked.yc
            || mono.tm != chunked.tm
            || mono.slots != chunked.slots
            || mono.best_idx != chunked.best_idx
            || mono.best_cost != chunked.best_cost
        {
            return Err(format!("chunked eval ({rows} rows/chunk) diverged"));
        }
        Ok(())
    });
}

/// A random two-tier fabric shape plus a capacity-skew seed.
#[derive(Debug)]
struct FatShape {
    edges: usize,
    per_edge: usize,
    cores: usize,
    seed: u64,
}

fn gen_fat_shape(r: &mut XorShift) -> FatShape {
    FatShape {
        edges: 2 + r.below(4),
        per_edge: 1 + r.below(4),
        cores: 1 + r.below(4),
        seed: r.next_u64(),
    }
}

/// The two-tier fat-tree path cache answers every host pair with the
/// exact link sequence of the flat per-source BFS table — across random
/// fat shapes with asymmetric link capacities (routing is hop-count
/// based, so capacity skew must not move routes in either
/// representation).
#[test]
fn prop_two_tier_pathcache_matches_flat_table() {
    forall(0x0FA7, 60, gen_fat_shape, |f| {
        let (mut topo, hosts) = fat_tree(f.edges, f.per_edge, f.cores, 100.0, 1000.0);
        let mut rng = XorShift::new(f.seed);
        for l in &mut topo.links {
            l.capacity_mbps = [50.0, 100.0, 400.0, 10_000.0][rng.below(4)];
        }
        let hier = PathCache::build(&topo);
        if !hier.is_hierarchical() {
            return Err(format!("{f:?}: two-tier cache did not engage"));
        }
        let flat = PathCache::build_flat(&topo);
        for &s in &hosts {
            for &d in &hosts {
                match (hier.path(s, d), flat.path(s, d)) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        if a[..] != b[..] {
                            return Err(format!(
                                "{s:?}->{d:?}: two-tier {:?} vs flat {:?}",
                                &a[..],
                                &b[..]
                            ));
                        }
                    }
                    (a, b) => {
                        return Err(format!(
                            "{s:?}->{d:?}: presence diverged ({} vs {})",
                            a.is_some(),
                            b.is_some()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The `BandwidthView` seam must be invisible when the information is
/// perfect: a zero-noise telemetry snapshot probed at `now` on the same
/// controller state yields bit-identical schedules to the clairvoyant
/// `Oracle` view for all three schedulers — even on a degraded cluster
/// (random link health + background traffic), where estimates actually
/// matter. Any drift here means `Measured` re-derives free bandwidth
/// with different arithmetic than `Controller::link_free_over`.
#[test]
fn prop_fresh_exact_measured_view_matches_oracle_bitwise() {
    use bass::sdn::{Measured, Oracle, Telemetry, TelemetrySpec};
    forall(0x73E, 40, gen_scenario, |s| {
        // Deterministic environment perturbation, applied identically to
        // both controllers so the only difference is the view.
        let perturb = |ctrl: &mut Controller, seed: u64| {
            let mut rng = XorShift::new(seed ^ 0xB40D);
            for l in 0..ctrl.topo().n_links() {
                if rng.below(3) == 0 {
                    ctrl.set_link_health(LinkId(l), rng.uniform(0.3, 1.0));
                }
                if rng.below(4) == 0 {
                    ctrl.set_background_mb_s(LinkId(l), rng.uniform(0.0, 3.0));
                }
            }
        };
        let kinds: [&str; 3] = ["hds", "bar", "bass"];
        for kind in kinds {
            let mk = || -> Box<dyn Scheduler> {
                match kind {
                    "hds" => Box::new(Hds::new()),
                    "bar" => Box::new(Bar::new()),
                    _ => Box::new(Bass::new()),
                }
            };

            // Clairvoyant run.
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            perturb(&mut ctrl, s.seed);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                view: &Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            let mut s1 = mk();
            let oracle = s1.schedule(&tasks, None, &mut ctx);

            // Measured run: fresh build, same perturbation, one exact
            // probe of every link at `now` (noise 0, alpha 1 adopts the
            // sample verbatim).
            let (mut ctrl2, nn2, nodes2, tasks2, _) = build(s);
            perturb(&mut ctrl2, s.seed);
            let mut tm = Telemetry::new(
                TelemetrySpec {
                    probe_period: 0.0,
                    noise: 0.0,
                    alpha: 1.0,
                    ..TelemetrySpec::measured()
                },
                ctrl2.topo().n_links(),
            );
            tm.advance(&ctrl2, Secs::ZERO);
            let measured_view = Measured::at(&tm, Secs::ZERO);
            let mut ledger2 = Ledger::new(nodes2.len());
            let mut ctx2 = SchedCtx {
                view: &measured_view,
                controller: &mut ctrl2,
                namenode: &nn2,
                ledger: &mut ledger2,
                authorized: nodes2.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            let mut s2 = mk();
            let measured = s2.schedule(&tasks2, None, &mut ctx2);

            // f64's Debug repr is round-trip exact, so string equality
            // here is bit equality of every window, rate and gate.
            let a = format!("{:?}", oracle.placements);
            let b = format!("{:?}", measured.placements);
            if a != b {
                return Err(format!(
                    "{kind}: measured schedule diverged from oracle\n oracle: {a}\n measured: {b}"
                ));
            }
        }
        Ok(())
    });
}
