//! Property-based tests over coordinator/substrate invariants, driven by
//! the deterministic `bass::testkit` runner (proptest substitute — see
//! DESIGN.md toolchain notes).

use bass::cluster::Ledger;
use bass::hdfs::{Namenode, PlacementPolicy};
use bass::mapreduce::TaskSpec;
use bass::runtime::{CostInputs, CostModel};
use bass::sched::{Bar, Bass, Hds, SchedCtx, Scheduler};
use bass::sdn::{Controller, Reservation, SlotCalendar};
use bass::sim::{Engine, FlowNet, TransferPlan};
use bass::testkit::forall;
use bass::topology::builders::tree_cluster;
use bass::topology::{LinkId, NodeId};
use bass::util::{Secs, XorShift, BLOCK_MB};

/// A random scheduling scenario over a random tree cluster.
#[derive(Debug)]
struct Scenario {
    n_switches: usize,
    per_switch: usize,
    m_tasks: usize,
    replication: usize,
    seed: u64,
}

fn gen_scenario(r: &mut XorShift) -> Scenario {
    let n_switches = 1 + r.below(3);
    let per_switch = 2 + r.below(3);
    Scenario {
        n_switches,
        per_switch,
        m_tasks: 1 + r.below(24),
        replication: 1 + r.below((n_switches * per_switch).min(3)),
        seed: r.next_u64(),
    }
}

fn build(s: &Scenario) -> (Controller, Namenode, Vec<NodeId>, Vec<TaskSpec>, Vec<f64>) {
    let (topo, nodes) = tree_cluster(s.n_switches, s.per_switch, 100.0, 100.0);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
    let ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(s.seed);
    let blocks =
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes, s.m_tasks, BLOCK_MB, s.replication, &mut rng);
    let tasks = blocks
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(5.0 + (i % 7) as f64), 8.0))
        .collect();
    (ctrl, nn, nodes, tasks, caps)
}

/// Every scheduler must place every task exactly once, on an authorized
/// node, and local placements must actually be replica holders.
#[test]
fn prop_schedulers_place_each_task_once_and_validly() {
    forall(0xA11, 60, gen_scenario, |s| {
        let schedulers: Vec<Box<dyn Scheduler>> =
            vec![Box::new(Hds::new()), Box::new(Bar::new()), Box::new(Bass::new())];
        for mut sched in schedulers {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
            node_speed: Vec::new(),
            };
            let a = sched.schedule(&tasks, None, &mut ctx);
            if a.placements.len() != tasks.len() {
                return Err(format!("{}: {} placements for {} tasks", sched.name(), a.placements.len(), tasks.len()));
            }
            let mut seen = vec![false; tasks.len()];
            for p in &a.placements {
                if seen[p.task.0] {
                    return Err(format!("{}: task {} placed twice", sched.name(), p.task.0));
                }
                seen[p.task.0] = true;
                if !nodes.contains(&p.node) {
                    return Err(format!("{}: unauthorized node {:?}", sched.name(), p.node));
                }
                if p.is_local {
                    let b = tasks[p.task.0].input.unwrap();
                    if !nn.is_local(b, p.node) {
                        return Err(format!("{}: fake locality for task {}", sched.name(), p.task.0));
                    }
                }
            }
        }
        Ok(())
    });
}

/// BASS's ledger estimate must equal DES execution exactly (reservations
/// make its world deterministic), and execution must finish all tasks.
#[test]
fn prop_bass_estimate_matches_execution() {
    forall(0xB0B, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, caps) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let a = {
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
            node_speed: Vec::new(),
            };
            Bass::new().schedule(&tasks, None, &mut ctx)
        };
        let est = nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max);
        let mut engine = Engine::new(FlowNet::new(&caps), vec![Secs::ZERO; nodes.len()]);
        engine.load(&a);
        let records = engine.run();
        if records.len() != tasks.len() {
            return Err(format!("{} records for {} tasks", records.len(), tasks.len()));
        }
        let exe = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        if (est - exe).abs() > 1e-6 {
            return Err(format!("estimate {est} != execution {exe}"));
        }
        Ok(())
    });
}

/// The slot calendar never oversubscribes: after any random sequence of
/// successful reservations, every (link, slot) stays within capacity;
/// releases restore exactly.
#[test]
fn prop_calendar_never_oversubscribes() {
    #[derive(Debug)]
    struct Ops {
        n_links: usize,
        ops: Vec<(usize, usize, usize, f64)>, // link, start, len, frac
    }
    forall(
        0xCA1,
        120,
        |r| {
            let n_links = 1 + r.below(6);
            let ops = (0..24)
                .map(|_| (r.below(n_links), r.below(40), 1 + r.below(10), r.uniform(0.05, 1.0)))
                .collect();
            Ops { n_links, ops }
        },
        |case| {
            let mut cal = SlotCalendar::new(case.n_links, 1.0);
            let mut grants = Vec::new();
            for &(l, start, len, frac) in &case.ops {
                if let Ok(res) = cal.reserve_path(&[LinkId(l)], start, len, frac) {
                    grants.push(res);
                }
                for link in 0..case.n_links {
                    for slot in 0..60 {
                        let r = cal.reserved_frac(LinkId(link), slot);
                        if r > 1.0 + 1e-9 {
                            return Err(format!("link {link} slot {slot} oversubscribed: {r}"));
                        }
                    }
                }
            }
            for g in &grants {
                cal.release(g);
            }
            for link in 0..case.n_links {
                for slot in 0..60 {
                    let r = cal.reserved_frac(LinkId(link), slot);
                    if r > 1e-9 {
                        return Err(format!("leak on link {link} slot {slot}: {r}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Max-min rates: per-link sums never exceed capacity, every flow gets a
/// positive rate, and rates are deterministic.
#[test]
fn prop_flownet_rates_feasible() {
    #[derive(Debug)]
    struct Net {
        n_links: usize,
        flows: Vec<Vec<usize>>,
    }
    forall(
        0xF10,
        100,
        |r| {
            let n_links = 1 + r.below(8);
            let flows = (0..1 + r.below(20))
                .map(|_| {
                    let len = 1 + r.below(3.min(n_links));
                    r.distinct(n_links, len)
                })
                .collect();
            Net { n_links, flows }
        },
        |case| {
            let caps: Vec<f64> = (0..case.n_links).map(|_| 80.0).collect();
            let mut net = FlowNet::new(&caps);
            let ids: Vec<_> = case
                .flows
                .iter()
                .map(|p| {
                    net.add_flow(
                        p.iter().map(|&l| LinkId(l)).collect(),
                        100.0,
                        bass::sdn::TrafficClass::HadoopOther,
                    )
                })
                .collect();
            let mut per_link = vec![0.0f64; case.n_links];
            for (i, id) in ids.iter().enumerate() {
                let rate = net.rate_of(*id).ok_or("missing flow")?;
                if rate <= 0.0 {
                    return Err(format!("flow {i} starved: {rate}"));
                }
                for &l in &case.flows[i] {
                    per_link[l] += rate;
                }
            }
            for (l, &sum) in per_link.iter().enumerate() {
                if sum > 10.0 + 1e-6 {
                    return Err(format!("link {l} oversubscribed: {sum} MB/s of 10"));
                }
            }
            Ok(())
        },
    );
}

/// XLA artifact output == Rust mirror, bit for bit, on random batches.
#[test]
fn prop_xla_matches_rust_mirror() {
    let model = CostModel::auto();
    if model.backend_for(16, 8) != bass::runtime::exec::Backend::Xla {
        eprintln!("skipping: artifacts not built");
        return;
    }
    forall(
        0x71A,
        30,
        |r| {
            let m = 1 + r.below(16);
            let n = 1 + r.below(8);
            fn mk(r: &mut XorShift, k: usize, lo: f64, hi: f64) -> Vec<f32> {
                (0..k).map(|_| r.uniform(lo, hi) as f32).collect()
            }
            let sz = mk(r, m, 0.0, 5000.0);
            let bw = mk(r, m * n, -5.0, 120.0);
            let tp = mk(r, m * n, 0.0, 900.0);
            let local = (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect();
            let idle = mk(r, n, 0.0, 200.0);
            CostInputs { m, n, sz, bw, tp, local, idle, ts: 1.0 }
        },
        |inp| {
            let x = model.eval(inp).map_err(|e| e.to_string())?;
            let y = CostModel::eval_rust(inp);
            if x.yc != y.yc || x.tm != y.tm || x.slots != y.slots
                || x.best_idx != y.best_idx || x.best_cost != y.best_cost
            {
                return Err("backend divergence".into());
            }
            Ok(())
        },
    );
}

/// Engine conservation: records == placements, finishes are monotone per
/// node, and no record finishes before its compute start.
#[test]
fn prop_engine_records_consistent() {
    forall(0xE46, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, caps) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let a = {
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
            node_speed: Vec::new(),
            };
            Hds::new().schedule(&tasks, None, &mut ctx)
        };
        let remote = a
            .placements
            .iter()
            .filter(|p| matches!(p.transfer, TransferPlan::FairShare { .. }))
            .count();
        let mut engine = Engine::new(FlowNet::new(&caps), vec![Secs::ZERO; nodes.len()]);
        engine.load(&a);
        let records = engine.run();
        if records.len() != tasks.len() {
            return Err(format!("{} records for {} tasks (remote={remote})", records.len(), tasks.len()));
        }
        let mut per_node: Vec<Vec<f64>> = vec![Vec::new(); nodes.len()];
        for r in &records {
            if r.finish < r.compute_start || r.compute_start < r.picked_at {
                return Err(format!("time travel in record {:?}", r));
            }
            per_node[r.node.0].push(r.finish.0);
        }
        Ok(())
    });
}

/// Pre-BASS invariant: prefetch never makes any transfer arrive later
/// than BASS's on-demand plan for the same (task, node) placement.
#[test]
fn prop_prefetch_never_later() {
    use bass::sched::PreBass;
    forall(0x9F3, 40, gen_scenario, |s| {
        let run = |pre: bool| -> Vec<(usize, f64)> {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
            };
            let a = if pre {
                PreBass::new().schedule(&tasks, None, &mut ctx)
            } else {
                Bass::new().schedule(&tasks, None, &mut ctx)
            };
            a.placements
                .iter()
                .filter_map(|p| match &p.transfer {
                    TransferPlan::Reserved(t) => Some((p.task.0, t.arrival.0)),
                    TransferPlan::Prefetched(t) => Some((p.task.0, t.arrival.0)),
                    _ => None,
                })
                .collect()
        };
        let bass = run(false);
        let pre = run(true);
        for (task, arr_pre) in &pre {
            if let Some((_, arr_bass)) = bass.iter().find(|(t, _)| t == task) {
                if *arr_pre > arr_bass + 1e-9 {
                    return Err(format!(
                        "task {task}: prefetch arrival {arr_pre} later than on-demand {arr_bass}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Controller reserve/complete cycles never leak calendar capacity.
#[test]
fn prop_controller_transfer_lifecycle_leak_free() {
    use bass::sdn::TrafficClass;
    forall(0x1EA, 60, gen_scenario, |s| {
        let (mut ctrl, _nn, nodes, _tasks, _) = build(s);
        let mut rng = XorShift::new(s.seed ^ 0xDEAD);
        let mut live = Vec::new();
        for i in 0..20 {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            if a == b {
                continue;
            }
            if let Some(plan) = ctrl.plan_transfer(a, b, 32.0, Secs(i as f64)) {
                let t = ctrl
                    .commit_transfer(a, b, TrafficClass::HadoopOther, plan, Secs(i as f64))
                    .map_err(|e| e.to_string())?;
                live.push(t);
            }
            // randomly complete some
            if !live.is_empty() && rng.chance(0.5) {
                let t = live.swap_remove(rng.below(live.len()));
                ctrl.complete_transfer(&t, 32.0);
            }
        }
        for t in live.drain(..) {
            ctrl.complete_transfer(&t, 32.0);
        }
        // all slots must be fully free again
        for l in 0..ctrl.topo().n_links() {
            for slot in 0..200 {
                let r = ctrl.calendar.reserved_frac(bass::topology::LinkId(l), slot);
                if r > 1e-9 {
                    return Err(format!("leak: link {l} slot {slot} frac {r}"));
                }
            }
        }
        if !ctrl.flows.is_empty() {
            return Err(format!("{} flow entries leaked", ctrl.flows.len()));
        }
        Ok(())
    });
}

/// Reference implementation for the calendar-equivalence property: the
/// seed's dense per-slot `Vec<f64>` calendar, ported verbatim (including
/// its `MAX_SEARCH_SLOTS` cap, which the scenarios below never hit).
/// The sparse interval calendar must be observationally equivalent.
mod dense_reference {
    use bass::sdn::Reservation;
    use bass::topology::LinkId;
    use bass::util::Secs;

    const MAX_SEARCH_SLOTS: usize = 4_000_000;

    pub struct DenseCalendar {
        slot_secs: f64,
        reserved: Vec<Vec<f64>>,
    }

    impl DenseCalendar {
        pub fn new(n_links: usize, slot_secs: f64) -> Self {
            Self { slot_secs, reserved: vec![Vec::new(); n_links] }
        }

        pub fn slot_of(&self, t: Secs) -> usize {
            (t.0 / self.slot_secs).floor() as usize
        }

        pub fn slots_for(&self, size_mb: f64, rate_mb_s: f64) -> usize {
            ((size_mb / rate_mb_s) / self.slot_secs).ceil().max(0.0) as usize
        }

        pub fn reserved_frac(&self, link: LinkId, slot: usize) -> f64 {
            self.reserved[link.0].get(slot).copied().unwrap_or(0.0)
        }

        pub fn residual_frac(&self, link: LinkId, slot: usize) -> f64 {
            (1.0 - self.reserved_frac(link, slot)).max(0.0)
        }

        pub fn path_residual(&self, links: &[LinkId], start: usize, n: usize) -> f64 {
            let mut min = 1.0f64;
            for &l in links {
                for s in start..start + n {
                    min = min.min(self.residual_frac(l, s));
                    if min <= 0.0 {
                        return 0.0;
                    }
                }
            }
            min
        }

        fn ensure_len(&mut self, link: LinkId, upto: usize) {
            let v = &mut self.reserved[link.0];
            if v.len() < upto {
                v.resize(upto, 0.0);
            }
        }

        pub fn reserve_path(
            &mut self,
            links: &[LinkId],
            start: usize,
            n: usize,
            frac: f64,
        ) -> Result<Reservation, String> {
            if !(frac > 0.0 && frac <= 1.0) || n == 0 {
                return Err("invalid".into());
            }
            const EPS: f64 = 1e-9;
            if self.path_residual(links, start, n) + EPS < frac {
                return Err("insufficient".into());
            }
            for &l in links {
                self.ensure_len(l, start + n);
                for s in start..start + n {
                    self.reserved[l.0][s] = (self.reserved[l.0][s] + frac).min(1.0);
                }
            }
            Ok(Reservation { links: links.to_vec(), start_slot: start, n_slots: n, frac })
        }

        pub fn release(&mut self, r: &Reservation) {
            for &l in &r.links {
                for s in r.start_slot..r.start_slot + r.n_slots {
                    if let Some(x) = self.reserved[l.0].get_mut(s) {
                        *x = (*x - r.frac).max(0.0);
                    }
                }
            }
        }

        pub fn find_window(
            &self,
            links: &[LinkId],
            earliest: usize,
            n: usize,
            frac: f64,
        ) -> Option<usize> {
            const EPS: f64 = 1e-9;
            let mut s = earliest;
            while s < earliest + MAX_SEARCH_SLOTS {
                let mut ok = true;
                'outer: for off in 0..n {
                    for &l in links {
                        if self.residual_frac(l, s + off) + EPS < frac {
                            s = s + off + 1;
                            ok = false;
                            break 'outer;
                        }
                    }
                }
                if ok {
                    return Some(s);
                }
            }
            None
        }

        pub fn plan_transfer(
            &self,
            links: &[LinkId],
            earliest: Secs,
            size_mb: f64,
            capacity_mb_s: f64,
            min_frac: f64,
        ) -> Option<Reservation> {
            if size_mb == 0.0 || links.is_empty() {
                return Some(Reservation {
                    links: links.to_vec(),
                    start_slot: self.slot_of(earliest),
                    n_slots: 0,
                    frac: 0.0,
                });
            }
            let mut start = self.slot_of(earliest);
            for _ in 0..MAX_SEARCH_SLOTS {
                let f0 = links
                    .iter()
                    .map(|&l| self.residual_frac(l, start))
                    .fold(1.0f64, f64::min);
                if f0 < min_frac || f0 <= 0.0 {
                    start += 1;
                    continue;
                }
                let mut frac = f0;
                let mut n = self.slots_for(size_mb, frac * capacity_mb_s);
                loop {
                    let avail = self.path_residual(links, start, n.max(1));
                    if avail + 1e-9 >= frac {
                        return Some(Reservation {
                            links: links.to_vec(),
                            start_slot: start,
                            n_slots: n.max(1),
                            frac,
                        });
                    }
                    if avail < min_frac || avail <= 0.0 {
                        break;
                    }
                    frac = avail;
                    n = self.slots_for(size_mb, frac * capacity_mb_s);
                }
                start += 1;
            }
            None
        }
    }
}

/// One randomized calendar interaction.
#[derive(Debug, Clone)]
enum CalOp {
    Reserve { links: Vec<usize>, start: usize, n: usize, frac: f64 },
    Release { pick: usize },
    FindWindow { links: Vec<usize>, earliest: usize, n: usize, frac: f64 },
    Plan { links: Vec<usize>, earliest: usize, size_mb: f64, min_frac: f64 },
}

#[derive(Debug)]
struct CalCase {
    n_links: usize,
    ops: Vec<CalOp>,
}

fn gen_cal_case(r: &mut XorShift) -> CalCase {
    let n_links = 1 + r.below(5);
    let pick_links = |r: &mut XorShift, n_links: usize| -> Vec<usize> {
        let k = 1 + r.below(3.min(n_links));
        r.distinct(n_links, k)
    };
    let ops = (0..32)
        .map(|_| match r.below(6) {
            0 | 1 | 2 => CalOp::Reserve {
                links: pick_links(r, n_links),
                start: r.below(50),
                n: 1 + r.below(12),
                // mix exact full-rate grabs with fractional ones
                frac: if r.chance(0.25) { 1.0 } else { r.uniform(0.05, 1.0) },
            },
            3 => CalOp::Release { pick: r.below(64) },
            4 => CalOp::FindWindow {
                links: pick_links(r, n_links),
                earliest: r.below(40),
                n: 1 + r.below(10),
                frac: if r.chance(0.25) { 1.0 } else { r.uniform(0.05, 1.0) },
            },
            _ => CalOp::Plan {
                links: pick_links(r, n_links),
                earliest: r.below(40),
                size_mb: r.uniform(1.0, 400.0),
                min_frac: r.uniform(0.01, 0.3),
            },
        })
        .collect();
    CalCase { n_links, ops }
}

/// The sparse interval calendar is observationally equivalent to the
/// seed's dense per-slot implementation: identical `reserve_path` /
/// `release` / `find_window` / `plan_transfer` outcomes and per-slot
/// occupancy matching within dust (the sparse calendar snaps sub-1e-12
/// f64 residue so released segments coalesce away; the decision
/// tolerance is 1e-9, so behavior is unaffected) — and it never
/// oversubscribes a link.
#[test]
fn prop_sparse_calendar_matches_dense_reference() {
    use dense_reference::DenseCalendar;
    const TOL: f64 = 1e-9;
    let res_close = |x: &Reservation, y: &Reservation| -> bool {
        x.links == y.links
            && x.start_slot == y.start_slot
            && x.n_slots == y.n_slots
            && (x.frac - y.frac).abs() <= TOL
    };
    forall(0x5AC, 120, gen_cal_case, |case| {
        let mut sparse = SlotCalendar::new(case.n_links, 1.0);
        let mut dense = DenseCalendar::new(case.n_links, 1.0);
        let mut grants: Vec<Reservation> = Vec::new();
        for (step, op) in case.ops.iter().enumerate() {
            let ids = |v: &[usize]| -> Vec<LinkId> { v.iter().map(|&l| LinkId(l)).collect() };
            match op {
                CalOp::Reserve { links, start, n, frac } => {
                    let links = ids(links);
                    let a = sparse.reserve_path(&links, *start, *n, *frac);
                    let b = dense.reserve_path(&links, *start, *n, *frac);
                    match (a, b) {
                        (Ok(x), Ok(y)) => {
                            if !res_close(&x, &y) {
                                return Err(format!("step {step}: grants differ {x:?} vs {y:?}"));
                            }
                            grants.push(x);
                        }
                        (Err(_), Err(_)) => {}
                        (a, b) => {
                            return Err(format!(
                                "step {step}: outcome mismatch sparse={:?} dense={:?}",
                                a.is_ok(),
                                b.is_ok()
                            ));
                        }
                    }
                }
                CalOp::Release { pick } => {
                    if !grants.is_empty() {
                        let r = grants.swap_remove(pick % grants.len());
                        sparse.release(&r);
                        dense.release(&r);
                    }
                }
                CalOp::FindWindow { links, earliest, n, frac } => {
                    let links = ids(links);
                    let a = sparse.find_window(&links, *earliest, *n, *frac);
                    let b = dense.find_window(&links, *earliest, *n, *frac);
                    if a != b {
                        return Err(format!("step {step}: find_window {a:?} vs {b:?}"));
                    }
                }
                CalOp::Plan { links, earliest, size_mb, min_frac } => {
                    let links = ids(links);
                    let a = sparse.plan_transfer(
                        &links,
                        Secs(*earliest as f64),
                        *size_mb,
                        12.5,
                        *min_frac,
                    );
                    let b = dense.plan_transfer(
                        &links,
                        Secs(*earliest as f64),
                        *size_mb,
                        12.5,
                        *min_frac,
                    );
                    let same = match (&a, &b) {
                        (Some(x), Some(y)) => res_close(x, y),
                        (None, None) => true,
                        _ => false,
                    };
                    if !same {
                        return Err(format!("step {step}: plan {a:?} vs {b:?}"));
                    }
                }
            }
            // occupancy must agree within dust and never oversubscribe
            for l in 0..case.n_links {
                for slot in [0usize, 1, 3, 7, 17, 29, 43, 59, 71, 97, 131] {
                    let s = sparse.reserved_frac(LinkId(l), slot);
                    let d = dense.reserved_frac(LinkId(l), slot);
                    if (s - d).abs() > TOL {
                        return Err(format!(
                            "step {step}: link {l} slot {slot}: sparse {s} != dense {d}"
                        ));
                    }
                    if s > 1.0 + 1e-9 {
                        return Err(format!("step {step}: link {l} slot {slot} oversubscribed {s}"));
                    }
                }
                // window minima agree too (path_residual drives planning)
                let pr_s = sparse.path_residual(&[LinkId(l)], 0, 80);
                let pr_d = dense.path_residual(&[LinkId(l)], 0, 80);
                if (pr_s - pr_d).abs() > TOL {
                    return Err(format!("step {step}: path_residual {pr_s} != {pr_d}"));
                }
            }
        }
        // drain everything: both must come back (dust-)free; the sparse
        // calendar additionally guarantees zero retained segments
        for r in grants.drain(..) {
            sparse.release(&r);
            dense.release(&r);
        }
        for l in 0..case.n_links {
            for slot in 0..80 {
                let s = sparse.reserved_frac(LinkId(l), slot);
                if (s - dense.reserved_frac(LinkId(l), slot)).abs() > TOL {
                    return Err(format!("post-drain mismatch link {l} slot {slot}"));
                }
                if s > 1e-9 {
                    return Err(format!("leak on link {l} slot {slot}: {s}"));
                }
            }
        }
        if sparse.n_segments() != 0 {
            return Err(format!(
                "post-drain segment leak: {} boundaries retained",
                sparse.n_segments()
            ));
        }
        Ok(())
    });
}

/// Heterogeneity invariant: scaling every node's speed by the same
/// factor scales every scheduler's makespan estimate consistently
/// (no hidden homogeneity assumptions).
#[test]
fn prop_uniform_speed_scaling() {
    forall(0x5CA, 30, gen_scenario, |s| {
        let jt_with = |speed: f64| -> f64 {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: vec![speed; nodes.len()],
            };
            Bass::new().schedule(&tasks, None, &mut ctx);
            nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max)
        };
        let base = jt_with(1.0);
        let double = jt_with(2.0);
        // all-compute lower bound: doubling TP at least doesn't shrink JT
        if double + 1e-9 < base {
            return Err(format!("doubling compute time shrank JT: {base} -> {double}"));
        }
        Ok(())
    });
}
