//! Property-based tests over coordinator/substrate invariants, driven by
//! the deterministic `bass::testkit` runner (proptest substitute — see
//! DESIGN.md toolchain notes).

use bass::cluster::Ledger;
use bass::hdfs::{Namenode, PlacementPolicy};
use bass::mapreduce::TaskSpec;
use bass::runtime::{CostInputs, CostModel};
use bass::sched::{Bar, Bass, Hds, SchedCtx, Scheduler};
use bass::sdn::{Controller, SlotCalendar};
use bass::sim::{Engine, FlowNet, TransferPlan};
use bass::testkit::forall;
use bass::topology::builders::tree_cluster;
use bass::topology::{LinkId, NodeId};
use bass::util::{Secs, XorShift, BLOCK_MB};

/// A random scheduling scenario over a random tree cluster.
#[derive(Debug)]
struct Scenario {
    n_switches: usize,
    per_switch: usize,
    m_tasks: usize,
    replication: usize,
    seed: u64,
}

fn gen_scenario(r: &mut XorShift) -> Scenario {
    let n_switches = 1 + r.below(3);
    let per_switch = 2 + r.below(3);
    Scenario {
        n_switches,
        per_switch,
        m_tasks: 1 + r.below(24),
        replication: 1 + r.below((n_switches * per_switch).min(3)),
        seed: r.next_u64(),
    }
}

fn build(s: &Scenario) -> (Controller, Namenode, Vec<NodeId>, Vec<TaskSpec>, Vec<f64>) {
    let (topo, nodes) = tree_cluster(s.n_switches, s.per_switch, 100.0, 100.0);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
    let ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(s.seed);
    let blocks =
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes, s.m_tasks, BLOCK_MB, s.replication, &mut rng);
    let tasks = blocks
        .iter()
        .enumerate()
        .map(|(i, &b)| TaskSpec::map(i, b, BLOCK_MB, Secs(5.0 + (i % 7) as f64), 8.0))
        .collect();
    (ctrl, nn, nodes, tasks, caps)
}

/// Every scheduler must place every task exactly once, on an authorized
/// node, and local placements must actually be replica holders.
#[test]
fn prop_schedulers_place_each_task_once_and_validly() {
    forall(0xA11, 60, gen_scenario, |s| {
        let schedulers: Vec<Box<dyn Scheduler>> =
            vec![Box::new(Hds::new()), Box::new(Bar::new()), Box::new(Bass::new())];
        for mut sched in schedulers {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
            node_speed: Vec::new(),
            };
            let a = sched.schedule(&tasks, None, &mut ctx);
            if a.placements.len() != tasks.len() {
                return Err(format!("{}: {} placements for {} tasks", sched.name(), a.placements.len(), tasks.len()));
            }
            let mut seen = vec![false; tasks.len()];
            for p in &a.placements {
                if seen[p.task.0] {
                    return Err(format!("{}: task {} placed twice", sched.name(), p.task.0));
                }
                seen[p.task.0] = true;
                if !nodes.contains(&p.node) {
                    return Err(format!("{}: unauthorized node {:?}", sched.name(), p.node));
                }
                if p.is_local {
                    let b = tasks[p.task.0].input.unwrap();
                    if !nn.is_local(b, p.node) {
                        return Err(format!("{}: fake locality for task {}", sched.name(), p.task.0));
                    }
                }
            }
        }
        Ok(())
    });
}

/// BASS's ledger estimate must equal DES execution exactly (reservations
/// make its world deterministic), and execution must finish all tasks.
#[test]
fn prop_bass_estimate_matches_execution() {
    forall(0xB0B, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, caps) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let a = {
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
            node_speed: Vec::new(),
            };
            Bass::new().schedule(&tasks, None, &mut ctx)
        };
        let est = nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max);
        let mut engine = Engine::new(FlowNet::new(&caps), vec![Secs::ZERO; nodes.len()]);
        engine.load(&a);
        let records = engine.run();
        if records.len() != tasks.len() {
            return Err(format!("{} records for {} tasks", records.len(), tasks.len()));
        }
        let exe = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        if (est - exe).abs() > 1e-6 {
            return Err(format!("estimate {est} != execution {exe}"));
        }
        Ok(())
    });
}

/// The slot calendar never oversubscribes: after any random sequence of
/// successful reservations, every (link, slot) stays within capacity;
/// releases restore exactly.
#[test]
fn prop_calendar_never_oversubscribes() {
    #[derive(Debug)]
    struct Ops {
        n_links: usize,
        ops: Vec<(usize, usize, usize, f64)>, // link, start, len, frac
    }
    forall(
        0xCA1,
        120,
        |r| {
            let n_links = 1 + r.below(6);
            let ops = (0..24)
                .map(|_| (r.below(n_links), r.below(40), 1 + r.below(10), r.uniform(0.05, 1.0)))
                .collect();
            Ops { n_links, ops }
        },
        |case| {
            let mut cal = SlotCalendar::new(case.n_links, 1.0);
            let mut grants = Vec::new();
            for &(l, start, len, frac) in &case.ops {
                if let Ok(res) = cal.reserve_path(&[LinkId(l)], start, len, frac) {
                    grants.push(res);
                }
                for link in 0..case.n_links {
                    for slot in 0..60 {
                        let r = cal.reserved_frac(LinkId(link), slot);
                        if r > 1.0 + 1e-9 {
                            return Err(format!("link {link} slot {slot} oversubscribed: {r}"));
                        }
                    }
                }
            }
            for g in &grants {
                cal.release(g);
            }
            for link in 0..case.n_links {
                for slot in 0..60 {
                    let r = cal.reserved_frac(LinkId(link), slot);
                    if r > 1e-9 {
                        return Err(format!("leak on link {link} slot {slot}: {r}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Max-min rates: per-link sums never exceed capacity, every flow gets a
/// positive rate, and rates are deterministic.
#[test]
fn prop_flownet_rates_feasible() {
    #[derive(Debug)]
    struct Net {
        n_links: usize,
        flows: Vec<Vec<usize>>,
    }
    forall(
        0xF10,
        100,
        |r| {
            let n_links = 1 + r.below(8);
            let flows = (0..1 + r.below(20))
                .map(|_| {
                    let len = 1 + r.below(3.min(n_links));
                    r.distinct(n_links, len)
                })
                .collect();
            Net { n_links, flows }
        },
        |case| {
            let caps: Vec<f64> = (0..case.n_links).map(|_| 80.0).collect();
            let mut net = FlowNet::new(&caps);
            let ids: Vec<_> = case
                .flows
                .iter()
                .map(|p| {
                    net.add_flow(
                        p.iter().map(|&l| LinkId(l)).collect(),
                        100.0,
                        bass::sdn::TrafficClass::HadoopOther,
                    )
                })
                .collect();
            let mut per_link = vec![0.0f64; case.n_links];
            for (i, id) in ids.iter().enumerate() {
                let rate = net.rate_of(*id).ok_or("missing flow")?;
                if rate <= 0.0 {
                    return Err(format!("flow {i} starved: {rate}"));
                }
                for &l in &case.flows[i] {
                    per_link[l] += rate;
                }
            }
            for (l, &sum) in per_link.iter().enumerate() {
                if sum > 10.0 + 1e-6 {
                    return Err(format!("link {l} oversubscribed: {sum} MB/s of 10"));
                }
            }
            Ok(())
        },
    );
}

/// XLA artifact output == Rust mirror, bit for bit, on random batches.
#[test]
fn prop_xla_matches_rust_mirror() {
    let model = CostModel::auto();
    if model.backend_for(16, 8) != bass::runtime::exec::Backend::Xla {
        eprintln!("skipping: artifacts not built");
        return;
    }
    forall(
        0x71A,
        30,
        |r| {
            let m = 1 + r.below(16);
            let n = 1 + r.below(8);
            fn mk(r: &mut XorShift, k: usize, lo: f64, hi: f64) -> Vec<f32> {
                (0..k).map(|_| r.uniform(lo, hi) as f32).collect()
            }
            let sz = mk(r, m, 0.0, 5000.0);
            let bw = mk(r, m * n, -5.0, 120.0);
            let tp = mk(r, m * n, 0.0, 900.0);
            let local = (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect();
            let idle = mk(r, n, 0.0, 200.0);
            CostInputs { m, n, sz, bw, tp, local, idle, ts: 1.0 }
        },
        |inp| {
            let x = model.eval(inp).map_err(|e| e.to_string())?;
            let y = CostModel::eval_rust(inp);
            if x.yc != y.yc || x.tm != y.tm || x.slots != y.slots
                || x.best_idx != y.best_idx || x.best_cost != y.best_cost
            {
                return Err("backend divergence".into());
            }
            Ok(())
        },
    );
}

/// Engine conservation: records == placements, finishes are monotone per
/// node, and no record finishes before its compute start.
#[test]
fn prop_engine_records_consistent() {
    forall(0xE46, 60, gen_scenario, |s| {
        let (mut ctrl, nn, nodes, tasks, caps) = build(s);
        let cost = CostModel::rust_only();
        let mut ledger = Ledger::new(nodes.len());
        let a = {
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
            node_speed: Vec::new(),
            };
            Hds::new().schedule(&tasks, None, &mut ctx)
        };
        let remote = a
            .placements
            .iter()
            .filter(|p| matches!(p.transfer, TransferPlan::FairShare { .. }))
            .count();
        let mut engine = Engine::new(FlowNet::new(&caps), vec![Secs::ZERO; nodes.len()]);
        engine.load(&a);
        let records = engine.run();
        if records.len() != tasks.len() {
            return Err(format!("{} records for {} tasks (remote={remote})", records.len(), tasks.len()));
        }
        let mut per_node: Vec<Vec<f64>> = vec![Vec::new(); nodes.len()];
        for r in &records {
            if r.finish < r.compute_start || r.compute_start < r.picked_at {
                return Err(format!("time travel in record {:?}", r));
            }
            per_node[r.node.0].push(r.finish.0);
        }
        Ok(())
    });
}

/// Pre-BASS invariant: prefetch never makes any transfer arrive later
/// than BASS's on-demand plan for the same (task, node) placement.
#[test]
fn prop_prefetch_never_later() {
    use bass::sched::PreBass;
    forall(0x9F3, 40, gen_scenario, |s| {
        let run = |pre: bool| -> Vec<(usize, f64)> {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
            };
            let a = if pre {
                PreBass::new().schedule(&tasks, None, &mut ctx)
            } else {
                Bass::new().schedule(&tasks, None, &mut ctx)
            };
            a.placements
                .iter()
                .filter_map(|p| match &p.transfer {
                    TransferPlan::Reserved(t) => Some((p.task.0, t.arrival.0)),
                    TransferPlan::Prefetched(t) => Some((p.task.0, t.arrival.0)),
                    _ => None,
                })
                .collect()
        };
        let bass = run(false);
        let pre = run(true);
        for (task, arr_pre) in &pre {
            if let Some((_, arr_bass)) = bass.iter().find(|(t, _)| t == task) {
                if *arr_pre > arr_bass + 1e-9 {
                    return Err(format!(
                        "task {task}: prefetch arrival {arr_pre} later than on-demand {arr_bass}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Controller reserve/complete cycles never leak calendar capacity.
#[test]
fn prop_controller_transfer_lifecycle_leak_free() {
    use bass::sdn::TrafficClass;
    forall(0x1EA, 60, gen_scenario, |s| {
        let (mut ctrl, _nn, nodes, _tasks, _) = build(s);
        let mut rng = XorShift::new(s.seed ^ 0xDEAD);
        let mut live = Vec::new();
        for i in 0..20 {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            if a == b {
                continue;
            }
            if let Some(plan) = ctrl.plan_transfer(a, b, 32.0, Secs(i as f64)) {
                let t = ctrl
                    .commit_transfer(a, b, TrafficClass::HadoopOther, plan, Secs(i as f64))
                    .map_err(|e| e.to_string())?;
                live.push(t);
            }
            // randomly complete some
            if !live.is_empty() && rng.chance(0.5) {
                let t = live.swap_remove(rng.below(live.len()));
                ctrl.complete_transfer(&t, 32.0);
            }
        }
        for t in live.drain(..) {
            ctrl.complete_transfer(&t, 32.0);
        }
        // all slots must be fully free again
        for l in 0..ctrl.topo().n_links() {
            for slot in 0..200 {
                let r = ctrl.calendar.reserved_frac(bass::topology::LinkId(l), slot);
                if r > 1e-9 {
                    return Err(format!("leak: link {l} slot {slot} frac {r}"));
                }
            }
        }
        if !ctrl.flows.is_empty() {
            return Err(format!("{} flow entries leaked", ctrl.flows.len()));
        }
        Ok(())
    });
}

/// Heterogeneity invariant: scaling every node's speed by the same
/// factor scales every scheduler's makespan estimate consistently
/// (no hidden homogeneity assumptions).
#[test]
fn prop_uniform_speed_scaling() {
    forall(0x5CA, 30, gen_scenario, |s| {
        let jt_with = |speed: f64| -> f64 {
            let (mut ctrl, nn, nodes, tasks, _) = build(s);
            let cost = CostModel::rust_only();
            let mut ledger = Ledger::new(nodes.len());
            let mut ctx = SchedCtx {
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: vec![speed; nodes.len()],
            };
            Bass::new().schedule(&tasks, None, &mut ctx);
            nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max)
        };
        let base = jt_with(1.0);
        let double = jt_with(2.0);
        // all-compute lower bound: doubling TP at least doesn't shrink JT
        if double + 1e-9 < base {
            return Err(format!("doubling compute time shrank JT: {base} -> {double}"));
        }
        Ok(())
    });
}
