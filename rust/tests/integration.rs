//! Integration tests: cross-module flows exercising the public API the
//! way `examples/` do — scheduler -> SDN controller -> DES engine ->
//! metrics, plus the XLA runtime path end to end.

use bass::cluster::Ledger;
use bass::coordinator::{ClusterSetup, Coordinator};
use bass::experiments::{
    run_example1, run_example3, run_table1, SchedulerKind, Table1Config,
};
use bass::hdfs::Namenode;
use bass::mapreduce::TaskSpec;
use bass::metrics::JobMetrics;
use bass::runtime::CostModel;
use bass::sched::{Bass, SchedCtx, Scheduler};
use bass::sdn::Controller;
use bass::sim::{Engine, FlowNet};
use bass::topology::builders::tree_cluster;
use bass::util::{Secs, XorShift};
use bass::workload::{JobKind, TraceGen, WorkloadBuilder};

#[test]
fn paper_headline_numbers_end_to_end() {
    let outcomes = run_example1(&CostModel::rust_only());
    let jts: Vec<f64> = outcomes.iter().map(|o| o.executed_jt).collect();
    assert_eq!(jts, vec![39.0, 38.0, 35.0, 34.0]);
}

#[test]
fn xla_and_rust_backends_schedule_identically() {
    let xla = CostModel::auto();
    if xla.backend_for(16, 8) != bass::runtime::exec::Backend::Xla {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let a = run_example1(&xla);
    let b = run_example1(&CostModel::rust_only());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.scheduler, y.scheduler);
        assert_eq!(x.executed_jt, y.executed_jt);
        assert_eq!(x.estimated_jt, y.estimated_jt);
    }
}

#[test]
fn full_job_through_public_api() {
    // mirror of quickstart.rs, with assertions
    let (topo, nodes) = tree_cluster(2, 3, 100.0, 100.0);
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
    let mut ctrl = Controller::new(topo, 1.0);
    let net = FlowNet::new(&caps);
    let mut nn = Namenode::new();
    let mut rng = XorShift::new(42);
    let job = WorkloadBuilder::new(JobKind::Wordcount).build(0, 600.0, &nodes, &mut nn, &mut rng);
    let maps: Vec<TaskSpec> = job.maps().cloned().collect();
    let cost = CostModel::rust_only();
    let mut ledger = Ledger::new(nodes.len());
    let assignment = {
        let mut ctx = SchedCtx {
            view: &bass::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        Bass::new().schedule(&maps, None, &mut ctx)
    };
    assert_eq!(assignment.placements.len(), 10);
    let mut engine = Engine::new(net, vec![Secs::ZERO; nodes.len()]);
    engine.load(&assignment);
    let records = engine.run();
    assert_eq!(records.len(), 10);
    let m = JobMetrics::from_records(&records, Secs::ZERO, None);
    assert!(m.jt >= 20.0, "10 maps x 22s on 6 nodes needs >= 2 waves: {}", m.jt);
    // executed completion of every reserved/local task matches the ledger
    // estimate for BASS (no contention surprises)
    let est = nodes.iter().map(|&n| ledger.idle(n).0).fold(0.0, f64::max);
    assert!((m.jt - est).abs() < 1e-6, "executed {} vs estimated {}", m.jt, est);
}

#[test]
fn table1_full_grid_orders_correctly() {
    let mut cfg = Table1Config::paper(JobKind::Wordcount);
    cfg.sizes_mb = vec![150.0, 300.0];
    let rows = run_table1(&cfg, &CostModel::rust_only());
    assert_eq!(rows.len(), 6);
    for &size in &cfg.sizes_mb {
        let jt = |n: &str| {
            rows.iter().find(|r| r.scheduler == n && r.data_mb == size).unwrap().metrics.jt
        };
        // tolerance: one slot per phase — TS quantization can cost BASS
        // up to slot_secs on ties (the paper's 1s slots behave the same)
        assert!(jt("BASS") <= jt("HDS") + 2.0, "BASS {} HDS {}", jt("BASS"), jt("HDS"));
    }
}

#[test]
fn qos_example3_shape() {
    let o = run_example3(5);
    assert!(o.speedup > 2.0);
}

#[test]
fn coordinator_trace_all_schedulers() {
    for kind in SchedulerKind::ALL {
        let mut rng = XorShift::new(1);
        let arrivals = TraceGen { mean_interarrival_secs: 200.0, sizes_mb: vec![150.0] }
            .generate(3, &mut rng);
        let coord = Coordinator::new(ClusterSetup::default(), kind, CostModel::rust_only());
        let results = coord.run_trace(arrivals).expect("no submissions lost");
        assert_eq!(results.len(), 3, "{}", kind.label());
        assert!(results.iter().all(|r| r.metrics.jt > 0.0));
    }
}

#[test]
fn bass_reads_from_the_better_connected_replica() {
    // the replica-selection fix, end to end: two racks of two nodes
    // (nodes 0,1 on switch A; 2,3 on switch B). A 64MB block has two
    // replica holders — node 0 (idle, but its edge link is congested to
    // 0.8 MB/s by background traffic) and node 2 (busier, but on the
    // destination's switch at the full 12.8 MB/s). The task is starved
    // onto node 3 (Case 2). The idle-only rule pulls from node 0 and
    // crawls; the bandwidth-aware rule pulls from node 2.
    let run = |bw_aware: bool| -> (bass::topology::NodeId, f64) {
        // 102.4 Mbps = the paper's effective 12.8 MB/s (round numbers)
        let (topo, nodes) = tree_cluster(2, 2, 102.4, 102.4);
        let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
        // node 0's own edge link (host-to-switch), found structurally —
        // path link order is not part of the route contract
        let edge0 = topo
            .links
            .iter()
            .find(|l| {
                l.a == bass::topology::Endpoint::Host(nodes[0])
                    || l.b == bass::topology::Endpoint::Host(nodes[0])
            })
            .unwrap()
            .id;
        let mut ctrl = Controller::new(topo, 1.0);
        let mut nn = Namenode::new();
        let b = nn.add_block(64.0, vec![nodes[0], nodes[2]]);
        // congest node 0's edge link: 12 of its 12.8 MB/s is background
        ctrl.set_background_mb_s(edge0, 12.0);
        let tasks = vec![TaskSpec::map(0, b, 64.0, Secs(9.0), 0.0)];
        let cost = CostModel::rust_only();
        // node 0 idle at 0 (the idle-rule favorite), node 2 busy until 5
        let mut ledger = Ledger::with_initial(vec![
            Secs::ZERO,
            Secs::ZERO,
            Secs(5.0),
            Secs::ZERO,
        ]);
        let assignment = {
            let mut ctx = SchedCtx {
                view: &bass::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: vec![nodes[3]],
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: bw_aware,
            };
            Bass::new().schedule(&tasks, None, &mut ctx)
        };
        let p = &assignment.placements[0];
        assert_eq!(p.node, nodes[3]);
        assert!(!p.is_local);
        let src = p.source.expect("starved task must pull remotely");
        let net = FlowNet::new(&caps);
        let mut engine = Engine::new(net, vec![Secs::ZERO; 4]);
        engine.load(&assignment);
        let records = engine.run();
        (src, records[0].finish.0)
    };
    let (src_bw, makespan_bw) = run(true);
    let (src_idle, makespan_idle) = run(false);
    // the legacy rule picks the idle holder behind the congested link...
    assert_eq!(src_idle, bass::topology::NodeId(0));
    // ...the bandwidth-aware rule reads from the same-switch replica
    assert_eq!(src_bw, bass::topology::NodeId(2));
    // 64MB at 12.8 MB/s from t=0 arrives at 5, +9s compute = 14;
    // at 0.8 MB/s the pull alone takes 80s
    assert!((makespan_bw - 14.0).abs() < 1e-9, "bw-aware makespan {makespan_bw}");
    assert!((makespan_idle - 89.0).abs() < 1e-9, "idle-rule makespan {makespan_idle}");
    assert!(makespan_bw < makespan_idle, "the fix must strictly win here");
}

#[test]
fn locality_starvation_cluster_subset() {
    // authorize a node subset that cannot hold any replica: Case 2 path
    let (topo, nodes) = tree_cluster(2, 3, 100.0, 100.0);
    let mut ctrl = Controller::new(topo, 1.0);
    let mut nn = Namenode::new();
    // all replicas on nodes 0..3; authorize only 4..6
    let b = nn.add_block(64.0, vec![nodes[0], nodes[1], nodes[2]]);
    let tasks = vec![TaskSpec::map(0, b, 64.0, Secs(9.0), 0.0)];
    let cost = CostModel::rust_only();
    let mut ledger = Ledger::new(nodes.len());
    let mut ctx = SchedCtx {
        view: &bass::sdn::Oracle,
        controller: &mut ctrl,
        namenode: &nn,
        ledger: &mut ledger,
        authorized: vec![nodes[4], nodes[5]],
        now: Secs::ZERO,
        cost: &cost,
        node_speed: Vec::new(),
        down: Vec::new(),
        bw_aware_sources: true,
    };
    let a = Bass::new().schedule(&tasks, None, &mut ctx);
    let p = &a.placements[0];
    assert!(p.node == nodes[4] || p.node == nodes[5]);
    assert!(!p.is_local);
    assert!(matches!(p.transfer, bass::sim::TransferPlan::Reserved(_)));
}
