//! Golden-trace snapshots: the deterministic execution logs of the
//! paper's *static* Example 1 (all four schedulers, full task records)
//! and Example 3 (QoS shuffle times) diffed against committed fixtures.
//!
//! Purpose: the dynamics subsystem threads new state through the engine,
//! flow network and calendar; these snapshots prove the static scenarios
//! stay bit-identical (at 1e-6 print precision) across such plumbing.
//!
//! After an *intentional* behavior change, regenerate with
//! `BASS_BLESS_GOLDEN=1 cargo test --test golden_traces` and commit the
//! fixture diff.

use bass::experiments::run_example3;
use bass::mapreduce::{TaskId, TaskSpec};
use bass::runtime::CostModel;
use bass::scenario::{
    AdmissionPolicy, ScenarioSpec, SimSession, Submission, SubmissionBody, TenancySpec,
    TenantClass, TenantSpec,
};
use bass::sched::SchedulerKind;
use bass::util::Secs;

fn check(name: &str, got: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name);
    if std::env::var("BASS_BLESS_GOLDEN").is_ok() {
        std::fs::write(&path, got).expect("bless golden fixture");
        return;
    }
    let want = std::fs::read_to_string(&path).expect("committed golden fixture");
    assert!(
        got == want,
        "golden trace {name} drifted — if intentional, regenerate with \
         BASS_BLESS_GOLDEN=1 cargo test --test golden_traces\n\
         --- want ---\n{want}\n--- got ---\n{got}"
    );
}

#[test]
fn example1_static_trace_is_bit_identical() {
    let cost = CostModel::rust_only();
    let mut out = String::new();
    for kind in SchedulerKind::ALL {
        let mut sess = SimSession::new(&ScenarioSpec::example1(kind));
        let tasks = sess.tasks.clone();
        let a = sess.schedule(&tasks, None, Secs::ZERO, &cost);
        let est = sess.estimated_makespan();
        let records = sess.execute(&a);
        out.push_str(&format!("== {} est={est:.6}\n", kind.label()));
        for r in &records {
            out.push_str(&format!(
                "task={} node={} picked={:.6} ready={:.6} start={:.6} finish={:.6} local={} map={}\n",
                r.task.0,
                r.node.0,
                r.picked_at.0,
                r.input_ready.0,
                r.compute_start.0,
                r.finish.0,
                r.is_local,
                r.is_map
            ));
        }
    }
    check("example1.trace", &out);
}

/// A fixed 3-job *overlapping* stream on the Example-1 cluster: the
/// paper's 9 hand-placed tasks split into three map waves arriving at
/// t = 0 / 4 / 6, run through the online session for HDS, BAR and BASS.
/// Jobs genuinely overlap (job 0 finishes long after job 2 arrives), so
/// the trace pins cross-job slot contention, the shared BASS calendar
/// (one reservation: TK1's ND2->ND1 window, slots 3..8) and job-tagged
/// record attribution. The stream makespans land on 41 / 38 / 35 —
/// echoing the paper's HDS > BAR > BASS ordering under concurrency.
#[test]
fn stream_three_job_overlap_trace_is_bit_identical() {
    let cost = CostModel::rust_only();
    let mut out = String::new();
    for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
        let mut sess = SimSession::new(&ScenarioSpec::example1(kind));
        let tasks = sess.tasks.clone();
        let wave = |slice: &[TaskSpec]| -> Vec<TaskSpec> {
            slice
                .iter()
                .cloned()
                .enumerate()
                .map(|(i, mut t)| {
                    t.id = TaskId(i);
                    t
                })
                .collect()
        };
        let sub = |at: f64, name: &str, ts: Vec<TaskSpec>| Submission {
            at_secs: at,
            body: SubmissionBody::Explicit { name: name.into(), tasks: ts, slowstart: 1.0 },
            tenant: None,
        };
        let subs = vec![
            sub(0.0, "wave-0", wave(&tasks[0..3])),
            sub(4.0, "wave-1", wave(&tasks[3..6])),
            sub(6.0, "wave-2", wave(&tasks[6..9])),
        ];
        let o = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
        out.push_str(&format!("== {} ==\n", kind.label()));
        for j in &o.jobs {
            out.push_str(&format!(
                "job={} name={} submit={:.6} admitted={:.6} gate={:.6} mt={:.6} rt={:.6} \
                 jt={:.6} lr={:.6}\n",
                j.job.0,
                j.name,
                j.submitted_at,
                j.admitted_at,
                j.gate,
                j.metrics.mt,
                j.metrics.rt,
                j.metrics.jt,
                j.metrics.lr
            ));
        }
        for (job, r) in &o.records {
            out.push_str(&format!(
                "job={} task={} node={} picked={:.6} ready={:.6} start={:.6} finish={:.6} \
                 local={} map={}\n",
                job.0,
                r.task.0,
                r.node.0,
                r.picked_at.0,
                r.input_ready.0,
                r.compute_start.0,
                r.finish.0,
                r.is_local,
                r.is_map
            ));
        }
        out.push_str(&format!(
            "makespan={:.6} last_finish={:.6} reservations={} queued={}\n",
            o.makespan,
            o.last_finish,
            o.reservations.len(),
            o.queued_jobs
        ));
    }
    check("stream_example1.trace", &out);
}

/// The same three waves re-run as two tenants — "prod" (guaranteed, DRF
/// weight 2, waves 0 and 2) against "batch" (spot, weight 1, wave 1) —
/// under the unlimited admission policy. With no cap and no quotas
/// every arrival admits at its own submit instant, so tenancy here is
/// pure attribution: the task records must stay bitwise identical to
/// the FIFO stream above (asserted in-test against a tenancy-free run),
/// while the fixture pins the hand-derived tenant ledger — which tenant
/// owned which job, the DRF admission order, and each tenant's last
/// finish (prod inherits the stream makespan on all three schedulers;
/// batch's single wave lands at 29 / 29 / 27).
#[test]
fn stream_two_tenant_ledger_is_bit_identical() {
    let cost = CostModel::rust_only();
    let mut tenancy =
        TenancySpec { tenants: vec![TenantSpec::named("prod"), TenantSpec::named("batch")] };
    tenancy.tenants[0].weight = 2.0;
    tenancy.tenants[0].class = TenantClass::Guaranteed;
    let mut out = String::new();
    for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
        let run = |tenanted: bool| {
            let mut spec = ScenarioSpec::example1(kind);
            if tenanted {
                spec.tenants = Some(tenancy.clone());
            }
            let mut sess = SimSession::new(&spec);
            let tasks = sess.tasks.clone();
            let wave = |slice: &[TaskSpec]| -> Vec<TaskSpec> {
                slice
                    .iter()
                    .cloned()
                    .enumerate()
                    .map(|(i, mut t)| {
                        t.id = TaskId(i);
                        t
                    })
                    .collect()
            };
            let sub = |at: f64, name: &str, owner: &str, ts: Vec<TaskSpec>| Submission {
                at_secs: at,
                body: SubmissionBody::Explicit { name: name.into(), tasks: ts, slowstart: 1.0 },
                tenant: tenanted.then(|| owner.to_string()),
            };
            let subs = vec![
                sub(0.0, "wave-0", "prod", wave(&tasks[0..3])),
                sub(4.0, "wave-1", "batch", wave(&tasks[3..6])),
                sub(6.0, "wave-2", "prod", wave(&tasks[6..9])),
            ];
            sess.run_stream(subs, AdmissionPolicy::default(), &cost)
        };
        let fifo = run(false);
        let o = run(true);
        assert_eq!(fifo.records.len(), o.records.len(), "{}", kind.label());
        for ((ja, a), (jb, b)) in fifo.records.iter().zip(&o.records) {
            assert!(
                ja == jb && a.task == b.task && a.node == b.node && a.finish == b.finish,
                "{}: attribution-only tenancy perturbed the schedule",
                kind.label()
            );
        }
        assert_eq!(fifo.makespan.to_bits(), o.makespan.to_bits(), "{}", kind.label());
        out.push_str(&format!("== {} ==\n", kind.label()));
        for j in &o.jobs {
            out.push_str(&format!(
                "job={} name={} tenant={} submit={:.6} admitted={:.6} jt={:.6}\n",
                j.job.0,
                j.name,
                j.tenant.as_deref().unwrap_or("-"),
                j.submitted_at,
                j.admitted_at,
                j.metrics.jt
            ));
        }
        for a in &o.admissions {
            out.push_str(&format!("admit at={:.6} job={} tenant={}\n", a.at, a.job.0, a.tenant));
        }
        for t in &o.tenant_stats {
            let last = o
                .records
                .iter()
                .filter(|(jid, _)| {
                    o.jobs
                        .iter()
                        .any(|j| j.job == *jid && j.tenant.as_deref() == Some(t.tenant.as_str()))
                })
                .map(|(_, r)| r.finish.0)
                .fold(0.0, f64::max);
            out.push_str(&format!(
                "tenant={} weight={:.6} jobs={} rejected={} last_finish={last:.6}\n",
                t.tenant, t.weight, t.jobs, t.rejected
            ));
        }
        out.push_str(&format!(
            "makespan={:.6} preemptions={} rejected={}\n",
            o.makespan,
            o.preemptions.len(),
            o.rejected_jobs
        ));
    }
    check("stream_tenancy_example1.trace", &out);
}

/// Example 1 re-derived with its multi-replica blocks (2 holders per
/// block) at placement granularity: which node each task landed on,
/// which replica holder a remote task pulls from under the
/// argmax-bandwidth source rule, and through which transfer plan. The
/// Fig. 2 testbed's links are symmetric at schedule time, so every
/// bandwidth argmax here ties and resolves by the min-idle tie-break —
/// which is exactly why the record-level `example1.trace` fixture above
/// survives the selection-rule change bit for bit.
#[test]
fn example1_replica_sources_are_pinned() {
    let cost = CostModel::rust_only();
    let mut out = String::new();
    for kind in SchedulerKind::ALL {
        let mut sess = SimSession::new(&ScenarioSpec::example1(kind));
        let tasks = sess.tasks.clone();
        let a = sess.schedule(&tasks, None, Secs::ZERO, &cost);
        let mut placements = a.placements.clone();
        placements.sort_by_key(|p| p.task);
        out.push_str(&format!("== {} ==\n", kind.label()));
        for p in &placements {
            let src = match p.source {
                Some(s) => s.0.to_string(),
                None => "-".into(),
            };
            let plan = match &p.transfer {
                bass::sim::TransferPlan::None => "none",
                bass::sim::TransferPlan::Reserved(_) => "reserved",
                bass::sim::TransferPlan::Prefetched(_) => "prefetch",
                bass::sim::TransferPlan::FairShare { .. } => "fair",
            };
            out.push_str(&format!(
                "task={} node={} src={} local={} plan={}\n",
                p.task.0, p.node.0, src, p.is_local, plan
            ));
        }
    }
    check("example1_sources.trace", &out);
}

#[test]
fn example3_static_trace_is_bit_identical() {
    let mut out = String::new();
    for bg in [0usize, 5] {
        let o = run_example3(bg);
        out.push_str(&format!(
            "bg={bg} shared={:.6} queued={:.6}\n",
            o.shared_secs, o.queued_secs
        ));
    }
    check("example3.trace", &out);
}
