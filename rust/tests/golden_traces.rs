//! Golden-trace snapshots: the deterministic execution logs of the
//! paper's *static* Example 1 (all four schedulers, full task records)
//! and Example 3 (QoS shuffle times) diffed against committed fixtures.
//!
//! Purpose: the dynamics subsystem threads new state through the engine,
//! flow network and calendar; these snapshots prove the static scenarios
//! stay bit-identical (at 1e-6 print precision) across such plumbing.
//!
//! After an *intentional* behavior change, regenerate with
//! `BASS_BLESS_GOLDEN=1 cargo test --test golden_traces` and commit the
//! fixture diff.

use bass::experiments::run_example3;
use bass::runtime::CostModel;
use bass::scenario::{ScenarioSpec, SimSession};
use bass::sched::SchedulerKind;
use bass::util::Secs;

fn check(name: &str, got: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name);
    if std::env::var("BASS_BLESS_GOLDEN").is_ok() {
        std::fs::write(&path, got).expect("bless golden fixture");
        return;
    }
    let want = std::fs::read_to_string(&path).expect("committed golden fixture");
    assert!(
        got == want,
        "golden trace {name} drifted — if intentional, regenerate with \
         BASS_BLESS_GOLDEN=1 cargo test --test golden_traces\n\
         --- want ---\n{want}\n--- got ---\n{got}"
    );
}

#[test]
fn example1_static_trace_is_bit_identical() {
    let cost = CostModel::rust_only();
    let mut out = String::new();
    for kind in SchedulerKind::ALL {
        let mut sess = SimSession::new(&ScenarioSpec::example1(kind));
        let tasks = sess.tasks.clone();
        let a = sess.schedule(&tasks, None, Secs::ZERO, &cost);
        let est = sess.estimated_makespan();
        let records = sess.execute(&a);
        out.push_str(&format!("== {} est={est:.6}\n", kind.label()));
        for r in &records {
            out.push_str(&format!(
                "task={} node={} picked={:.6} ready={:.6} start={:.6} finish={:.6} local={} map={}\n",
                r.task.0,
                r.node.0,
                r.picked_at.0,
                r.input_ready.0,
                r.compute_start.0,
                r.finish.0,
                r.is_local,
                r.is_map
            ));
        }
    }
    check("example1.trace", &out);
}

#[test]
fn example3_static_trace_is_bit_identical() {
    let mut out = String::new();
    for bg in [0usize, 5] {
        let o = run_example3(bg);
        out.push_str(&format!(
            "bg={bg} shared={:.6} queued={:.6}\n",
            o.shared_secs, o.queued_secs
        ));
    }
    check("example3.trace", &out);
}
