//! API-compatible stub of the `xla` (PJRT) bindings.
//!
//! The offline build image carries no XLA runtime, so this crate mirrors
//! the handful of types and signatures `bass::runtime` compiles against.
//! [`PjRtClient::cpu`] always reports "unavailable", which makes
//! `Artifacts::open` fail and routes every `CostModel` call to the
//! bit-identical pure-Rust evaluator — the documented fallback path.
//! Swapping in the real bindings is a Cargo.toml change only.

use std::fmt;
use std::path::Path;

/// Stub error: a plain message.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("xla stub: PJRT runtime not available in this build".to_string())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Host tensor handle (never holds data in the stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Lowered computation.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side execution output; fetchable back to a [`Literal`].
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle. `cpu()` always errors in the stub.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }

    #[test]
    fn literal_roundtrip_is_refused() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
