//! Minimal offline shim of the `anyhow` crate.
//!
//! The build image vendors no registry crates, so this in-tree package
//! provides exactly the surface `bass` uses: [`Error`], [`Result`], the
//! `anyhow!` / `bail!` / `ensure!` macros and the [`Context`] extension
//! trait. Error values carry a flattened message chain (no backtraces,
//! no downcasting) — enough for diagnostics, deliberately nothing more.

use std::fmt;

/// A flattened error: message plus optional source description.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prefix the existing message with `context` (anyhow renders the
    /// chain outermost-first; the shim flattens it the same way).
    pub fn wrap(self, context: impl fmt::Display) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow, Error deliberately does NOT implement
// std::error::Error, so this blanket conversion stays coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` with the shim's [`Error`] as the default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Attach context to a fallible value (subset of anyhow's trait: any
/// displayable error type qualifies, which covers every call site here).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse().context("not a number")?;
        ensure!(n < 100, "too big: {n}");
        Ok(n)
    }

    #[test]
    fn happy_path() {
        assert_eq!(parse("42").unwrap(), 42);
    }

    #[test]
    fn context_prefixes() {
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not a number: "), "{e}");
    }

    #[test]
    fn ensure_formats() {
        assert_eq!(parse("250").unwrap_err().to_string(), "too big: 250");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/here")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| format!("outer {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "outer 7: inner");
    }
}
