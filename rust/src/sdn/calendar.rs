//! Time-Slot bandwidth calendars (Section IV-A of the paper).
//!
//! Before scheduling starts, "the occupation time of each link's residue
//! bandwidth is disintegrated into equal time slots TS_1, TS_2, ...",
//! whose duration is a tunable parameter (1s in the paper's examples).
//! A task that needs to move data over a path during `(t_m, t_n)` gets
//! the corresponding slots reserved **on every link of the path** in
//! advance; after the transfer the slots are released back.
//!
//! [`SlotCalendar`] stores, per link, the reserved bandwidth fraction of
//! each future slot; reservations never oversubscribe a slot.

use crate::topology::LinkId;
use crate::util::Secs;

/// Safety cap on how far into the future a window search may walk.
const MAX_SEARCH_SLOTS: usize = 4_000_000;

/// A granted path reservation (returned by [`SlotCalendar::reserve_path`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Reservation {
    pub links: Vec<LinkId>,
    /// First reserved slot index.
    pub start_slot: usize,
    /// Number of consecutive slots reserved.
    pub n_slots: usize,
    /// Reserved fraction of each link's capacity, in (0, 1].
    pub frac: f64,
}

impl Reservation {
    /// Wall-clock start of the reservation window.
    pub fn start(&self, slot_secs: f64) -> Secs {
        Secs(self.start_slot as f64 * slot_secs)
    }

    /// Wall-clock end of the reservation window.
    pub fn end(&self, slot_secs: f64) -> Secs {
        Secs((self.start_slot + self.n_slots) as f64 * slot_secs)
    }
}

/// Per-link slot reservation ledgers.
#[derive(Debug, Clone)]
pub struct SlotCalendar {
    slot_secs: f64,
    /// reserved[link][slot] = fraction of capacity already promised.
    reserved: Vec<Vec<f64>>,
}

impl SlotCalendar {
    /// `slot_secs` is the tunable TS duration (1.0 in the paper).
    pub fn new(n_links: usize, slot_secs: f64) -> Self {
        assert!(slot_secs > 0.0, "slot duration must be positive");
        Self { slot_secs, reserved: vec![Vec::new(); n_links] }
    }

    pub fn slot_secs(&self) -> f64 {
        self.slot_secs
    }

    pub fn n_links(&self) -> usize {
        self.reserved.len()
    }

    /// Slot index containing time `t`.
    pub fn slot_of(&self, t: Secs) -> usize {
        assert!(t.0 >= 0.0, "negative time");
        (t.0 / self.slot_secs).floor() as usize
    }

    /// Number of slots needed to move `size_mb` at `rate_mb_s`.
    pub fn slots_for(&self, size_mb: f64, rate_mb_s: f64) -> usize {
        assert!(rate_mb_s > 0.0);
        ((size_mb / rate_mb_s) / self.slot_secs).ceil().max(0.0) as usize
    }

    /// Reserved fraction of `link` during `slot` (0 if untouched).
    pub fn reserved_frac(&self, link: LinkId, slot: usize) -> f64 {
        self.reserved[link.0].get(slot).copied().unwrap_or(0.0)
    }

    /// Residual (unreserved) fraction of `link` during `slot`.
    pub fn residual_frac(&self, link: LinkId, slot: usize) -> f64 {
        (1.0 - self.reserved_frac(link, slot)).max(0.0)
    }

    /// Min residual fraction over a path during `[start, start + n)`.
    pub fn path_residual(&self, links: &[LinkId], start: usize, n: usize) -> f64 {
        let mut min = 1.0f64;
        for &l in links {
            for s in start..start + n {
                min = min.min(self.residual_frac(l, s));
                if min <= 0.0 {
                    return 0.0;
                }
            }
        }
        min
    }

    fn ensure_len(&mut self, link: LinkId, upto: usize) {
        let v = &mut self.reserved[link.0];
        if v.len() < upto {
            v.resize(upto, 0.0);
        }
    }

    /// Reserve `frac` of every link on `links` for slots
    /// `[start, start + n)`. Fails (leaving the calendar untouched) if any
    /// slot lacks the residual.
    pub fn reserve_path(
        &mut self,
        links: &[LinkId],
        start: usize,
        n: usize,
        frac: f64,
    ) -> anyhow::Result<Reservation> {
        anyhow::ensure!(frac > 0.0 && frac <= 1.0, "frac out of (0,1]: {frac}");
        anyhow::ensure!(n > 0, "empty reservation window");
        const EPS: f64 = 1e-9;
        if self.path_residual(links, start, n) + EPS < frac {
            anyhow::bail!(
                "insufficient residual bandwidth on path {links:?} slots {start}..{}",
                start + n
            );
        }
        for &l in links {
            self.ensure_len(l, start + n);
            for s in start..start + n {
                self.reserved[l.0][s] = (self.reserved[l.0][s] + frac).min(1.0);
            }
        }
        Ok(Reservation { links: links.to_vec(), start_slot: start, n_slots: n, frac })
    }

    /// Release a previous reservation (idempotence is the caller's duty).
    pub fn release(&mut self, r: &Reservation) {
        for &l in &r.links {
            for s in r.start_slot..r.start_slot + r.n_slots {
                if let Some(x) = self.reserved[l.0].get_mut(s) {
                    *x = (*x - r.frac).max(0.0);
                }
            }
        }
    }

    /// Earliest start slot `>= earliest` where every link on the path can
    /// give `frac` for `n` consecutive slots.
    pub fn find_window(
        &self,
        links: &[LinkId],
        earliest: usize,
        n: usize,
        frac: f64,
    ) -> Option<usize> {
        const EPS: f64 = 1e-9;
        let mut s = earliest;
        while s < earliest + MAX_SEARCH_SLOTS {
            // find first violating slot in window; jump past it
            let mut ok = true;
            'outer: for off in 0..n {
                for &l in links {
                    if self.residual_frac(l, s + off) + EPS < frac {
                        s = s + off + 1;
                        ok = false;
                        break 'outer;
                    }
                }
            }
            if ok {
                return Some(s);
            }
        }
        None
    }

    /// The paper's "most residue bandwidth" policy: starting at `earliest`,
    /// find the window that moves `size_mb` soonest, grabbing the full
    /// residual fraction of the path (at least `min_frac`). The window
    /// length depends on the grabbed rate, so the search fixes-points on
    /// (start, rate, length). Returns the reservation to apply.
    ///
    /// `capacity_mb_s` is the bottleneck line rate of the path in MB/s; the
    /// granted rate is `frac * capacity_mb_s`.
    pub fn plan_transfer(
        &self,
        links: &[LinkId],
        earliest: Secs,
        size_mb: f64,
        capacity_mb_s: f64,
        min_frac: f64,
    ) -> Option<Reservation> {
        assert!(capacity_mb_s > 0.0 && size_mb >= 0.0);
        if size_mb == 0.0 || links.is_empty() {
            return Some(Reservation {
                links: links.to_vec(),
                start_slot: self.slot_of(earliest),
                n_slots: 0,
                frac: 0.0,
            });
        }
        let mut start = self.slot_of(earliest);
        for _ in 0..MAX_SEARCH_SLOTS {
            // rate available at the candidate start slot
            let f0 = links
                .iter()
                .map(|&l| self.residual_frac(l, start))
                .fold(1.0f64, f64::min);
            if f0 < min_frac || f0 <= 0.0 {
                start += 1;
                continue;
            }
            // fixed-point on window length
            let mut frac = f0;
            let mut n = self.slots_for(size_mb, frac * capacity_mb_s);
            loop {
                let avail = self.path_residual(links, start, n.max(1));
                if avail + 1e-9 >= frac {
                    return Some(Reservation {
                        links: links.to_vec(),
                        start_slot: start,
                        n_slots: n.max(1),
                        frac,
                    });
                }
                if avail < min_frac || avail <= 0.0 {
                    break; // window blocked; advance start
                }
                frac = avail;
                n = self.slots_for(size_mb, frac * capacity_mb_s);
            }
            start += 1;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> SlotCalendar {
        SlotCalendar::new(4, 1.0)
    }

    #[test]
    fn slot_of_floors() {
        let c = cal();
        assert_eq!(c.slot_of(Secs(0.0)), 0);
        assert_eq!(c.slot_of(Secs(0.99)), 0);
        assert_eq!(c.slot_of(Secs(3.0)), 3);
        assert_eq!(c.slot_of(Secs(3.5)), 3);
    }

    #[test]
    fn slots_for_paper_example() {
        // 64MB at 12.8 MB/s = 5.0s = 5 slots (Example 1)
        let c = cal();
        assert_eq!(c.slots_for(64.0, 12.8), 5);
        // 64MB at 12.5 MB/s = 5.12s -> 6 slots
        assert_eq!(c.slots_for(64.0, 12.5), 6);
    }

    #[test]
    fn reserve_then_residual_drops() {
        let mut c = cal();
        let links = [LinkId(0), LinkId(2)];
        let r = c.reserve_path(&links, 3, 5, 1.0).unwrap();
        assert_eq!(r.start(1.0), Secs(3.0));
        assert_eq!(r.end(1.0), Secs(8.0));
        assert_eq!(c.residual_frac(LinkId(0), 4), 0.0);
        assert_eq!(c.residual_frac(LinkId(1), 4), 1.0); // untouched link
        assert_eq!(c.residual_frac(LinkId(0), 8), 1.0); // after the window
    }

    #[test]
    fn oversubscription_rejected_and_atomic() {
        let mut c = cal();
        c.reserve_path(&[LinkId(0)], 0, 3, 0.7).unwrap();
        // second reservation over same slots would need 0.4 -> rejected
        assert!(c.reserve_path(&[LinkId(0), LinkId(1)], 1, 2, 0.4).is_err());
        // atomicity: link 1 must be untouched by the failed attempt
        assert_eq!(c.residual_frac(LinkId(1), 1), 1.0);
    }

    #[test]
    fn release_restores() {
        let mut c = cal();
        let r = c.reserve_path(&[LinkId(0)], 2, 4, 0.5).unwrap();
        c.release(&r);
        assert_eq!(c.residual_frac(LinkId(0), 3), 1.0);
    }

    #[test]
    fn find_window_skips_busy_slots() {
        let mut c = cal();
        c.reserve_path(&[LinkId(0)], 2, 3, 1.0).unwrap(); // busy 2..5
        assert_eq!(c.find_window(&[LinkId(0)], 0, 2, 1.0), Some(0));
        assert_eq!(c.find_window(&[LinkId(0)], 1, 2, 1.0), Some(5));
        assert_eq!(c.find_window(&[LinkId(0)], 0, 3, 0.5), Some(5));
    }

    #[test]
    fn plan_transfer_full_rate() {
        let c = cal();
        // Example 1: 64MB, bottleneck 12.8 MB/s, from t=3
        let r = c
            .plan_transfer(&[LinkId(0), LinkId(1)], Secs(3.0), 64.0, 12.8, 0.05)
            .unwrap();
        assert_eq!(r.start_slot, 3);
        assert_eq!(r.n_slots, 5); // TS_4..TS_8 in the paper's 1-based naming
        assert!((r.frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn plan_transfer_degrades_rate_when_partially_reserved() {
        let mut c = cal();
        c.reserve_path(&[LinkId(0)], 0, 100, 0.5).unwrap();
        let r = c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 0.05).unwrap();
        assert!((r.frac - 0.5).abs() < 1e-12);
        assert_eq!(r.n_slots, 10); // half rate, twice the slots
    }

    #[test]
    fn plan_transfer_zero_size_is_instant() {
        let c = cal();
        let r = c.plan_transfer(&[LinkId(0)], Secs(7.0), 0.0, 12.8, 0.05).unwrap();
        assert_eq!(r.n_slots, 0);
        assert_eq!(r.start_slot, 7);
    }

    #[test]
    fn plan_transfer_empty_path_local() {
        let c = cal();
        let r = c.plan_transfer(&[], Secs(1.0), 64.0, 12.8, 0.05).unwrap();
        assert_eq!(r.n_slots, 0);
    }
}
