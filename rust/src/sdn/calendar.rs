//! Time-Slot bandwidth calendars (Section IV-A of the paper).
//!
//! Before scheduling starts, "the occupation time of each link's residue
//! bandwidth is disintegrated into equal time slots TS_1, TS_2, ...",
//! whose duration is a tunable parameter (1s in the paper's examples).
//! A task that needs to move data over a path during `(t_m, t_n)` gets
//! the corresponding slots reserved **on every link of the path** in
//! advance; after the transfer the slots are released back.
//!
//! [`SlotCalendar`] stores, per link, the reserved bandwidth fraction of
//! each future slot; reservations never oversubscribe a slot.
//!
//! # Representation
//!
//! Each link's reserved fraction is a **sparse step function** over slot
//! indices: a `BTreeMap<usize, f64>` whose entry `(s, v)` means "fraction
//! `v` from slot `s` until the next boundary"; before the first boundary
//! the fraction is 0.0, and the trailing segment is always 0.0 because
//! every reservation restores the pre-existing level at its end. Reserve
//! and release touch `O(log B + k)` boundaries (`B` boundaries on the
//! link, `k` inside the window) regardless of how far in the future the
//! window sits — the seed's dense `Vec<f64>`-per-slot version walked and
//! resized arrays proportional to the absolute slot index and capped
//! searches at a `MAX_SEARCH_SLOTS` cliff; both are gone. Window
//! searches jump between boundaries instead of probing slot-by-slot, so
//! an empty month-long horizon costs the same as an empty second.

use std::collections::BTreeMap;

use crate::topology::LinkId;
use crate::util::Secs;

/// Tolerance for residual-vs-fraction comparisons (same as the seed).
const EPS: f64 = 1e-9;

/// Dust threshold for segment maintenance, far below the decision
/// tolerance [`EPS`]: boundaries whose levels differ by at most this
/// merge, and released levels this close to zero snap to exactly 0.0.
/// Without it, f64 residue from stacked reserve/release cycles (e.g.
/// `(0.1 + 0.2) - 0.1 - 0.2 != 0`) would leave phantom boundaries that
/// accumulate forever in long-lived calendars.
const DUST: f64 = 1e-12;

/// A granted path reservation (returned by [`SlotCalendar::reserve_path`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Reservation {
    pub links: Vec<LinkId>,
    /// First reserved slot index.
    pub start_slot: usize,
    /// Number of consecutive slots reserved.
    pub n_slots: usize,
    /// Reserved fraction of each link's capacity, in (0, 1].
    pub frac: f64,
}

impl Reservation {
    /// Wall-clock start of the reservation window.
    pub fn start(&self, slot_secs: f64) -> Secs {
        Secs(self.start_slot as f64 * slot_secs)
    }

    /// Wall-clock end of the reservation window.
    pub fn end(&self, slot_secs: f64) -> Secs {
        Secs((self.start_slot + self.n_slots) as f64 * slot_secs)
    }
}

/// One link's occupancy step function.
type Segments = BTreeMap<usize, f64>;

/// Reserved level at `slot` (0.0 before the first boundary).
fn level_at(seg: &Segments, slot: usize) -> f64 {
    seg.range(..=slot).next_back().map(|(_, &v)| v).unwrap_or(0.0)
}

/// Apply `f` to the level over `[start, end)`, splitting boundaries as
/// needed and coalescing equal neighbours afterwards.
fn update_range(seg: &mut Segments, start: usize, end: usize, f: impl Fn(f64) -> f64) {
    if start >= end {
        return;
    }
    // split so the window is covered by whole segments
    let end_level = level_at(seg, end);
    let start_level = level_at(seg, start);
    seg.entry(start).or_insert(start_level);
    seg.entry(end).or_insert(end_level);
    let updates: Vec<(usize, f64)> =
        seg.range(start..end).map(|(&k, &v)| (k, f(v))).collect();
    for (k, v) in updates {
        seg.insert(k, v);
    }
    // coalesce: drop boundaries whose level matches their predecessor's
    // within DUST (the implicit predecessor of the first boundary is 0.0)
    let keys: Vec<usize> = seg.range(start..=end).map(|(&k, _)| k).collect();
    for k in keys {
        let prev = seg.range(..k).next_back().map(|(_, &v)| v).unwrap_or(0.0);
        if (seg[&k] - prev).abs() <= DUST {
            seg.remove(&k);
        }
    }
}

/// Per-link slot reservation ledgers.
#[derive(Debug, Clone)]
pub struct SlotCalendar {
    slot_secs: f64,
    /// Sparse occupancy per link: slot boundary -> reserved fraction.
    reserved: Vec<Segments>,
    /// Usable capacity fraction per link (1.0 = healthy). Degradation
    /// (dynamics) lowers the ceiling reservations may fill up to.
    usable: Vec<f64>,
}

impl SlotCalendar {
    /// `slot_secs` is the tunable TS duration (1.0 in the paper).
    pub fn new(n_links: usize, slot_secs: f64) -> Self {
        assert!(slot_secs > 0.0, "slot duration must be positive");
        Self { slot_secs, reserved: vec![Segments::new(); n_links], usable: vec![1.0; n_links] }
    }

    /// Dynamics hook: set the usable capacity fraction of a link (1.0 =
    /// healthy, lower = degraded). New reservations are admitted against
    /// the reduced ceiling; reservations committed *before* the change
    /// may now oversubscribe it — revalidate them with
    /// [`SlotCalendar::reservation_within_capacity`].
    pub fn set_usable_frac(&mut self, link: LinkId, frac: f64) {
        self.usable[link.0] = frac.clamp(0.0, 1.0);
    }

    pub fn usable_frac(&self, link: LinkId) -> f64 {
        self.usable[link.0]
    }

    /// Revalidation: does the total reserved level (this reservation plus
    /// everything stacked with it) stay within every link's current
    /// usable fraction over the whole window?
    pub fn reservation_within_capacity(&self, r: &Reservation) -> bool {
        if r.n_slots == 0 {
            return true;
        }
        r.links.iter().all(|&l| {
            let seg = &self.reserved[l.0];
            let mut peak = level_at(seg, r.start_slot);
            for (_, &v) in seg.range(r.start_slot + 1..r.start_slot + r.n_slots) {
                if v > peak {
                    peak = v;
                }
            }
            peak <= self.usable[l.0] + EPS
        })
    }

    pub fn slot_secs(&self) -> f64 {
        self.slot_secs
    }

    pub fn n_links(&self) -> usize {
        self.reserved.len()
    }

    /// Total occupancy boundaries across links (diagnostics / benches:
    /// memory scales with *reservations*, not with the horizon).
    pub fn n_segments(&self) -> usize {
        self.reserved.iter().map(|s| s.len()).sum()
    }

    /// Slot index containing time `t`.
    pub fn slot_of(&self, t: Secs) -> usize {
        assert!(t.0 >= 0.0, "negative time");
        (t.0 / self.slot_secs).floor() as usize
    }

    /// Number of slots needed to move `size_mb` at `rate_mb_s`.
    pub fn slots_for(&self, size_mb: f64, rate_mb_s: f64) -> usize {
        assert!(rate_mb_s > 0.0);
        ((size_mb / rate_mb_s) / self.slot_secs).ceil().max(0.0) as usize
    }

    /// Reserved fraction of `link` during `slot` (0 if untouched).
    pub fn reserved_frac(&self, link: LinkId, slot: usize) -> f64 {
        level_at(&self.reserved[link.0], slot)
    }

    /// Residual (unreserved, usable) fraction of `link` during `slot`.
    pub fn residual_frac(&self, link: LinkId, slot: usize) -> f64 {
        (self.usable[link.0] - self.reserved_frac(link, slot)).max(0.0)
    }

    /// Peak reserved fraction of `link` over `[start, start + n)` (the
    /// worst slot in the span). The measured control plane combines this
    /// exact ledger with *estimated* link environments, so its view
    /// matches the clairvoyant one bit-for-bit when estimates are exact.
    pub fn peak_reserved(&self, link: LinkId, start: usize, n: usize) -> f64 {
        let seg = &self.reserved[link.0];
        let mut peak = level_at(seg, start);
        if n > 1 {
            for (_, &v) in seg.range(start + 1..start + n) {
                if v > peak {
                    peak = v;
                }
            }
        }
        peak
    }

    /// Min residual fraction over a path during `[start, start + n)`.
    pub fn path_residual(&self, links: &[LinkId], start: usize, n: usize) -> f64 {
        let mut min = 1.0f64;
        if n == 0 {
            return min;
        }
        for &l in links {
            let seg = &self.reserved[l.0];
            let mut peak = level_at(seg, start);
            for (_, &v) in seg.range(start + 1..start + n) {
                if v > peak {
                    peak = v;
                }
            }
            min = min.min((self.usable[l.0] - peak).max(0.0));
            if min <= 0.0 {
                return 0.0;
            }
        }
        min
    }

    /// Reserve `frac` of every link on `links` for slots
    /// `[start, start + n)`. Fails (leaving the calendar untouched) if any
    /// slot lacks the residual.
    pub fn reserve_path(
        &mut self,
        links: &[LinkId],
        start: usize,
        n: usize,
        frac: f64,
    ) -> anyhow::Result<Reservation> {
        anyhow::ensure!(frac > 0.0 && frac <= 1.0, "frac out of (0,1]: {frac}");
        anyhow::ensure!(n > 0, "empty reservation window");
        if self.path_residual(links, start, n) + EPS < frac {
            anyhow::bail!(
                "insufficient residual bandwidth on path {links:?} slots {start}..{}",
                start + n
            );
        }
        for &l in links {
            update_range(&mut self.reserved[l.0], start, start + n, |v| {
                (v + frac).min(1.0)
            });
        }
        Ok(Reservation { links: links.to_vec(), start_slot: start, n_slots: n, frac })
    }

    /// Re-apply a previously released reservation *without* a capacity
    /// check: the exact inverse of [`SlotCalendar::release`]. Mid-flow
    /// renegotiation releases a grant, re-plans, and — when conditions
    /// admit nothing better — restores the old grant verbatim. The grant
    /// was admitted when committed; if the link degraded underneath it
    /// since, restoring merely returns to the prior (oversubscribed)
    /// state, which [`SlotCalendar::reservation_within_capacity`]
    /// already detects.
    pub fn restore(&mut self, r: &Reservation) {
        for &l in &r.links {
            update_range(&mut self.reserved[l.0], r.start_slot, r.start_slot + r.n_slots, |v| {
                (v + r.frac).min(1.0)
            });
        }
    }

    /// Release a previous reservation (idempotence is the caller's duty).
    pub fn release(&mut self, r: &Reservation) {
        for &l in &r.links {
            update_range(&mut self.reserved[l.0], r.start_slot, r.start_slot + r.n_slots, |v| {
                let left = (v - r.frac).max(0.0);
                if left <= DUST {
                    0.0
                } else {
                    left
                }
            });
        }
    }

    /// Garbage-collect history: drop every occupancy boundary strictly
    /// before `slot`, folding the level crossing `slot` into a single
    /// boundary. Long-lived online streams never release their
    /// reservations (transfers simply end), so without compaction the
    /// step functions would grow with every job ever admitted; queries
    /// at slots `>= slot` are unaffected. Releasing a reservation whose
    /// window lies before `slot` afterwards is harmless — it only edits
    /// already-forgotten history.
    pub fn forget_before(&mut self, slot: usize) {
        for seg in &mut self.reserved {
            let first_kept = seg.range(slot..).next().map(|(&k, _)| k);
            if seg.range(..slot).next().is_none() {
                continue; // nothing to forget on this link
            }
            let lvl = level_at(seg, slot);
            let old: Vec<usize> = seg.range(..slot).map(|(&k, _)| k).collect();
            for k in old {
                seg.remove(&k);
            }
            // restore the level in force at `slot` unless a boundary
            // already sits there or the level is (dust-)zero
            if first_kept != Some(slot) && lvl.abs() > DUST {
                seg.insert(slot, lvl);
            }
        }
    }

    /// First slot in `[lo, hi)` where any link's residual can't give
    /// `frac` (the window-search violation test).
    fn first_blocked(&self, links: &[LinkId], lo: usize, hi: usize, frac: f64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for &l in links {
            let seg = &self.reserved[l.0];
            let usable = self.usable[l.0];
            let hi_l = best.unwrap_or(hi);
            if lo >= hi_l {
                break; // links can't beat an already-found block at `lo`
            }
            if (usable - level_at(seg, lo)).max(0.0) + EPS < frac {
                best = Some(lo);
                continue;
            }
            for (&k, &v) in seg.range(lo + 1..hi_l) {
                if (usable - v).max(0.0) + EPS < frac {
                    best = Some(k);
                    break;
                }
            }
        }
        best
    }

    /// First slot `>= pos` where every link's residual can give `frac`.
    /// Jumps boundary-to-boundary; the trailing level of every link is
    /// 0.0-reserved (residual = its usable fraction), so this terminates
    /// as long as callers screen demands above the usable ceiling out.
    fn next_open(&self, links: &[LinkId], mut pos: usize, blocked: impl Fn(f64) -> bool) -> usize {
        'outer: loop {
            for &l in links {
                let seg = &self.reserved[l.0];
                if blocked((self.usable[l.0] - level_at(seg, pos)).max(0.0)) {
                    match seg.range(pos + 1..).next() {
                        Some((&k, _)) => {
                            pos = k;
                            continue 'outer;
                        }
                        // trailing segment is always 0.0-reserved: a block
                        // there means the demand exceeds the usable ceiling
                        // and callers have already screened that out
                        None => unreachable!("blocked on a free trailing segment"),
                    }
                }
            }
            return pos;
        }
    }

    /// Earliest start slot `>= earliest` where every link on the path can
    /// give `frac` for `n` consecutive slots. `None` only if the demand is
    /// infeasible outright (`frac` above line rate) — there is no search
    /// horizon cap; an empty far future is found in O(boundaries).
    pub fn find_window(
        &self,
        links: &[LinkId],
        earliest: usize,
        n: usize,
        frac: f64,
    ) -> Option<usize> {
        if links.is_empty() || n == 0 {
            return Some(earliest);
        }
        // ceiling: the path's worst usable fraction (1.0 when healthy)
        let cap = links.iter().map(|&l| self.usable[l.0]).fold(1.0f64, f64::min);
        if cap + EPS < frac {
            return None; // no slot can ever satisfy it
        }
        let mut s = earliest;
        loop {
            match self.first_blocked(links, s, s + n, frac) {
                None => return Some(s),
                // skip the whole blocked run: every start in (s..=q] keeps
                // slot q inside its window, so none of them is viable
                Some(q) => s = self.next_open(links, q + 1, |r| r + EPS < frac),
            }
        }
    }

    /// The paper's "most residue bandwidth" policy: starting at `earliest`,
    /// find the window that moves `size_mb` soonest, grabbing the full
    /// residual fraction of the path (at least `min_frac`). The window
    /// length depends on the grabbed rate, so the search fixes-points on
    /// (start, rate, length). Returns the reservation to apply.
    ///
    /// `capacity_mb_s` is the bottleneck line rate of the path in MB/s; the
    /// granted rate is `frac * capacity_mb_s`.
    pub fn plan_transfer(
        &self,
        links: &[LinkId],
        earliest: Secs,
        size_mb: f64,
        capacity_mb_s: f64,
        min_frac: f64,
    ) -> Option<Reservation> {
        assert!(capacity_mb_s > 0.0 && size_mb >= 0.0);
        if size_mb == 0.0 || links.is_empty() {
            return Some(Reservation {
                links: links.to_vec(),
                start_slot: self.slot_of(earliest),
                n_slots: 0,
                frac: 0.0,
            });
        }
        let cap_frac = links.iter().map(|&l| self.usable[l.0]).fold(1.0f64, f64::min);
        if min_frac > cap_frac || cap_frac <= 0.0 {
            return None; // no start slot can ever offer it (degraded path)
        }
        let mut start = self.slot_of(earliest);
        loop {
            // rate available at the candidate start slot
            let f0 = links
                .iter()
                .map(|&l| self.residual_frac(l, start))
                .fold(1.0f64, f64::min);
            if f0 < min_frac || f0 <= 0.0 {
                // skip the run of starts the point test rejects; beyond the
                // last boundary every link is free, so this terminates
                start = self.next_open(links, start + 1, |r| r < min_frac || r <= 0.0);
                continue;
            }
            // fixed-point on window length
            let mut frac = f0;
            let mut n = self.slots_for(size_mb, frac * capacity_mb_s);
            loop {
                let avail = self.path_residual(links, start, n.max(1));
                if avail + EPS >= frac {
                    return Some(Reservation {
                        links: links.to_vec(),
                        start_slot: start,
                        n_slots: n.max(1),
                        frac,
                    });
                }
                if avail < min_frac || avail <= 0.0 {
                    break; // window blocked; advance start
                }
                frac = avail;
                n = self.slots_for(size_mb, frac * capacity_mb_s);
            }
            // a blocked window can only clear slot by slot (the blocking
            // reservation leaves the window at a bounded offset), so the
            // retry count is bounded by the window length, not the horizon
            start += 1;
        }
    }

    /// A read-only occupancy view over a link subset — the shard layer's
    /// per-shard calendar slice (DESIGN.md §10).
    pub fn view<'a>(&'a self, links: &'a [LinkId]) -> CalendarView<'a> {
        CalendarView { cal: self, links }
    }
}

/// Calendar occupancy scoped to one shard's links. Calendar state is
/// strictly per-link, so a link-partition view is behavior-preserving by
/// construction: views serve shard-local diagnostics and bench
/// accounting, while path admission ([`SlotCalendar::plan_transfer`])
/// stays global because paths cross shards at the core layer.
#[derive(Debug, Clone, Copy)]
pub struct CalendarView<'a> {
    cal: &'a SlotCalendar,
    links: &'a [LinkId],
}

impl CalendarView<'_> {
    pub fn links(&self) -> &[LinkId] {
        self.links
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// Occupancy boundaries across this shard's links only.
    pub fn n_segments(&self) -> usize {
        self.links.iter().map(|&l| self.cal.reserved[l.0].len()).sum()
    }

    /// Residual (unreserved, usable) fraction of one shard link at `slot`.
    pub fn residual_frac(&self, link: LinkId, slot: usize) -> f64 {
        self.cal.residual_frac(link, slot)
    }

    /// Min residual fraction across the shard's links over
    /// `[start, start + n)` (1.0 for an empty shard).
    pub fn window_residual(&self, start: usize, n: usize) -> f64 {
        self.cal.path_residual(self.links, start, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> SlotCalendar {
        SlotCalendar::new(4, 1.0)
    }

    #[test]
    fn slot_of_floors() {
        let c = cal();
        assert_eq!(c.slot_of(Secs(0.0)), 0);
        assert_eq!(c.slot_of(Secs(0.99)), 0);
        assert_eq!(c.slot_of(Secs(3.0)), 3);
        assert_eq!(c.slot_of(Secs(3.5)), 3);
    }

    #[test]
    fn slots_for_paper_example() {
        // 64MB at 12.8 MB/s = 5.0s = 5 slots (Example 1)
        let c = cal();
        assert_eq!(c.slots_for(64.0, 12.8), 5);
        // 64MB at 12.5 MB/s = 5.12s -> 6 slots
        assert_eq!(c.slots_for(64.0, 12.5), 6);
    }

    #[test]
    fn reserve_then_residual_drops() {
        let mut c = cal();
        let links = [LinkId(0), LinkId(2)];
        let r = c.reserve_path(&links, 3, 5, 1.0).unwrap();
        assert_eq!(r.start(1.0), Secs(3.0));
        assert_eq!(r.end(1.0), Secs(8.0));
        assert_eq!(c.residual_frac(LinkId(0), 4), 0.0);
        assert_eq!(c.residual_frac(LinkId(1), 4), 1.0); // untouched link
        assert_eq!(c.residual_frac(LinkId(0), 8), 1.0); // after the window
    }

    #[test]
    fn oversubscription_rejected_and_atomic() {
        let mut c = cal();
        c.reserve_path(&[LinkId(0)], 0, 3, 0.7).unwrap();
        // second reservation over same slots would need 0.4 -> rejected
        assert!(c.reserve_path(&[LinkId(0), LinkId(1)], 1, 2, 0.4).is_err());
        // atomicity: link 1 must be untouched by the failed attempt
        assert_eq!(c.residual_frac(LinkId(1), 1), 1.0);
    }

    #[test]
    fn release_restores() {
        let mut c = cal();
        let r = c.reserve_path(&[LinkId(0)], 2, 4, 0.5).unwrap();
        c.release(&r);
        assert_eq!(c.residual_frac(LinkId(0), 3), 1.0);
    }

    #[test]
    fn find_window_skips_busy_slots() {
        let mut c = cal();
        c.reserve_path(&[LinkId(0)], 2, 3, 1.0).unwrap(); // busy 2..5
        assert_eq!(c.find_window(&[LinkId(0)], 0, 2, 1.0), Some(0));
        assert_eq!(c.find_window(&[LinkId(0)], 1, 2, 1.0), Some(5));
        assert_eq!(c.find_window(&[LinkId(0)], 0, 3, 0.5), Some(5));
    }

    #[test]
    fn plan_transfer_full_rate() {
        let c = cal();
        // Example 1: 64MB, bottleneck 12.8 MB/s, from t=3
        let r = c
            .plan_transfer(&[LinkId(0), LinkId(1)], Secs(3.0), 64.0, 12.8, 0.05)
            .unwrap();
        assert_eq!(r.start_slot, 3);
        assert_eq!(r.n_slots, 5); // TS_4..TS_8 in the paper's 1-based naming
        assert!((r.frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn plan_transfer_degrades_rate_when_partially_reserved() {
        let mut c = cal();
        c.reserve_path(&[LinkId(0)], 0, 100, 0.5).unwrap();
        let r = c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 0.05).unwrap();
        assert!((r.frac - 0.5).abs() < 1e-12);
        assert_eq!(r.n_slots, 10); // half rate, twice the slots
    }

    #[test]
    fn plan_transfer_zero_size_is_instant() {
        let c = cal();
        let r = c.plan_transfer(&[LinkId(0)], Secs(7.0), 0.0, 12.8, 0.05).unwrap();
        assert_eq!(r.n_slots, 0);
        assert_eq!(r.start_slot, 7);
    }

    #[test]
    fn plan_transfer_empty_path_local() {
        let c = cal();
        let r = c.plan_transfer(&[], Secs(1.0), 64.0, 12.8, 0.05).unwrap();
        assert_eq!(r.n_slots, 0);
    }

    // ---- sparse-representation specifics ----

    #[test]
    fn far_future_reservation_stays_sparse() {
        // the dense seed allocated ~10M f64 slots for this; the sparse
        // calendar stores two boundaries
        let mut c = SlotCalendar::new(1, 1.0);
        let r = c.reserve_path(&[LinkId(0)], 10_000_000, 5, 0.5).unwrap();
        assert_eq!(c.n_segments(), 2);
        assert_eq!(c.residual_frac(LinkId(0), 10_000_002), 0.5);
        assert_eq!(c.residual_frac(LinkId(0), 9_999_999), 1.0);
        c.release(&r);
        assert_eq!(c.n_segments(), 0);
    }

    #[test]
    fn find_window_has_no_horizon_cliff() {
        // saturate 5M slots; the seed's MAX_SEARCH_SLOTS (4M) gave up here
        let mut c = SlotCalendar::new(1, 1.0);
        c.reserve_path(&[LinkId(0)], 0, 5_000_000, 1.0).unwrap();
        assert_eq!(c.find_window(&[LinkId(0)], 0, 3, 1.0), Some(5_000_000));
        let r = c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 0.05).unwrap();
        assert_eq!(r.start_slot, 5_000_000);
    }

    #[test]
    fn infeasible_fraction_is_rejected_not_scanned() {
        let c = cal();
        assert_eq!(c.find_window(&[LinkId(0)], 0, 2, 1.5), None);
        assert!(c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 1.5).is_none());
    }

    #[test]
    fn adjacent_equal_reservations_coalesce() {
        let mut c = SlotCalendar::new(1, 1.0);
        c.reserve_path(&[LinkId(0)], 0, 4, 0.25).unwrap();
        c.reserve_path(&[LinkId(0)], 4, 4, 0.25).unwrap();
        // one level over [0, 8): two boundaries, not four
        assert_eq!(c.n_segments(), 2);
        assert_eq!(c.reserved_frac(LinkId(0), 3), 0.25);
        assert_eq!(c.reserved_frac(LinkId(0), 4), 0.25);
        assert_eq!(c.reserved_frac(LinkId(0), 8), 0.0);
    }

    #[test]
    fn overlapping_reservations_stack_and_unstack() {
        let mut c = SlotCalendar::new(1, 1.0);
        let a = c.reserve_path(&[LinkId(0)], 0, 10, 0.3).unwrap();
        let b = c.reserve_path(&[LinkId(0)], 5, 10, 0.3).unwrap();
        assert!((c.reserved_frac(LinkId(0), 2) - 0.3).abs() < 1e-12);
        assert!((c.reserved_frac(LinkId(0), 7) - 0.6).abs() < 1e-12);
        assert!((c.reserved_frac(LinkId(0), 12) - 0.3).abs() < 1e-12);
        c.release(&a);
        assert_eq!(c.reserved_frac(LinkId(0), 2), 0.0);
        assert!((c.reserved_frac(LinkId(0), 7) - 0.3).abs() < 1e-12);
        c.release(&b);
        assert_eq!(c.n_segments(), 0);
    }

    #[test]
    fn fp_dust_from_stacked_releases_does_not_leak_segments() {
        // (0.1 + 0.2) - 0.1 - 0.2 != 0.0 in f64; the dust snap keeps a
        // long-lived calendar from accumulating phantom boundaries
        let mut c = SlotCalendar::new(1, 1.0);
        let a = c.reserve_path(&[LinkId(0)], 0, 10, 0.1).unwrap();
        let b = c.reserve_path(&[LinkId(0)], 5, 10, 0.2).unwrap();
        c.release(&a);
        c.release(&b);
        assert_eq!(c.n_segments(), 0);
        assert_eq!(c.reserved_frac(LinkId(0), 7), 0.0);
    }

    #[test]
    fn forget_before_compacts_history_without_touching_the_future() {
        let mut c = SlotCalendar::new(2, 1.0);
        c.reserve_path(&[LinkId(0)], 0, 5, 0.5).unwrap(); // fully past
        c.reserve_path(&[LinkId(0)], 8, 4, 0.25).unwrap(); // spans the cut
        c.reserve_path(&[LinkId(1)], 20, 2, 1.0).unwrap(); // fully future
        let before = c.n_segments();
        c.forget_before(10);
        assert!(c.n_segments() < before);
        // future queries unchanged: the spanning level survives at the cut
        assert!((c.reserved_frac(LinkId(0), 10) - 0.25).abs() < 1e-12);
        assert_eq!(c.reserved_frac(LinkId(0), 12), 0.0);
        assert_eq!(c.reserved_frac(LinkId(1), 20), 1.0);
        assert_eq!(c.find_window(&[LinkId(1)], 10, 2, 1.0), Some(10));
        // idempotent
        let n = c.n_segments();
        c.forget_before(10);
        assert_eq!(c.n_segments(), n);
    }

    // ---- time-varying capacity (dynamics) ----

    #[test]
    fn degraded_link_lowers_the_reservable_ceiling() {
        let mut c = cal();
        c.set_usable_frac(LinkId(0), 0.5);
        assert_eq!(c.residual_frac(LinkId(0), 3), 0.5);
        // a full-rate reservation no longer fits, half-rate does
        assert!(c.reserve_path(&[LinkId(0)], 0, 4, 1.0).is_err());
        let r = c.reserve_path(&[LinkId(0)], 0, 4, 0.5).unwrap();
        assert_eq!(c.residual_frac(LinkId(0), 2), 0.0);
        c.release(&r);
        c.set_usable_frac(LinkId(0), 1.0); // restoration
        assert_eq!(c.residual_frac(LinkId(0), 2), 1.0);
    }

    #[test]
    fn plan_transfer_grabs_only_the_degraded_residue() {
        let mut c = cal();
        c.set_usable_frac(LinkId(0), 0.5);
        // 64MB at half of 12.8MB/s -> 10 slots
        let r = c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 0.05).unwrap();
        assert!((r.frac - 0.5).abs() < 1e-12);
        assert_eq!(r.n_slots, 10);
        // a demand above the ceiling is rejected outright, not scanned
        assert!(c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 0.6).is_none());
        assert_eq!(c.find_window(&[LinkId(0)], 0, 2, 0.6), None);
        assert_eq!(c.find_window(&[LinkId(0)], 0, 2, 0.5), Some(0));
    }

    #[test]
    fn degradation_invalidates_prior_reservations() {
        let mut c = cal();
        let r = c.reserve_path(&[LinkId(0), LinkId(1)], 2, 5, 0.8).unwrap();
        assert!(c.reservation_within_capacity(&r));
        c.set_usable_frac(LinkId(1), 0.5);
        assert!(!c.reservation_within_capacity(&r), "0.8 > 0.5 ceiling");
        c.set_usable_frac(LinkId(1), 0.8);
        assert!(c.reservation_within_capacity(&r), "exactly at the ceiling");
    }

    #[test]
    fn fully_degraded_link_cannot_host_transfers() {
        let mut c = cal();
        c.set_usable_frac(LinkId(0), 0.0);
        assert!(c.plan_transfer(&[LinkId(0)], Secs(0.0), 64.0, 12.8, 0.05).is_none());
        assert!(c.reserve_path(&[LinkId(0)], 0, 2, 0.1).is_err());
    }

    #[test]
    fn calendar_view_is_scoped_to_its_links() {
        let mut c = SlotCalendar::new(4, 1.0);
        c.reserve_path(&[LinkId(0), LinkId(1)], 2, 3, 0.5).unwrap();
        let left = [LinkId(0), LinkId(1)];
        let right = [LinkId(2), LinkId(3)];
        let v0 = c.view(&left);
        let v1 = c.view(&right);
        assert_eq!(v0.n_links(), 2);
        assert_eq!(v0.n_segments(), 4); // two boundaries per reserved link
        assert_eq!(v1.n_segments(), 0);
        assert!((v0.window_residual(2, 3) - 0.5).abs() < 1e-12);
        assert_eq!(v1.window_residual(2, 3), 1.0);
        assert!((v0.residual_frac(LinkId(0), 3) - 0.5).abs() < 1e-12);
        // empty view: vacuous full residual
        assert_eq!(c.view(&[]).window_residual(0, 100), 1.0);
    }

    #[test]
    fn path_residual_spans_boundaries() {
        let mut c = SlotCalendar::new(2, 1.0);
        c.reserve_path(&[LinkId(0)], 3, 2, 0.4).unwrap();
        c.reserve_path(&[LinkId(1)], 6, 2, 0.7).unwrap();
        // window [0, 10) crosses both: bottleneck is link 1's 0.3 residual
        assert!((c.path_residual(&[LinkId(0), LinkId(1)], 0, 10) - 0.3).abs() < 1e-12);
        // window [0, 5) only sees link 0's 0.6 residual
        assert!((c.path_residual(&[LinkId(0), LinkId(1)], 0, 5) - 0.6).abs() < 1e-12);
    }
}
