//! OpenFlow-style flow table: installed entries + per-entry counters.
//!
//! The simulator's controller installs one entry per admitted transfer
//! (matching on src/dst host and traffic class, the way the paper's
//! Example 3 adds "new flow entries to direct shuffling traffic to Q1").
//! Counters feed the controller's link-statistics view.

use crate::topology::{LinkId, NodeId};
use crate::util::Secs;

use super::qos::QueueId;

/// Coarse traffic classes of the paper's Example 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrafficClass {
    /// MapReduce shuffle traffic (highest priority in Example 3).
    Shuffle,
    /// Other Hadoop traffic: split movement, HDFS replication.
    HadoopOther,
    /// Non-Hadoop background traffic (lowest priority).
    Background,
}

/// One installed flow entry.
#[derive(Debug, Clone)]
pub struct FlowEntry {
    pub id: usize,
    pub src: NodeId,
    pub dst: NodeId,
    pub class: TrafficClass,
    pub path: Vec<LinkId>,
    pub queue: Option<QueueId>,
    pub installed_at: Secs,
    /// Cumulative bytes forwarded (MB) — OpenFlow per-flow counter.
    pub mb_forwarded: f64,
}

/// The controller's flow table.
#[derive(Debug, Clone, Default)]
pub struct FlowTable {
    entries: Vec<FlowEntry>,
    next_id: usize,
}

impl FlowTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install an entry; returns its id (flow cookie).
    pub fn install(
        &mut self,
        src: NodeId,
        dst: NodeId,
        class: TrafficClass,
        path: Vec<LinkId>,
        queue: Option<QueueId>,
        at: Secs,
    ) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        self.entries.push(FlowEntry {
            id,
            src,
            dst,
            class,
            path,
            queue,
            installed_at: at,
            mb_forwarded: 0.0,
        });
        id
    }

    /// Remove an entry (flow-removed message); returns it if present.
    pub fn remove(&mut self, id: usize) -> Option<FlowEntry> {
        let idx = self.entries.iter().position(|e| e.id == id)?;
        Some(self.entries.swap_remove(idx))
    }

    pub fn get_mut(&mut self, id: usize) -> Option<&mut FlowEntry> {
        self.entries.iter_mut().find(|e| e.id == id)
    }

    pub fn get(&self, id: usize) -> Option<&FlowEntry> {
        self.entries.iter().find(|e| e.id == id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &FlowEntry> {
        self.entries.iter()
    }

    /// Entries whose path crosses `link` (for port-stats aggregation).
    pub fn on_link(&self, link: LinkId) -> impl Iterator<Item = &FlowEntry> {
        self.entries.iter().filter(move |e| e.path.contains(&link))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_remove_roundtrip() {
        let mut t = FlowTable::new();
        let id = t.install(
            NodeId(0),
            NodeId(1),
            TrafficClass::Shuffle,
            vec![LinkId(0), LinkId(1)],
            None,
            Secs(1.0),
        );
        assert_eq!(t.len(), 1);
        let e = t.remove(id).unwrap();
        assert_eq!(e.src, NodeId(0));
        assert!(t.is_empty());
        assert!(t.remove(id).is_none());
    }

    #[test]
    fn ids_are_unique_across_removals() {
        let mut t = FlowTable::new();
        let a = t.install(NodeId(0), NodeId(1), TrafficClass::Background, vec![], None, Secs(0.0));
        t.remove(a);
        let b = t.install(NodeId(0), NodeId(1), TrafficClass::Background, vec![], None, Secs(0.0));
        assert_ne!(a, b);
    }

    #[test]
    fn on_link_filters() {
        let mut t = FlowTable::new();
        t.install(NodeId(0), NodeId(1), TrafficClass::Shuffle, vec![LinkId(0)], None, Secs(0.0));
        t.install(NodeId(2), NodeId(3), TrafficClass::Shuffle, vec![LinkId(1)], None, Secs(0.0));
        assert_eq!(t.on_link(LinkId(0)).count(), 1);
        assert_eq!(t.on_link(LinkId(7)).count(), 0);
    }

    #[test]
    fn counters_accumulate() {
        let mut t = FlowTable::new();
        let id = t.install(NodeId(0), NodeId(1), TrafficClass::Shuffle, vec![LinkId(0)], None, Secs(0.0));
        t.get_mut(id).unwrap().mb_forwarded += 64.0;
        t.get_mut(id).unwrap().mb_forwarded += 32.0;
        assert!((t.get(id).unwrap().mb_forwarded - 96.0).abs() < 1e-12);
    }
}
