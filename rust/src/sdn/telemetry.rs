//! The measured control plane: probes, estimators, and bandwidth views.
//!
//! Every scheduler before this module read *clairvoyant* bandwidth — the
//! controller's exact calendar and link state at query time. A real SDN
//! controller measures: it probes ports periodically, smooths the
//! samples, and schedules from estimates that are noisy and stale. This
//! module supplies that layer (DESIGN.md §12):
//!
//! * [`Telemetry`] — a seeded probe loop sampling each link's
//!   *environment* (usable-fraction health and background load) on a
//!   `probe_period` grid, feeding per-link EWMA estimators with
//!   staleness expiry.
//! * [`BandwidthView`] — the trait schedulers consume instead of calling
//!   [`Controller`] bandwidth getters directly. [`Oracle`] delegates to
//!   the controller (bit-identical to the pre-telemetry code paths, and
//!   the default everywhere); [`Measured`] combines the controller's
//!   *exact* reservation ledger with the *estimated* link environment.
//!
//! The split matters: reservations are the controller's own bookkeeping
//! (it granted them, it knows them exactly — no probe needed), while
//! health and cross traffic are external facts it can only measure. A
//! `Measured` view therefore stays coherent mid-batch as BASS commits
//! reservations, and collapses to `Oracle` bit-for-bit when noise is
//! zero and probes are fresh — the convergence contract the estimate
//! sweep (`experiments/estimate.rs`) leans on.
//!
//! Mid-flow reallocation (the loop-closing half: renegotiating grants
//! whose links drifted) lives with the mitigated runner in
//! `scenario/mitigation.rs`; the utility-weighted max-min share rule it
//! orders renegotiations by is [`weighted_max_min`] here.

use crate::topology::{LinkId, NodeId};
use crate::util::{Secs, XorShift};

use super::controller::Controller;

/// Probe epochs processed per `advance` call are capped so a
/// pathologically tiny `probe_period` cannot spin the loop for hours of
/// simulated time; only the most recent epochs are played (EWMA history
/// further back is geometrically negligible). Deterministic: the cap
/// depends only on the spec and the advance times.
const MAX_EPOCHS_PER_ADVANCE: usize = 10_000;

/// Configuration of the measurement plane (the `[telemetry]` table).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySpec {
    /// Seconds between probe sweeps. `0` = continuous: every `advance`
    /// refreshes every estimate (the probe_period -> 0 limit).
    pub probe_period: f64,
    /// Relative (multiplicative) Gaussian noise sigma on each sample:
    /// `sample = truth * (1 + noise * N(0,1))`. `0` = exact probes.
    pub noise: f64,
    /// EWMA gain in (0, 1]: `est += alpha * (sample - est)`. 1 = keep
    /// only the latest sample, adopted bit-exactly (no blend rounding).
    pub alpha: f64,
    /// Estimates older than this fall back to the static healthy prior
    /// (full health, no background); a probe gap beyond it resets the
    /// EWMA instead of blending across the hole.
    pub stale_secs: f64,
    /// Probe-noise RNG seed (independent of workload/dynamics seeds).
    pub seed: u64,
    /// Renegotiate drifting calendar grants at probe epochs (the
    /// mitigated runner's reallocation pass).
    pub reallocate: bool,
}

impl TelemetrySpec {
    /// The default measured plane: 5s probes, exact samples, mild
    /// smoothing, no reallocation.
    pub fn measured() -> Self {
        Self {
            probe_period: 5.0,
            noise: 0.0,
            alpha: 0.3,
            stale_secs: 30.0,
            seed: 4457,
            reallocate: false,
        }
    }
}

/// One link's estimated environment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkEstimate {
    /// Estimated usable capacity fraction (health), clamped to [0, 1].
    pub usable: f64,
    /// Estimated background load, MB/s (>= 0).
    pub bg_mb_s: f64,
    /// When the estimate was last refreshed.
    pub at: Secs,
}

/// The probe loop + per-link EWMA estimators.
#[derive(Debug, Clone)]
pub struct Telemetry {
    pub spec: TelemetrySpec,
    est: Vec<Option<LinkEstimate>>,
    rng: XorShift,
    next_probe: Secs,
    /// Probe sweeps executed so far (diagnostics).
    pub probes: usize,
}

impl Telemetry {
    pub fn new(spec: TelemetrySpec, n_links: usize) -> Self {
        let rng = XorShift::new(spec.seed);
        Self { spec, est: vec![None; n_links], rng, next_probe: Secs::ZERO, probes: 0 }
    }

    /// Play every probe epoch up to `now` (inclusive). Call sites drive
    /// this from their own clocks (scheduling rounds, mitigation
    /// checkpoints); the epoch grid and the per-link RNG draw order are
    /// fixed by the spec, so estimates at a given `now` do not depend on
    /// how the calls were batched (up to [`MAX_EPOCHS_PER_ADVANCE`]).
    pub fn advance(&mut self, ctrl: &Controller, now: Secs) {
        if self.spec.probe_period <= 0.0 {
            self.probe(ctrl, now);
            return;
        }
        let pending =
            ((now.0 - self.next_probe.0) / self.spec.probe_period).max(0.0) as usize;
        if pending > MAX_EPOCHS_PER_ADVANCE {
            // skip all but the newest epochs, keeping the grid phase
            let skipped = pending - MAX_EPOCHS_PER_ADVANCE;
            self.next_probe.0 += skipped as f64 * self.spec.probe_period;
        }
        while self.next_probe.0 <= now.0 {
            let t = self.next_probe;
            self.probe(ctrl, t);
            self.next_probe.0 += self.spec.probe_period;
        }
    }

    /// One probe sweep at time `t`: sample every link's environment with
    /// multiplicative Gaussian noise and fold it into the estimators.
    fn probe(&mut self, ctrl: &Controller, t: Secs) {
        for i in 0..self.est.len() {
            let link = LinkId(i);
            let (mut usable, mut bg) = (ctrl.link_health(link), ctrl.background_mb_s(link));
            if self.spec.noise > 0.0 {
                usable *= 1.0 + self.spec.noise * gaussian(&mut self.rng);
                bg *= 1.0 + self.spec.noise * gaussian(&mut self.rng);
            }
            let usable = usable.clamp(0.0, 1.0);
            let bg = bg.max(0.0);
            self.est[i] = Some(match self.est[i] {
                // a gap beyond stale_secs resets instead of blending
                // across the hole; `est += a * (sample - est)` is an
                // exact fixpoint when the sample repeats, so zero-noise
                // estimates of a static environment are bit-exact.
                // alpha >= 1 adopts the sample outright — `p + (s - p)`
                // is not guaranteed to round back to `s` — giving the
                // estimate sweep its exact-tracking convergence limit
                Some(p) if t.0 - p.at.0 <= self.spec.stale_secs && self.spec.alpha < 1.0 => LinkEstimate {
                    usable: p.usable + self.spec.alpha * (usable - p.usable),
                    bg_mb_s: p.bg_mb_s + self.spec.alpha * (bg - p.bg_mb_s),
                    at: t,
                },
                _ => LinkEstimate { usable, bg_mb_s: bg, at: t },
            });
        }
        self.probes += 1;
    }

    /// The current `(usable, bg_mb_s)` estimate for a link, or `None`
    /// when nothing fresh is known (never probed, or last refresh is
    /// more than `stale_secs` before `now`).
    pub fn estimate(&self, link: LinkId, now: Secs) -> Option<(f64, f64)> {
        self.est[link.0]
            .filter(|e| now.0 - e.at.0 <= self.spec.stale_secs)
            .map(|e| (e.usable, e.bg_mb_s))
    }

}

/// Standard normal draw (Box–Muller on the XorShift uniforms).
fn gaussian(rng: &mut XorShift) -> f64 {
    let u1 = rng.uniform(f64::MIN_POSITIVE, 1.0);
    let u2 = rng.uniform(0.0, 1.0);
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// What a scheduler is allowed to know about bandwidth. Every method
/// takes the controller by shared reference so a `SchedCtx` can hold the
/// view and `&mut Controller` side by side.
pub trait BandwidthView {
    /// `BW_rl` of the path at `at`; `None` = unreachable (distinct from
    /// `Some(0.0)` = congested/degraded to zero).
    fn try_path_bw_mb_s(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
    ) -> Option<f64>;

    /// Span-aware `BW_rl`: worst over every slot `[at, at + duration)`
    /// covers (see [`Controller::try_path_bw_over`]).
    fn try_path_bw_over(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
        duration: Secs,
    ) -> Option<f64>;

    /// Scheduler-priced bottleneck capacity of a path (health-scaled,
    /// net of background, ignoring per-slot reservations).
    fn path_capacity_mb_s(&self, ctrl: &Controller, links: &[LinkId]) -> f64;

    /// Unreachable-collapsed convenience (matches the historical
    /// `Controller::path_bw_mb_s` contract).
    fn path_bw_mb_s(&self, ctrl: &Controller, src: NodeId, dst: NodeId, at: Secs) -> f64 {
        self.try_path_bw_mb_s(ctrl, src, dst, at).unwrap_or(0.0)
    }

    /// Unreachable-collapsed span pricing.
    fn path_bw_over(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
        duration: Secs,
    ) -> f64 {
        self.try_path_bw_over(ctrl, src, dst, at, duration).unwrap_or(0.0)
    }
}

/// The clairvoyant view: exactly the controller's own numbers. This is
/// the default everywhere a `[telemetry]` table is absent, and is
/// bit-identical to calling the controller directly.
#[derive(Debug, Clone, Copy, Default)]
pub struct Oracle;

impl BandwidthView for Oracle {
    fn try_path_bw_mb_s(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
    ) -> Option<f64> {
        ctrl.try_path_bw_mb_s(src, dst, at)
    }

    fn try_path_bw_over(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
        duration: Secs,
    ) -> Option<f64> {
        ctrl.try_path_bw_over(src, dst, at, duration)
    }

    fn path_capacity_mb_s(&self, ctrl: &Controller, links: &[LinkId]) -> f64 {
        ctrl.path_capacity_mb_s(links)
    }
}

/// The measured view: the controller's exact reservation ledger plus the
/// *estimated* link environment from [`Telemetry`]. Links without a
/// fresh estimate fall back to the static healthy prior (full health,
/// zero background) — exactly what a controller that has never heard
/// from a port must assume.
#[derive(Debug, Clone, Copy)]
pub struct Measured<'t> {
    telem: &'t Telemetry,
    /// Staleness reference clock (usually the scheduling round's `now`).
    now: Secs,
}

impl<'t> Measured<'t> {
    pub fn at(telem: &'t Telemetry, now: Secs) -> Self {
        Self { telem, now }
    }

    fn env(&self, link: LinkId) -> (f64, f64) {
        self.telem.estimate(link, self.now).unwrap_or((1.0, 0.0))
    }

    /// Estimated free capacity of one link over slots `[lo, lo + n)`:
    /// mirrors [`Controller::link_free_over`] with the estimated
    /// environment substituted for the true one (same operation order,
    /// so exact estimates reproduce the oracle bit-for-bit).
    fn link_free(&self, ctrl: &Controller, link: LinkId, lo: usize, n: usize) -> f64 {
        let (usable, bg) = self.env(link);
        let peak = ctrl.calendar.peak_reserved(link, lo, n);
        (ctrl.link_capacity_mb_s(link) * (usable - peak).max(0.0) - bg).max(0.0)
    }
}

impl BandwidthView for Measured<'_> {
    fn try_path_bw_mb_s(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
    ) -> Option<f64> {
        let links = ctrl.path(src, dst)?;
        if links.is_empty() {
            return Some(f64::INFINITY);
        }
        let slot = ctrl.calendar.slot_of(at);
        Some(
            links
                .iter()
                .map(|&l| self.link_free(ctrl, l, slot, 1))
                .fold(f64::INFINITY, f64::min),
        )
    }

    fn try_path_bw_over(
        &self,
        ctrl: &Controller,
        src: NodeId,
        dst: NodeId,
        at: Secs,
        duration: Secs,
    ) -> Option<f64> {
        let links = ctrl.path(src, dst)?;
        if links.is_empty() {
            return Some(f64::INFINITY);
        }
        let lo = ctrl.calendar.slot_of(at);
        let n = ctrl.span_slots(at, duration, lo);
        Some(
            links
                .iter()
                .map(|&l| self.link_free(ctrl, l, lo, n))
                .fold(f64::INFINITY, f64::min),
        )
    }

    fn path_capacity_mb_s(&self, ctrl: &Controller, links: &[LinkId]) -> f64 {
        links
            .iter()
            .map(|&l| {
                let (usable, bg) = self.env(l);
                (ctrl.link_capacity_mb_s(l) * usable - bg).max(0.0)
            })
            .fold(f64::INFINITY, f64::min)
    }
}

/// Utility-weighted max-min (water-filling): split `capacity` across
/// flows with `demands` and positive `weights` so that no flow can gain
/// without a higher-weighted or equally-weighted flow losing. Saturated
/// flows (share == demand) drop out; the rest split the remainder in
/// weight proportion. The reallocator derives per-class target shares
/// from estimated path capacity with this rule before renegotiating
/// grants (QoS classes keep their priority under drift).
pub fn weighted_max_min(capacity: f64, demands: &[f64], weights: &[f64]) -> Vec<f64> {
    assert_eq!(demands.len(), weights.len());
    let n = demands.len();
    let mut share = vec![0.0; n];
    let mut active: Vec<usize> =
        (0..n).filter(|&i| demands[i] > 0.0 && weights[i] > 0.0).collect();
    let mut left = capacity.max(0.0);
    while !active.is_empty() && left > 1e-12 {
        let wsum: f64 = active.iter().map(|&i| weights[i]).sum();
        let fill = left / wsum;
        let saturated: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&i| demands[i] - share[i] <= fill * weights[i] + 1e-12)
            .collect();
        if saturated.is_empty() {
            for &i in &active {
                share[i] += fill * weights[i];
            }
            break;
        }
        for &i in &saturated {
            left -= demands[i] - share[i];
            share[i] = demands[i];
        }
        left = left.max(0.0);
        active.retain(|i| !saturated.contains(i));
    }
    share
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdn::TrafficClass;
    use crate::topology::builders::fig2;

    fn ctrl() -> (Controller, [NodeId; 4]) {
        let f = fig2(102.4); // 12.8 MB/s effective links
        let nodes = f.task_nodes;
        (Controller::new(f.topo, 1.0), nodes)
    }

    fn spec(noise: f64, period: f64) -> TelemetrySpec {
        TelemetrySpec { probe_period: period, noise, ..TelemetrySpec::measured() }
    }

    #[test]
    fn zero_noise_probe_is_bit_exact_and_stays_at_the_fixpoint() {
        let (mut c, n) = ctrl();
        let link = c.path(n[1], n[0]).unwrap()[0];
        c.set_link_health(link, 0.37);
        c.set_background_mb_s(link, 2.5);
        let mut tm = Telemetry::new(spec(0.0, 5.0), c.topo().n_links());
        tm.advance(&c, Secs(0.0));
        assert_eq!(tm.estimate(link, Secs(0.0)), Some((0.37, 2.5)));
        // repeated probes of a static environment never drift a ulp
        tm.advance(&c, Secs(25.0));
        assert_eq!(tm.probes, 6);
        let (u, bg) = tm.estimate(link, Secs(25.0)).unwrap();
        assert_eq!(u.to_bits(), 0.37f64.to_bits());
        assert_eq!(bg.to_bits(), 2.5f64.to_bits());
    }

    #[test]
    fn ewma_converges_geometrically_to_a_changed_truth() {
        let (mut c, n) = ctrl();
        let link = c.path(n[1], n[0]).unwrap()[0];
        let mut tm = Telemetry::new(spec(0.0, 1.0), c.topo().n_links());
        tm.advance(&c, Secs(0.0)); // healthy baseline: est = 1.0
        c.set_link_health(link, 0.5);
        let mut prev_err = f64::INFINITY;
        for k in 1..=20 {
            tm.advance(&c, Secs(k as f64));
            let (u, _) = tm.estimate(link, Secs(k as f64)).unwrap();
            let err = (u - 0.5).abs();
            assert!(err < prev_err || err == 0.0, "monotone approach at step {k}");
            prev_err = err;
        }
        assert!(prev_err < 1e-3, "converged, err {prev_err}");
    }

    #[test]
    fn staleness_expires_estimates_and_resets_the_blend() {
        let (mut c, n) = ctrl();
        let link = c.path(n[1], n[0]).unwrap()[0];
        c.set_link_health(link, 0.4);
        let mut tm = Telemetry::new(
            TelemetrySpec { stale_secs: 8.0, ..spec(0.0, 5.0) },
            c.topo().n_links(),
        );
        tm.advance(&c, Secs(0.0));
        assert!(tm.estimate(link, Secs(8.0)).is_some());
        assert_eq!(tm.estimate(link, Secs(8.1)), None, "past stale_secs");
        // a Measured view past staleness falls back to the healthy prior
        let m = Measured::at(&tm, Secs(9.0));
        let bw = m.path_bw_mb_s(&c, n[1], n[0], Secs(9.0));
        assert!((bw - 12.8).abs() < 1e-9, "prior ignores the unseen degradation: {bw}");
        // the next probe resets rather than blending across the hole:
        // alpha 0.3 of truth would give 1 - 0.3*0.6 = 0.82, reset gives 0.4
        let mut gap = Telemetry::new(
            TelemetrySpec { stale_secs: 8.0, ..spec(0.0, 20.0) },
            c.topo().n_links(),
        );
        gap.advance(&c, Secs(0.0));
        gap.advance(&c, Secs(20.0));
        // both probes saw 0.4 here; rebuild with a change between probes
        let mut gap2 = Telemetry::new(
            TelemetrySpec { stale_secs: 8.0, ..spec(0.0, 20.0) },
            c.topo().n_links(),
        );
        c.set_link_health(link, 1.0);
        gap2.advance(&c, Secs(0.0)); // sees healthy
        c.set_link_health(link, 0.4);
        gap2.advance(&c, Secs(20.0)); // gap > stale: reset to 0.4 exactly
        assert_eq!(gap2.estimate(link, Secs(20.0)), Some((0.4, 0.0)));
    }

    #[test]
    fn alpha_one_tracks_a_moving_truth_exactly() {
        let (mut c, n) = ctrl();
        let link = c.path(n[1], n[0]).unwrap()[0];
        let mut tm = Telemetry::new(
            TelemetrySpec { alpha: 1.0, ..spec(0.0, 1.0) },
            c.topo().n_links(),
        );
        tm.advance(&c, Secs(0.0));
        c.set_link_health(link, 0.123456789);
        c.set_background_mb_s(link, 7.654321);
        tm.advance(&c, Secs(1.0)); // one probe after the change suffices
        let (u, bg) = tm.estimate(link, Secs(1.0)).unwrap();
        assert_eq!(u.to_bits(), 0.123456789f64.to_bits());
        assert_eq!(bg.to_bits(), 7.654321f64.to_bits());
    }

    #[test]
    fn seeded_noise_is_deterministic_and_seed_sensitive() {
        let (c, n) = ctrl();
        let link = c.path(n[1], n[0]).unwrap()[0];
        let run = |seed: u64| {
            let mut tm = Telemetry::new(
                TelemetrySpec { seed, ..spec(0.2, 1.0) },
                c.topo().n_links(),
            );
            tm.advance(&c, Secs(10.0));
            tm.estimate(link, Secs(10.0)).unwrap()
        };
        let (a1, b1) = run(7);
        let (a2, b2) = run(7);
        assert_eq!(a1.to_bits(), a2.to_bits());
        assert_eq!(b1.to_bits(), b2.to_bits());
        let (a3, _) = run(8);
        assert_ne!(a1.to_bits(), a3.to_bits(), "different seed, different noise");
        // noise stays within the clamp
        assert!((0.0..=1.0).contains(&a1));
    }

    #[test]
    fn continuous_mode_refreshes_on_every_advance() {
        let (c, _) = ctrl();
        let mut tm = Telemetry::new(spec(0.0, 0.0), c.topo().n_links());
        tm.advance(&c, Secs(0.3));
        tm.advance(&c, Secs(0.7));
        assert_eq!(tm.probes, 2);
        assert!(tm.estimate(LinkId(0), Secs(0.7)).is_some());
    }

    #[test]
    fn pathological_probe_period_is_capped_not_spun() {
        let (c, _) = ctrl();
        let mut tm = Telemetry::new(spec(0.0, 1e-6), c.topo().n_links());
        tm.advance(&c, Secs(100.0)); // 1e8 nominal epochs
        assert!(tm.probes <= MAX_EPOCHS_PER_ADVANCE + 1);
        assert!(tm.estimate(LinkId(0), Secs(100.0)).is_some());
    }

    #[test]
    fn fresh_exact_measured_view_is_bit_identical_to_oracle() {
        // reservations + degradation + background all at once: the
        // measured view must reproduce the oracle exactly when the
        // estimated environment equals the true one
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 48.0, Secs(2.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(2.0)).unwrap();
        let link = c.path(n[2], n[0]).unwrap()[0];
        c.set_link_health(link, 0.6);
        c.set_background_mb_s(link, 1.5);
        let mut tm = Telemetry::new(spec(0.0, 5.0), c.topo().n_links());
        tm.advance(&c, Secs(10.0));
        let m = Measured::at(&tm, Secs(10.0));
        let o = Oracle;
        for src in [n[0], n[1], n[2], n[3]] {
            for dst in [n[0], n[1], n[2], n[3]] {
                for at in [0.0, 2.5, 4.0, 9.0] {
                    let a = o.try_path_bw_mb_s(&c, src, dst, Secs(at));
                    let b = m.try_path_bw_mb_s(&c, src, dst, Secs(at));
                    assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits), "{src:?}->{dst:?}@{at}");
                    let a = o.try_path_bw_over(&c, src, dst, Secs(at), Secs(3.0));
                    let b = m.try_path_bw_over(&c, src, dst, Secs(at), Secs(3.0));
                    assert_eq!(a.map(f64::to_bits), b.map(f64::to_bits), "over {src:?}->{dst:?}");
                }
                if src != dst {
                    let links: Vec<_> = c.path(src, dst).unwrap().to_vec();
                    let a = o.path_capacity_mb_s(&c, &links);
                    let b = m.path_capacity_mb_s(&c, &links);
                    assert_eq!(a.to_bits(), b.to_bits(), "capacity {src:?}->{dst:?}");
                }
            }
        }
    }

    #[test]
    fn noisy_measured_view_diverges_from_oracle() {
        let (c, n) = ctrl();
        let mut tm = Telemetry::new(spec(0.4, 1.0), c.topo().n_links());
        tm.advance(&c, Secs(0.0));
        let m = Measured::at(&tm, Secs(0.0));
        let bw_m = m.path_bw_mb_s(&c, n[1], n[0], Secs(0.0));
        let bw_o = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert_ne!(bw_m.to_bits(), bw_o.to_bits(), "noise must actually perturb");
        assert!(bw_m >= 0.0);
    }

    #[test]
    fn weighted_max_min_fills_water() {
        // equal weights, ample capacity: everyone gets their demand
        assert_eq!(weighted_max_min(100.0, &[10.0, 20.0], &[1.0, 1.0]), vec![10.0, 20.0]);
        // tight capacity, equal weights: even split
        let s = weighted_max_min(10.0, &[20.0, 20.0], &[1.0, 1.0]);
        assert!((s[0] - 5.0).abs() < 1e-9 && (s[1] - 5.0).abs() < 1e-9);
        // weights tilt the unsaturated split 2:1
        let s = weighted_max_min(30.0, &[100.0, 100.0], &[2.0, 1.0]);
        assert!((s[0] - 20.0).abs() < 1e-9 && (s[1] - 10.0).abs() < 1e-9);
        // a small demand saturates and releases its weight to the rest
        let s = weighted_max_min(30.0, &[4.0, 100.0, 100.0], &[1.0, 1.0, 1.0]);
        assert!((s[0] - 4.0).abs() < 1e-9);
        assert!((s[1] - 13.0).abs() < 1e-9 && (s[2] - 13.0).abs() < 1e-9);
        // zero weight or demand gets nothing; conservation holds
        let s = weighted_max_min(10.0, &[5.0, 0.0, 8.0], &[1.0, 1.0, 0.0]);
        assert_eq!(s[1], 0.0);
        assert_eq!(s[2], 0.0);
        assert!((s[0] - 5.0).abs() < 1e-9);
        assert!(s.iter().sum::<f64>() <= 10.0 + 1e-9);
    }
}
