//! OpenFlow QoS queues (Discussion 3 / Example 3 of the paper).
//!
//! Example 3 caps both OpenFlow switches at 150 Mbps and sets up three
//! egress queues — Q1 = 100 Mbps (shuffle), Q2 = 40 Mbps (other Hadoop),
//! Q3 = 10 Mbps (background) — versus the default scheme where all
//! traffic shares the 150 Mbps fairly. [`QosPolicy`] captures both modes
//! and answers "what rate does a flow of class C get when k flows of each
//! class are active?", which is what the fluid flow model in
//! [`crate::sim::flownet`] needs.

use super::flowtable::TrafficClass;

/// Queue identifier (index into the policy's queue list).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QueueId(pub usize);

/// One rate-limited egress queue.
#[derive(Debug, Clone)]
pub struct Queue {
    pub id: QueueId,
    pub rate_mbps: f64,
    pub label: &'static str,
}

/// A per-switch QoS configuration.
#[derive(Debug, Clone)]
pub struct QosPolicy {
    /// Total egress rate of the switch (paper: 150 Mbps).
    pub max_rate_mbps: f64,
    /// Rate-limited queues; empty = default single shared queue.
    pub queues: Vec<Queue>,
}

impl QosPolicy {
    /// The paper's Example 3 policy: Q1=100 (shuffle), Q2=40 (other),
    /// Q3=10 (background) on a 150 Mbps switch.
    pub fn example3() -> Self {
        Self {
            max_rate_mbps: 150.0,
            queues: vec![
                Queue { id: QueueId(0), rate_mbps: 100.0, label: "Q1-shuffle" },
                Queue { id: QueueId(1), rate_mbps: 40.0, label: "Q2-hadoop" },
                Queue { id: QueueId(2), rate_mbps: 10.0, label: "Q3-background" },
            ],
        }
    }

    /// The paper's default comparison: one shared queue at the max rate.
    pub fn default_shared(max_rate_mbps: f64) -> Self {
        Self { max_rate_mbps, queues: Vec::new() }
    }

    /// Queue a traffic class maps to (`None` in shared mode).
    pub fn classify(&self, class: TrafficClass) -> Option<QueueId> {
        if self.queues.is_empty() {
            return None;
        }
        let idx = match class {
            TrafficClass::Shuffle => 0,
            TrafficClass::HadoopOther => 1,
            TrafficClass::Background => 2,
        };
        Some(self.queues[idx.min(self.queues.len() - 1)].id)
    }

    /// Per-flow rate (Mbps) for a flow of `class` when `counts[c]` flows of
    /// each class are concurrently active on the egress.
    ///
    /// Queued mode: each queue's rate is split fairly among its own flows;
    /// shared mode: the max rate is split fairly among all flows.
    pub fn flow_rate_mbps(&self, class: TrafficClass, counts: &ClassCounts) -> f64 {
        let total = counts.total();
        if total == 0 {
            return 0.0;
        }
        if self.queues.is_empty() {
            return self.max_rate_mbps / total as f64;
        }
        let q = &self.queues[self.classify(class).expect("queued mode").0];
        let in_class = counts.get(class).max(1);
        q.rate_mbps / in_class as f64
    }
}

/// Active-flow counts per class on one egress.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassCounts {
    pub shuffle: usize,
    pub hadoop_other: usize,
    pub background: usize,
}

impl ClassCounts {
    pub fn get(&self, c: TrafficClass) -> usize {
        match c {
            TrafficClass::Shuffle => self.shuffle,
            TrafficClass::HadoopOther => self.hadoop_other,
            TrafficClass::Background => self.background,
        }
    }

    pub fn add(&mut self, c: TrafficClass) {
        match c {
            TrafficClass::Shuffle => self.shuffle += 1,
            TrafficClass::HadoopOther => self.hadoop_other += 1,
            TrafficClass::Background => self.background += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.shuffle + self.hadoop_other + self.background
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example3_shape() {
        let p = QosPolicy::example3();
        assert_eq!(p.max_rate_mbps, 150.0);
        let rates: Vec<f64> = p.queues.iter().map(|q| q.rate_mbps).collect();
        assert_eq!(rates, vec![100.0, 40.0, 10.0]);
    }

    #[test]
    fn classify_maps_paper_classes() {
        let p = QosPolicy::example3();
        assert_eq!(p.classify(TrafficClass::Shuffle), Some(QueueId(0)));
        assert_eq!(p.classify(TrafficClass::HadoopOther), Some(QueueId(1)));
        assert_eq!(p.classify(TrafficClass::Background), Some(QueueId(2)));
        let shared = QosPolicy::default_shared(150.0);
        assert_eq!(shared.classify(TrafficClass::Shuffle), None);
    }

    #[test]
    fn queued_mode_isolates_shuffle_from_background() {
        let p = QosPolicy::example3();
        let counts =
            ClassCounts { shuffle: 1, hadoop_other: 0, background: 10 };
        // shuffle keeps its full 100 Mbps despite 10 background flows
        assert_eq!(p.flow_rate_mbps(TrafficClass::Shuffle, &counts), 100.0);
        assert_eq!(p.flow_rate_mbps(TrafficClass::Background, &counts), 1.0);
    }

    #[test]
    fn shared_mode_dilutes_shuffle() {
        let p = QosPolicy::default_shared(150.0);
        let counts =
            ClassCounts { shuffle: 1, hadoop_other: 0, background: 10 };
        let r = p.flow_rate_mbps(TrafficClass::Shuffle, &counts);
        assert!((r - 150.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn fair_split_within_queue() {
        let p = QosPolicy::example3();
        let counts = ClassCounts { shuffle: 4, hadoop_other: 0, background: 0 };
        assert_eq!(p.flow_rate_mbps(TrafficClass::Shuffle, &counts), 25.0);
    }

    #[test]
    fn zero_flows_zero_rate() {
        let p = QosPolicy::example3();
        assert_eq!(p.flow_rate_mbps(TrafficClass::Shuffle, &ClassCounts::default()), 0.0);
    }
}
