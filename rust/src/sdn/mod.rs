//! SDN substrate: the OpenFlow-style controller the paper leans on.
//!
//! The paper's BASS scheduler consumes three controller capabilities:
//!
//! 1. **Real-time residual bandwidth** `BW_rl` per link/path (OpenFlow
//!    port stats) — [`controller::Controller::path_bw_mbps`].
//! 2. **Time-Slot bandwidth allocation** (`SL_rl`, Section IV-A): each
//!    link's future capacity is split into fixed-duration slots that the
//!    scheduler reserves along a path before moving a split —
//!    [`calendar::SlotCalendar`].
//! 3. **QoS queues** (Discussion 3 / Example 3): per-class egress queues
//!    (Q1/Q2/Q3) that prioritize shuffle traffic — [`qos`].

pub mod calendar;
pub mod controller;
pub mod flowtable;
pub mod qos;
pub mod telemetry;

pub use calendar::{CalendarView, Reservation, SlotCalendar};
pub use controller::{Controller, Renegotiation};
pub use flowtable::{FlowEntry, FlowTable, TrafficClass};
pub use qos::{QosPolicy, Queue, QueueId};
pub use telemetry::{
    weighted_max_min, BandwidthView, Measured, Oracle, Telemetry, TelemetrySpec,
};
