//! The SDN controller: the scheduler's window into the network.
//!
//! Mirrors what the paper extracts from OpenFlow: per-link statistics
//! (capacity, background usage, current reservations), path lookup, the
//! time-slot calendar, and flow-entry installation for admitted
//! transfers. All bandwidth figures exposed to schedulers are **MB/s**
//! (Eq. 1 works in MB and seconds).
//!
//! Simplification (documented in DESIGN.md): a path reservation grabs the
//! same capacity *fraction* on every link of the path. With the paper's
//! uniform link rates this is exact; with heterogeneous rates it
//! over-reserves the faster links, which is conservative.

use crate::cluster::ShardPlan;
use crate::topology::{host_racks, Endpoint, LinkId, NodeId, PathCache, PathRef, Topology};
use crate::util::{mbps_to_mb_per_s, Secs};

use super::calendar::{CalendarView, Reservation, SlotCalendar};
use super::flowtable::{FlowTable, TrafficClass};
use super::qos::QosPolicy;

/// Minimum capacity fraction worth reserving; below this a remote
/// placement is treated as bandwidth-starved (Case 1.3).
pub const MIN_RESERVE_FRAC: f64 = 0.02;

/// An admitted, slot-reserved transfer.
#[derive(Debug, Clone)]
pub struct Transfer {
    pub flow_id: usize,
    pub reservation: Reservation,
    /// Granted rate in MB/s (bottleneck capacity x reserved fraction).
    pub rate_mb_s: f64,
    /// When the last byte lands.
    pub arrival: Secs,
    /// When the first byte leaves.
    pub start: Secs,
}

/// The central controller (one per cluster, as in Fig. 1/2).
#[derive(Debug, Clone)]
pub struct Controller {
    topo: Topology,
    cache: PathCache,
    pub calendar: SlotCalendar,
    /// Static background load per link, MB/s (subtracted from capacity).
    background_mb_s: Vec<f64>,
    pub flows: FlowTable,
    pub qos: QosPolicy,
    /// Scheduler-state shard plan (DESIGN.md §10): one shard per rack by
    /// default, overridable via [`Controller::set_shard_plan`].
    shards: ShardPlan,
    /// Host-touching links per shard — the scope of each shard's
    /// calendar view.
    shard_links: Vec<Vec<LinkId>>,
}

/// Links with a host endpoint, bucketed by the host's shard.
fn shard_host_links(topo: &Topology, plan: &ShardPlan) -> Vec<Vec<LinkId>> {
    let mut links = vec![Vec::new(); plan.n_shards()];
    for l in &topo.links {
        let h = match (l.a, l.b) {
            (Endpoint::Host(h), _) | (_, Endpoint::Host(h)) => h,
            _ => continue,
        };
        links[plan.shard_of(h)].push(l.id);
    }
    links
}

impl Controller {
    pub fn new(topo: Topology, slot_secs: f64) -> Self {
        let cache = PathCache::build(&topo);
        let n_links = topo.n_links();
        let shards = ShardPlan::by_rack(&host_racks(&topo, &topo.hosts));
        let shard_links = shard_host_links(&topo, &shards);
        Self {
            topo,
            cache,
            calendar: SlotCalendar::new(n_links, slot_secs),
            background_mb_s: vec![0.0; n_links],
            flows: FlowTable::new(),
            qos: QosPolicy::default_shared(f64::INFINITY),
            shards,
            shard_links,
        }
    }

    /// The shard plan the schedulers partition their per-node state by.
    pub fn shard_plan(&self) -> &ShardPlan {
        &self.shards
    }

    /// Replace the shard plan (scale experiments; the plan must cover
    /// every host). Sharding is bit-identical to the flat path for any
    /// plan — see DESIGN.md §10 — so this only tunes working-set size.
    pub fn set_shard_plan(&mut self, plan: ShardPlan) {
        assert_eq!(plan.n_hosts(), self.topo.n_hosts(), "shard plan must cover every host");
        self.shard_links = shard_host_links(&self.topo, &plan);
        self.shards = plan;
    }

    /// Fold the current plan down to at most `max_shards` shards.
    pub fn set_max_shards(&mut self, max_shards: usize) {
        let plan = self.shards.regrouped(max_shards);
        self.set_shard_plan(plan);
    }

    /// Host-touching links of one shard.
    pub fn shard_links(&self, shard: usize) -> &[LinkId] {
        &self.shard_links[shard]
    }

    /// Read-only calendar occupancy scoped to one shard's links.
    pub fn shard_calendar_view(&self, shard: usize) -> CalendarView<'_> {
        self.calendar.view(&self.shard_links[shard])
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    pub fn n_hosts(&self) -> usize {
        self.topo.n_hosts()
    }

    /// Install a static background load on a link (MB/s).
    pub fn set_background_mb_s(&mut self, link: LinkId, mb_s: f64) {
        self.background_mb_s[link.0] = mb_s.max(0.0);
    }

    /// Dynamics: set a link's health as the usable fraction of its line
    /// rate (1.0 = healthy). This lowers the calendar's reservable
    /// ceiling — [`Controller::plan_transfer`] then grants at most
    /// `health x line rate`, and the real-time `BW_rl` view shrinks
    /// accordingly. `path_capacity_mb_s` keeps reporting line rate:
    /// calendar fractions are relative to it, so scaling both would
    /// double-count the degradation.
    pub fn set_link_health(&mut self, link: LinkId, frac: f64) {
        self.calendar.set_usable_frac(link, frac);
    }

    pub fn link_health(&self, link: LinkId) -> f64 {
        self.calendar.usable_frac(link)
    }

    /// Usable capacity fraction of every link on a path (audit trail for
    /// the reservation oracles — `testkit::oracles` re-checks per-slot
    /// sums against the healths in force at commit time).
    pub fn path_health(&self, links: &[LinkId]) -> Vec<f64> {
        links.iter().map(|&l| self.link_health(l)).collect()
    }

    /// Online streams: compact calendar history before time `t` (see
    /// [`SlotCalendar::forget_before`]). Stream reservations are never
    /// released — transfers simply end — so long job streams call this
    /// at each arrival to keep calendar memory proportional to the
    /// *live* horizon, not to every job ever admitted.
    pub fn gc_calendar_before(&mut self, t: Secs) {
        let slot = self.calendar.slot_of(t);
        self.calendar.forget_before(slot);
    }

    /// Revalidate a committed transfer after a capacity change: false
    /// when its reservation (plus everything stacked with it) now
    /// oversubscribes a degraded link, i.e. the SDN controller could no
    /// longer honor the promised rate.
    pub fn revalidate_transfer(&self, t: &Transfer) -> bool {
        self.calendar.reservation_within_capacity(&t.reservation)
    }

    pub fn background_mb_s(&self, link: LinkId) -> f64 {
        self.background_mb_s[link.0]
    }

    /// Cached host-to-host path (derefs to `[LinkId]`; may be
    /// synthesized inline by the hierarchical cache).
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<PathRef<'_>> {
        self.cache.path(src, dst)
    }

    /// Line rate of a link in MB/s (paper-consistent decimal conversion).
    pub fn link_capacity_mb_s(&self, link: LinkId) -> f64 {
        mbps_to_mb_per_s(self.topo.link(link).capacity_mbps)
    }

    /// Effective free capacity of `link` during `slot`: line rate minus
    /// background minus existing reservations.
    pub fn link_free_mb_s(&self, link: LinkId, slot: usize) -> f64 {
        let cap = self.link_capacity_mb_s(link);
        (cap * self.calendar.residual_frac(link, slot) - self.background_mb_s[link.0]).max(0.0)
    }

    /// The paper's `BW_rl`: real-time available bandwidth of the path
    /// `src -> dst` at time `at` (MB/s). 0 if disconnected; +INF for the
    /// local case (`src == dst`, no network involved).
    pub fn path_bw_mb_s(&self, src: NodeId, dst: NodeId, at: Secs) -> f64 {
        match self.path(src, dst) {
            None => 0.0,
            Some(links) if links.is_empty() => f64::INFINITY,
            Some(links) => {
                let slot = self.calendar.slot_of(at);
                links
                    .iter()
                    .map(|&l| self.link_free_mb_s(l, slot))
                    .fold(f64::INFINITY, f64::min)
            }
        }
    }

    /// Bottleneck *line* capacity of a path net of background (MB/s),
    /// ignoring reservations (the calendar handles those per-slot).
    pub fn path_capacity_mb_s(&self, links: &[LinkId]) -> f64 {
        links
            .iter()
            .map(|&l| (self.link_capacity_mb_s(l) - self.background_mb_s[l.0]).max(0.0))
            .fold(f64::INFINITY, f64::min)
    }

    /// Plan (but do not commit) a slot-reserved transfer of `size_mb` from
    /// `src` to `dst` starting no earlier than `earliest`.
    pub fn plan_transfer(
        &self,
        src: NodeId,
        dst: NodeId,
        size_mb: f64,
        earliest: Secs,
    ) -> Option<(Reservation, f64, Secs)> {
        let links = self.path(src, dst)?;
        if links.is_empty() || size_mb == 0.0 {
            return Some((
                Reservation { links: vec![], start_slot: 0, n_slots: 0, frac: 0.0 },
                f64::INFINITY,
                earliest,
            ));
        }
        let cap = self.path_capacity_mb_s(&links);
        if cap <= 0.0 {
            return None;
        }
        let r = self
            .calendar
            .plan_transfer(&links, earliest, size_mb, cap, MIN_RESERVE_FRAC)?;
        let rate = r.frac * cap;
        let slot_secs = self.calendar.slot_secs();
        // transfer starts at the beginning of its window (>= earliest) and
        // takes size/rate wall seconds inside the reserved slots
        let start = r.start(slot_secs).max(earliest);
        let arrival = Secs(start.0 + size_mb / rate);
        Some((r, rate, arrival))
    }

    /// Commit a planned transfer: reserve the slots and install the flow
    /// entry. Returns the admitted [`Transfer`].
    pub fn commit_transfer(
        &mut self,
        src: NodeId,
        dst: NodeId,
        class: TrafficClass,
        plan: (Reservation, f64, Secs),
        at: Secs,
    ) -> anyhow::Result<Transfer> {
        let (res, rate, arrival) = plan;
        if res.n_slots > 0 {
            self.calendar
                .reserve_path(&res.links, res.start_slot, res.n_slots, res.frac)?;
        }
        let queue = self.qos.classify(class);
        let flow_id =
            self.flows.install(src, dst, class, res.links.clone(), queue, at);
        let slot_secs = self.calendar.slot_secs();
        let start = res.start(slot_secs).max(at);
        Ok(Transfer { flow_id, reservation: res, rate_mb_s: rate, arrival, start })
    }

    /// Release a finished transfer's slots and drop its flow entry.
    pub fn complete_transfer(&mut self, t: &Transfer, size_mb: f64) {
        if t.reservation.n_slots > 0 {
            self.calendar.release(&t.reservation);
        }
        if let Some(e) = self.flows.get_mut(t.flow_id) {
            e.mb_forwarded += size_mb;
        }
        self.flows.remove(t.flow_id);
    }

    /// Effective bandwidth matrix for the cost model: `bw[i][j]` is the
    /// current path bandwidth from `sources[i]` to node `j` (MB/s), with
    /// the local case capped at the shared f32-safe sentinel
    /// ([`crate::runtime::exec::BW_SENTINEL_MB_S`]).
    pub fn bw_matrix(&self, sources: &[NodeId], at: Secs) -> Vec<Vec<f64>> {
        let n = self.topo.n_hosts();
        sources
            .iter()
            .map(|&s| {
                (0..n)
                    .map(|j| {
                        let bw = self.path_bw_mb_s(s, NodeId(j), at);
                        if bw.is_infinite() {
                            crate::runtime::exec::BW_SENTINEL_MB_S as f64
                        } else {
                            bw
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builders::fig2;

    fn ctrl() -> (Controller, [NodeId; 4]) {
        let f = fig2(102.4); // paper Example 1 effective rate: 12.8 MB/s
        let nodes = f.task_nodes;
        (Controller::new(f.topo, 1.0), nodes)
    }

    #[test]
    fn path_bw_full_when_idle() {
        let (c, n) = ctrl();
        let bw = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert!((bw - 12.8).abs() < 1e-9);
    }

    #[test]
    fn local_path_is_infinite() {
        let (c, n) = ctrl();
        assert!(c.path_bw_mb_s(n[0], n[0], Secs(0.0)).is_infinite());
    }

    #[test]
    fn plan_and_commit_example1_transfer() {
        // TK1: 64MB ND2 -> ND1, node free at t=3 => slots 3..8, arrive at 8
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(3.0)).unwrap();
        let t = c
            .commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(3.0))
            .unwrap();
        assert_eq!(t.reservation.start_slot, 3);
        assert_eq!(t.reservation.n_slots, 5);
        assert!((t.arrival.0 - 8.0).abs() < 1e-9);
        assert_eq!(c.flows.len(), 1);
        // the path is now saturated during the window
        let bw_mid = c.path_bw_mb_s(n[1], n[0], Secs(5.0));
        assert!(bw_mid < 1e-9, "expected saturated path, got {bw_mid}");
        // and free again afterwards
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(9.0)) - 12.8).abs() < 1e-9);
        // completion releases everything
        c.complete_transfer(&t, 64.0);
        assert_eq!(c.flows.len(), 0);
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(5.0)) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn background_reduces_bw() {
        let (mut c, n) = ctrl();
        let path: Vec<_> = c.path(n[1], n[0]).unwrap().to_vec();
        c.set_background_mb_s(path[0], 6.4);
        let bw = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert!((bw - 6.4).abs() < 1e-9);
    }

    #[test]
    fn transfer_queues_behind_reservation() {
        let (mut c, n) = ctrl();
        let p1 = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, p1, Secs(0.0)).unwrap();
        // second transfer over the shared Link1 must wait for slot 5
        let (r2, _, _) = c.plan_transfer(n[2], n[0], 64.0, Secs(0.0)).unwrap();
        assert_eq!(r2.start_slot, 5);
    }

    #[test]
    fn bw_matrix_shape_and_local_cap() {
        let (c, n) = ctrl();
        let m = c.bw_matrix(&[n[0], n[2]], Secs(0.0));
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].len(), c.n_hosts());
        assert!(m[0][0] > 1e11); // local: huge finite stand-in
        assert!((m[0][1] - 12.8).abs() < 1e-9);
    }

    #[test]
    fn degraded_link_shrinks_plans_and_revalidation_catches_stale_grants() {
        let (mut c, n) = ctrl();
        // commit a full-rate transfer, then degrade a link under it
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        let t = c
            .commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(0.0))
            .unwrap();
        assert!(c.revalidate_transfer(&t));
        let link = t.reservation.links[0];
        c.set_link_health(link, 0.5);
        assert!(!c.revalidate_transfer(&t), "full-rate grant exceeds half a link");
        // BW_rl reflects the degradation once the stale grant is released
        c.complete_transfer(&t, 64.0);
        let bw = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert!((bw - 6.4).abs() < 1e-9, "half of 12.8, got {bw}");
        // new plans are admitted against the reduced ceiling
        let (r2, rate2, _) = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        assert!((r2.frac - 0.5).abs() < 1e-9);
        assert!((rate2 - 6.4).abs() < 1e-9);
        c.set_link_health(link, 1.0);
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(0.0)) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn default_shard_plan_follows_racks() {
        let (c, n) = ctrl();
        // Fig.2: {ND1, ND2, master} on SW1, {ND3, ND4, controller} on SW2
        let plan = c.shard_plan();
        assert_eq!(plan.n_shards(), 2);
        assert_eq!(plan.shard_of(n[0]), plan.shard_of(n[1]));
        assert_eq!(plan.shard_of(n[2]), plan.shard_of(n[3]));
        assert_ne!(plan.shard_of(n[0]), plan.shard_of(n[2]));
        // each shard's link view covers its 3 host links
        assert_eq!(c.shard_links(0).len(), 3);
        assert_eq!(c.shard_links(1).len(), 3);
    }

    #[test]
    fn shard_calendar_view_sees_only_its_links() {
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(0.0)).unwrap();
        // the ND2->ND1 reservation touches only shard 0's host links (plus
        // uplinks, which no shard owns): shard 1's view stays empty
        let s0 = c.shard_calendar_view(0);
        let s1 = c.shard_calendar_view(1);
        assert_eq!(s0.n_links(), 3);
        assert!(s0.n_segments() > 0);
        assert_eq!(s1.n_segments(), 0);
        // the reserved window is saturated in shard 0's view only
        assert!(s0.window_residual(3, 1) < 1.0);
        assert_eq!(s1.window_residual(3, 1), 1.0);
    }

    #[test]
    fn set_max_shards_folds_plan() {
        let (mut c, n) = ctrl();
        c.set_max_shards(1);
        assert_eq!(c.shard_plan().n_shards(), 1);
        assert_eq!(c.shard_plan().shard_of(n[0]), c.shard_plan().shard_of(n[3]));
        assert_eq!(c.shard_links(0).len(), 6); // every host link
    }

    #[test]
    fn disconnected_pair_is_zero() {
        let mut topo = crate::topology::Topology::new();
        let a = topo.add_host();
        let b = topo.add_host();
        let c = Controller::new(topo, 1.0);
        assert_eq!(c.path_bw_mb_s(a, b, Secs(0.0)), 0.0);
    }
}
