//! The SDN controller: the scheduler's window into the network.
//!
//! Mirrors what the paper extracts from OpenFlow: per-link statistics
//! (capacity, background usage, current reservations), path lookup, the
//! time-slot calendar, and flow-entry installation for admitted
//! transfers. All bandwidth figures exposed to schedulers are **MB/s**
//! (Eq. 1 works in MB and seconds).
//!
//! Simplification (documented in DESIGN.md): a path reservation grabs the
//! same capacity *fraction* on every link of the path. With the paper's
//! uniform link rates this is exact; with heterogeneous rates it
//! over-reserves the faster links, which is conservative.

use crate::cluster::ShardPlan;
use crate::topology::{host_racks, Endpoint, LinkId, NodeId, PathCache, PathRef, Topology};
use crate::util::{mbps_to_mb_per_s, Secs};

use super::calendar::{CalendarView, Reservation, SlotCalendar};
use super::flowtable::{FlowTable, TrafficClass};
use super::qos::QosPolicy;

/// Minimum capacity fraction worth reserving; below this a remote
/// placement is treated as bandwidth-starved (Case 1.3).
pub const MIN_RESERVE_FRAC: f64 = 0.02;

/// An admitted, slot-reserved transfer.
#[derive(Debug, Clone)]
pub struct Transfer {
    pub flow_id: usize,
    pub reservation: Reservation,
    /// Granted rate in MB/s (bottleneck capacity x reserved fraction).
    pub rate_mb_s: f64,
    /// When the last byte lands.
    pub arrival: Secs,
    /// When the first byte leaves.
    pub start: Secs,
}

/// Outcome of [`Controller::renegotiate_transfer`].
#[derive(Debug, Clone)]
pub enum Renegotiation {
    /// A fresh grant replaced the old one (the window, rate or arrival
    /// may or may not differ — compare reservations to tell a real
    /// drift correction from an idempotent re-plan).
    Regranted(Transfer),
    /// Current conditions admit no plan at all; the old grant was
    /// restored exactly as it was.
    Kept(Transfer),
}

/// The central controller (one per cluster, as in Fig. 1/2).
#[derive(Debug, Clone)]
pub struct Controller {
    topo: Topology,
    cache: PathCache,
    pub calendar: SlotCalendar,
    /// Static background load per link, MB/s (subtracted from capacity).
    background_mb_s: Vec<f64>,
    pub flows: FlowTable,
    pub qos: QosPolicy,
    /// Scheduler-state shard plan (DESIGN.md §10): one shard per rack by
    /// default, overridable via [`Controller::set_shard_plan`].
    shards: ShardPlan,
    /// Host-touching links per shard — the scope of each shard's
    /// calendar view.
    shard_links: Vec<Vec<LinkId>>,
    /// Periodic-compaction policy (soak streams): gc runs at most once
    /// per period instead of on every arrival. `None` = compact only on
    /// explicit [`Controller::gc_calendar_before`] calls (the classic
    /// stream path).
    gc_period_secs: Option<f64>,
    last_gc: Secs,
    /// Lifetime count of policy-driven compaction passes.
    compactions: usize,
}

/// Links with a host endpoint, bucketed by the host's shard.
fn shard_host_links(topo: &Topology, plan: &ShardPlan) -> Vec<Vec<LinkId>> {
    let mut links = vec![Vec::new(); plan.n_shards()];
    for l in &topo.links {
        let h = match (l.a, l.b) {
            (Endpoint::Host(h), _) | (_, Endpoint::Host(h)) => h,
            _ => continue,
        };
        links[plan.shard_of(h)].push(l.id);
    }
    links
}

impl Controller {
    pub fn new(topo: Topology, slot_secs: f64) -> Self {
        let cache = PathCache::build(&topo);
        let n_links = topo.n_links();
        let shards = ShardPlan::by_rack(&host_racks(&topo, &topo.hosts));
        let shard_links = shard_host_links(&topo, &shards);
        Self {
            topo,
            cache,
            calendar: SlotCalendar::new(n_links, slot_secs),
            background_mb_s: vec![0.0; n_links],
            flows: FlowTable::new(),
            qos: QosPolicy::default_shared(f64::INFINITY),
            shards,
            shard_links,
            gc_period_secs: None,
            last_gc: Secs::ZERO,
            compactions: 0,
        }
    }

    /// The shard plan the schedulers partition their per-node state by.
    pub fn shard_plan(&self) -> &ShardPlan {
        &self.shards
    }

    /// Replace the shard plan (scale experiments; the plan must cover
    /// every host). Sharding is bit-identical to the flat path for any
    /// plan — see DESIGN.md §10 — so this only tunes working-set size.
    pub fn set_shard_plan(&mut self, plan: ShardPlan) {
        assert_eq!(plan.n_hosts(), self.topo.n_hosts(), "shard plan must cover every host");
        self.shard_links = shard_host_links(&self.topo, &plan);
        self.shards = plan;
    }

    /// Fold the current plan down to at most `max_shards` shards.
    pub fn set_max_shards(&mut self, max_shards: usize) {
        let plan = self.shards.regrouped(max_shards);
        self.set_shard_plan(plan);
    }

    /// Host-touching links of one shard.
    pub fn shard_links(&self, shard: usize) -> &[LinkId] {
        &self.shard_links[shard]
    }

    /// Read-only calendar occupancy scoped to one shard's links.
    pub fn shard_calendar_view(&self, shard: usize) -> CalendarView<'_> {
        self.calendar.view(&self.shard_links[shard])
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    pub fn n_hosts(&self) -> usize {
        self.topo.n_hosts()
    }

    /// Install a static background load on a link (MB/s).
    pub fn set_background_mb_s(&mut self, link: LinkId, mb_s: f64) {
        self.background_mb_s[link.0] = mb_s.max(0.0);
    }

    /// Dynamics: set a link's health as the usable fraction of its line
    /// rate (1.0 = healthy). This lowers the calendar's reservable
    /// ceiling — [`Controller::plan_transfer`] then grants at most
    /// `health x line rate`, and the real-time `BW_rl` view shrinks
    /// accordingly. [`Controller::path_line_mb_s`] keeps reporting line
    /// rate: calendar fractions are relative to it, so the transfer
    /// planner scaling both would double-count the degradation. The
    /// scheduler-facing [`Controller::path_capacity_mb_s`] *does* scale
    /// by health — it ignores calendar fractions entirely, so without
    /// the scaling every `tm` estimate would price a degraded path at
    /// full line rate.
    pub fn set_link_health(&mut self, link: LinkId, frac: f64) {
        self.calendar.set_usable_frac(link, frac);
    }

    pub fn link_health(&self, link: LinkId) -> f64 {
        self.calendar.usable_frac(link)
    }

    /// Usable capacity fraction of every link on a path (audit trail for
    /// the reservation oracles — `testkit::oracles` re-checks per-slot
    /// sums against the healths in force at commit time).
    pub fn path_health(&self, links: &[LinkId]) -> Vec<f64> {
        links.iter().map(|&l| self.link_health(l)).collect()
    }

    /// Online streams: compact calendar history before time `t` (see
    /// [`SlotCalendar::forget_before`]). Stream reservations are never
    /// released — transfers simply end — so long job streams call this
    /// at each arrival to keep calendar memory proportional to the
    /// *live* horizon, not to every job ever admitted.
    pub fn gc_calendar_before(&mut self, t: Secs) {
        let slot = self.calendar.slot_of(t);
        self.calendar.forget_before(slot);
    }

    /// Arm the periodic compaction policy: [`Controller::maybe_gc`]
    /// then compacts at most once per `period_secs` regardless of how
    /// often it is polled. Soak streams poll it at every arrival *and*
    /// every job completion, keeping calendar memory proportional to
    /// the live horizon on 100k-job runs without per-event BTreeMap
    /// sweeps.
    pub fn set_gc_period(&mut self, period_secs: f64) {
        assert!(
            period_secs > 0.0 && period_secs.is_finite(),
            "gc period must be positive seconds, got {period_secs}"
        );
        self.gc_period_secs = Some(period_secs);
    }

    /// Run the periodic policy if armed and due; returns whether a
    /// compaction pass ran. A no-policy controller never compacts here,
    /// so the classic per-arrival `gc_calendar_before` path is
    /// untouched.
    pub fn maybe_gc(&mut self, now: Secs) -> bool {
        let Some(period) = self.gc_period_secs else {
            return false;
        };
        if self.compactions > 0 && now.0 - self.last_gc.0 < period {
            return false;
        }
        self.gc_calendar_before(now);
        self.last_gc = now;
        self.compactions += 1;
        true
    }

    /// Policy-driven compaction passes so far (soak bounded-memory
    /// assertions).
    pub fn compactions(&self) -> usize {
        self.compactions
    }

    /// Total calendar occupancy boundaries (the memory the compaction
    /// policy bounds).
    pub fn calendar_segments(&self) -> usize {
        self.calendar.n_segments()
    }

    /// Revalidate a committed transfer after a capacity change: false
    /// when its reservation (plus everything stacked with it) now
    /// oversubscribes a degraded link, i.e. the SDN controller could no
    /// longer honor the promised rate.
    pub fn revalidate_transfer(&self, t: &Transfer) -> bool {
        self.calendar.reservation_within_capacity(&t.reservation)
    }

    pub fn background_mb_s(&self, link: LinkId) -> f64 {
        self.background_mb_s[link.0]
    }

    /// Cached host-to-host path (derefs to `[LinkId]`; may be
    /// synthesized inline by the hierarchical cache).
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<PathRef<'_>> {
        self.cache.path(src, dst)
    }

    /// Line rate of a link in MB/s (paper-consistent decimal conversion).
    pub fn link_capacity_mb_s(&self, link: LinkId) -> f64 {
        mbps_to_mb_per_s(self.topo.link(link).capacity_mbps)
    }

    /// Effective free capacity of `link` during `slot`: line rate minus
    /// background minus existing reservations.
    pub fn link_free_mb_s(&self, link: LinkId, slot: usize) -> f64 {
        let cap = self.link_capacity_mb_s(link);
        (cap * self.calendar.residual_frac(link, slot) - self.background_mb_s[link.0]).max(0.0)
    }

    /// Effective free capacity of `link` over the slot span
    /// `[lo, lo + n)`: line rate times the worst residual fraction in the
    /// span, minus background. `n = 1` is exactly
    /// [`Controller::link_free_mb_s`] at slot `lo`.
    pub fn link_free_over(&self, link: LinkId, lo: usize, n: usize) -> f64 {
        let cap = self.link_capacity_mb_s(link);
        let residual = self.calendar.path_residual(&[link], lo, n.max(1));
        (cap * residual - self.background_mb_s[link.0]).max(0.0)
    }

    /// The paper's `BW_rl`: real-time available bandwidth of the path
    /// `src -> dst` at time `at` (MB/s). 0 if disconnected; +INF for the
    /// local case (`src == dst`, no network involved). Callers that must
    /// distinguish "unreachable" from "congested to zero" use
    /// [`Controller::try_path_bw_mb_s`] instead.
    pub fn path_bw_mb_s(&self, src: NodeId, dst: NodeId, at: Secs) -> f64 {
        self.try_path_bw_mb_s(src, dst, at).unwrap_or(0.0)
    }

    /// `BW_rl` with the unreachable case made explicit: `None` when no
    /// path exists (a transfer can never be admitted), `Some(0.0)` when a
    /// path exists but its current slot is fully reserved or degraded
    /// away (a transfer could be admitted later).
    pub fn try_path_bw_mb_s(&self, src: NodeId, dst: NodeId, at: Secs) -> Option<f64> {
        let links = self.path(src, dst)?;
        if links.is_empty() {
            return Some(f64::INFINITY);
        }
        let slot = self.calendar.slot_of(at);
        Some(
            links
                .iter()
                .map(|&l| self.link_free_mb_s(l, slot))
                .fold(f64::INFINITY, f64::min),
        )
    }

    /// Span-aware `BW_rl`: the worst available bandwidth of the path over
    /// every slot a transfer occupying `[at, at + duration)` would cover.
    /// `path_bw_mb_s` samples only `slot_of(at)`, so a multi-slot
    /// transfer priced off it alone can sail into a window something else
    /// has reserved; this takes the min over the covered span. With
    /// `duration` inside one slot the answer is bit-identical to
    /// [`Controller::try_path_bw_mb_s`]. Non-positive / NaN durations
    /// fall back to the single-slot view; infinite durations cover the
    /// whole future calendar.
    pub fn try_path_bw_over(
        &self,
        src: NodeId,
        dst: NodeId,
        at: Secs,
        duration: Secs,
    ) -> Option<f64> {
        let links = self.path(src, dst)?;
        if links.is_empty() {
            return Some(f64::INFINITY);
        }
        let lo = self.calendar.slot_of(at);
        let n = self.span_slots(at, duration, lo);
        Some(
            links
                .iter()
                .map(|&l| self.link_free_over(l, lo, n))
                .fold(f64::INFINITY, f64::min),
        )
    }

    /// [`Controller::try_path_bw_over`] with unreachable collapsed to 0.
    pub fn path_bw_over(&self, src: NodeId, dst: NodeId, at: Secs, duration: Secs) -> f64 {
        self.try_path_bw_over(src, dst, at, duration).unwrap_or(0.0)
    }

    /// Number of calendar slots `[at, at + duration)` covers, given
    /// `lo = slot_of(at)`. At least 1; saturates (instead of overflowing
    /// the slot arithmetic) for infinite durations.
    pub(crate) fn span_slots(&self, at: Secs, duration: Secs, lo: usize) -> usize {
        if !(duration.0 > 0.0) {
            return 1;
        }
        let end = (at.0 + duration.0) / self.calendar.slot_secs();
        if !end.is_finite() {
            return usize::MAX - lo;
        }
        // `as usize` saturates, so a huge finite end stays safe too
        let hi = (end.ceil() as usize).min(usize::MAX - lo);
        hi.max(lo + 1) - lo
    }

    /// Bottleneck capacity of a path as the *scheduler* should price it
    /// (MB/s): line rate scaled by each link's usable-fraction health,
    /// net of background, ignoring calendar reservations (those are
    /// per-slot). This is what HDS/BAR `tm` estimates divide by; before
    /// the health scaling, every caller priced degraded links at full
    /// line rate for the whole degradation window.
    pub fn path_capacity_mb_s(&self, links: &[LinkId]) -> f64 {
        links
            .iter()
            .map(|&l| {
                (self.link_capacity_mb_s(l) * self.calendar.usable_frac(l)
                    - self.background_mb_s[l.0])
                    .max(0.0)
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Bottleneck *line* capacity of a path net of background (MB/s),
    /// ignoring both reservations and health. The transfer planner works
    /// in fractions *of this rate* — the calendar's usable ceiling
    /// already encodes health, so planning against the health-scaled
    /// capacity would double-count the degradation.
    pub fn path_line_mb_s(&self, links: &[LinkId]) -> f64 {
        links
            .iter()
            .map(|&l| (self.link_capacity_mb_s(l) - self.background_mb_s[l.0]).max(0.0))
            .fold(f64::INFINITY, f64::min)
    }

    /// Plan (but do not commit) a slot-reserved transfer of `size_mb` from
    /// `src` to `dst` starting no earlier than `earliest`.
    pub fn plan_transfer(
        &self,
        src: NodeId,
        dst: NodeId,
        size_mb: f64,
        earliest: Secs,
    ) -> Option<(Reservation, f64, Secs)> {
        let links = self.path(src, dst)?;
        if links.is_empty() || size_mb == 0.0 {
            return Some((
                Reservation { links: vec![], start_slot: 0, n_slots: 0, frac: 0.0 },
                f64::INFINITY,
                earliest,
            ));
        }
        let cap = self.path_line_mb_s(&links);
        if cap <= 0.0 {
            return None;
        }
        let r = self
            .calendar
            .plan_transfer(&links, earliest, size_mb, cap, MIN_RESERVE_FRAC)?;
        let rate = r.frac * cap;
        let slot_secs = self.calendar.slot_secs();
        // transfer starts at the beginning of its window (>= earliest) and
        // takes size/rate wall seconds inside the reserved slots
        let start = r.start(slot_secs).max(earliest);
        let arrival = Secs(start.0 + size_mb / rate);
        Some((r, rate, arrival))
    }

    /// Commit a planned transfer: reserve the slots and install the flow
    /// entry. Returns the admitted [`Transfer`].
    pub fn commit_transfer(
        &mut self,
        src: NodeId,
        dst: NodeId,
        class: TrafficClass,
        plan: (Reservation, f64, Secs),
        at: Secs,
    ) -> anyhow::Result<Transfer> {
        let (res, rate, arrival) = plan;
        if res.n_slots > 0 {
            self.calendar
                .reserve_path(&res.links, res.start_slot, res.n_slots, res.frac)?;
        }
        let queue = self.qos.classify(class);
        let flow_id =
            self.flows.install(src, dst, class, res.links.clone(), queue, at);
        let slot_secs = self.calendar.slot_secs();
        let start = res.start(slot_secs).max(at);
        Ok(Transfer { flow_id, reservation: res, rate_mb_s: rate, arrival, start })
    }

    /// Mid-flow renegotiation of a committed grant whose window has not
    /// started yet: release the old reservation, re-plan from `earliest`
    /// under current conditions, and commit the better window. When no
    /// plan is admissible (the path degraded below `MIN_RESERVE_FRAC`),
    /// the old grant is restored verbatim — the reallocator never leaks
    /// a reservation and never leaves a task grantless.
    ///
    /// Re-planning is idempotent: under unchanged conditions the search
    /// re-finds the identical window (the released slots are the
    /// earliest feasible ones), so `Regranted` with an unchanged
    /// reservation means "nothing drifted".
    pub fn renegotiate_transfer(
        &mut self,
        t: &Transfer,
        src: NodeId,
        dst: NodeId,
        class: TrafficClass,
        size_mb: f64,
        earliest: Secs,
    ) -> Renegotiation {
        if t.reservation.n_slots > 0 {
            self.calendar.release(&t.reservation);
        }
        let Some(plan) = self.plan_transfer(src, dst, size_mb, earliest) else {
            if t.reservation.n_slots > 0 {
                self.calendar.restore(&t.reservation);
            }
            return Renegotiation::Kept(t.clone());
        };
        match self.commit_transfer(src, dst, class, plan, earliest) {
            Ok(nt) => {
                self.flows.remove(t.flow_id);
                Renegotiation::Regranted(nt)
            }
            // unreachable in practice (plan just validated the residual),
            // but a failed commit must not leak the released slots
            Err(_) => {
                if t.reservation.n_slots > 0 {
                    self.calendar.restore(&t.reservation);
                }
                Renegotiation::Kept(t.clone())
            }
        }
    }

    /// Release a finished transfer's slots and drop its flow entry.
    pub fn complete_transfer(&mut self, t: &Transfer, size_mb: f64) {
        if t.reservation.n_slots > 0 {
            self.calendar.release(&t.reservation);
        }
        if let Some(e) = self.flows.get_mut(t.flow_id) {
            e.mb_forwarded += size_mb;
        }
        self.flows.remove(t.flow_id);
    }

    /// Effective bandwidth matrix for the cost model: `bw[i][j]` is the
    /// current path bandwidth from `sources[i]` to node `j` (MB/s), with
    /// the local case capped at the shared f32-safe sentinel
    /// ([`crate::runtime::exec::BW_SENTINEL_MB_S`]).
    pub fn bw_matrix(&self, sources: &[NodeId], at: Secs) -> Vec<Vec<f64>> {
        let n = self.topo.n_hosts();
        sources
            .iter()
            .map(|&s| {
                (0..n)
                    .map(|j| {
                        let bw = self.path_bw_mb_s(s, NodeId(j), at);
                        if bw.is_infinite() {
                            crate::runtime::exec::BW_SENTINEL_MB_S as f64
                        } else {
                            bw
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builders::fig2;

    fn ctrl() -> (Controller, [NodeId; 4]) {
        let f = fig2(102.4); // paper Example 1 effective rate: 12.8 MB/s
        let nodes = f.task_nodes;
        (Controller::new(f.topo, 1.0), nodes)
    }

    #[test]
    fn path_bw_full_when_idle() {
        let (c, n) = ctrl();
        let bw = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert!((bw - 12.8).abs() < 1e-9);
    }

    #[test]
    fn local_path_is_infinite() {
        let (c, n) = ctrl();
        assert!(c.path_bw_mb_s(n[0], n[0], Secs(0.0)).is_infinite());
    }

    #[test]
    fn plan_and_commit_example1_transfer() {
        // TK1: 64MB ND2 -> ND1, node free at t=3 => slots 3..8, arrive at 8
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(3.0)).unwrap();
        let t = c
            .commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(3.0))
            .unwrap();
        assert_eq!(t.reservation.start_slot, 3);
        assert_eq!(t.reservation.n_slots, 5);
        assert!((t.arrival.0 - 8.0).abs() < 1e-9);
        assert_eq!(c.flows.len(), 1);
        // the path is now saturated during the window
        let bw_mid = c.path_bw_mb_s(n[1], n[0], Secs(5.0));
        assert!(bw_mid < 1e-9, "expected saturated path, got {bw_mid}");
        // and free again afterwards
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(9.0)) - 12.8).abs() < 1e-9);
        // completion releases everything
        c.complete_transfer(&t, 64.0);
        assert_eq!(c.flows.len(), 0);
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(5.0)) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn background_reduces_bw() {
        let (mut c, n) = ctrl();
        let path: Vec<_> = c.path(n[1], n[0]).unwrap().to_vec();
        c.set_background_mb_s(path[0], 6.4);
        let bw = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert!((bw - 6.4).abs() < 1e-9);
    }

    #[test]
    fn transfer_queues_behind_reservation() {
        let (mut c, n) = ctrl();
        let p1 = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, p1, Secs(0.0)).unwrap();
        // second transfer over the shared Link1 must wait for slot 5
        let (r2, _, _) = c.plan_transfer(n[2], n[0], 64.0, Secs(0.0)).unwrap();
        assert_eq!(r2.start_slot, 5);
    }

    #[test]
    fn bw_matrix_shape_and_local_cap() {
        let (c, n) = ctrl();
        let m = c.bw_matrix(&[n[0], n[2]], Secs(0.0));
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].len(), c.n_hosts());
        assert!(m[0][0] > 1e11); // local: huge finite stand-in
        assert!((m[0][1] - 12.8).abs() < 1e-9);
    }

    #[test]
    fn degraded_link_shrinks_plans_and_revalidation_catches_stale_grants() {
        let (mut c, n) = ctrl();
        // commit a full-rate transfer, then degrade a link under it
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        let t = c
            .commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(0.0))
            .unwrap();
        assert!(c.revalidate_transfer(&t));
        let link = t.reservation.links[0];
        c.set_link_health(link, 0.5);
        assert!(!c.revalidate_transfer(&t), "full-rate grant exceeds half a link");
        // BW_rl reflects the degradation once the stale grant is released
        c.complete_transfer(&t, 64.0);
        let bw = c.path_bw_mb_s(n[1], n[0], Secs(0.0));
        assert!((bw - 6.4).abs() < 1e-9, "half of 12.8, got {bw}");
        // new plans are admitted against the reduced ceiling
        let (r2, rate2, _) = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        assert!((r2.frac - 0.5).abs() < 1e-9);
        assert!((rate2 - 6.4).abs() < 1e-9);
        c.set_link_health(link, 1.0);
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(0.0)) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn default_shard_plan_follows_racks() {
        let (c, n) = ctrl();
        // Fig.2: {ND1, ND2, master} on SW1, {ND3, ND4, controller} on SW2
        let plan = c.shard_plan();
        assert_eq!(plan.n_shards(), 2);
        assert_eq!(plan.shard_of(n[0]), plan.shard_of(n[1]));
        assert_eq!(plan.shard_of(n[2]), plan.shard_of(n[3]));
        assert_ne!(plan.shard_of(n[0]), plan.shard_of(n[2]));
        // each shard's link view covers its 3 host links
        assert_eq!(c.shard_links(0).len(), 3);
        assert_eq!(c.shard_links(1).len(), 3);
    }

    #[test]
    fn shard_calendar_view_sees_only_its_links() {
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(0.0)).unwrap();
        // the ND2->ND1 reservation touches only shard 0's host links (plus
        // uplinks, which no shard owns): shard 1's view stays empty
        let s0 = c.shard_calendar_view(0);
        let s1 = c.shard_calendar_view(1);
        assert_eq!(s0.n_links(), 3);
        assert!(s0.n_segments() > 0);
        assert_eq!(s1.n_segments(), 0);
        // the reserved window is saturated in shard 0's view only
        assert!(s0.window_residual(3, 1) < 1.0);
        assert_eq!(s1.window_residual(3, 1), 1.0);
    }

    #[test]
    fn set_max_shards_folds_plan() {
        let (mut c, n) = ctrl();
        c.set_max_shards(1);
        assert_eq!(c.shard_plan().n_shards(), 1);
        assert_eq!(c.shard_plan().shard_of(n[0]), c.shard_plan().shard_of(n[3]));
        assert_eq!(c.shard_links(0).len(), 6); // every host link
    }

    #[test]
    fn disconnected_pair_is_zero() {
        let mut topo = crate::topology::Topology::new();
        let a = topo.add_host();
        let b = topo.add_host();
        let c = Controller::new(topo, 1.0);
        assert_eq!(c.path_bw_mb_s(a, b, Secs(0.0)), 0.0);
    }

    #[test]
    fn unreachable_is_distinct_from_congested_to_zero() {
        // disconnected: no path at all -> None (and 0.0 via the collapse)
        let mut topo = crate::topology::Topology::new();
        let a = topo.add_host();
        let b = topo.add_host();
        let c = Controller::new(topo, 1.0);
        assert_eq!(c.try_path_bw_mb_s(a, b, Secs(0.0)), None);
        assert_eq!(c.try_path_bw_over(a, b, Secs(0.0), Secs(5.0)), None);
        // congested: a saturating reservation -> Some(0.0), never None
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(0.0)).unwrap();
        let mid = c.try_path_bw_mb_s(n[1], n[0], Secs(2.0)).expect("reachable");
        assert!(mid < 1e-9, "saturated, got {mid}");
    }

    #[test]
    fn path_capacity_is_health_scaled_but_line_rate_is_not() {
        // the regression: capacity estimates ignored usable_frac, so
        // every tm estimate priced a degraded path at full line rate
        let (mut c, n) = ctrl();
        let links: Vec<_> = c.path(n[1], n[0]).unwrap().to_vec();
        assert!((c.path_capacity_mb_s(&links) - 12.8).abs() < 1e-9);
        c.set_link_health(links[0], 0.5);
        assert!((c.path_capacity_mb_s(&links) - 6.4).abs() < 1e-9);
        // the planner's reference stays line rate (calendar fracs are
        // relative to it; scaling both would double-count)
        assert!((c.path_line_mb_s(&links) - 12.8).abs() < 1e-9);
        let (r, rate, _) = c.plan_transfer(n[1], n[0], 64.0, Secs(0.0)).unwrap();
        assert!((r.frac - 0.5).abs() < 1e-9);
        assert!((rate - 6.4).abs() < 1e-9, "granted rate reflects health once, not twice");
    }

    #[test]
    fn span_aware_bw_prices_future_reservations() {
        // reserve slots 3..8; the first slot alone says "free"
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(3.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(3.0)).unwrap();
        assert!((c.path_bw_mb_s(n[1], n[0], Secs(0.0)) - 12.8).abs() < 1e-9);
        // a 5s transfer from t=0 covers slots 0..5 and hits the window
        let over = c.path_bw_over(n[1], n[0], Secs(0.0), Secs(5.0));
        assert!(over < 1e-9, "span view must see the reservation, got {over}");
        // a 2s transfer from t=0 stays clear of it
        assert!((c.path_bw_over(n[1], n[0], Secs(0.0), Secs(2.0)) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn single_slot_span_is_bit_identical_to_the_point_view() {
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 32.0, Secs(2.0)).unwrap();
        c.commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(2.0)).unwrap();
        let bg_link = c.path(n[2], n[0]).unwrap()[0];
        c.set_background_mb_s(bg_link, 3.0);
        for (at, dur) in [(0.0, 0.9), (0.2, 0.5), (2.4, 0.1), (7.0, 1.0), (3.0, 0.0)] {
            for (src, dst) in [(n[1], n[0]), (n[2], n[0]), (n[0], n[0])] {
                let point = c.path_bw_mb_s(src, dst, Secs(at));
                let span = c.path_bw_over(src, dst, Secs(at), Secs(dur));
                assert_eq!(point.to_bits(), span.to_bits(), "at={at} dur={dur}");
            }
        }
        // degenerate durations never panic and fall back to the point view
        let point = c.path_bw_mb_s(n[1], n[0], Secs(1.0));
        assert_eq!(c.path_bw_over(n[1], n[0], Secs(1.0), Secs(-2.0)).to_bits(), point.to_bits());
        assert_eq!(
            c.path_bw_over(n[1], n[0], Secs(1.0), Secs(f64::NAN)).to_bits(),
            point.to_bits()
        );
        // an infinite span covers the far future without overflowing
        assert!(c.path_bw_over(n[1], n[0], Secs(1.0), Secs(f64::INFINITY)) <= point);
    }

    #[test]
    fn renegotiation_regrants_on_drift_and_restores_when_infeasible() {
        let (mut c, n) = ctrl();
        let plan = c.plan_transfer(n[1], n[0], 64.0, Secs(10.0)).unwrap();
        let t = c
            .commit_transfer(n[1], n[0], TrafficClass::HadoopOther, plan, Secs(0.0))
            .unwrap();
        let link = t.reservation.links[0];

        // unchanged conditions: re-planning is idempotent
        match c.renegotiate_transfer(&t, n[1], n[0], TrafficClass::HadoopOther, 64.0, Secs(10.0))
        {
            Renegotiation::Regranted(nt) => {
                assert_eq!(nt.reservation, t.reservation, "idempotent re-plan");
                assert_eq!(nt.arrival.0.to_bits(), t.arrival.0.to_bits());
                // drift: a degraded link shrinks the regrant
                c.set_link_health(link, 0.5);
                assert!(!c.revalidate_transfer(&nt));
                match c.renegotiate_transfer(
                    &nt,
                    n[1],
                    n[0],
                    TrafficClass::HadoopOther,
                    64.0,
                    Secs(10.0),
                ) {
                    Renegotiation::Regranted(shrunk) => {
                        assert!((shrunk.reservation.frac - 0.5).abs() < 1e-9);
                        assert!(shrunk.arrival > nt.arrival, "half rate lands later");
                        assert!(c.revalidate_transfer(&shrunk), "regrant fits the ceiling");
                        // a dead path cannot be re-planned: restore verbatim
                        c.set_link_health(link, 0.0);
                        match c.renegotiate_transfer(
                            &shrunk,
                            n[1],
                            n[0],
                            TrafficClass::HadoopOther,
                            64.0,
                            Secs(10.0),
                        ) {
                            Renegotiation::Kept(kept) => {
                                assert_eq!(kept.reservation, shrunk.reservation);
                                assert_eq!(c.flows.len(), 1, "no leaked or dropped flow");
                                c.complete_transfer(&kept, 64.0);
                                assert_eq!(c.calendar.n_segments(), 0, "no leaked slots");
                            }
                            other => panic!("expected Kept, got {other:?}"),
                        }
                    }
                    other => panic!("expected shrunk regrant, got {other:?}"),
                }
            }
            other => panic!("expected idempotent regrant, got {other:?}"),
        }
    }
}
