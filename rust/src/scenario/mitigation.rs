//! Straggler mitigation: bandwidth-aware speculative execution,
//! eviction off degraded nodes, and a scoring rebalancer.
//!
//! Since the dynamics layer landed, churn timelines could *inject*
//! stragglers but no scheduler reacted — a slowed node simply stretched
//! the tail of every sweep. This layer closes the loop, staying true to
//! the paper's premise that the SDN controller's bandwidth view should
//! gate every placement decision:
//!
//! * **Speculative execution** ([`SpeculationMode`]): a LATE-style
//!   detector thresholds the realized compute stretch of the running
//!   population ([`crate::sim::Engine::running_snapshot`]) and launches
//!   a duplicate attempt for slow outliers on the best idle healthy
//!   node. The novel twist is the *bandwidth-aware* gate: under
//!   [`SpeculationMode::BwAware`] a duplicate is only worth launching if
//!   its input pull is serviceable — BASS/Pre-BASS ask the controller
//!   for a calendar reservation window ([`crate::sdn::Controller::
//!   plan_transfer`]; no window, no duplicate) and commit it, HDS/BAR
//!   check the instantaneous path bandwidth. [`SpeculationMode::Late`]
//!   is the classic bandwidth-blind baseline: it estimates the duplicate
//!   from compute time alone and pulls fair-share. First finisher wins:
//!   the loser's attempt is killed through the engine
//!   ([`crate::sim::Engine::kill_attempt`]) and its flow + calendar
//!   grant cancelled through the controller
//!   ([`crate::sdn::Controller::complete_transfer`]) — the no-leak
//!   oracle re-checks every duel from the [`DuelAudit`] trail.
//! * **Eviction**: when a node's straggle factor reaches
//!   [`MitigationSpec::evict_factor`], its queued and running work is
//!   descheduled through the existing orphan path
//!   ([`crate::sim::Engine::evict_node`]) and re-enters the next
//!   rescheduling round, which sees the *effective* node speeds and
//!   places around the straggler. One eviction per (node, straggle
//!   onset) keeps the round loop convergent.
//! * **Scoring rebalancer** ([`Rebalancer`]): the evaluate/score/evict
//!   descheduler split for long streams — rank nodes by realized-vs-
//!   promised service over their finished records and drain the worst
//!   offender's *pending* queue (the running attempt is left to finish).
//!   Wired into the online stream driver (`scenario::online`) at
//!   [`MitigationSpec::rebalance_period`] intervals.
//!
//! Duplicate attempts execute under a synthetic task id
//! (`orig + `[`DUP_BASE`]) so every TaskId-keyed engine structure stays
//! collision-free; a winning duplicate's record is rewritten to the
//! original id at round end, so exactly-once completion (and every
//! downstream metric) is preserved. A task whose original *and*
//! duplicate both die in a crash storm re-enters the orphan carry set —
//! never silently dropped (pinned by the replication-1 regression test).
//!
//! With an inert spec ([`MitigationSpec::is_inert`]) [`run_mitigated`]
//! delegates to [`run_dynamic`] — `speculation = "off"` is bit-identical
//! to the plain dynamics path by construction.

use std::collections::{HashMap, HashSet};

use crate::cluster::Ledger;
use crate::mapreduce::{TaskId, TaskSpec};
use crate::runtime::CostModel;
use crate::sched::{SchedCtx, Scheduler as _, SchedulerKind};
use crate::sdn::controller::Transfer;
use crate::sdn::{
    weighted_max_min, BandwidthView, Controller, Measured, Oracle, Renegotiation, Reservation,
    Telemetry, TrafficClass,
};
use crate::sim::{
    Assignment, ClusterEvent, Engine, Placement, RunningTask, TaskRecord, TransferPlan,
};
use crate::topology::{LinkId, NodeId};
use crate::util::{mbps_to_mb_per_s, Secs};

use super::dynamics::{
    down_intervals, run_dynamic, state_at, ClusterState, DynEvent, DynamicsOutcome, DynamicsSpec,
    PullAudit, ReallocAudit, ReservationAudit,
};
use super::session::SimSession;

/// Duplicate attempts run under `orig.id + DUP_BASE` so TaskId-keyed
/// engine state (watches, done-tracking, job tags) stays collision-free;
/// winning duplicates are rewritten to the original id at round end.
pub const DUP_BASE: usize = 1 << 40;

/// Speculative-execution policy (the `[mitigation] speculation` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpeculationMode {
    /// No duplicates — the plain dynamics path.
    Off,
    /// Classic LATE: slow outliers are duplicated on estimated compute
    /// time alone; the duplicate's input pull contends fair-share. The
    /// bandwidth-blind baseline.
    Late,
    /// Bandwidth-aware: a duplicate launches only if its input pull is
    /// serviceable — BASS/Pre-BASS require (and commit) a calendar
    /// reservation window, HDS/BAR require instantaneous path bandwidth
    /// that still beats the straggling original.
    BwAware,
}

impl SpeculationMode {
    /// Strict parse of the config/CLI value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(Self::Off),
            "late" => Some(Self::Late),
            "bw_aware" => Some(Self::BwAware),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Late => "late",
            Self::BwAware => "bw_aware",
        }
    }
}

/// The `[mitigation]` knobs, threaded via `ScenarioSpec.mitigation`.
#[derive(Debug, Clone, PartialEq)]
pub struct MitigationSpec {
    pub speculation: SpeculationMode,
    /// LATE stretch threshold: an attempt is a slow outlier once its
    /// realized compute stretch reaches this factor (and its remaining
    /// time is at least the running population's median). `>= 1`.
    pub slow_threshold: f64,
    /// Evict a node's work once its straggle factor reaches this
    /// ceiling (`> 1`; infinite = eviction off, the default).
    pub evict_factor: f64,
    /// Stream rebalancer period in seconds (`<= 0` = off, the default).
    pub rebalance_period: f64,
}

impl MitigationSpec {
    /// Everything off — behaves exactly like no mitigation at all.
    pub fn off() -> Self {
        Self {
            speculation: SpeculationMode::Off,
            slow_threshold: 1.5,
            evict_factor: f64::INFINITY,
            rebalance_period: 0.0,
        }
    }

    /// Classic LATE speculation, everything else off.
    pub fn late() -> Self {
        Self { speculation: SpeculationMode::Late, ..Self::off() }
    }

    /// Bandwidth-aware speculation, everything else off.
    pub fn bw_aware() -> Self {
        Self { speculation: SpeculationMode::BwAware, ..Self::off() }
    }

    /// An inert spec changes nothing: [`run_mitigated`] delegates to
    /// the plain [`run_dynamic`] path (bit-identical by construction).
    pub fn is_inert(&self) -> bool {
        self.speculation == SpeculationMode::Off
            && !self.evict_factor.is_finite()
            && self.rebalance_period <= 0.0
    }
}

/// Audit record of one speculation duel (original vs duplicate), enough
/// for the no-reservation-leak oracle to re-check kill semantics
/// independently of the controller's bookkeeping.
#[derive(Debug, Clone)]
pub struct DuelAudit {
    pub round: usize,
    /// The straggling original task.
    pub task: TaskId,
    /// The duplicate's synthetic id (`task + DUP_BASE`).
    pub dup: TaskId,
    /// Node the duplicate was launched on.
    pub node: NodeId,
    /// Resolution instant (first finish, or round end if both died).
    pub at: Secs,
    /// Surviving attempt (`None` = both died in a crash storm; the task
    /// re-enters the orphan carry set).
    pub winner: Option<TaskId>,
    /// The duplicate's pull held a calendar grant.
    pub reserved: bool,
    /// That grant was released (must hold whenever the duplicate lost).
    pub released: bool,
    /// The original's pull held a calendar grant.
    pub orig_reserved: bool,
    /// That grant was released (must hold whenever the original lost).
    pub orig_released: bool,
}

/// One in-flight duel, keyed by the duplicate's watch key.
struct Duel {
    orig: TaskId,
    dup: TaskId,
    orig_node: NodeId,
    dup_node: NodeId,
    round: usize,
    /// The duplicate's committed grant (BwAware + reserving scheduler).
    grant: Option<Transfer>,
    /// The original placement's committed grant, if any.
    orig_grant: Option<Transfer>,
    resolved: bool,
}

/// LATE detector: over the running originals (duplicates are never
/// themselves duplicated), flag attempts whose realized compute stretch
/// reaches `threshold` *and* whose remaining time is at least the
/// population median — the classic "longest remaining time among the
/// slow" rule, so a lone tail straggler still qualifies.
fn slow_outliers(snap: &[RunningTask], now: Secs, threshold: f64) -> Vec<RunningTask> {
    let originals: Vec<&RunningTask> = snap.iter().filter(|r| r.task.0 < DUP_BASE).collect();
    if originals.is_empty() {
        return Vec::new();
    }
    let mut remaining: Vec<f64> = originals.iter().map(|r| (r.finish - now).0).collect();
    remaining.sort_by(f64::total_cmp);
    let median = remaining[remaining.len() / 2];
    originals
        .into_iter()
        .filter(|r| {
            let stretch = (r.finish - r.compute_start).0 / r.nominal.0.max(1e-9);
            stretch >= threshold && (r.finish - now).0 >= median
        })
        .cloned()
        .collect()
}

/// Remove the audit row a released grant contributed (the capacity
/// oracle sums co-resident grants; a released one no longer is).
fn unaudit(reservations: &mut Vec<ReservationAudit>, round: usize, r: &Reservation) {
    if let Some(i) = reservations.iter().position(|a| {
        a.round == round
            && a.start_slot == r.start_slot
            && a.n_slots == r.n_slots
            && a.frac == r.frac
            && a.links == r.links
    }) {
        reservations.remove(i);
    }
}

/// Try to launch a duplicate attempt for `victim` at instant `now`.
/// Returns the registered duel, or `None` when no candidate node is
/// idle, the bandwidth gate fails, or the duplicate would not beat the
/// original's estimated finish.
#[allow(clippy::too_many_arguments)]
fn try_speculate(
    engine: &mut Engine,
    ctrl: &mut Controller,
    view: &dyn BandwidthView,
    sess: &SimSession,
    mode: SpeculationMode,
    victim: &RunningTask,
    task: &TaskSpec,
    orig_grant: Option<Transfer>,
    st: &ClusterState,
    now: Secs,
    round: usize,
    reservations: &mut Vec<ReservationAudit>,
    pulls: &mut Vec<PullAudit>,
) -> Option<Duel> {
    // candidate: the first idle, healthy, authorized node that is not
    // the victim's (sess.nodes order keeps the choice deterministic)
    let cand = sess.nodes.iter().copied().find(|&nd| {
        let j = nd.0;
        nd != victim.node
            && !st.down[j]
            && st.speed[j] == 1.0
            && !engine.has_pending(nd)
            && engine.node_free_times()[j] <= now
    })?;
    let factor = sess
        .spec
        .node_speed
        .get(cand.0)
        .copied()
        .filter(|&f| f > 0.0)
        .unwrap_or(1.0);
    let compute = Secs(task.compute.0 * factor);
    let holders: Vec<NodeId> = match task.input {
        Some(b) => {
            let live: Vec<NodeId> = sess
                .nn
                .block(b)
                .replicas
                .iter()
                .copied()
                .filter(|h| !st.down[h.0])
                .collect();
            if live.is_empty() {
                return None; // block unreadable right now
            }
            live
        }
        None => Vec::new(),
    };
    let local = task.input.is_none() || holders.contains(&cand);
    // remote source: the bandwidth-argmax live holder (ties -> first)
    let (src, src_bw) = if local {
        (cand, f64::INFINITY)
    } else {
        let mut best = (holders[0], view.path_bw_mb_s(ctrl, holders[0], cand, now));
        for &h in &holders[1..] {
            let bw = view.path_bw_mb_s(ctrl, h, cand, now);
            if bw > best.1 {
                best = (h, bw);
            }
        }
        best
    };
    let reserving = matches!(sess.spec.scheduler, SchedulerKind::Bass | SchedulerKind::PreBass);

    // estimate the duplicate's finish under the mode's bandwidth model
    let mut planned: Option<(Reservation, f64, Secs)> = None;
    let est_finish = if local {
        now + compute
    } else if mode == SpeculationMode::BwAware && reserving {
        // the bandwidth-aware rule: no reservation window, no duplicate
        let plan = ctrl.plan_transfer(src, cand, task.input_mb, now)?;
        let est = plan.2.max(now) + compute;
        planned = Some(plan);
        est
    } else if mode == SpeculationMode::BwAware {
        // HDS/BAR: gate on path bandwidth over the pull's whole span.
        // The instantaneous rate sizes the span; re-pricing over it
        // catches reservations that close the window mid-pull (a
        // transfer fitting one slot re-prices to exactly `src_bw`, so
        // the historical single-slot gate is bit-identical)
        if src_bw <= 0.0 {
            return None;
        }
        let span_bw = view.path_bw_over(ctrl, src, cand, now, Secs(task.input_mb / src_bw));
        if span_bw <= 0.0 {
            return None;
        }
        now + Secs(task.input_mb / span_bw) + compute
    } else {
        // classic LATE is bandwidth-blind: compute-only estimate
        now + compute
    };
    if est_finish >= victim.finish {
        return None; // the duplicate would not beat the original
    }

    let (transfer, grant) = if local {
        (TransferPlan::None, None)
    } else if let Some(plan) = planned {
        let t = ctrl.commit_transfer(src, cand, TrafficClass::HadoopOther, plan, now).ok()?;
        if t.reservation.n_slots > 0 {
            reservations.push(ReservationAudit {
                round,
                links: t.reservation.links.clone(),
                start_slot: t.reservation.start_slot,
                n_slots: t.reservation.n_slots,
                frac: t.reservation.frac,
                usable: ctrl.path_health(&t.reservation.links),
            });
        }
        (TransferPlan::Reserved(t.clone()), Some(t))
    } else {
        let path = ctrl.path(src, cand)?.to_vec();
        let fs = TransferPlan::FairShare {
            path,
            size_mb: task.input_mb,
            class: TrafficClass::HadoopOther,
        };
        (fs, None)
    };
    if !local {
        // audited under the original id: oracles cross-check pull
        // sources against the submitted task set
        pulls.push(PullAudit { task: task.id, source: src, at: now });
    }
    let dup = TaskId(task.id.0 + DUP_BASE);
    engine.load(&Assignment {
        placements: vec![Placement {
            task: dup,
            node: cand,
            compute,
            transfer,
            gate: Some(now),
            source: (!local).then_some(src),
            is_local: local,
            is_map: task.is_map(),
        }],
    });
    engine.watch_threshold(dup.0 as u64, &[task.id, dup], 1);
    Some(Duel {
        orig: task.id,
        dup,
        orig_node: victim.node,
        dup_node: cand,
        round,
        grant,
        orig_grant,
        resolved: false,
    })
}

/// Utility weight of a QoS class for the reallocator's water-filling
/// pass (Example 3's queue priorities, as relative weights).
fn class_weight(class: TrafficClass) -> f64 {
    match class {
        TrafficClass::Shuffle => 4.0,
        TrafficClass::HadoopOther => 2.0,
        TrafficClass::Background => 1.0,
    }
}

/// One reallocation pass of the measured control plane's closed loop
/// (`[telemetry] reallocate`), run at a probe epoch: renegotiate every
/// committed grant whose reserved window has not started and whose
/// attempt is still queued in the engine, in utility-weighted order.
///
/// Per-class entitlements come from [`weighted_max_min`] over the
/// *estimated* bottleneck capacity — higher classes re-plan first, so
/// under drift they regrab the earliest feasible windows. Each
/// renegotiation goes through [`Controller::renegotiate_transfer`]
/// (release → re-plan → commit, restore-on-failure), the engine
/// placement is retimed to the new grant, and the audit trail is
/// maintained: the stale [`ReservationAudit`] row is withdrawn, the new
/// one pushed, and a [`ReallocAudit`] row records the old→new chain the
/// grant-accounting oracle walks. Re-plans that re-find the identical
/// window are treated as "nothing drifted": the fresh grant is adopted
/// (its flow entry is new) but neither audited nor counted.
///
/// Returns the number of grants actually changed.
#[allow(clippy::too_many_arguments)]
fn reallocate_grants(
    engine: &mut Engine,
    ctrl: &mut Controller,
    telem: &Telemetry,
    tasks: &[TaskSpec],
    spec_of: &HashMap<TaskId, usize>,
    grant_of: &mut HashMap<TaskId, Transfer>,
    route_of: &HashMap<TaskId, (NodeId, NodeId)>,
    now: Secs,
    round: usize,
    reservations: &mut Vec<ReservationAudit>,
    reallocs: &mut Vec<ReallocAudit>,
) -> usize {
    struct Cand {
        task: TaskId,
        src: NodeId,
        dst: NodeId,
        size_mb: f64,
        class: TrafficClass,
        weight: f64,
        rate_mb_s: f64,
    }
    let m = Measured::at(telem, now);
    let mut cands: Vec<Cand> = Vec::new();
    for (&task, tr) in grant_of.iter() {
        // only grants the engine has not begun honoring: a future window
        // and a still-queued attempt (a picked-up placement has latched
        // its arrival; renegotiating it would desynchronize the engine)
        if tr.reservation.n_slots == 0 || tr.start <= now {
            continue;
        }
        let Some(&(src, dst)) = route_of.get(&task) else { continue };
        if !engine.queued(dst, task) {
            continue;
        }
        let Some(&ti) = spec_of.get(&task) else { continue };
        let class = ctrl
            .flows
            .get(tr.flow_id)
            .map(|f| f.class)
            .unwrap_or(TrafficClass::HadoopOther);
        cands.push(Cand {
            task,
            src,
            dst,
            size_mb: tasks[ti].input_mb,
            class,
            weight: class_weight(class),
            rate_mb_s: tr.rate_mb_s,
        });
    }
    if cands.is_empty() {
        return 0;
    }
    // deterministic order: class weight desc, then task id — HashMap
    // iteration order must never leak into the outcome
    cands.sort_by(|a, b| b.weight.total_cmp(&a.weight).then(a.task.cmp(&b.task)));
    // utility-weighted max-min entitlements over the estimated shared
    // pool (the tightest estimated path bottleneck among candidates —
    // exactly the quantity drift perturbs); recorded per row so the
    // sweep can audit how the shares responded to estimate error
    let caps: Vec<f64> = cands
        .iter()
        .map(|c| {
            ctrl.path(c.src, c.dst)
                .map(|p| p.to_vec())
                .map(|links| m.path_capacity_mb_s(ctrl, &links))
                .unwrap_or(f64::INFINITY)
        })
        .collect();
    let pool = caps.iter().copied().fold(f64::INFINITY, f64::min);
    let demands: Vec<f64> = cands.iter().map(|c| c.rate_mb_s).collect();
    let weights: Vec<f64> = cands.iter().map(|c| c.weight).collect();
    let shares = if pool.is_finite() {
        weighted_max_min(pool, &demands, &weights)
    } else {
        demands.clone()
    };
    let mut changed = 0usize;
    for (i, c) in cands.iter().enumerate() {
        let old = grant_of[&c.task].clone();
        match ctrl.renegotiate_transfer(&old, c.src, c.dst, c.class, c.size_mb, now) {
            Renegotiation::Kept(_) => {} // infeasible re-plan; grant restored verbatim
            Renegotiation::Regranted(nt) => {
                let drifted = nt.reservation != old.reservation
                    || nt.rate_mb_s.to_bits() != old.rate_mb_s.to_bits();
                // adopt the fresh grant either way (its flow entry is
                // new); the engine prices the pull off the new window
                let retimed = engine.retime_transfer(c.dst, c.task, nt.clone());
                debug_assert!(retimed, "queued placement vanished mid-checkpoint");
                grant_of.insert(c.task, nt.clone());
                if !drifted {
                    continue; // re-found the identical window: no drift
                }
                unaudit(reservations, round, &old.reservation);
                if nt.reservation.n_slots > 0 {
                    reservations.push(ReservationAudit {
                        round,
                        links: nt.reservation.links.clone(),
                        start_slot: nt.reservation.start_slot,
                        n_slots: nt.reservation.n_slots,
                        frac: nt.reservation.frac,
                        usable: ctrl.path_health(&nt.reservation.links),
                    });
                }
                reallocs.push(ReallocAudit {
                    round,
                    task: c.task,
                    at: now,
                    old: old.reservation.clone(),
                    new: nt.reservation.clone(),
                    class_share_mb_s: shares[i],
                });
                changed += 1;
            }
        }
    }
    changed
}

/// Play a session's dynamics timeline with the mitigation layer active:
/// the round structure of [`run_dynamic`] (schedule the pending set,
/// execute, collect orphans, repeat from the earliest loss) with the
/// round's execution driven in control-period checkpoints so the layer
/// can observe progress, launch duplicates, resolve duels at first
/// finish, and evict collapsed nodes mid-round.
pub fn run_mitigated(sess: &SimSession, cost: &CostModel) -> DynamicsOutcome {
    let spec = &sess.spec;
    let mit = spec.mitigation.clone().unwrap_or_else(MitigationSpec::off);
    let closed_loop = spec.telemetry.as_ref().is_some_and(|ts| ts.reallocate);
    if mit.is_inert() && !closed_loop {
        // `speculation = "off"` (and no eviction/rebalance) is the plain
        // dynamics path, bit-identical by delegation. A reallocating
        // measurement plane needs this runner's checkpoint clock even
        // with mitigation off — probe-only telemetry does not.
        return run_dynamic(sess, cost);
    }
    let dspec = spec.dynamics.clone().unwrap_or_else(DynamicsSpec::none);
    let n_links = sess.link_caps_mbps.len();
    let n_hosts = sess.engine_init.len();
    let timeline = dspec.compile(&sess.nodes, n_links);
    let base_caps_mb_s: Vec<f64> =
        sess.link_caps_mbps.iter().map(|&c| mbps_to_mb_per_s(c)).collect();

    let tasks: Vec<TaskSpec> = if !sess.tasks.is_empty() {
        sess.tasks.clone()
    } else if let Some(job) = &sess.job {
        job.maps().cloned().collect()
    } else {
        Vec::new()
    };
    let submitted: Vec<TaskId> = tasks.iter().map(|t| t.id).collect();
    let spec_of: HashMap<TaskId, usize> =
        tasks.iter().enumerate().map(|(i, t)| (t.id, i)).collect();
    let intervals = down_intervals(&timeline);
    // control-period: one mitigation checkpoint per calendar slot (at
    // least one simulated second apart)
    let period = Secs(spec.slot_secs.max(1.0));

    let mut avail = sess.engine_init.clone();
    let mut pending = tasks.clone();
    let mut now = Secs::ZERO;
    let mut records: Vec<TaskRecord> = Vec::new();
    let mut reservations: Vec<ReservationAudit> = Vec::new();
    let mut reassignments = 0usize;
    let mut rounds = 0usize;
    let mut stale_reservations = 0usize;
    let mut pulls: Vec<PullAudit> = Vec::new();
    let mut deferrals = 0usize;
    let mut under_replicated_peak = 0usize;
    let mut speculated = 0usize;
    let mut spec_wins = 0usize;
    let mut evictions = 0usize;
    let mut duels: Vec<DuelAudit> = Vec::new();
    // once per (node, straggle onset): keeps eviction rounds bounded
    let mut evicted: HashSet<(usize, u64)> = HashSet::new();
    // measurement plane: estimators persist across rounds
    let mut telem =
        spec.telemetry.clone().map(|ts| Telemetry::new(ts, n_links));
    let mut reallocs: Vec<ReallocAudit> = Vec::new();
    let mut reallocations = 0usize;

    while !pending.is_empty() {
        rounds += 1;
        assert!(
            rounds <= 3 * timeline.len() + 4,
            "mitigated dynamics run did not converge in {rounds} rounds"
        );
        let st = state_at(&timeline, now, n_hosts, n_links);
        let up = |nd: NodeId| !st.down[nd.0];
        let next_recovery = |now: Secs| -> Secs {
            timeline
                .iter()
                .find(|te| te.at > now && matches!(te.ev, DynEvent::NodeUp(_)))
                .expect("compiled timelines pair every crash with a recovery")
                .at
        };
        if sess.nodes.iter().all(|nd| st.down[nd.0]) {
            now = next_recovery(now);
            continue;
        }
        under_replicated_peak = under_replicated_peak.max(sess.nn.under_replicated(up).len());
        let (ready, blocked): (Vec<TaskSpec>, Vec<TaskSpec>) =
            pending.iter().cloned().partition(|t| match t.input {
                Some(b) => sess.nn.is_readable(b, up),
                None => true,
            });
        deferrals += blocked.len();
        if ready.is_empty() {
            now = next_recovery(now);
            continue;
        }

        // ---- scheduling: fresh SDN view, straggle-aware speeds ----
        let mut ctrl = sess.ctrl.clone();
        for (l, &f) in st.link_frac.iter().enumerate() {
            if f < 1.0 {
                ctrl.set_link_health(LinkId(l), f);
            }
        }
        for &(_, src, dst, rate) in &st.cross {
            if let Some(path) = ctrl.path(src, dst).map(|p| p.to_vec()) {
                for &l in &path {
                    let cur = ctrl.background_mb_s(l);
                    ctrl.set_background_mb_s(l, cur + rate);
                }
            }
        }
        let mut ledger_init = vec![Secs::INF; n_hosts];
        for &nd in &sess.nodes {
            if !st.down[nd.0] {
                ledger_init[nd.0] = avail[nd.0].max(now);
            }
        }
        let mut ledger = Ledger::with_initial(ledger_init);
        let authorized: Vec<NodeId> =
            sess.nodes.iter().copied().filter(|nd| !st.down[nd.0]).collect();
        // unlike the plain path, reschedules see the *effective* speeds
        // (spec heterogeneity x current straggle factor), so evicted and
        // orphaned work is placed around live stragglers
        let eff_speed: Vec<f64> = (0..n_hosts)
            .map(|j| {
                let base =
                    spec.node_speed.get(j).copied().filter(|&f| f > 0.0).unwrap_or(1.0);
                base * st.speed[j]
            })
            .collect();
        let mut sched = spec.scheduler.make();
        if let Some(tm) = telem.as_mut() {
            tm.advance(&ctrl, now);
        }
        let assignment = {
            let measured = telem.as_ref().map(|tm| Measured::at(tm, now));
            let view: &dyn BandwidthView = match measured.as_ref() {
                Some(m) => m,
                None => &Oracle,
            };
            let mut ctx = SchedCtx {
                view,
                controller: &mut ctrl,
                namenode: &sess.nn,
                ledger: &mut ledger,
                authorized,
                now,
                cost,
                node_speed: eff_speed,
                down: st.down.clone(),
                bw_aware_sources: spec.bw_aware_sources,
            };
            sched.schedule(&ready, Some(now), &mut ctx)
        };
        let mut grant_of: HashMap<TaskId, Transfer> = HashMap::new();
        // src/dst route of each granted pull, for the reallocator
        let mut route_of: HashMap<TaskId, (NodeId, NodeId)> = HashMap::new();
        for p in &assignment.placements {
            if let Some(src) = p.source {
                pulls.push(PullAudit { task: p.task, source: src, at: now });
            }
            let tr = match &p.transfer {
                TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => t,
                _ => continue,
            };
            if tr.reservation.n_slots == 0 {
                continue;
            }
            grant_of.insert(p.task, tr.clone());
            if let Some(src) = p.source {
                route_of.insert(p.task, (src, p.node));
            }
            reservations.push(ReservationAudit {
                round: rounds,
                links: tr.reservation.links.clone(),
                start_slot: tr.reservation.start_slot,
                n_slots: tr.reservation.n_slots,
                frac: tr.reservation.frac,
                usable: ctrl.path_health(&tr.reservation.links),
            });
        }

        // revalidation sweep, identical to the plain path
        let slot_secs = sess.spec.slot_secs;
        for te in timeline.iter().filter(|te| te.at > now) {
            let DynEvent::LinkDegrade { link, frac } = &te.ev else { continue };
            let restore = te.at + Secs(dspec.degrade_secs.max(1e-3));
            let healthy = ctrl.link_health(*link);
            ctrl.set_link_health(*link, *frac);
            for p in &assignment.placements {
                let tr = match &p.transfer {
                    TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => t,
                    _ => continue,
                };
                let r = &tr.reservation;
                if r.n_slots == 0
                    || !r.links.contains(link)
                    || te.at >= r.end(slot_secs)
                    || restore <= r.start(slot_secs)
                {
                    continue;
                }
                if !ctrl.revalidate_transfer(tr) {
                    stale_reservations += 1;
                }
            }
            ctrl.set_link_health(*link, healthy);
        }

        // ---- execution: engine + remaining timeline, as usual ----
        let mut net = sess.net.clone();
        for (l, &f) in st.link_frac.iter().enumerate() {
            if f < 1.0 {
                net.set_link_capacity_mb_s(LinkId(l), base_caps_mb_s[l] * f);
            }
        }
        let mut engine = Engine::new(net, avail.clone());
        for j in 0..n_hosts {
            if st.down[j] {
                engine.set_node_down(NodeId(j));
            }
            if st.speed[j] != 1.0 {
                engine.set_node_speed(NodeId(j), st.speed[j]);
            }
        }
        for &(key, src, dst, rate) in &st.cross {
            if let Some(path) = sess.ctrl.path(src, dst).map(|p| p.to_vec()) {
                engine.inject(now, ClusterEvent::FlowStart { key, path, rate_mb_s: rate });
            }
        }
        for te in timeline.iter().filter(|te| te.at > now) {
            let ev = match &te.ev {
                DynEvent::NodeDown(nd) => ClusterEvent::NodeDown(*nd),
                DynEvent::NodeUp(nd) => ClusterEvent::NodeUp(*nd),
                DynEvent::LinkDegrade { link, frac } => {
                    ClusterEvent::LinkCapacity(*link, base_caps_mb_s[link.0] * frac)
                }
                DynEvent::LinkRestore { link } => {
                    ClusterEvent::LinkCapacity(*link, base_caps_mb_s[link.0])
                }
                DynEvent::Straggle { node, factor } => ClusterEvent::NodeSpeed(*node, *factor),
                DynEvent::StraggleEnd { node } => ClusterEvent::NodeSpeed(*node, 1.0),
                DynEvent::CrossStart { key, src, dst, rate_mb_s } => {
                    match sess.ctrl.path(*src, *dst) {
                        Some(p) => ClusterEvent::FlowStart {
                            key: *key,
                            path: p.to_vec(),
                            rate_mb_s: *rate_mb_s,
                        },
                        None => continue,
                    }
                }
                DynEvent::CrossStop { key } => ClusterEvent::FlowStop { key: *key },
            };
            engine.inject(te.at, ev);
        }
        engine.load(&assignment);

        // ---- the mitigation drive loop: checkpointed execution ----
        let mut live: Vec<Duel> = Vec::new();
        let mut duel_of: HashMap<u64, usize> = HashMap::new();
        // one speculation per original per round
        let mut tried: HashSet<TaskId> = HashSet::new();
        let mut next_ctl = now + period;
        let mut guard = 0usize;
        loop {
            guard += 1;
            if guard > 65_536 {
                break; // stop intervening; engine.run() below finishes
            }
            let fired = engine.run_until(next_ctl);
            if !fired.is_empty() {
                // first finish of a duel: kill the loser, release grants
                for key in fired {
                    let Some(&i) = duel_of.get(&key) else { continue };
                    if live[i].resolved {
                        continue;
                    }
                    live[i].resolved = true;
                    let at = engine.now();
                    let orig_won = engine
                        .records_so_far()
                        .iter()
                        .any(|r| r.task == live[i].orig && r.finish <= at);
                    let (winner, loser, loser_node) = if orig_won {
                        (live[i].orig, live[i].dup, live[i].dup_node)
                    } else {
                        (live[i].dup, live[i].orig, live[i].orig_node)
                    };
                    engine.kill_attempt(loser_node, loser);
                    let (mut released, mut orig_released) = (false, false);
                    if loser == live[i].dup {
                        if let Some(t) = &live[i].grant {
                            ctrl.complete_transfer(t, 0.0);
                            unaudit(&mut reservations, rounds, &t.reservation);
                            released = true;
                        }
                    } else {
                        spec_wins += 1;
                        if let Some(t) = &live[i].orig_grant {
                            ctrl.complete_transfer(t, 0.0);
                            unaudit(&mut reservations, rounds, &t.reservation);
                            orig_released = true;
                        }
                    }
                    duels.push(DuelAudit {
                        round: live[i].round,
                        task: live[i].orig,
                        dup: live[i].dup,
                        node: live[i].dup_node,
                        at,
                        winner: Some(winner),
                        reserved: live[i].grant.is_some(),
                        released,
                        orig_reserved: live[i].orig_grant.is_some(),
                        orig_released,
                    });
                }
                continue;
            }
            if !engine.work_left() {
                break;
            }
            let t = engine.now();
            let stc = state_at(&timeline, t, n_hosts, n_links);
            // (c) measurement plane: probe on the checkpoint clock; a
            // checkpoint that crossed a probe epoch renegotiates the
            // drifting grants when the closed loop is on
            if let Some(tm) = telem.as_mut() {
                // sync the controller's environment to the checkpoint
                // state first — probes must measure *current* truth, not
                // the round-start snapshot (only done with telemetry
                // active, so telemetry-free runs keep PR 7's behavior
                // bit-for-bit)
                for l in 0..n_links {
                    let link = LinkId(l);
                    ctrl.set_link_health(link, stc.link_frac[l]);
                    ctrl.set_background_mb_s(link, sess.ctrl.background_mb_s(link));
                }
                for &(_, csrc, cdst, rate) in &stc.cross {
                    if let Some(path) = ctrl.path(csrc, cdst).map(|p| p.to_vec()) {
                        for &l in &path {
                            let cur = ctrl.background_mb_s(l);
                            ctrl.set_background_mb_s(l, cur + rate);
                        }
                    }
                }
                let before = tm.probes;
                tm.advance(&ctrl, t);
                if closed_loop && tm.probes > before {
                    reallocations += reallocate_grants(
                        &mut engine,
                        &mut ctrl,
                        tm,
                        &tasks,
                        &spec_of,
                        &mut grant_of,
                        &route_of,
                        t,
                        rounds,
                        &mut reservations,
                        &mut reallocs,
                    );
                }
            }
            // (b) eviction: a node straggling at or past the ceiling is
            // drained through the orphan path, once per onset
            if mit.evict_factor.is_finite() {
                for &nd in &sess.nodes {
                    let j = nd.0;
                    if stc.down[j] || stc.speed[j] < mit.evict_factor {
                        continue;
                    }
                    let onset = timeline
                        .iter()
                        .filter(|te| {
                            te.at <= t
                                && matches!(&te.ev, DynEvent::Straggle { node, .. } if *node == nd)
                        })
                        .map(|te| te.at)
                        .next_back()
                        .unwrap_or(Secs::ZERO);
                    if !evicted.insert((j, onset.0.to_bits())) {
                        continue;
                    }
                    evictions += engine.evict_node(nd);
                }
            }
            // (a) speculation: duplicate the slow outliers
            if mit.speculation != SpeculationMode::Off {
                let snap = engine.running_snapshot();
                let measured = telem.as_ref().map(|tm| Measured::at(tm, t));
                let view: &dyn BandwidthView = match measured.as_ref() {
                    Some(m) => m,
                    None => &Oracle,
                };
                for victim in slow_outliers(&snap, t, mit.slow_threshold) {
                    if !tried.insert(victim.task) {
                        continue;
                    }
                    let Some(&ti) = spec_of.get(&victim.task) else { continue };
                    if let Some(duel) = try_speculate(
                        &mut engine,
                        &mut ctrl,
                        view,
                        sess,
                        mit.speculation,
                        &victim,
                        &tasks[ti],
                        grant_of.get(&victim.task).cloned(),
                        &stc,
                        t,
                        rounds,
                        &mut reservations,
                        &mut pulls,
                    ) {
                        speculated += 1;
                        duel_of.insert(duel.dup.0 as u64, live.len());
                        live.push(duel);
                    }
                }
            }
            next_ctl = next_ctl + period;
        }
        let mut round_recs = engine.run();
        // duels left unresolved have no surviving attempt (crash storm):
        // release the duplicate's grant so nothing leaks
        for d in live.iter().filter(|d| !d.resolved) {
            let (mut released, mut orig_released) = (false, false);
            if let Some(t) = &d.grant {
                ctrl.complete_transfer(t, 0.0);
                unaudit(&mut reservations, rounds, &t.reservation);
                released = true;
            }
            if let Some(t) = &d.orig_grant {
                ctrl.complete_transfer(t, 0.0);
                unaudit(&mut reservations, rounds, &t.reservation);
                orig_released = true;
            }
            duels.push(DuelAudit {
                round: d.round,
                task: d.orig,
                dup: d.dup,
                node: d.dup_node,
                at: engine.now(),
                winner: None,
                reserved: d.grant.is_some(),
                released,
                orig_reserved: d.orig_grant.is_some(),
                orig_released,
            });
        }
        // a winning duplicate *is* the task: rewrite to the original id
        // (ties — both finished in one batch — keep the original record)
        for r in &mut round_recs {
            if r.task.0 >= DUP_BASE {
                r.task = TaskId(r.task.0 - DUP_BASE);
            }
        }
        let mut seen: HashSet<TaskId> = HashSet::new();
        round_recs.retain(|r| seen.insert(r.task));
        records.extend(round_recs);
        let orphans = engine.take_orphans();
        avail = engine.node_free_times().to_vec();
        // silent-tail fix: an orphan only re-enters if the task has no
        // surviving record — a task whose original AND duplicate both
        // died carries over; a duel loser's orphaned original does not
        let completed: HashSet<TaskId> = records.iter().map(|r| r.task).collect();
        let lost: Vec<(TaskId, Secs)> = orphans
            .iter()
            .map(|(p, at)| {
                let id =
                    if p.task.0 >= DUP_BASE { TaskId(p.task.0 - DUP_BASE) } else { p.task };
                (id, *at)
            })
            .filter(|(id, _)| !completed.contains(id))
            .collect();
        if lost.is_empty() && blocked.is_empty() {
            break;
        }
        reassignments += lost.len();
        now = if lost.is_empty() {
            next_recovery(now)
        } else {
            lost.iter().map(|&(_, at)| at).fold(Secs::INF, Secs::min)
        };
        let mut carry: HashSet<TaskId> = lost.iter().map(|&(id, _)| id).collect();
        carry.extend(blocked.iter().map(|t| t.id));
        pending = tasks.iter().filter(|t| carry.contains(&t.id)).cloned().collect();
    }

    records.sort_by_key(|r| r.task);
    let makespan = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
    let (mut maps, mut local) = (0usize, 0usize);
    for r in &records {
        if r.is_map {
            maps += 1;
            if r.is_local {
                local += 1;
            }
        }
    }
    let locality = if maps == 0 { 1.0 } else { local as f64 / maps as f64 };
    DynamicsOutcome {
        records,
        makespan,
        locality,
        reassignments,
        rounds,
        down_intervals: intervals,
        reservations,
        stale_reservations,
        submitted,
        pulls,
        deferrals,
        under_replicated_peak,
        speculated,
        spec_wins,
        evictions,
        duels,
        probes: telem.map_or(0, |tm| tm.probes),
        reallocations,
        reallocs,
    }
}

impl SimSession {
    /// [`run_mitigated`] as a session method.
    pub fn run_mitigated(&self, cost: &CostModel) -> DynamicsOutcome {
        run_mitigated(self, cost)
    }
}

/// Per-node service score from the rebalancer's evaluate pass.
#[derive(Debug, Clone)]
pub struct NodeScore {
    pub node: NodeId,
    /// Mean realized-vs-promised compute stretch over finished records
    /// (1.0 = the node delivered exactly what its placements promised).
    pub stretch: f64,
}

/// The evaluate/score/evict descheduler split for long streams: rank
/// nodes by realized-vs-promised service, drain the worst offender's
/// pending queue (the running attempt finishes undisturbed) so the
/// stream driver reschedules that work elsewhere.
#[derive(Debug, Clone)]
pub struct Rebalancer {
    period: Secs,
    next_eval: Secs,
}

/// A node is an offender once it delivers at least 20% less service
/// than promised (realized stretch >= 1.2 over its finished records).
const OFFENDER_STRETCH: f64 = 1.2;

impl Rebalancer {
    pub fn new(period_secs: f64) -> Self {
        Self { period: Secs(period_secs), next_eval: Secs(period_secs) }
    }

    pub fn due(&self, now: Secs) -> bool {
        self.period.0 > 0.0 && now >= self.next_eval
    }

    /// Evaluate: mean realized-vs-promised stretch per node over the
    /// finished records (`nominal_of` maps a task to its promised
    /// compute seconds; unknown tasks are skipped).
    pub fn evaluate(
        engine: &Engine,
        n_hosts: usize,
        nominal_of: impl Fn(TaskId) -> Option<f64>,
    ) -> Vec<NodeScore> {
        let now = engine.now();
        let mut realized = vec![0.0f64; n_hosts];
        let mut promised = vec![0.0f64; n_hosts];
        for r in engine.records_so_far() {
            if r.finish > now {
                continue;
            }
            if let Some(nom) = nominal_of(r.task) {
                realized[r.node.0] += (r.finish - r.compute_start).0;
                promised[r.node.0] += nom;
            }
        }
        (0..n_hosts)
            .map(|j| NodeScore {
                node: NodeId(j),
                stretch: if promised[j] > 0.0 { realized[j] / promised[j] } else { 1.0 },
            })
            .collect()
    }

    /// Score + evict: drain the worst offender's pending queue through
    /// the orphan path. Returns the offender and how many placements
    /// were drained (`None` when no node crosses the offender bar or
    /// none of the offenders has pending work). Advances the period.
    pub fn tick(
        &mut self,
        engine: &mut Engine,
        n_hosts: usize,
        nominal_of: impl Fn(TaskId) -> Option<f64>,
    ) -> Option<(NodeId, usize)> {
        let now = engine.now();
        if !self.due(now) {
            return None;
        }
        while self.next_eval <= now {
            self.next_eval = self.next_eval + self.period;
        }
        let mut scores = Self::evaluate(engine, n_hosts, nominal_of);
        // worst first; ties resolve to the lower node id (stable order)
        scores.sort_by(|a, b| b.stretch.total_cmp(&a.stretch).then(a.node.cmp(&b.node)));
        let worst = scores
            .into_iter()
            .find(|s| s.stretch >= OFFENDER_STRETCH && engine.has_pending(s.node))?;
        let drained = engine.drain_node_queue(worst.node);
        if drained == 0 {
            return None; // only an in-flight pull was pending: leave it
        }
        Some((worst.node, drained))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{InitialLoad, ScenarioSpec, TopologyShape, WorkloadSpec};

    fn wave_spec(kind: SchedulerKind, dynamics: Option<DynamicsSpec>) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(
            "mit-test",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 3,
                edge_mbps: 100.0,
                uplink_mbps: 400.0,
            },
            WorkloadSpec::MapWave { tasks: 10, compute_secs: 12.0, output_mb: 4.0 },
        );
        s.scheduler = kind;
        s.replication = 2;
        s.seed = 99;
        s.initial = InitialLoad::Sampled { max_secs: 8.0 };
        s.dynamics = dynamics;
        s
    }

    /// One long straggler hitting most of the cluster from t~0: the
    /// regime where speculation must rescue the tail.
    fn straggler_dynamics() -> DynamicsSpec {
        DynamicsSpec {
            stragglers: 5,
            straggle_factor: 6.0,
            straggle_secs: 500.0,
            horizon_secs: 2.0,
            ..DynamicsSpec::none()
        }
    }

    #[test]
    fn spec_defaults_are_inert_and_parse_is_strict() {
        assert!(MitigationSpec::off().is_inert());
        assert!(!MitigationSpec::late().is_inert());
        assert!(!MitigationSpec::bw_aware().is_inert());
        let mut evict_only = MitigationSpec::off();
        evict_only.evict_factor = 3.0;
        assert!(!evict_only.is_inert());
        assert_eq!(SpeculationMode::parse("off"), Some(SpeculationMode::Off));
        assert_eq!(SpeculationMode::parse("late"), Some(SpeculationMode::Late));
        assert_eq!(SpeculationMode::parse("bw_aware"), Some(SpeculationMode::BwAware));
        assert_eq!(SpeculationMode::parse("LATE"), None);
        assert_eq!(SpeculationMode::parse("bw-aware"), None);
        for m in [SpeculationMode::Off, SpeculationMode::Late, SpeculationMode::BwAware] {
            assert_eq!(SpeculationMode::parse(m.label()), Some(m));
        }
    }

    #[test]
    fn detector_flags_stretched_long_remaining_attempts() {
        let rt = |task: usize, stretch: f64, start: f64, nominal: f64| RunningTask {
            task: TaskId(task),
            node: NodeId(task % 4),
            compute_start: Secs(start),
            finish: Secs(start + nominal * stretch),
            nominal: Secs(nominal),
        };
        // three healthy attempts nearly done, one 6x straggler
        let snap = vec![
            rt(0, 1.0, 0.0, 10.0),
            rt(1, 1.0, 0.0, 10.0),
            rt(2, 1.0, 0.0, 10.0),
            rt(3, 6.0, 0.0, 10.0),
        ];
        let out = slow_outliers(&snap, Secs(8.0), 1.5);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].task, TaskId(3));
        // duplicates are never duplicated
        let snap2 = vec![rt(DUP_BASE + 3, 6.0, 0.0, 10.0)];
        assert!(slow_outliers(&snap2, Secs(8.0), 1.5).is_empty());
        // below threshold: nothing flags even with long remaining
        let snap3 = vec![rt(0, 1.4, 0.0, 10.0), rt(1, 1.0, 0.0, 10.0)];
        assert!(slow_outliers(&snap3, Secs(2.0), 1.5).is_empty());
    }

    #[test]
    fn releasing_a_grant_restores_the_calendar_plan() {
        // plan A -> commit -> the next plan differs -> release -> the
        // plan is bitwise A again (kill semantics leak nothing)
        let sess = SimSession::new(&wave_spec(SchedulerKind::Bass, None));
        let mut ctrl = sess.ctrl.clone();
        let (src, dst) = (sess.nodes[0], sess.nodes[3]);
        let a = ctrl.plan_transfer(src, dst, 256.0, Secs(1.0)).expect("plan A");
        assert!(a.0.n_slots > 0, "a real window is reserved");
        let t = ctrl.commit_transfer(src, dst, TrafficClass::HadoopOther, a.clone(), Secs(1.0));
        let t = t.expect("commit");
        let b = ctrl.plan_transfer(src, dst, 256.0, Secs(1.0)).expect("plan B");
        assert_ne!(a.0, b.0, "the committed grant displaces the next plan");
        ctrl.complete_transfer(&t, 0.0);
        let c = ctrl.plan_transfer(src, dst, 256.0, Secs(1.0)).expect("plan C");
        assert_eq!(a.0, c.0, "release restores the calendar bitwise");
    }

    #[test]
    fn inert_spec_delegates_to_run_dynamic_bitwise() {
        let cost = CostModel::rust_only();
        let d = DynamicsSpec::churn(1.0);
        for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
            let mut spec = wave_spec(kind, Some(d.clone()));
            spec.mitigation = Some(MitigationSpec::off());
            let sess = SimSession::new(&spec);
            let plain = run_dynamic(&sess, &cost);
            let mitigated = run_mitigated(&sess, &cost);
            assert_eq!(plain.makespan.to_bits(), mitigated.makespan.to_bits(), "{kind:?}");
            assert_eq!(plain.records.len(), mitigated.records.len());
            for (a, b) in plain.records.iter().zip(&mitigated.records) {
                assert_eq!(a.task, b.task);
                assert_eq!(a.node, b.node);
                assert_eq!(a.finish.0.to_bits(), b.finish.0.to_bits());
            }
            assert_eq!(mitigated.speculated, 0);
            assert!(mitigated.duels.is_empty());
        }
    }

    #[test]
    fn speculation_completes_every_task_exactly_once() {
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
            for mit in [MitigationSpec::late(), MitigationSpec::bw_aware()] {
                let mut spec = wave_spec(kind, Some(straggler_dynamics()));
                spec.mitigation = Some(mit.clone());
                let sess = SimSession::new(&spec);
                let out = sess.run_mitigated(&cost);
                assert_eq!(
                    out.records.len(),
                    out.submitted.len(),
                    "{kind:?}/{:?}: exactly-once",
                    mit.speculation
                );
                let mut ids: Vec<TaskId> = out.records.iter().map(|r| r.task).collect();
                ids.sort();
                ids.dedup();
                assert_eq!(ids.len(), out.submitted.len());
                assert!(ids.iter().all(|t| t.0 < DUP_BASE), "no synthetic ids leak out");
            }
        }
    }

    #[test]
    fn bw_aware_speculation_beats_no_mitigation_on_stragglers() {
        // 5 of 6 nodes straggle 6x for the whole run: duplicates on the
        // healthy node must shorten the tail
        let cost = CostModel::rust_only();
        let mut spec = wave_spec(SchedulerKind::Bass, Some(straggler_dynamics()));
        let sess_off = SimSession::new(&spec);
        let off = sess_off.run_mitigated(&cost);
        spec.mitigation = Some(MitigationSpec::bw_aware());
        let sess_on = SimSession::new(&spec);
        let on = sess_on.run_mitigated(&cost);
        assert!(on.speculated > 0, "the detector fired");
        assert!(on.spec_wins > 0, "at least one duplicate won");
        assert!(
            on.makespan < off.makespan,
            "bw_aware {} must beat off {}",
            on.makespan,
            off.makespan
        );
        // every lost duel released its grant
        for d in &on.duels {
            if d.winner != Some(d.dup) {
                assert!(!d.reserved || d.released, "loser duplicate leaked a grant");
            }
            if d.winner == Some(d.dup) {
                assert!(!d.orig_reserved || d.orig_released, "killed original leaked a grant");
            }
        }
    }

    #[test]
    fn eviction_drains_a_collapsed_node_and_converges() {
        let cost = CostModel::rust_only();
        let mut spec = wave_spec(SchedulerKind::Bass, Some(straggler_dynamics()));
        let mut mit = MitigationSpec::off();
        mit.evict_factor = 3.0; // straggle factor 6 crosses the ceiling
        spec.mitigation = Some(mit);
        let sess = SimSession::new(&spec);
        let out = sess.run_mitigated(&cost);
        assert!(out.evictions > 0, "stragglers past the ceiling are drained");
        assert!(out.reassignments > 0, "evicted work is rescheduled");
        assert_eq!(out.records.len(), out.submitted.len(), "exactly-once survives eviction");
    }

    #[test]
    fn mitigated_runs_are_deterministic() {
        let cost = CostModel::rust_only();
        let run = || {
            let mut spec = wave_spec(SchedulerKind::Bass, Some(straggler_dynamics()));
            spec.mitigation = Some(MitigationSpec::bw_aware());
            let sess = SimSession::new(&spec);
            let out = sess.run_mitigated(&cost);
            (out.makespan, out.speculated, out.spec_wins, out.rounds, out.records.len())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn rebalancer_scores_realized_vs_promised() {
        // synthetic engine: two nodes, one delivering half speed
        use crate::sim::FlowNet;
        let net = FlowNet::new(&[100.0, 100.0]);
        let mut engine = Engine::new(net, vec![Secs::ZERO; 2]);
        engine.load(&Assignment {
            placements: vec![
                Placement {
                    task: TaskId(0),
                    node: NodeId(0),
                    compute: Secs(10.0),
                    transfer: TransferPlan::None,
                    gate: None,
                    source: None,
                    is_local: true,
                    is_map: true,
                },
                Placement {
                    task: TaskId(1),
                    node: NodeId(1),
                    compute: Secs(20.0), // promised 10, placed at 20: 2x stretch
                    transfer: TransferPlan::None,
                    gate: None,
                    source: None,
                    is_local: true,
                    is_map: true,
                },
            ],
        });
        engine.run_until(Secs(30.0));
        let nominal = |_t: TaskId| Some(10.0);
        let scores = Rebalancer::evaluate(&engine, 2, nominal);
        assert_eq!(scores[0].stretch, 1.0);
        assert_eq!(scores[1].stretch, 2.0);
        let mut rb = Rebalancer::new(5.0);
        assert!(rb.due(Secs(30.0)));
        // nothing pending on the offender: tick declines to evict
        assert!(rb.tick(&mut engine, 2, nominal).is_none());
        assert!(!rb.due(Secs(30.0)), "tick advances the period");
    }
}
