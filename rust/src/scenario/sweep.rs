//! Deterministic parallel sweep execution.
//!
//! Sweep drivers (`scale`, Table I, Fig. 5, the bench harness) expand a
//! scenario into a grid of independent points; each point builds its own
//! hermetic [`super::SimSession`] from its own seed, so fanning the grid
//! across threads changes wall-clock time and nothing else — results are
//! reassembled in input order and are bitwise-identical to a serial run.

use std::sync::Mutex;

use crate::metrics::JobMetrics;
use crate::runtime::CostModel;

use super::session::SimSession;
use super::spec::ScenarioSpec;

/// One executed grid point of a scenario sweep.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub scenario: String,
    pub scheduler: &'static str,
    pub data_mb: f64,
    pub metrics: JobMetrics,
}

/// Run a grid of job scenarios (each must carry a `Job` workload) on up
/// to `threads` workers; rows come back in grid order.
pub fn run_job_grid(specs: Vec<ScenarioSpec>, threads: usize, cost: &CostModel) -> Vec<SweepRow> {
    parallel_map(specs, threads, |spec| {
        let data_mb = match spec.workload {
            super::spec::WorkloadSpec::Job { data_mb, .. } => data_mb,
            ref other => panic!("run_job_grid needs Job workloads, got {other:?}"),
        };
        let scheduler = spec.scheduler.label();
        let scenario = spec.name.clone();
        let metrics = SimSession::new(&spec).run_job(cost);
        SweepRow { scenario, scheduler, data_mb, metrics }
    })
}

/// Map `f` over `items` on up to `threads` workers, preserving input
/// order. `threads <= 1` runs inline. Work is pulled from a shared queue
/// so uneven point costs still balance.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Mutex<Vec<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().rev().collect());
    let results: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                let Some((i, item)) = job else { break };
                let r = f(item);
                results.lock().unwrap().push((i, r));
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let out = parallel_map((0..64).collect(), 8, |x: i32| x * 2);
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let work = |x: u64| -> u64 {
            // a little arithmetic so threads actually interleave
            (0..500).fold(x, |a, b| a.wrapping_mul(31).wrapping_add(b))
        };
        let items: Vec<u64> = (0..40).collect();
        let serial = parallel_map(items.clone(), 1, work);
        let parallel = parallel_map(items, 6, work);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn job_grid_runs_each_point_in_order() {
        use super::super::spec::{ScenarioSpec, TopologyShape, WorkloadSpec};
        use crate::sched::SchedulerKind;
        use crate::workload::JobKind;
        let spec = |mb: f64, k: SchedulerKind| {
            ScenarioSpec::new(
                format!("grid-{mb}"),
                TopologyShape::Tree {
                    switches: 2,
                    hosts_per_switch: 3,
                    edge_mbps: 100.0,
                    uplink_mbps: 100.0,
                },
                WorkloadSpec::Job { kind: JobKind::Sort, data_mb: mb },
            )
            .with_scheduler(k)
        };
        let grid = vec![
            spec(150.0, SchedulerKind::Bass),
            spec(150.0, SchedulerKind::Hds),
            spec(300.0, SchedulerKind::Bass),
        ];
        let rows = run_job_grid(grid, 2, &CostModel::rust_only());
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].scheduler, "BASS");
        assert_eq!(rows[1].scheduler, "HDS");
        assert_eq!(rows[2].data_mb, 300.0);
        assert!(rows.iter().all(|r| r.metrics.jt > 0.0));
    }

    #[test]
    fn degenerate_shapes() {
        assert_eq!(parallel_map(Vec::<i32>::new(), 4, |x| x), Vec::<i32>::new());
        assert_eq!(parallel_map(vec![7], 4, |x: i32| x + 1), vec![8]);
        assert_eq!(parallel_map(vec![1, 2], 0, |x: i32| x), vec![1, 2]);
    }
}
