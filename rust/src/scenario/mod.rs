//! The scenario layer: declarative cluster construction + session state.
//!
//! Every experiment in the repo — the paper's Example 1/3, Table I,
//! Fig. 5, the scale sweep, the ablations, the online coordinator — used
//! to hand-wire its own `Topology`/`Controller`/`Namenode`/`Ledger`/
//! `FlowNet` stack. This module replaces that copy-pasted wiring with
//! two pieces:
//!
//! * [`ScenarioSpec`] — a declarative description of a cluster scenario:
//!   topology shape, HDFS placement policy, workload profile, scheduler
//!   kind, QoS policy, slot granularity, background load, seed.
//! * [`SimSession`] — the built session: it owns construction of every
//!   substrate object and drives schedule → execute → metrics. A session
//!   is one `Send` value, so sweep drivers fan independent scenario
//!   points out across worker threads ([`sweep::parallel_map`]) with
//!   bitwise-identical results to a serial run (each point is hermetic:
//!   its own seed, its own session).
//!
//! New workloads need a `ScenarioSpec` (or a TOML file for the CLI's
//! `scenario` subcommand), not a new driver. See DESIGN.md.
//!
//! Two execution overlays build on the session: [`dynamics`] plays a
//! scenario against an injected churn timeline, and [`online`] runs a
//! virtual-time **multi-job stream** where overlapping jobs share the
//! session's engine, ledger view, flow network and SDN calendar.

pub mod dynamics;
pub mod mitigation;
pub mod online;
pub mod session;
pub mod spec;
pub mod sweep;

pub use dynamics::{
    down_intervals, run_dynamic, run_dynamic_grid, DynEvent, DynSweepRow, DynamicsOutcome,
    DynamicsSpec, PullAudit, ReallocAudit, ReservationAudit, TimedEvent,
};
pub use mitigation::{run_mitigated, DuelAudit, MitigationSpec, SpeculationMode};
pub use online::{
    checkpoint_soak, checkpoint_stream, resume_soak, resume_stream, run_soak, run_stream,
    AdmissionAudit, AdmissionPolicy, JobOutcome, PreemptionAudit, SessionCheckpoint, SoakConfig,
    SoakOutcome, StreamOutcome, StreamSpec, Submission, SubmissionBody,
};
pub use session::{shuffle_majority_node, slowstart_gate, SimSession};
pub use spec::{
    cell_seed, BackgroundSpec, InitialLoad, ScenarioSpec, TenancySpec, TenantClass, TenantSpec,
    TopologyShape, WorkloadSpec,
};
pub use sweep::{parallel_map, run_job_grid, SweepRow};
