//! Online multi-job execution: a virtual-time job stream where
//! overlapping jobs share one cluster.
//!
//! The paper schedules a single job's tasks against SDN-reported link
//! bandwidth; its premise — bandwidth as a globally contended,
//! reservable resource — only bites when many jobs overlap on the same
//! cluster. This layer makes the job *stream* the unit of execution:
//!
//! * **One engine.** All jobs execute in a single [`Engine`]
//!   ([`Engine::run_until`] plays the cluster up to each control
//!   instant), so tasks from distinct jobs interleave in the node FIFO
//!   queues and their fair-share transfers contend in the one flow
//!   network. Records are job-tagged ([`Engine::tag_job`]).
//! * **One controller / calendar.** Every scheduler invocation mutates
//!   the session's live [`crate::sdn::Controller`]: BASS reservations
//!   committed for an earlier job persist, so a later job's
//!   `plan_transfer` sees the earlier grants and queues behind them.
//!   Calendar history is compacted at each arrival
//!   ([`crate::sdn::Controller::gc_calendar_before`]) so memory tracks
//!   the live horizon, not every job ever admitted.
//! * **One availability view.** The scheduler's per-invocation ledger is
//!   rebuilt from the cluster's *committed* occupancy: a node with
//!   queued or in-flight work carries the planned ledger value its
//!   scheduler committed (raised to any actual overrun), an idle node
//!   carries its actual engine availability. With no overlapping work
//!   this collapses to the actual availability the static path uses.
//! * **Admission control.** FIFO with a slot-availability gate: a job is
//!   admitted when fewer than `max_active` jobs are running *and* at
//!   least `min_free_slots` authorized nodes are free; otherwise it
//!   queues and is re-considered whenever a job completes (or, on an
//!   idle cluster, at the earliest instant the gate can pass). Queue
//!   wait counts toward the job's completion time.
//! * **Multi-tenancy (optional).** When the scenario carries a
//!   `[tenants]` table ([`TenancySpec`]), the FIFO queue is replaced by
//!   **DRF admission**: each tenant keeps its own FIFO, and at every
//!   admission opportunity the queued head of the tenant with the
//!   smallest weighted dominant share — `max(slot share, reserved
//!   calendar-bandwidth share) / weight` — is admitted, subject to the
//!   tenant's slot and bandwidth quotas. Jobs that can never meet their
//!   tenant's deadline (best-case critical path already past it) or
//!   never fit its slot quota are **rejected** up front. A *guaranteed*
//!   tenant whose job would still miss its deadline behind the
//!   committed backlog triggers **preemption**: every *spot* tenant's
//!   queued (not yet started) placements are drained through the
//!   descheduler's orphan path, their calendar grants are released, the
//!   guaranteed job is admitted first, and the drained work is
//!   rescheduled behind it — every grant move audited as an old→new
//!   [`ReallocAudit`] chain. A tenancy table with one default tenant
//!   (no caps) degenerates to the FIFO path bit-for-bit.
//!
//! # Phase pipeline per job (and the static differential pin)
//!
//! Each job still runs the paper's two-phase pipeline, driven by engine
//! completion watches instead of run-to-completion loops:
//!
//! 1. maps are scheduled at the admission instant against the committed
//!    view and loaded into the shared engine;
//! 2. a *threshold* watch ([`Engine::watch_threshold`]) fires at the
//!    `ceil(slowstart * m)`-th map finish — the engine clock then sits
//!    exactly on the slowstart gate — and the reduces are scheduled at
//!    that instant. The reduce ledger needs the maps' *actual* finish
//!    times (the static path reads them off executed records); a cloned
//!    **forecast probe** of the engine is run ahead to map completion to
//!    recover them. The forecast is exact unless a later arrival would
//!    have changed in-flight contention — precisely the information an
//!    online system cannot have.
//!
//! For a 1-job stream, or a stream whose inter-arrival gaps exceed every
//! job's makespan, the whole construction degenerates to the static
//! sequential path bit-for-bit (`rust/tests/proptests.rs` pins this
//! against `Coordinator::handle`) — with one documented exception: at
//! `slowstart < 1` the shared engine lets a job's reduce shuffles
//! contend with its own still-running map transfers, which the static
//! path's phase-split engines cannot represent. The pin therefore runs
//! at `slowstart = 1.0` (where the models provably coincide for every
//! scheduler) plus BASS at the default slowstart (reserved transfers
//! never touch the shared flow network). The richer contention at
//! `slowstart < 1` is a deliberate fidelity gain of the online model.
//!
//! # Staged load pipeline: soak streams and checkpoints
//!
//! The driver loop is factored into explicit stages — **admit** (build
//! + admission at each arrival), **schedule** (the batch commits inside
//! the admission/gate handlers), **execute** (play the engine to
//! quiescence) and **account** (outcome assembly) — with two
//! consequences:
//!
//! * **Snapshot/resume.** [`checkpoint_stream`] plays a submission
//!   prefix and captures a [`SessionCheckpoint`]: engine clock and
//!   queues, calendar, tenant usage, RNG cursors, audit trails.
//!   [`resume_stream`] restores it into a fresh session built from the
//!   same spec and plays the remaining submissions; the resumed run's
//!   [`StreamOutcome`] is bit-for-bit the uninterrupted run's.
//! * **Bounded-memory soaks.** [`run_soak`] executes the same stream
//!   under per-completion finalization: finished records are drained
//!   out of the engine and folded into a [`StreamAccum`] sketch, the
//!   completed job's engine bookkeeping is forgotten
//!   ([`Engine::forget_job`]), and the placement arena and SDN calendar
//!   are compacted periodically. Retained state tracks the live working
//!   set instead of stream length, so 100k-job streams run in bounded
//!   memory; the cost is that [`SoakOutcome`] reports distribution
//!   sketches and counters instead of per-job outcomes, and slowdowns
//!   are measured against a *class* baseline (the isolated run of the
//!   first completed job with the same name and input size) rather than
//!   a per-job isolated run.

use std::collections::{HashMap, VecDeque};

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::{JobId, JobSpec, TaskId, TaskSpec};
use crate::metrics::{
    jain_index, jobs_per_hour, sustained_jobs_per_hour, JobMetrics, StreamAccum, StreamStats,
    TenantStats,
};
use crate::runtime::CostModel;
use crate::sched::{SchedCtx, Scheduler as _};
use crate::sdn::{Controller, Reservation};
use crate::sim::{Assignment, Engine, FlowNet, Placement, TaskRecord, TransferPlan};
use crate::topology::NodeId;
use crate::util::{Secs, XorShift};
use crate::workload::{JobArrival, JobKind, TraceGen, WorkloadBuilder};

use super::dynamics::{ReallocAudit, ReservationAudit};
use super::mitigation::Rebalancer;
use super::session::{shuffle_majority_node, slowstart_gate, SimSession};
use super::spec::{TenancySpec, TenantClass};

/// One job handed to the stream at an absolute submission time.
#[derive(Debug, Clone)]
pub struct Submission {
    pub at_secs: f64,
    pub body: SubmissionBody,
    /// Owning tenant by name (must resolve in the scenario's
    /// [`TenancySpec`]). `None` on a multi-tenant stream attributes the
    /// job round-robin by arrival index; ignored without tenancy.
    pub tenant: Option<String>,
}

/// What the submission carries.
#[derive(Debug, Clone)]
pub enum SubmissionBody {
    /// A Wordcount/Sort job generated through [`WorkloadBuilder`]
    /// against the session's namenode and RNG (the trace-driven route).
    Generated { kind: JobKind, data_mb: f64 },
    /// Pre-built tasks (dense ids, maps before reduces — validated via
    /// [`JobSpec`]); the golden-trace streams use this.
    Explicit { name: String, tasks: Vec<TaskSpec>, slowstart: f64 },
}

impl From<JobArrival> for Submission {
    fn from(a: JobArrival) -> Self {
        Self {
            at_secs: a.at_secs,
            body: SubmissionBody::Generated { kind: a.kind, data_mb: a.data_mb },
            tenant: None,
        }
    }
}

/// FIFO admission with a slot-availability gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionPolicy {
    /// Maximum concurrently active (admitted, incomplete) jobs.
    pub max_active: usize,
    /// Admission additionally waits until at least this many authorized
    /// nodes are free (committed occupancy <= now); clamped to the
    /// cluster size. 0 (the default) admits against busy nodes — the
    /// paper's shared-cluster regime and the static path's behavior.
    pub min_free_slots: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self { max_active: usize::MAX, min_free_slots: 0 }
    }
}

/// One DRF admission decision on a multi-tenant stream — enough to
/// replay the pick: the winner is the finite-key minimum, ties broken
/// by larger weight, then lower tenant index.
#[derive(Debug, Clone)]
pub struct AdmissionAudit {
    pub at: f64,
    /// The admitted job.
    pub job: JobId,
    /// Index of the winning tenant in the [`TenancySpec`].
    pub tenant: usize,
    /// Weighted dominant share per tenant at decision time:
    /// `max(slot share, bandwidth share) / weight`, `INFINITY` for
    /// tenants with no eligible queued head (empty queue or quota hit).
    pub keys: Vec<f64>,
}

/// One preempted (drained and rescheduled) spot placement.
#[derive(Debug, Clone)]
pub struct PreemptionAudit {
    pub at: f64,
    /// The drained queued task.
    pub task: TaskId,
    /// Its owning (spot) job and tenant.
    pub victim: JobId,
    pub victim_tenant: String,
    /// The guaranteed job whose deadline risk triggered the drain.
    pub by: JobId,
}

/// Declarative stream description (the `[stream]` config table / `bass
/// stream` CLI route): a Poisson job trace plus the admission policy.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    /// Jobs in the stream.
    pub jobs: usize,
    /// Mean of the exponential inter-arrival gap (seconds); smaller =
    /// higher arrival rate = more overlap.
    pub mean_interarrival_secs: f64,
    /// Job input sizes drawn uniformly per arrival (MB).
    pub sizes_mb: Vec<f64>,
    /// Admission: max concurrently active jobs (`usize::MAX` = no cap).
    pub max_active: usize,
    /// Admission: free authorized nodes required to admit.
    pub min_free_slots: usize,
    /// Trace seed (independent of the scenario seed, so schedulers
    /// compared on one cluster face the identical arrival sequence).
    pub seed: u64,
}

impl StreamSpec {
    pub fn defaults() -> Self {
        Self {
            jobs: 12,
            mean_interarrival_secs: 60.0,
            sizes_mb: vec![150.0, 300.0, 600.0],
            max_active: usize::MAX,
            min_free_slots: 0,
            seed: 2014,
        }
    }

    pub fn policy(&self) -> AdmissionPolicy {
        AdmissionPolicy { max_active: self.max_active, min_free_slots: self.min_free_slots }
    }

    /// Expand into the Poisson submission trace (deterministic per seed).
    pub fn submissions(&self) -> Vec<Submission> {
        let mut rng = XorShift::new(self.seed);
        TraceGen {
            mean_interarrival_secs: self.mean_interarrival_secs,
            sizes_mb: self.sizes_mb.clone(),
        }
        .generate_poisson(self.jobs, &mut rng)
        .into_iter()
        .map(Submission::from)
        .collect()
    }
}

/// One job's outcome within the stream.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job: JobId,
    pub name: String,
    pub submitted_at: f64,
    /// When the admission gate let it through (== submitted_at unless it
    /// queued).
    pub admitted_at: f64,
    /// The reduce slowstart gate the run used.
    pub gate: f64,
    /// Whether the job waited in the admission queue.
    pub queued: bool,
    /// MT/RT/JT/LR measured from *submission* (queue wait counts).
    pub metrics: JobMetrics,
    /// Completion time of the same job alone on the pristine cluster.
    pub isolated_jt: f64,
    /// `metrics.jt / isolated_jt` (1.0 = uncontended).
    pub slowdown: f64,
    /// The job's task specs with their stream-global ids (oracle fodder).
    pub tasks: Vec<TaskSpec>,
    /// Owning tenant name on a multi-tenant stream, `None` otherwise.
    pub tenant: Option<String>,
    /// Rejected at admission (infeasible deadline or impossible quota):
    /// the job never ran, its metrics are zeroed and excluded from the
    /// stream statistics.
    pub rejected: bool,
}

/// Everything one stream run produced — self-describing enough for the
/// concurrency oracles (`testkit::oracles`).
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub jobs: Vec<JobOutcome>,
    /// Job-tagged execution records, sorted by stream-global task id.
    pub records: Vec<(JobId, TaskRecord)>,
    /// Every committed slot reservation across all jobs, with the link
    /// healths in force at commit time (all on the one shared calendar,
    /// so cross-job stacking is checked together).
    pub reservations: Vec<ReservationAudit>,
    /// Absolute finish of the last task.
    pub last_finish: f64,
    /// `last_finish - first submission`.
    pub makespan: f64,
    /// JT / slowdown distribution statistics.
    pub stats: StreamStats,
    /// Jobs that waited in the admission queue.
    pub queued_jobs: usize,
    /// Drain events by the scoring descheduler (`[mitigation]
    /// rebalance_period`): evaluate/score/evict passes that actually
    /// moved pending work off a service offender.
    pub rebalances: usize,
    /// The tenancy table the stream ran under, when multi-tenant.
    pub tenants: Option<TenancySpec>,
    /// Per-tenant slowdown/SLO aggregates (empty without tenancy).
    pub tenant_stats: Vec<TenantStats>,
    /// Jain index over the per-tenant mean slowdowns (1.0 without
    /// tenancy or with fewer than two tenants).
    pub fairness_jain: f64,
    /// Every DRF admission decision, in admission order.
    pub admissions: Vec<AdmissionAudit>,
    /// Every preempted spot placement, in drain order.
    pub preemptions: Vec<PreemptionAudit>,
    /// Grant moves from preemption and descheduler drains, as old→new
    /// chains per task ([`crate::testkit::oracles`] checks them against
    /// `reservations`).
    pub reallocs: Vec<ReallocAudit>,
    /// Jobs rejected at admission.
    pub rejected_jobs: usize,
}

/// Watch keys: three per job.
fn gate_key(jid: usize) -> u64 {
    3 * jid as u64
}
fn maps_key(jid: usize) -> u64 {
    3 * jid as u64 + 1
}
fn all_key(jid: usize) -> u64 {
    3 * jid as u64 + 2
}

/// Per-job driver state.
#[derive(Clone)]
struct JobRun {
    name: String,
    submit: Secs,
    admitted: Secs,
    queued: bool,
    /// First stream-global task id (ids are `base..base + tasks`).
    base: usize,
    /// Task counts, kept even after a soak finalization clears the spec
    /// vectors (the id-range arithmetic in [`job_index_of`] and the DRF
    /// slot accounting live on these, not on the vectors).
    n_maps: usize,
    n_reduces: usize,
    maps: Vec<TaskSpec>,
    /// Reduce specs (un-hinted; the gate handler hints a copy).
    reduces: Vec<TaskSpec>,
    slowstart: f64,
    gate: Option<Secs>,
    /// Map locality of the committed assignment.
    lr: f64,
    /// Placement node per map (maps order) — determines the shuffle
    /// majority node without waiting for records.
    map_nodes: Vec<NodeId>,
    done: bool,
    /// Owning tenant index (multi-tenant streams only).
    tenant: Option<usize>,
    /// Admitted (scheduled into the engine); distinguishes active jobs
    /// from queued ones for the DRF usage accounting.
    started: bool,
    /// Rejected at admission; never ran.
    rejected: bool,
    /// Best-case critical path: the longest task compute on the fastest
    /// node — the deadline-feasibility floor.
    cp_min: f64,
    /// Calendar-bandwidth area (`frac * n_slots`) currently reserved for
    /// this job's transfers (the DRF bandwidth dimension).
    reserved_area: f64,
    /// Generated input size — the soak baseline-cache key (`None` for
    /// explicit submissions, which are never cached).
    data_mb: Option<f64>,
}

impl JobRun {
    fn n_tasks(&self) -> usize {
        self.n_maps + self.n_reduces
    }
}

/// The shuffle-majority node from committed placements. Bit-identical
/// to [`super::session::shuffle_majority_node`] over the executed
/// records: records land on their placement nodes and both walk tasks
/// in ascending id order, so the per-node sums accumulate identically.
fn hint_from_placements(maps: &[TaskSpec], nodes: &[NodeId], n_hosts: usize) -> NodeId {
    let mut out_mb = vec![0.0f64; n_hosts];
    for (t, nd) in maps.iter().zip(nodes) {
        out_mb[nd.0] += t.output_mb;
    }
    let best = out_mb
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    NodeId(best)
}

/// Knobs for a bounded-memory soak run ([`run_soak`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SoakConfig {
    /// SLO for the throughput figure of merit: the stream "sustains" its
    /// rate only while the p95 slowdown stays at or under this.
    pub target_p95_slowdown: f64,
    /// Per-distribution retention cap of the quantile sketches
    /// ([`crate::metrics::QuantileSketch`]).
    pub sketch_cap: usize,
    /// Virtual seconds between periodic calendar compactions
    /// ([`crate::sdn::Controller::maybe_gc`]); completions in between
    /// still compact the placement arena.
    pub gc_period_secs: f64,
}

impl SoakConfig {
    pub fn defaults() -> Self {
        Self { target_p95_slowdown: 2.0, sketch_cap: 256, gc_period_secs: 300.0 }
    }
}

/// What a soak run reports: sketch-backed distribution statistics and
/// compaction/throughput counters — deliberately *not* per-job outcomes,
/// so the report itself is O(1) in stream length.
#[derive(Debug, Clone)]
pub struct SoakOutcome {
    /// Jobs that ran to completion (excludes rejections).
    pub jobs: usize,
    pub rejected_jobs: usize,
    pub queued_jobs: usize,
    /// Absolute finish of the last completed task.
    pub last_finish: f64,
    /// `last_finish - first submission`.
    pub makespan: f64,
    /// JT / slowdown statistics off the accumulator (exact up to the
    /// sketch cap, rank-bounded beyond it).
    pub stats: StreamStats,
    pub p95_slowdown: f64,
    /// Raw completion rate over the makespan.
    pub jobs_per_hour: f64,
    /// The soak figure of merit: jobs/hour while the p95 slowdown meets
    /// the target, 0 once the tail blows through it.
    pub sustained_jobs_per_hour: f64,
    /// Periodic calendar compactions that actually ran.
    pub compactions: usize,
    /// Placement-arena slots shrunk to skeletons across the run.
    pub compacted_placements: usize,
    /// High-water marks of retained state — the bounded-memory
    /// evidence: live (undrained) engine records and calendar segments.
    pub peak_live_records: usize,
    pub peak_calendar_segments: usize,
    /// Samples held by the two quantile sketches at the end.
    pub retained_samples: usize,
    pub rebalances: usize,
    /// DRF decisions / preemptions / grant moves (counted, not kept).
    pub admissions: usize,
    pub preemptions: usize,
    pub reallocs: usize,
}

/// Soak-mode driver state: the streaming accumulator plus the
/// per-completion finalization bookkeeping.
#[derive(Clone)]
struct SoakState {
    cfg: SoakConfig,
    accum: StreamAccum,
    /// Drained records of still-active jobs, keyed by job index; an
    /// entry is removed (and folded into the accumulator) when its job
    /// finalizes, so the map tracks the active set only.
    buffers: HashMap<usize, Vec<TaskRecord>>,
    /// Class-baseline cache: isolated JT of the first completed job per
    /// (name, input-size) class. Valid because generated job names are
    /// `kind-sizeMB` and the isolated baseline is shift-invariant once
    /// the submit time clears the initial node idles.
    iso_cache: HashMap<(String, u64), f64>,
    finalized: usize,
    last_finish: f64,
    compacted_placements: usize,
    peak_live_records: usize,
    peak_calendar_segments: usize,
    n_admissions: usize,
    n_preemptions: usize,
    n_reallocs: usize,
}

impl SoakState {
    fn new(cfg: SoakConfig) -> Self {
        Self {
            cfg,
            accum: StreamAccum::new(cfg.sketch_cap),
            buffers: HashMap::new(),
            iso_cache: HashMap::new(),
            finalized: 0,
            last_finish: 0.0,
            compacted_placements: 0,
            peak_live_records: 0,
            peak_calendar_segments: 0,
            n_admissions: 0,
            n_preemptions: 0,
            n_reallocs: 0,
        }
    }
}

/// A mid-stream snapshot: everything the driver and session mutate
/// while a stream plays. Captured by [`checkpoint_stream`] /
/// [`checkpoint_soak`] after a submission prefix; restored by
/// [`resume_stream`] / [`resume_soak`] into a fresh [`SimSession`]
/// built from the *same* [`super::spec::ScenarioSpec`] (everything not
/// in the snapshot — topology, cost model, scheduler — is rebuilt
/// deterministically from the spec; schedulers are decision-stateless).
#[derive(Clone)]
pub struct SessionCheckpoint {
    policy: AdmissionPolicy,
    engine: Engine,
    ctrl: Controller,
    nn: Namenode,
    rng: XorShift,
    planned: Vec<Secs>,
    jobs: Vec<JobRun>,
    active: usize,
    admit_q: VecDeque<usize>,
    audits: Vec<ReservationAudit>,
    next_base: usize,
    rebalancer: Option<Rebalancer>,
    rebalances: usize,
    admissions: Vec<AdmissionAudit>,
    preemptions: Vec<PreemptionAudit>,
    reallocs: Vec<ReallocAudit>,
    rejected: usize,
    soak: Option<SoakState>,
}

impl SessionCheckpoint {
    /// Submissions already ingested (resume from this index).
    pub fn submissions_seen(&self) -> usize {
        self.jobs.len()
    }

    /// Engine clock at capture.
    pub fn now_secs(&self) -> f64 {
        self.engine.now().0
    }

    /// Whether this snapshot came from a soak run (resume with
    /// [`resume_soak`]) or a classic stream ([`resume_stream`]).
    pub fn is_soak(&self) -> bool {
        self.soak.is_some()
    }
}

struct StreamDriver<'a> {
    sess: &'a mut SimSession,
    cost: &'a CostModel,
    policy: AdmissionPolicy,
    /// The one shared engine all jobs execute in.
    engine: Engine,
    /// Planned per-host availability from the last scheduler commit.
    planned: Vec<Secs>,
    n_hosts: usize,
    jobs: Vec<JobRun>,
    active: usize,
    admit_q: VecDeque<usize>,
    audits: Vec<ReservationAudit>,
    /// Cluster snapshots before any stream job (isolated-run baseline).
    pristine_ctrl: Controller,
    pristine_net: FlowNet,
    next_base: usize,
    /// The scoring descheduler, when `[mitigation] rebalance_period > 0`.
    rebalancer: Option<Rebalancer>,
    rebalances: usize,
    /// The tenancy table, when the scenario declares `[tenants]`.
    tenancy: Option<TenancySpec>,
    admissions: Vec<AdmissionAudit>,
    preemptions: Vec<PreemptionAudit>,
    reallocs: Vec<ReallocAudit>,
    rejected: usize,
    /// Largest initial node idle — the horizon past which the isolated
    /// baseline is shift-invariant (soak cache validity).
    max_init: Secs,
    /// `Some` on a soak run: per-completion finalization is on.
    soak: Option<SoakState>,
}

/// The owning job of a stream-global task id (ids are dense per job).
fn job_index_of(jobs: &[JobRun], tid: TaskId) -> Option<usize> {
    jobs.iter().position(|jr| tid.0 >= jr.base && tid.0 < jr.base + jr.n_tasks())
}

/// The stored (un-hinted) spec of a stream-global task id.
fn task_of(jobs: &[JobRun], tid: TaskId) -> Option<&TaskSpec> {
    let jr = &jobs[job_index_of(jobs, tid)?];
    let local = tid.0 - jr.base;
    if local < jr.n_maps {
        jr.maps.get(local)
    } else {
        jr.reduces.get(local - jr.n_maps)
    }
}

impl<'a> StreamDriver<'a> {
    /// The committed availability view at `floor`, read from `from` (the
    /// live engine, or a forecast probe): planned for busy/queued nodes,
    /// actual for idle ones, floored at the invocation instant.
    fn committed_ledger(&self, from: &Engine, floor: Secs) -> Ledger {
        let actual = from.node_free_times();
        let mut v = vec![Secs::INF; self.n_hosts];
        for &nd in &self.sess.nodes {
            let a = actual[nd.0];
            v[nd.0] = if from.has_pending(nd) { self.planned[nd.0].max(a) } else { a };
        }
        let mut l = Ledger::with_initial(v);
        l.raise_all(floor);
        l
    }

    /// Free authorized nodes at `now` (the admission gate's view).
    fn free_slots(&self, now: Secs) -> usize {
        let actual = self.engine.node_free_times();
        self.sess
            .nodes
            .iter()
            .filter(|&&nd| {
                let a = actual[nd.0];
                let c = if self.engine.has_pending(nd) { self.planned[nd.0].max(a) } else { a };
                c <= now
            })
            .count()
    }

    fn admissible(&self, now: Secs) -> bool {
        if self.active >= self.policy.max_active {
            return false;
        }
        let need = self.policy.min_free_slots.min(self.sess.nodes.len());
        need == 0 || self.free_slots(now) >= need
    }

    /// Schedule one batch against the given committed view, mutating the
    /// live controller/calendar; absorb the scheduler's plan and audit
    /// its reservations. `authorized` is usually the full session node
    /// set; the rebalancer passes it minus the drained offender.
    fn schedule_batch(
        &mut self,
        tasks: &[TaskSpec],
        gate: Secs,
        now: Secs,
        view: Ledger,
        authorized: Vec<NodeId>,
    ) -> Assignment {
        let mut ledger = view;
        let a = {
            // Streams still schedule clairvoyantly: threading the
            // measured view (DESIGN.md §12) through the stream
            // coordinator is open headroom (ROADMAP item 2).
            let mut ctx = SchedCtx {
                view: &crate::sdn::Oracle,
                controller: &mut self.sess.ctrl,
                namenode: &self.sess.nn,
                ledger: &mut ledger,
                authorized,
                now,
                cost: self.cost,
                node_speed: self.sess.spec.node_speed.clone(),
                down: Vec::new(),
                bw_aware_sources: self.sess.spec.bw_aware_sources,
            };
            self.sess.sched.schedule(tasks, Some(gate), &mut ctx)
        };
        for &nd in &self.sess.nodes {
            self.planned[nd.0] = ledger.idle(nd);
        }
        for p in &a.placements {
            let tr = match &p.transfer {
                TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => t,
                _ => continue,
            };
            if tr.reservation.n_slots == 0 {
                continue;
            }
            self.audits.push(ReservationAudit {
                round: 1,
                links: tr.reservation.links.clone(),
                start_slot: tr.reservation.start_slot,
                n_slots: tr.reservation.n_slots,
                frac: tr.reservation.frac,
                usable: self.sess.ctrl.path_health(&tr.reservation.links),
            });
            if let Some(j) = job_index_of(&self.jobs, p.task) {
                self.jobs[j].reserved_area += tr.reservation.frac * tr.reservation.n_slots as f64;
            }
        }
        a
    }

    /// Build the job at its arrival (RNG draws stay in arrival order no
    /// matter how long it queues) and offset its task ids into the
    /// stream-global space.
    fn build(&mut self, jid: usize, submit: Secs, body: SubmissionBody) -> JobRun {
        let data_mb = match &body {
            SubmissionBody::Generated { data_mb, .. } => Some(*data_mb),
            SubmissionBody::Explicit { .. } => None,
        };
        let (name, tasks, slowstart) = match body {
            SubmissionBody::Generated { kind, data_mb } => {
                let mut builder = WorkloadBuilder::new(kind);
                builder.replication = self.sess.spec.replication.min(self.sess.nodes.len());
                builder.reduces = self.sess.spec.reduces;
                builder.placement = self.sess.spec.placement.clone();
                builder.racks = self.sess.racks.clone();
                let job = builder.build(
                    jid,
                    data_mb,
                    &self.sess.nodes,
                    &mut self.sess.nn,
                    &mut self.sess.rng,
                );
                (job.name, job.tasks, job.slowstart)
            }
            SubmissionBody::Explicit { name, tasks, slowstart } => {
                // shape-check through the JobSpec constructor
                let job = JobSpec::new(jid, name, tasks);
                (job.name, job.tasks, slowstart)
            }
        };
        let base = self.next_base;
        self.next_base += tasks.len();
        let (mut maps, mut reduces) = (Vec::new(), Vec::new());
        for mut t in tasks {
            t.id = TaskId(base + t.id.0);
            if t.is_map() {
                maps.push(t);
            } else {
                reduces.push(t);
            }
        }
        assert!(!maps.is_empty(), "stream jobs need at least one map task");
        let min_factor = self
            .sess
            .nodes
            .iter()
            .map(|&nd| match self.sess.spec.node_speed.get(nd.0) {
                Some(&f) if f > 0.0 => f,
                _ => 1.0,
            })
            .fold(f64::INFINITY, f64::min);
        let cp_min = maps
            .iter()
            .chain(reduces.iter())
            .map(|t| t.compute.0 * min_factor)
            .fold(0.0, f64::max);
        JobRun {
            name,
            submit,
            admitted: submit,
            queued: false,
            base,
            n_maps: maps.len(),
            n_reduces: reduces.len(),
            maps,
            reduces,
            slowstart,
            gate: None,
            lr: 1.0,
            map_nodes: Vec::new(),
            done: false,
            tenant: None,
            started: false,
            rejected: false,
            cp_min,
            reserved_area: 0.0,
            data_mb,
        }
    }

    /// Admit a job at `at`: schedule its map wave against the committed
    /// cluster, register its watches, load it into the shared engine.
    fn admit(&mut self, jid: usize, at: Secs) {
        self.jobs[jid].admitted = at;
        self.jobs[jid].started = true;
        self.active += 1;
        let maps = self.jobs[jid].maps.clone();
        let view = self.committed_ledger(&self.engine, at);
        let a = self.schedule_batch(&maps, at, at, view, self.sess.nodes.clone());
        self.jobs[jid].lr = a.locality_ratio();
        let mut map_nodes = vec![NodeId(0); maps.len()];
        for p in &a.placements {
            map_nodes[p.task.0 - self.jobs[jid].base] = p.node;
        }
        self.jobs[jid].map_nodes = map_nodes;
        let map_ids: Vec<TaskId> = maps.iter().map(|t| t.id).collect();
        let all_ids: Vec<TaskId> = map_ids
            .iter()
            .copied()
            .chain(self.jobs[jid].reduces.iter().map(|t| t.id))
            .collect();
        self.engine.tag_job(JobId(jid), all_ids.iter().copied());
        let need = ((maps.len() as f64 * self.jobs[jid].slowstart).ceil() as usize)
            .clamp(1, maps.len());
        self.engine.watch_threshold(gate_key(jid), &map_ids, need);
        self.engine.watch(maps_key(jid), &map_ids);
        self.engine.watch(all_key(jid), &all_ids);
        self.engine.load(&a);
    }

    /// The slowstart threshold fired: the engine clock sits exactly on
    /// the job's reduce gate. Schedule the reduces now, against the
    /// forecast of the maps' actual finish times.
    fn on_gate(&mut self, jid: usize) {
        let gate = self.engine.now().max(self.jobs[jid].admitted);
        self.jobs[jid].gate = Some(gate);
        if self.jobs[jid].reduces.is_empty() {
            return;
        }
        let floor = self.jobs[jid].admitted;
        let view = if self.engine.watch_remaining(maps_key(jid)) == Some(0) {
            // every map already finished (slowstart = 1, or a shared
            // batch): the live engine holds the actual finishes
            self.committed_ledger(&self.engine, floor)
        } else {
            let mut probe = self.engine.clone();
            loop {
                let fired = probe.run_until(Secs::INF);
                assert!(!fired.is_empty(), "forecast probe stalled before map completion");
                if fired.contains(&maps_key(jid)) {
                    break;
                }
            }
            self.committed_ledger(&probe, floor)
        };
        let hint =
            hint_from_placements(&self.jobs[jid].maps, &self.jobs[jid].map_nodes, self.n_hosts);
        let mut reduces = self.jobs[jid].reduces.clone();
        for r in &mut reduces {
            r.src_hint = Some(hint);
        }
        let a = self.schedule_batch(&reduces, gate, gate, view, self.sess.nodes.clone());
        self.engine.load(&a);
    }

    fn on_job_done(&mut self, jid: usize) {
        debug_assert!(!self.jobs[jid].done, "job completed twice");
        self.jobs[jid].done = true;
        self.active -= 1;
        self.rebalance();
        let now = self.engine.now();
        self.try_admit(now);
        if self.soak.is_some() {
            self.soak_finalize(jid);
        }
    }

    /// Soak mode: the **account** stage running incrementally, at every
    /// job completion. Finished records are drained out of the engine
    /// and routed to their owning jobs' buffers; the completed job is
    /// folded into the accumulator (JT from its buffered records,
    /// slowdown against the class-baseline cache), its engine
    /// bookkeeping is forgotten, its spec vectors shrink to the count
    /// skeleton, and the placement arena + calendar are compacted.
    fn soak_finalize(&mut self, jid: usize) {
        let now = self.engine.now();
        let live_before = self.engine.records_so_far().len();
        for r in self.engine.drain_finished_records() {
            let j = job_index_of(&self.jobs, r.task).expect("drained record has an owning job");
            self.soak.as_mut().expect("soak mode").buffers.entry(j).or_default().push(r);
        }
        let buf =
            self.soak.as_mut().expect("soak mode").buffers.remove(&jid).unwrap_or_default();
        let gate = self.jobs[jid].gate.unwrap_or(self.jobs[jid].submit);
        let mut m = JobMetrics::from_records(&buf, self.jobs[jid].submit, Some(gate));
        m.lr = self.jobs[jid].lr;
        // Class-baseline slowdown: one isolated run per (name, size)
        // class instead of one per job. Only generated jobs past the
        // initial-idle horizon are cacheable (the baseline is a pure
        // time shift there); block layouts still vary per job, so the
        // cached denominator is the class representative's, not the
        // job's own — the documented soak approximation.
        let key = self.jobs[jid]
            .data_mb
            .filter(|_| self.jobs[jid].submit >= self.max_init)
            .map(|mb| (self.jobs[jid].name.clone(), mb.to_bits()));
        let cached = key
            .as_ref()
            .and_then(|k| self.soak.as_ref().expect("soak mode").iso_cache.get(k))
            .copied();
        let iso_jt = match cached {
            Some(v) => v,
            None => {
                let v = self.isolated_metrics(&self.jobs[jid]).jt;
                if let Some(k) = key {
                    self.soak.as_mut().expect("soak mode").iso_cache.insert(k, v);
                }
                v
            }
        };
        let slowdown = if iso_jt > 0.0 { m.jt / iso_jt } else { 1.0 };
        let buf_last = buf.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        let (base, nt) = (self.jobs[jid].base, self.jobs[jid].n_tasks());
        self.engine.forget_job(
            JobId(jid),
            (base..base + nt).map(TaskId),
            &[gate_key(jid), maps_key(jid), all_key(jid)],
        );
        {
            let jr = &mut self.jobs[jid];
            jr.maps = Vec::new();
            jr.reduces = Vec::new();
            jr.map_nodes = Vec::new();
        }
        let compacted = self.engine.compact_finished_placements();
        self.sess.ctrl.maybe_gc(now);
        let segs = self.sess.ctrl.calendar_segments();
        // audit trails are counted, not kept — a soak report is O(1)
        // in stream length
        let n_adm = self.admissions.drain(..).count();
        let n_pre = self.preemptions.drain(..).count();
        let n_re = self.reallocs.drain(..).count();
        self.audits.clear();
        let s = self.soak.as_mut().expect("soak mode");
        s.accum.push(m.jt, slowdown);
        s.finalized += 1;
        s.last_finish = s.last_finish.max(buf_last);
        s.compacted_placements += compacted;
        s.peak_live_records = s.peak_live_records.max(live_before);
        s.peak_calendar_segments = s.peak_calendar_segments.max(segs);
        s.n_admissions += n_adm;
        s.n_preemptions += n_pre;
        s.n_reallocs += n_re;
    }

    /// Release a drained placement's calendar grant, if it holds one: the
    /// transfer is completed at zero bytes (freeing the slots) and its
    /// reservation-audit row withdrawn. Returns the released reservation
    /// so the caller can chain it into a [`ReallocAudit`] row.
    fn release_grant(&mut self, p: &Placement) -> Option<Reservation> {
        let tr = match &p.transfer {
            TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => t,
            _ => return None,
        };
        self.sess.ctrl.complete_transfer(tr, 0.0);
        if tr.reservation.n_slots > 0 {
            if let Some(i) = self.audits.iter().position(|a| {
                a.start_slot == tr.reservation.start_slot
                    && a.n_slots == tr.reservation.n_slots
                    && a.frac == tr.reservation.frac
                    && a.links == tr.reservation.links
            }) {
                self.audits.remove(i);
            }
            if let Some(j) = job_index_of(&self.jobs, p.task) {
                self.jobs[j].reserved_area -=
                    tr.reservation.frac * tr.reservation.n_slots as f64;
            }
        }
        Some(tr.reservation.clone())
    }

    /// Reschedule drained placements on `authorized` at `now`: reduce
    /// shuffle hints are re-derived from the owning job's (possibly
    /// moved) map placements, map bookkeeping is kept in step, and every
    /// grant change is chained as an old→new [`ReallocAudit`] row
    /// (grantless sides are the empty reservation).
    fn reschedule_orphans(
        &mut self,
        orphans: &[(Placement, Option<Reservation>)],
        now: Secs,
        authorized: Vec<NodeId>,
    ) {
        if orphans.is_empty() {
            return;
        }
        let mut tasks: Vec<TaskSpec> = Vec::with_capacity(orphans.len());
        for (p, _) in orphans {
            let spec = task_of(&self.jobs, p.task).expect("drained task has an owning job");
            let mut t = spec.clone();
            if !t.is_map() {
                // re-derive the shuffle hint from the owning job's
                // (possibly rebalanced) map placements
                let jr = &self.jobs[job_index_of(&self.jobs, p.task).expect("owned task")];
                t.src_hint =
                    Some(hint_from_placements(&jr.maps, &jr.map_nodes, self.n_hosts));
            }
            tasks.push(t);
        }
        let view = self.committed_ledger(&self.engine, now);
        let a = self.schedule_batch(&tasks, now, now, view, authorized);
        // keep the shuffle-hint bookkeeping in step with moved maps
        for p in &a.placements {
            if !p.is_map {
                continue;
            }
            if let Some(j) = job_index_of(&self.jobs, p.task) {
                let local = p.task.0 - self.jobs[j].base;
                if local < self.jobs[j].map_nodes.len() {
                    self.jobs[j].map_nodes[local] = p.node;
                }
            }
        }
        let empty =
            || Reservation { links: Vec::new(), start_slot: 0, n_slots: 0, frac: 0.0 };
        for (p, old) in orphans {
            let old_r = old.clone().unwrap_or_else(empty);
            let new_r = a
                .placements
                .iter()
                .find(|q| q.task == p.task)
                .and_then(|q| match &q.transfer {
                    TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => {
                        Some(t.reservation.clone())
                    }
                    _ => None,
                })
                .unwrap_or_else(empty);
            if old_r == new_r {
                continue;
            }
            self.reallocs.push(ReallocAudit {
                round: 1,
                task: p.task,
                at: now,
                old: old_r,
                new: new_r,
                class_share_mb_s: 0.0,
            });
        }
        self.engine.load(&a);
    }

    /// Evaluate/score/evict at a control instant: when the scoring
    /// descheduler drains a service offender's pending queue, release
    /// any calendar grants the drained placements held and reschedule
    /// that work on the rest of the cluster.
    fn rebalance(&mut self) {
        let jobs = &self.jobs;
        let engine = &mut self.engine;
        let offender = match &mut self.rebalancer {
            Some(rb) => {
                match rb.tick(engine, self.n_hosts, |tid| {
                    task_of(jobs, tid).map(|t| t.compute.0)
                }) {
                    Some((offender, _)) => offender,
                    None => return,
                }
            }
            None => return,
        };
        self.rebalances += 1;
        let orphans = self.engine.take_orphans();
        // a drained BASS placement still holds its calendar grant:
        // release it (and its audit row) before rescheduling the task
        let released: Vec<(Placement, Option<Reservation>)> = orphans
            .into_iter()
            .map(|(p, _)| {
                let old = self.release_grant(&p);
                (p, old)
            })
            .collect();
        let now = self.engine.now();
        let authorized: Vec<NodeId> =
            self.sess.nodes.iter().copied().filter(|&nd| nd != offender).collect();
        self.reschedule_orphans(&released, now, authorized);
    }

    /// Reject queued jobs that can never be admitted or never meet their
    /// tenant's deadline: more tasks than the tenant's slot quota, or a
    /// best-case critical path from `now` already past the deadline.
    fn reject_infeasible(&mut self, now: Secs) {
        let tn = match &self.tenancy {
            Some(t) => t,
            None => return,
        };
        let mut rejects: Vec<usize> = Vec::new();
        for &jid in &self.admit_q {
            let jr = &self.jobs[jid];
            let ts = &tn.tenants[jr.tenant.expect("tenancy jobs carry a tenant")];
            let quota_impossible = jr.n_tasks() > ts.slot_quota;
            let deadline_impossible = ts
                .deadline_secs
                .map_or(false, |dl| now.0 + jr.cp_min > jr.submit.0 + dl + 1e-9);
            if quota_impossible || deadline_impossible {
                rejects.push(jid);
            }
        }
        if rejects.is_empty() {
            return;
        }
        self.admit_q.retain(|jid| !rejects.contains(jid));
        for jid in rejects {
            self.jobs[jid].rejected = true;
            self.rejected += 1;
            if self.soak.is_some() {
                // a rejected job never runs: shrink it to the count
                // skeleton right away
                let jr = &mut self.jobs[jid];
                jr.maps = Vec::new();
                jr.reduces = Vec::new();
                jr.map_nodes = Vec::new();
            }
        }
    }

    /// The DRF pick: per-tenant FIFO heads compete on weighted dominant
    /// share — `max(slot share, reserved-bandwidth share) / weight` over
    /// the tenant's started, unfinished jobs — and the smallest key wins
    /// (ties prefer the larger weight, then the lower tenant index).
    /// Heads that would break their tenant's slot or bandwidth quota are
    /// ineligible (key `INFINITY`). Returns the winner's queue position
    /// and job id, and logs the decision for replay.
    fn drf_pick(&mut self, now: Secs) -> Option<(usize, usize)> {
        let tn = self.tenancy.as_ref().expect("drf_pick requires tenancy");
        let n = tn.tenants.len();
        let mut slots = vec![0usize; n];
        let mut bw = vec![0.0f64; n];
        for jr in &self.jobs {
            if jr.started && !jr.done {
                let t = jr.tenant.expect("tenancy jobs carry a tenant");
                slots[t] += jr.n_tasks();
                bw[t] += jr.reserved_area;
            }
        }
        let norm = self.n_hosts.max(1) as f64;
        let mut keys = vec![f64::INFINITY; n];
        let mut heads: Vec<Option<usize>> = vec![None; n];
        for (q, &jid) in self.admit_q.iter().enumerate() {
            let t = self.jobs[jid].tenant.expect("tenancy jobs carry a tenant");
            if heads[t].is_some() {
                continue;
            }
            heads[t] = Some(q);
            let ts = &tn.tenants[t];
            let fits =
                slots[t] + self.jobs[jid].n_tasks() <= ts.slot_quota && bw[t] < ts.bw_quota;
            if fits {
                keys[t] = (slots[t] as f64 / norm).max(bw[t] / norm) / ts.weight;
            }
        }
        let mut win: Option<usize> = None;
        for t in 0..n {
            if !keys[t].is_finite() {
                continue;
            }
            win = Some(match win {
                None => t,
                Some(w)
                    if keys[t] < keys[w]
                        || (keys[t] == keys[w]
                            && tn.tenants[t].weight > tn.tenants[w].weight) =>
                {
                    t
                }
                Some(w) => w,
            });
        }
        let w = win?;
        let q = heads[w].expect("winning tenant has a queued head");
        let jid = self.admit_q[q];
        let audit = AdmissionAudit { at: now.0, job: JobId(jid), tenant: w, keys };
        self.admissions.push(audit);
        Some((q, jid))
    }

    /// Would the job — feasible in the best case — still miss its
    /// guaranteed deadline behind the committed backlog? True when even
    /// the earliest committed node availability plus the job's best-case
    /// critical path overshoots the deadline.
    fn deadline_at_risk(&self, jid: usize, now: Secs) -> bool {
        let tn = match &self.tenancy {
            Some(t) => t,
            None => return false,
        };
        let jr = &self.jobs[jid];
        let ts = &tn.tenants[jr.tenant.expect("tenancy jobs carry a tenant")];
        if ts.class != TenantClass::Guaranteed {
            return false;
        }
        let dl = match ts.deadline_secs {
            Some(d) => d,
            None => return false,
        };
        let view = self.committed_ledger(&self.engine, now);
        let avail = self
            .sess
            .nodes
            .iter()
            .map(|&nd| view.idle(nd))
            .fold(Secs::INF, Secs::min);
        avail.0 + jr.cp_min > jr.submit.0 + dl + 1e-9
    }

    /// Preempt for a deadline-at-risk guaranteed job: drain every spot
    /// tenant's queued (not yet started) placements through the orphan
    /// path and release their grants. Running work is never interrupted
    /// and guaranteed tenants are never victims. Returns the drained
    /// placements paired with their released grants; the caller admits
    /// the guaranteed job first, then reschedules these behind it.
    fn preempt_spot(&mut self, by: usize, now: Secs) -> Vec<(Placement, Option<Reservation>)> {
        let (victims, names) = {
            let tn = self.tenancy.as_ref().expect("preemption requires tenancy");
            let names: Vec<String> = tn.tenants.iter().map(|t| t.name.clone()).collect();
            let victims: Vec<JobId> = self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, jr)| {
                    jr.started
                        && !jr.done
                        && tn.tenants[jr.tenant.expect("tenancy jobs carry a tenant")].class
                            == TenantClass::Spot
                })
                .map(|(j, _)| JobId(j))
                .collect();
            (victims, names)
        };
        if victims.is_empty() || self.engine.drain_jobs_queued(&victims) == 0 {
            return Vec::new();
        }
        let orphans = self.engine.take_orphans();
        let mut out = Vec::with_capacity(orphans.len());
        for (p, _) in orphans {
            let old = self.release_grant(&p);
            let vj = job_index_of(&self.jobs, p.task).expect("preempted task has an owner");
            let vt = self.jobs[vj].tenant.expect("tenancy jobs carry a tenant");
            self.preemptions.push(PreemptionAudit {
                at: now.0,
                task: p.task,
                victim: JobId(vj),
                victim_tenant: names[vt].clone(),
                by: JobId(by),
            });
            out.push((p, old));
        }
        out
    }

    fn try_admit(&mut self, now: Secs) {
        if self.tenancy.is_none() {
            while let Some(&head) = self.admit_q.front() {
                if !self.admissible(now) {
                    break;
                }
                self.admit_q.pop_front();
                self.admit(head, now);
            }
            return;
        }
        loop {
            self.reject_infeasible(now);
            if self.admit_q.is_empty() || !self.admissible(now) {
                return;
            }
            let (qpos, jid) = match self.drf_pick(now) {
                Some(pick) => pick,
                None => return, // every head quota-blocked
            };
            let preempted = if self.deadline_at_risk(jid, now) {
                self.preempt_spot(jid, now)
            } else {
                Vec::new()
            };
            self.admit_q.remove(qpos).expect("picked head is queued");
            self.admit(jid, now);
            if !preempted.is_empty() {
                self.reschedule_orphans(&preempted, now, self.sess.nodes.clone());
            }
        }
    }

    fn handle_fired(&mut self, fired: Vec<u64>) {
        for key in fired {
            let jid = (key / 3) as usize;
            match key % 3 {
                0 => self.on_gate(jid),
                1 => {} // full-map marker: consumed by forecast probes
                2 => self.on_job_done(jid),
                _ => unreachable!(),
            }
        }
    }

    /// Play the cluster forward to `t`, servicing every gate/completion
    /// on the way (engine events at an instant precede control actions
    /// at the same instant).
    fn advance(&mut self, t: Secs) {
        loop {
            let fired = self.engine.run_until(t);
            if fired.is_empty() {
                return;
            }
            self.handle_fired(fired);
        }
    }

    /// The isolated baseline: the same job alone on the pristine cluster
    /// at its submission time — the static two-phase pipeline
    /// (`Coordinator::handle`) against the pre-stream controller and
    /// flow network.
    ///
    /// Keep in sync with `Coordinator::handle_with_records`: this is the
    /// slowdown denominator, and the sparse stream tests
    /// (`single_job_stream_is_uncontended`,
    /// `stream_trace_matches_isolated_for_sparse_arrivals`) pin the
    /// chain stream == this baseline == `handle` exactly, so a change
    /// to one side without the other fails them.
    fn isolated_metrics(&self, jr: &JobRun) -> JobMetrics {
        let now = jr.submit;
        let mut init = self.sess.engine_init.clone();
        for v in &mut init {
            if *v < now {
                *v = now;
            }
        }
        let mut ledger_init = vec![Secs::INF; self.n_hosts];
        for &nd in &self.sess.nodes {
            ledger_init[nd.0] = init[nd.0];
        }
        let mut ctrl = self.pristine_ctrl.clone();
        let mut sched = self.sess.spec.scheduler.make();
        let mut ledger = Ledger::with_initial(ledger_init);
        let schedule = |sched: &mut Box<dyn crate::sched::Scheduler + Send>,
                        ctrl: &mut Controller,
                        ledger: &mut Ledger,
                        tasks: &[TaskSpec],
                        gate: Secs,
                        at: Secs|
         -> Assignment {
            let mut ctx = SchedCtx {
                view: &crate::sdn::Oracle,
                controller: ctrl,
                namenode: &self.sess.nn,
                ledger,
                authorized: self.sess.nodes.clone(),
                now: at,
                cost: self.cost,
                node_speed: self.sess.spec.node_speed.clone(),
                down: Vec::new(),
                bw_aware_sources: self.sess.spec.bw_aware_sources,
            };
            sched.schedule(tasks, Some(gate), &mut ctx)
        };

        // ---- phase 1: maps ----
        let a = schedule(&mut sched, &mut ctrl, &mut ledger, &jr.maps, now, now);
        let lr = a.locality_ratio();
        let mut engine = Engine::new(self.pristine_net.clone(), init.clone());
        engine.load(&a);
        let map_records = engine.run();

        // ---- phase 2: reduces at the slowstart gate ----
        let gate = slowstart_gate(&map_records, jr.slowstart).max(now);
        let mut all = map_records;
        if !jr.reduces.is_empty() {
            let hint = shuffle_majority_node(&all, &jr.maps, self.n_hosts);
            let mut reduces = jr.reduces.clone();
            for r in &mut reduces {
                r.src_hint = Some(hint);
            }
            let mut reduce_init = init;
            for r in &all {
                if reduce_init[r.node.0] < r.finish {
                    reduce_init[r.node.0] = r.finish;
                }
            }
            let mut ledger2_init = vec![Secs::INF; self.n_hosts];
            for &nd in &self.sess.nodes {
                ledger2_init[nd.0] = reduce_init[nd.0];
            }
            let mut ledger2 = Ledger::with_initial(ledger2_init);
            let a2 = schedule(&mut sched, &mut ctrl, &mut ledger2, &reduces, gate, gate);
            let mut engine2 = Engine::new(self.pristine_net.clone(), reduce_init);
            engine2.load(&a2);
            all.extend(engine2.run());
        }
        let mut m = JobMetrics::from_records(&all, now, Some(gate));
        m.lr = lr;
        m
    }

    /// Stage **admit**: play the cluster to the arrival instant (the
    /// interleaved **execute** slice), then build the job and admit or
    /// queue it. The **schedule** stage — committing map/reduce batches
    /// against the calendar — runs inside `admit`/`on_gate`.
    fn ingest(&mut self, sub: Submission) {
        assert!(sub.at_secs >= 0.0, "submission before t=0");
        let t = Secs(sub.at_secs);
        self.advance(t);
        self.rebalance();
        self.sess.ctrl.gc_calendar_before(t);
        let jid = self.jobs.len();
        let Submission { body, tenant, .. } = sub;
        let jr = self.build(jid, t, body);
        self.jobs.push(jr);
        let tenant_idx = self.tenancy.as_ref().map(|tn| match &tenant {
            Some(name) => tn
                .resolve(name)
                .unwrap_or_else(|| panic!("unknown tenant '{name}' in submission")),
            None => jid % tn.tenants.len(),
        });
        if let Some(idx) = tenant_idx {
            self.jobs[jid].tenant = Some(idx);
            self.admit_q.push_back(jid);
            self.try_admit(t);
            if self.admit_q.contains(&jid) {
                self.jobs[jid].queued = true;
            }
        } else {
            self.try_admit(t); // completions at exactly t may have freed slots
            if self.admit_q.is_empty() && self.admissible(t) {
                self.admit(jid, t);
            } else {
                self.jobs[jid].queued = true;
                self.admit_q.push_back(jid);
            }
        }
    }

    fn run(mut self, submissions: Vec<Submission>) -> StreamOutcome {
        for sub in submissions {
            self.ingest(sub);
        }
        self.drain();
        let records = self.engine.run();
        self.finish(records)
    }

    /// Soak flavor of [`StreamDriver::run`]: the account stage already
    /// ran incrementally at each completion, so nothing is left in the
    /// engine to collect.
    fn run_soak(mut self, submissions: Vec<Submission>) -> SoakOutcome {
        for sub in submissions {
            self.ingest(sub);
        }
        self.drain();
        self.finish_soak()
    }

    /// Stage **execute**: play out the remaining work to quiescence.
    fn drain(&mut self) {
        while self.active > 0 || !self.admit_q.is_empty() {
            if self.active == 0 {
                // idle cluster, gated queue: jump to the earliest instant
                // the slot gate can pass (the k-th smallest availability)
                let need = self.policy.min_free_slots.clamp(1, self.sess.nodes.len());
                let mut avail: Vec<Secs> = {
                    let actual = self.engine.node_free_times();
                    self.sess.nodes.iter().map(|&nd| actual[nd.0]).collect()
                };
                avail.sort();
                let t = avail[need - 1].max(self.engine.now());
                let fired = self.engine.run_until(t);
                self.handle_fired(fired);
                let before = self.admit_q.len();
                self.try_admit(t);
                assert!(self.admit_q.len() < before, "admission gate cannot pass");
                continue;
            }
            let fired = self.engine.run_until(Secs::INF);
            assert!(!fired.is_empty(), "stream stalled with active jobs");
            self.handle_fired(fired);
        }
    }

    /// Snapshot everything the stream mutates — driver state plus the
    /// session's controller/namenode/RNG. The cluster substrate
    /// (topology, flow network, pristine baselines, scheduler) is *not*
    /// captured: it is rebuilt deterministically from the same spec at
    /// restore time.
    fn checkpoint(&self) -> SessionCheckpoint {
        SessionCheckpoint {
            policy: self.policy,
            engine: self.engine.clone(),
            ctrl: self.sess.ctrl.clone(),
            nn: self.sess.nn.clone(),
            rng: self.sess.rng.clone(),
            planned: self.planned.clone(),
            jobs: self.jobs.clone(),
            active: self.active,
            admit_q: self.admit_q.clone(),
            audits: self.audits.clone(),
            next_base: self.next_base,
            rebalancer: self.rebalancer.clone(),
            rebalances: self.rebalances,
            admissions: self.admissions.clone(),
            preemptions: self.preemptions.clone(),
            reallocs: self.reallocs.clone(),
            rejected: self.rejected,
            soak: self.soak.clone(),
        }
    }

    /// Stage **account**, soak flavor: the accumulated O(1) report.
    fn finish_soak(self) -> SoakOutcome {
        let s = self.soak.expect("finish_soak requires soak mode");
        let first_submit = self.jobs.iter().map(|j| j.submit).fold(Secs::INF, Secs::min);
        let makespan = if first_submit.is_finite() {
            (s.last_finish - first_submit.0).max(0.0)
        } else {
            0.0
        };
        let queued_jobs = self.jobs.iter().filter(|j| j.queued).count();
        let p95 = s.accum.p95_slowdown();
        SoakOutcome {
            jobs: s.finalized,
            rejected_jobs: self.rejected,
            queued_jobs,
            last_finish: s.last_finish,
            makespan,
            stats: s.accum.stats(),
            p95_slowdown: p95,
            jobs_per_hour: jobs_per_hour(s.finalized, makespan),
            sustained_jobs_per_hour: sustained_jobs_per_hour(
                s.finalized,
                makespan,
                p95,
                s.cfg.target_p95_slowdown,
            ),
            compactions: self.sess.ctrl.compactions(),
            compacted_placements: s.compacted_placements,
            peak_live_records: s.peak_live_records,
            peak_calendar_segments: s.peak_calendar_segments,
            retained_samples: s.accum.retained(),
            rebalances: self.rebalances,
            admissions: s.n_admissions + self.admissions.len(),
            preemptions: s.n_preemptions + self.preemptions.len(),
            reallocs: s.n_reallocs + self.reallocs.len(),
        }
    }

    fn finish(self, records: Vec<TaskRecord>) -> StreamOutcome {
        let mut tagged = Vec::with_capacity(records.len());
        for r in &records {
            let job = self.engine.job_of(r.task).expect("stream records are job-tagged");
            tagged.push((job, r.clone()));
        }
        let first_submit = self.jobs.iter().map(|j| j.submit).fold(Secs::INF, Secs::min);
        let last_finish = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        let mut jobs_out = Vec::with_capacity(self.jobs.len());
        let (mut jts, mut slowdowns) = (Vec::new(), Vec::new());
        for (jid, jr) in self.jobs.iter().enumerate() {
            let tenant_name = match (&self.tenancy, jr.tenant) {
                (Some(tn), Some(t)) => Some(tn.tenants[t].name.clone()),
                _ => None,
            };
            if jr.rejected {
                // never admitted: zeroed metrics, neutral slowdown,
                // excluded from the stream statistics
                jobs_out.push(JobOutcome {
                    job: JobId(jid),
                    name: jr.name.clone(),
                    submitted_at: jr.submit.0,
                    admitted_at: jr.submit.0,
                    gate: jr.submit.0,
                    queued: jr.queued,
                    metrics: JobMetrics::from_records(&[], jr.submit, None),
                    isolated_jt: 0.0,
                    slowdown: 1.0,
                    tasks: jr.maps.iter().chain(jr.reduces.iter()).cloned().collect(),
                    tenant: tenant_name,
                    rejected: true,
                });
                continue;
            }
            let job_records: Vec<TaskRecord> = records
                .iter()
                .filter(|r| r.task.0 >= jr.base && r.task.0 < jr.base + jr.n_tasks())
                .cloned()
                .collect();
            let gate = jr.gate.unwrap_or(jr.submit);
            let mut m = JobMetrics::from_records(&job_records, jr.submit, Some(gate));
            m.lr = jr.lr;
            let iso = self.isolated_metrics(jr);
            let slowdown = if iso.jt > 0.0 { m.jt / iso.jt } else { 1.0 };
            jts.push(m.jt);
            slowdowns.push(slowdown);
            jobs_out.push(JobOutcome {
                job: JobId(jid),
                name: jr.name.clone(),
                submitted_at: jr.submit.0,
                admitted_at: jr.admitted.0,
                gate: gate.0,
                queued: jr.queued,
                metrics: m,
                isolated_jt: iso.jt,
                slowdown,
                tasks: jr.maps.iter().chain(jr.reduces.iter()).cloned().collect(),
                tenant: tenant_name,
                rejected: false,
            });
        }
        let queued_jobs = self.jobs.iter().filter(|j| j.queued).count();
        let (tenant_stats, fairness_jain) = match &self.tenancy {
            None => (Vec::new(), 1.0),
            Some(tn) => {
                let n = tn.tenants.len();
                let mut slow: Vec<Vec<f64>> = vec![Vec::new(); n];
                let mut rej = vec![0usize; n];
                let mut met = vec![0usize; n];
                let mut tot = vec![0usize; n];
                for (jid, jr) in self.jobs.iter().enumerate() {
                    let t = jr.tenant.expect("tenancy jobs carry a tenant");
                    let dl = tn.tenants[t].deadline_secs;
                    if jr.rejected {
                        rej[t] += 1;
                        if dl.is_some() {
                            tot[t] += 1; // a rejected deadline job is a missed SLO
                        }
                        continue;
                    }
                    slow[t].push(jobs_out[jid].slowdown);
                    if let Some(dl) = dl {
                        tot[t] += 1;
                        if jobs_out[jid].metrics.jt <= dl + 1e-9 {
                            met[t] += 1;
                        }
                    }
                }
                let stats: Vec<TenantStats> = tn
                    .tenants
                    .iter()
                    .enumerate()
                    .map(|(t, ts)| {
                        TenantStats::from_jobs(
                            ts.name.clone(),
                            ts.weight,
                            &slow[t],
                            rej[t],
                            met[t],
                            tot[t],
                        )
                    })
                    .collect();
                let means: Vec<f64> = stats.iter().map(|s| s.mean_slowdown).collect();
                let jain = jain_index(&means);
                (stats, jain)
            }
        };
        StreamOutcome {
            jobs: jobs_out,
            records: tagged,
            reservations: self.audits,
            last_finish,
            makespan: if first_submit.is_finite() { last_finish - first_submit.0 } else { 0.0 },
            stats: StreamStats::from_jobs(&jts, &slowdowns),
            queued_jobs,
            rebalances: self.rebalances,
            tenants: self.tenancy,
            tenant_stats,
            fairness_jain,
            admissions: self.admissions,
            preemptions: self.preemptions,
            reallocs: self.reallocs,
            rejected_jobs: self.rejected,
        }
    }
}

fn assert_time_ordered(submissions: &[Submission]) {
    for w in submissions.windows(2) {
        assert!(w[0].at_secs <= w[1].at_secs, "submissions must be time-ordered");
    }
}

/// Build a fresh driver over a built session (the stream has not played
/// yet — pristine baselines are captured here).
fn new_driver<'a>(
    sess: &'a mut SimSession,
    policy: AdmissionPolicy,
    cost: &'a CostModel,
) -> StreamDriver<'a> {
    assert!(policy.max_active >= 1, "admission cap must allow at least one active job");
    let engine = Engine::new(sess.net.clone(), sess.engine_init.clone());
    let planned = sess.engine_init.clone();
    let n_hosts = sess.engine_init.len();
    let pristine_ctrl = sess.ctrl.clone();
    let pristine_net = sess.net.clone();
    let max_init = sess.engine_init.iter().copied().fold(Secs(0.0), Secs::max);
    let rebalancer = sess
        .spec
        .mitigation
        .as_ref()
        .filter(|m| m.rebalance_period > 0.0)
        .map(|m| Rebalancer::new(m.rebalance_period));
    let tenancy = sess.spec.tenants.clone();
    if let Some(tn) = &tenancy {
        if let Err(e) = tn.validate() {
            panic!("invalid [tenants] spec: {e}");
        }
    }
    StreamDriver {
        sess,
        cost,
        policy,
        engine,
        planned,
        n_hosts,
        jobs: Vec::new(),
        active: 0,
        admit_q: VecDeque::new(),
        audits: Vec::new(),
        pristine_ctrl,
        pristine_net,
        next_base: 0,
        rebalancer,
        rebalances: 0,
        tenancy,
        admissions: Vec::new(),
        preemptions: Vec::new(),
        reallocs: Vec::new(),
        rejected: 0,
        max_init,
        soak: None,
    }
}

/// Restore a checkpoint into a driver over `sess`, which must be a
/// fresh [`SimSession`] built from the same spec the checkpointed run
/// used (session construction is deterministic, so the substrate the
/// snapshot omits — topology, pristine baselines, scheduler — rebuilds
/// bit-identically; the snapshot then overwrites the mutated state).
fn restore_driver<'a>(
    sess: &'a mut SimSession,
    ckpt: SessionCheckpoint,
    cost: &'a CostModel,
) -> StreamDriver<'a> {
    let mut d = new_driver(sess, ckpt.policy, cost);
    d.sess.ctrl = ckpt.ctrl;
    d.sess.nn = ckpt.nn;
    d.sess.rng = ckpt.rng;
    d.engine = ckpt.engine;
    d.planned = ckpt.planned;
    d.jobs = ckpt.jobs;
    d.active = ckpt.active;
    d.admit_q = ckpt.admit_q;
    d.audits = ckpt.audits;
    d.next_base = ckpt.next_base;
    d.rebalancer = ckpt.rebalancer;
    d.rebalances = ckpt.rebalances;
    d.admissions = ckpt.admissions;
    d.preemptions = ckpt.preemptions;
    d.reallocs = ckpt.reallocs;
    d.rejected = ckpt.rejected;
    d.soak = ckpt.soak;
    d
}

/// Run a job stream on a built session. Submissions must be
/// time-ordered; the session's controller/namenode/RNG carry the stream
/// state (a fresh session per stream keeps runs hermetic).
pub fn run_stream(
    sess: &mut SimSession,
    submissions: Vec<Submission>,
    policy: AdmissionPolicy,
    cost: &CostModel,
) -> StreamOutcome {
    assert_time_ordered(&submissions);
    new_driver(sess, policy, cost).run(submissions)
}

/// Play `submissions[..prefix]` and capture the mid-stream state.
/// `sess` is consumed conceptually (it carries half-played stream
/// state afterwards) — discard it and hand the checkpoint plus the
/// remaining submissions to [`resume_stream`] on a fresh session.
pub fn checkpoint_stream(
    sess: &mut SimSession,
    submissions: &[Submission],
    prefix: usize,
    policy: AdmissionPolicy,
    cost: &CostModel,
) -> SessionCheckpoint {
    assert!(prefix <= submissions.len(), "checkpoint prefix exceeds the submission count");
    assert_time_ordered(submissions);
    let mut d = new_driver(sess, policy, cost);
    for sub in &submissions[..prefix] {
        d.ingest(sub.clone());
    }
    d.checkpoint()
}

/// Resume a checkpointed stream: restore into a fresh session of the
/// same spec, play the remaining submissions, drain, account. The
/// result is bit-for-bit the uninterrupted run's [`StreamOutcome`].
pub fn resume_stream(
    sess: &mut SimSession,
    ckpt: SessionCheckpoint,
    rest: Vec<Submission>,
    cost: &CostModel,
) -> StreamOutcome {
    assert!(!ckpt.is_soak(), "soak checkpoints resume via resume_soak");
    assert_time_ordered(&rest);
    restore_driver(sess, ckpt, cost).run(rest)
}

fn soak_driver<'a>(
    sess: &'a mut SimSession,
    policy: AdmissionPolicy,
    cost: &'a CostModel,
    cfg: SoakConfig,
) -> StreamDriver<'a> {
    assert!(
        cfg.target_p95_slowdown >= 1.0 && cfg.target_p95_slowdown.is_finite(),
        "soak target_p95_slowdown must be a finite value >= 1"
    );
    assert!(cfg.sketch_cap >= 1, "soak sketch_cap must be at least 1");
    let mut d = new_driver(sess, policy, cost);
    d.sess.ctrl.set_gc_period(cfg.gc_period_secs);
    d.soak = Some(SoakState::new(cfg));
    d
}

/// Run a job stream in bounded memory: per-completion finalization
/// into sketch statistics instead of a full per-job outcome list. See
/// the module docs for what is (and is not) retained.
pub fn run_soak(
    sess: &mut SimSession,
    submissions: Vec<Submission>,
    policy: AdmissionPolicy,
    cost: &CostModel,
    cfg: SoakConfig,
) -> SoakOutcome {
    assert_time_ordered(&submissions);
    soak_driver(sess, policy, cost, cfg).run_soak(submissions)
}

/// [`checkpoint_stream`] for a soak run (the snapshot carries the
/// accumulator, buffers and baseline cache too).
pub fn checkpoint_soak(
    sess: &mut SimSession,
    submissions: &[Submission],
    prefix: usize,
    policy: AdmissionPolicy,
    cost: &CostModel,
    cfg: SoakConfig,
) -> SessionCheckpoint {
    assert!(prefix <= submissions.len(), "checkpoint prefix exceeds the submission count");
    assert_time_ordered(submissions);
    let mut d = soak_driver(sess, policy, cost, cfg);
    for sub in &submissions[..prefix] {
        d.ingest(sub.clone());
    }
    d.checkpoint()
}

/// Resume a checkpointed soak; the [`SoakOutcome`] is bit-for-bit the
/// uninterrupted run's.
pub fn resume_soak(
    sess: &mut SimSession,
    ckpt: SessionCheckpoint,
    rest: Vec<Submission>,
    cost: &CostModel,
) -> SoakOutcome {
    assert!(ckpt.is_soak(), "stream checkpoints resume via resume_stream");
    assert_time_ordered(&rest);
    restore_driver(sess, ckpt, cost).run_soak(rest)
}

impl SimSession {
    /// [`run_stream`] as a session method.
    pub fn run_stream(
        &mut self,
        submissions: Vec<Submission>,
        policy: AdmissionPolicy,
        cost: &CostModel,
    ) -> StreamOutcome {
        run_stream(self, submissions, policy, cost)
    }

    /// [`run_soak`] as a session method.
    pub fn run_soak(
        &mut self,
        submissions: Vec<Submission>,
        policy: AdmissionPolicy,
        cost: &CostModel,
        cfg: SoakConfig,
    ) -> SoakOutcome {
        run_soak(self, submissions, policy, cost, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{
        BackgroundSpec, InitialLoad, MitigationSpec, ScenarioSpec, TenancySpec, TenantClass,
        TenantSpec, TopologyShape, WorkloadSpec,
    };
    use crate::sched::SchedulerKind;

    fn stream_session(kind: SchedulerKind) -> SimSession {
        let mut s = ScenarioSpec::new(
            "stream-test",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 3,
                edge_mbps: 100.0,
                uplink_mbps: 100.0,
            },
            WorkloadSpec::None,
        );
        s.scheduler = kind;
        s.replication = 3;
        s.reduces = 2;
        s.seed = 7;
        s.initial = InitialLoad::Sampled { max_secs: 0.0 };
        s.background = BackgroundSpec { flows: 2, rate_mb_s: 2.0 };
        SimSession::new(&s)
    }

    fn sort_at(at: f64, mb: f64) -> Submission {
        Submission {
            at_secs: at,
            body: SubmissionBody::Generated { kind: JobKind::Sort, data_mb: mb },
            tenant: None,
        }
    }

    fn sort_for(tenant: &str, at: f64, mb: f64) -> Submission {
        Submission { tenant: Some(tenant.into()), ..sort_at(at, mb) }
    }

    #[test]
    fn single_job_stream_is_uncontended() {
        let cost = CostModel::rust_only();
        let mut sess = stream_session(SchedulerKind::Bass);
        let out =
            sess.run_stream(vec![sort_at(5.0, 300.0)], AdmissionPolicy::default(), &cost);
        assert_eq!(out.jobs.len(), 1);
        assert_eq!(out.rebalances, 0, "no [mitigation] table means no descheduler");
        let j = &out.jobs[0];
        assert!(j.metrics.jt > 0.0);
        assert!(!j.queued);
        assert_eq!(j.admitted_at, 5.0);
        // BASS transfers are calendar-reserved (no shared-net flows), so
        // a lone job is bitwise its own isolated run
        assert_eq!(j.slowdown, 1.0, "jt {} vs isolated {}", j.metrics.jt, j.isolated_jt);
        assert_eq!(out.queued_jobs, 0);
        // records are tagged and cover the whole job
        assert_eq!(out.records.len(), j.tasks.len());
        assert!(out.records.iter().all(|(job, _)| *job == JobId(0)));
    }

    #[test]
    fn overlapping_jobs_contend_and_slow_down() {
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
            let mut sess = stream_session(kind);
            // three sizeable jobs in quick succession: the later ones
            // must feel the earlier ones' occupancy
            let subs = vec![sort_at(1.0, 600.0), sort_at(3.0, 600.0), sort_at(5.0, 600.0)];
            let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
            assert_eq!(out.jobs.len(), 3);
            assert!(
                out.stats.mean_slowdown > 1.0,
                "{}: overlapping jobs should contend (mean slowdown {})",
                kind.label(),
                out.stats.mean_slowdown
            );
            assert!(out.jobs[2].slowdown >= out.jobs[0].slowdown - 1e-9);
            // every task of every job completes exactly once
            let total: usize = out.jobs.iter().map(|j| j.tasks.len()).sum();
            assert_eq!(out.records.len(), total);
        }
    }

    #[test]
    fn sparse_stream_matches_per_job_isolated_runs() {
        // gaps far beyond any makespan: every job behaves as if alone
        let cost = CostModel::rust_only();
        let mut sess = stream_session(SchedulerKind::Bass);
        let subs = vec![sort_at(10.0, 300.0), sort_at(5000.0, 150.0), sort_at(10000.0, 300.0)];
        let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
        for j in &out.jobs {
            assert_eq!(
                j.slowdown, 1.0,
                "job {} jt {} vs isolated {}",
                j.name, j.metrics.jt, j.isolated_jt
            );
        }
        assert_eq!(out.stats.mean_slowdown, 1.0);
    }

    #[test]
    fn admission_cap_queues_fifo() {
        let cost = CostModel::rust_only();
        let mut sess = stream_session(SchedulerKind::Bass);
        let policy = AdmissionPolicy { max_active: 1, min_free_slots: 1 };
        let subs = vec![sort_at(1.0, 600.0), sort_at(2.0, 300.0), sort_at(3.0, 150.0)];
        let out = sess.run_stream(subs, policy, &cost);
        assert_eq!(out.queued_jobs, 2);
        assert!(out.jobs[1].queued && out.jobs[2].queued);
        // FIFO: job 1 admitted no later than job 2, both after submit
        assert!(out.jobs[1].admitted_at > out.jobs[1].submitted_at);
        assert!(out.jobs[1].admitted_at <= out.jobs[2].admitted_at);
        // queue wait counts toward completion time
        assert!(out.jobs[1].metrics.jt > out.jobs[1].isolated_jt);
    }

    #[test]
    fn initial_idle_cluster_admits_once_the_gate_passes() {
        // every node busy past the only arrival: the driver must jump to
        // the earliest gate-pass instant instead of stalling
        let cost = CostModel::rust_only();
        let mut s = ScenarioSpec::new(
            "busy-start",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 2,
                edge_mbps: 100.0,
                uplink_mbps: 100.0,
            },
            WorkloadSpec::None,
        );
        s.initial = InitialLoad::Explicit(vec![40.0, 45.0, 50.0, 55.0]);
        s.seed = 3;
        let mut sess = SimSession::new(&s);
        let out = sess.run_stream(
            vec![sort_at(1.0, 150.0)],
            AdmissionPolicy { max_active: usize::MAX, min_free_slots: 1 },
            &cost,
        );
        assert_eq!(out.jobs.len(), 1);
        assert!(out.jobs[0].queued);
        assert_eq!(out.jobs[0].admitted_at, 40.0, "earliest free node");
        assert!(out.last_finish > 40.0);
    }

    #[test]
    fn streams_are_deterministic() {
        let cost = CostModel::rust_only();
        let run = || {
            let mut sess = stream_session(SchedulerKind::Bar);
            let spec = StreamSpec {
                jobs: 5,
                mean_interarrival_secs: 20.0,
                sizes_mb: vec![150.0, 300.0],
                seed: 11,
                ..StreamSpec::defaults()
            };
            let out = sess.run_stream(spec.submissions(), spec.policy(), &cost);
            (
                out.last_finish,
                out.stats.mean_slowdown,
                out.records.len(),
                out.jobs.iter().map(|j| j.metrics.jt).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn explicit_map_only_submissions_run() {
        let cost = CostModel::rust_only();
        let mut sess = SimSession::new(&ScenarioSpec::example1(SchedulerKind::Bass));
        let tasks: Vec<TaskSpec> = sess.tasks[..3]
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, mut t)| {
                t.id = TaskId(i);
                t
            })
            .collect();
        let sub = Submission {
            at_secs: 0.0,
            body: SubmissionBody::Explicit { name: "wave".into(), tasks, slowstart: 1.0 },
            tenant: None,
        };
        let out = sess.run_stream(vec![sub], AdmissionPolicy::default(), &cost);
        assert_eq!(out.records.len(), 3);
        assert!(out.jobs[0].metrics.rt == 0.0, "map-only job has no reduce phase");
        assert!(out.last_finish > 0.0);
    }

    fn rebalance_session(kind: SchedulerKind, period: f64) -> SimSession {
        let mut s = ScenarioSpec::new(
            "stream-rebalance",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 2,
                edge_mbps: 100.0,
                uplink_mbps: 100.0,
            },
            WorkloadSpec::None,
        );
        s.scheduler = kind;
        s.replication = 2;
        s.reduces = 2;
        s.seed = 7;
        // node 3 delivers 4x less compute than its placements promise
        s.node_speed = vec![1.0, 1.0, 1.0, 4.0];
        let mut mit = MitigationSpec::off();
        mit.rebalance_period = period;
        s.mitigation = Some(mit);
        SimSession::new(&s)
    }

    #[test]
    fn rebalancer_drains_the_slow_node_and_the_stream_stays_exactly_once() {
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
            let mut sess = rebalance_session(kind, 5.0);
            // enough overlap that the slow node accumulates a queue
            let subs: Vec<Submission> =
                (0..6).map(|i| sort_at(1.0 + i as f64 * 2.0, 300.0)).collect();
            let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
            assert!(
                out.rebalances > 0,
                "{}: a 4x service offender with queued work must be drained",
                kind.label()
            );
            // drained tasks are rescheduled, not lost or duplicated
            let total: usize = out.jobs.iter().map(|j| j.tasks.len()).sum();
            assert_eq!(out.records.len(), total, "{}", kind.label());
            crate::testkit::oracles::check_stream(&out, &sess.nodes, &sess.spec.node_speed)
                .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        }
    }

    #[test]
    fn rebalanced_streams_are_deterministic() {
        let cost = CostModel::rust_only();
        let run = || {
            let mut sess = rebalance_session(SchedulerKind::Bass, 5.0);
            let subs: Vec<Submission> =
                (0..5).map(|i| sort_at(1.0 + i as f64 * 2.0, 300.0)).collect();
            let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
            (
                out.last_finish,
                out.rebalances,
                out.records.len(),
                out.jobs.iter().map(|j| j.metrics.jt).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn inert_mitigation_leaves_the_stream_bitwise_unchanged() {
        // rebalance_period = 0 (the off() default) must not even build
        // the descheduler: the stream is bit-identical to no [mitigation]
        let cost = CostModel::rust_only();
        let subs =
            || vec![sort_at(1.0, 600.0), sort_at(3.0, 600.0), sort_at(5.0, 300.0)];
        let mut plain_sess = stream_session(SchedulerKind::Bass);
        let plain = plain_sess.run_stream(subs(), AdmissionPolicy::default(), &cost);
        let mut spec = plain_sess.spec.clone();
        spec.mitigation = Some(MitigationSpec::off());
        let mut sess = SimSession::new(&spec);
        let out = sess.run_stream(subs(), AdmissionPolicy::default(), &cost);
        assert_eq!(out.rebalances, 0);
        assert_eq!(out.last_finish.to_bits(), plain.last_finish.to_bits());
        assert_eq!(out.records.len(), plain.records.len());
        for ((ja, a), (jb, b)) in out.records.iter().zip(&plain.records) {
            assert_eq!((ja, a.task, a.node, a.finish), (jb, b.task, b.node, b.finish));
        }
    }

    #[test]
    fn stream_spec_expands_to_sorted_submissions() {
        let spec = StreamSpec { jobs: 8, ..StreamSpec::defaults() };
        let subs = spec.submissions();
        assert_eq!(subs.len(), 8);
        for w in subs.windows(2) {
            assert!(w[0].at_secs < w[1].at_secs);
        }
        // same seed, same trace
        let again = spec.submissions();
        for (a, b) in subs.iter().zip(&again) {
            assert_eq!(a.at_secs, b.at_secs);
        }
    }

    // ---- multi-tenancy ----

    fn two_tenants() -> TenancySpec {
        TenancySpec { tenants: vec![TenantSpec::named("prod"), TenantSpec::named("batch")] }
    }

    #[test]
    fn single_default_tenant_is_bitwise_identical_to_fifo() {
        // a [tenants] table with one unconstrained tenant must not
        // perturb the stream at all: same admission instants, same
        // records, bit for bit
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
            let subs =
                || vec![sort_at(1.0, 600.0), sort_at(3.0, 600.0), sort_at(5.0, 300.0)];
            let mut plain_sess = stream_session(kind);
            let plain = plain_sess.run_stream(subs(), AdmissionPolicy::default(), &cost);
            let mut spec = plain_sess.spec.clone();
            spec.tenants = Some(TenancySpec::single_default());
            let mut sess = SimSession::new(&spec);
            let out = sess.run_stream(subs(), AdmissionPolicy::default(), &cost);
            assert_eq!(out.last_finish.to_bits(), plain.last_finish.to_bits(), "{kind:?}");
            assert_eq!(out.records.len(), plain.records.len());
            for ((ja, a), (jb, b)) in out.records.iter().zip(&plain.records) {
                assert_eq!((ja, a.task, a.node), (jb, b.task, b.node));
                assert_eq!(a.finish.0.to_bits(), b.finish.0.to_bits());
            }
            for (a, b) in out.jobs.iter().zip(&plain.jobs) {
                assert_eq!(a.admitted_at.to_bits(), b.admitted_at.to_bits());
            }
            assert_eq!(out.rejected_jobs, 0);
            assert!(out.preemptions.is_empty());
            assert_eq!(out.jobs[0].tenant.as_deref(), Some("default"));
        }
    }

    #[test]
    fn drf_admits_the_underserved_tenant_first() {
        // prod has two jobs active when its third and batch's first
        // queue up: batch's dominant share is zero, so DRF admits batch
        // ahead of the earlier-queued prod job
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
            let mut spec = stream_session(kind).spec.clone();
            spec.tenants = Some(two_tenants());
            let mut sess = SimSession::new(&spec);
            let subs = vec![
                sort_for("prod", 0.0, 600.0),
                sort_for("prod", 0.5, 600.0),
                sort_for("prod", 1.0, 150.0),
                sort_for("batch", 1.5, 150.0),
            ];
            let policy = AdmissionPolicy { max_active: 2, min_free_slots: 0 };
            let out = sess.run_stream(subs, policy, &cost);
            assert!(out.jobs[2].queued && out.jobs[3].queued, "{kind:?}");
            assert!(
                out.jobs[3].admitted_at <= out.jobs[2].admitted_at,
                "{kind:?}: batch (share 0) must not wait behind prod's third job \
                 (batch at {}, prod at {})",
                out.jobs[3].admitted_at,
                out.jobs[2].admitted_at
            );
            // the decision trail is complete and replayable in shape
            assert_eq!(out.admissions.len(), 4, "{kind:?}");
            for ad in &out.admissions {
                assert_eq!(ad.keys.len(), 2);
                assert!(ad.keys[ad.tenant].is_finite());
            }
            assert_eq!(out.tenant_stats.len(), 2);
            assert!(out.fairness_jain > 0.0 && out.fairness_jain <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn slot_quota_defers_admission_until_usage_drains() {
        let cost = CostModel::rust_only();
        // learn the per-job task count, then cap the tenant at exactly
        // one job's worth of slots
        let mut sess = stream_session(SchedulerKind::Bass);
        let probe = sess.run_stream(vec![sort_at(0.0, 300.0)], AdmissionPolicy::default(), &cost);
        let per_job = probe.jobs[0].tasks.len();
        let mut spec = stream_session(SchedulerKind::Bass).spec.clone();
        let mut only = TenantSpec::named("only");
        only.slot_quota = per_job;
        spec.tenants = Some(TenancySpec { tenants: vec![only] });
        let mut sess = SimSession::new(&spec);
        let out = sess.run_stream(
            vec![sort_for("only", 0.0, 300.0), sort_for("only", 1.0, 300.0)],
            AdmissionPolicy::default(),
            &cost,
        );
        assert_eq!(out.rejected_jobs, 0);
        assert!(!out.jobs[0].queued);
        assert!(out.jobs[1].queued, "second job must wait for the quota");
        assert!(out.jobs[1].admitted_at > out.jobs[1].submitted_at);
        // both ran to completion once the quota freed
        let total: usize = out.jobs.iter().map(|j| j.tasks.len()).sum();
        assert_eq!(out.records.len(), total);
    }

    #[test]
    fn impossible_quota_and_deadline_reject_jobs_upfront() {
        let cost = CostModel::rust_only();
        let mut spec = stream_session(SchedulerKind::Bass).spec.clone();
        let mut tiny = TenantSpec::named("tiny");
        tiny.slot_quota = 1; // any real job has > 1 task
        let mut late = TenantSpec::named("late");
        late.deadline_secs = Some(1e-3); // far below any critical path
        spec.tenants = Some(TenancySpec { tenants: vec![tiny, late] });
        let mut sess = SimSession::new(&spec);
        let out = sess.run_stream(
            vec![sort_for("tiny", 0.0, 300.0), sort_for("late", 1.0, 300.0)],
            AdmissionPolicy::default(),
            &cost,
        );
        assert_eq!(out.rejected_jobs, 2);
        assert!(out.jobs.iter().all(|j| j.rejected));
        assert!(out.records.is_empty(), "rejected jobs never run");
        assert_eq!(out.stats.jobs, 0, "rejected jobs are excluded from stream stats");
        let late_stats =
            out.tenant_stats.iter().find(|t| t.tenant == "late").expect("late tenant");
        assert_eq!(late_stats.rejected, 1);
        assert_eq!(late_stats.slo_attainment, 0.0);
    }

    #[test]
    fn guaranteed_tenant_preempts_spot_queued_work() {
        let cost = CostModel::rust_only();
        for kind in [SchedulerKind::Bass, SchedulerKind::Hds] {
            let mut spec = stream_session(kind).spec.clone();
            let mut prod = TenantSpec::named("prod");
            prod.class = TenantClass::Guaranteed;
            // feasible in the best case (the 150 MB sort's critical
            // path is its ~53 s reduce), hopeless behind two 600 MB
            // spot jobs' committed backlog: preemption must fire
            prod.deadline_secs = Some(60.0);
            let batch = TenantSpec::named("batch");
            spec.tenants = Some(TenancySpec { tenants: vec![prod, batch] });
            let mut sess = SimSession::new(&spec);
            let subs = vec![
                sort_for("batch", 0.0, 600.0),
                sort_for("batch", 0.2, 600.0),
                sort_for("prod", 1.0, 150.0),
            ];
            let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
            assert!(
                !out.preemptions.is_empty(),
                "{kind:?}: a deadline-at-risk guaranteed job behind a deep spot \
                 backlog must preempt"
            );
            assert!(out.preemptions.iter().all(|p| p.victim_tenant == "batch"), "{kind:?}");
            assert!(out.preemptions.iter().all(|p| p.by == JobId(2)), "{kind:?}");
            assert!(!out.jobs[2].rejected);
            // preempted work is rescheduled, not lost or duplicated
            let total: usize = out.jobs.iter().map(|j| j.tasks.len()).sum();
            assert_eq!(out.records.len(), total, "{kind:?}");
            crate::testkit::oracles::check_stream(&out, &sess.nodes, &sess.spec.node_speed)
                .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn unattributed_submissions_round_robin_across_tenants() {
        let cost = CostModel::rust_only();
        let mut spec = stream_session(SchedulerKind::Bass).spec.clone();
        spec.tenants = Some(two_tenants());
        let mut sess = SimSession::new(&spec);
        let subs = vec![sort_at(0.0, 150.0), sort_at(50.0, 150.0), sort_at(100.0, 150.0)];
        let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
        let tenants: Vec<_> =
            out.jobs.iter().map(|j| j.tenant.as_deref().unwrap()).collect();
        assert_eq!(tenants, ["prod", "batch", "prod"]);
    }

    #[test]
    fn tenant_streams_are_deterministic() {
        let cost = CostModel::rust_only();
        let run = || {
            let mut spec = stream_session(SchedulerKind::Bass).spec.clone();
            let mut prod = TenantSpec::named("prod");
            prod.weight = 2.0;
            prod.class = TenantClass::Guaranteed;
            prod.deadline_secs = Some(60.0);
            let batch = TenantSpec::named("batch");
            spec.tenants = Some(TenancySpec { tenants: vec![prod, batch] });
            let mut sess = SimSession::new(&spec);
            let subs = vec![
                sort_for("batch", 0.0, 600.0),
                sort_for("batch", 0.2, 600.0),
                sort_for("prod", 1.0, 150.0),
                sort_for("batch", 2.0, 300.0),
            ];
            let out = sess.run_stream(subs, AdmissionPolicy::default(), &cost);
            (
                out.last_finish.to_bits(),
                out.preemptions.len(),
                out.admissions.len(),
                out.reallocs.len(),
                out.jobs.iter().map(|j| j.metrics.jt.to_bits()).collect::<Vec<_>>(),
            )
        };
        assert_eq!(run(), run());
    }

    // ---- checkpoints and soak streams ----

    fn outcome_fingerprint(
        out: &StreamOutcome,
    ) -> (u64, Vec<(JobId, usize, usize, u64)>, Vec<u64>, usize, usize) {
        (
            out.last_finish.to_bits(),
            out.records
                .iter()
                .map(|(j, r)| (*j, r.task.0, r.node.0, r.finish.0.to_bits()))
                .collect(),
            out.jobs.iter().map(|j| j.metrics.jt.to_bits()).collect(),
            out.queued_jobs,
            out.rebalances,
        )
    }

    #[test]
    fn checkpoint_resume_reproduces_the_stream_bit_for_bit() {
        let cost = CostModel::rust_only();
        let spec = StreamSpec {
            jobs: 8,
            mean_interarrival_secs: 15.0,
            sizes_mb: vec![150.0, 300.0],
            seed: 11,
            ..StreamSpec::defaults()
        };
        let subs = spec.submissions();
        let mut full_sess = stream_session(SchedulerKind::Bass);
        let full = full_sess.run_stream(subs.clone(), spec.policy(), &cost);
        // cut at nothing, mid-stream (jobs still in flight), everything
        for cut in [0, 3, subs.len()] {
            let mut a = stream_session(SchedulerKind::Bass);
            let ckpt = checkpoint_stream(&mut a, &subs, cut, spec.policy(), &cost);
            assert_eq!(ckpt.submissions_seen(), cut);
            assert!(!ckpt.is_soak());
            let mut b = stream_session(SchedulerKind::Bass);
            let out = resume_stream(&mut b, ckpt, subs[cut..].to_vec(), &cost);
            assert_eq!(outcome_fingerprint(&out), outcome_fingerprint(&full), "cut {cut}");
        }
    }

    #[test]
    fn checkpoint_resume_covers_rebalancer_and_tenancy_state() {
        let cost = CostModel::rust_only();
        // a mid-stream snapshot must carry the descheduler's tick state
        let subs: Vec<Submission> =
            (0..6).map(|i| sort_at(1.0 + i as f64 * 2.0, 300.0)).collect();
        let mut full_sess = rebalance_session(SchedulerKind::Bass, 5.0);
        let full = full_sess.run_stream(subs.clone(), AdmissionPolicy::default(), &cost);
        let mut a = rebalance_session(SchedulerKind::Bass, 5.0);
        let ckpt = checkpoint_stream(&mut a, &subs, 4, AdmissionPolicy::default(), &cost);
        let mut b = rebalance_session(SchedulerKind::Bass, 5.0);
        let out = resume_stream(&mut b, ckpt, subs[4..].to_vec(), &cost);
        assert_eq!(outcome_fingerprint(&out), outcome_fingerprint(&full));

        // and the DRF/preemption trail across a tenant stream
        let mk = || {
            let mut spec = stream_session(SchedulerKind::Bass).spec.clone();
            let mut prod = TenantSpec::named("prod");
            prod.class = TenantClass::Guaranteed;
            prod.deadline_secs = Some(60.0);
            spec.tenants =
                Some(TenancySpec { tenants: vec![prod, TenantSpec::named("batch")] });
            SimSession::new(&spec)
        };
        let subs = vec![
            sort_for("batch", 0.0, 600.0),
            sort_for("batch", 0.2, 600.0),
            sort_for("prod", 1.0, 150.0),
            sort_for("batch", 2.0, 300.0),
        ];
        let full = mk().run_stream(subs.clone(), AdmissionPolicy::default(), &cost);
        let ckpt = checkpoint_stream(&mut mk(), &subs, 3, AdmissionPolicy::default(), &cost);
        let out = resume_stream(&mut mk(), ckpt, subs[3..].to_vec(), &cost);
        assert_eq!(out.last_finish.to_bits(), full.last_finish.to_bits());
        assert_eq!(out.preemptions.len(), full.preemptions.len());
        assert_eq!(out.admissions.len(), full.admissions.len());
        assert_eq!(out.reallocs.len(), full.reallocs.len());
        assert_eq!(out.records.len(), full.records.len());
        for ((ja, a), (jb, b)) in out.records.iter().zip(&full.records) {
            assert_eq!((ja, a.task, a.node), (jb, b.task, b.node));
            assert_eq!(a.finish.0.to_bits(), b.finish.0.to_bits());
        }
    }

    #[test]
    fn soak_streams_run_in_bounded_memory_without_perturbing_the_engine() {
        let cost = CostModel::rust_only();
        let spec = StreamSpec {
            jobs: 40,
            mean_interarrival_secs: 30.0,
            sizes_mb: vec![150.0, 300.0],
            seed: 5,
            ..StreamSpec::defaults()
        };
        let cfg =
            SoakConfig { sketch_cap: 16, gc_period_secs: 120.0, ..SoakConfig::defaults() };
        let mut sess = stream_session(SchedulerKind::Bass);
        let out = sess.run_soak(spec.submissions(), spec.policy(), &cost, cfg);
        let mut classic = stream_session(SchedulerKind::Bass);
        let full = classic.run_stream(spec.submissions(), spec.policy(), &cost);
        // the per-completion drain/forget/compact machinery must not
        // change the simulation itself
        assert_eq!(out.jobs, full.jobs.len());
        assert_eq!(out.last_finish.to_bits(), full.last_finish.to_bits());
        assert_eq!(out.queued_jobs, full.queued_jobs);
        // bounded retained state: records track the live set, sketches
        // their cap, and the calendar actually compacts
        let total = full.records.len();
        assert!(
            out.peak_live_records < total / 2,
            "peak live records {} should be far below the stream total {total}",
            out.peak_live_records
        );
        assert!(out.retained_samples <= 2 * cfg.sketch_cap);
        assert!(out.compactions >= 2, "periodic gc must fire ({})", out.compactions);
        assert!(out.compacted_placements > 0);
        assert!(out.peak_calendar_segments > 0);
        assert_eq!(out.stats.jobs, 40);
        assert!(out.jobs_per_hour > 0.0);
        assert!(out.makespan > 0.0 && out.last_finish > 0.0);
        assert_eq!(out.rejected_jobs, 0);
    }

    #[test]
    fn soak_checkpoint_resume_is_bit_identical() {
        let cost = CostModel::rust_only();
        let spec = StreamSpec {
            jobs: 20,
            mean_interarrival_secs: 25.0,
            sizes_mb: vec![150.0, 300.0],
            seed: 9,
            ..StreamSpec::defaults()
        };
        let cfg =
            SoakConfig { sketch_cap: 16, gc_period_secs: 100.0, ..SoakConfig::defaults() };
        let subs = spec.submissions();
        let mut full_sess = stream_session(SchedulerKind::Bar);
        let full = full_sess.run_soak(subs.clone(), spec.policy(), &cost, cfg);
        let ckpt = checkpoint_soak(
            &mut stream_session(SchedulerKind::Bar),
            &subs,
            7,
            spec.policy(),
            &cost,
            cfg,
        );
        assert!(ckpt.is_soak());
        assert!(ckpt.now_secs() >= 0.0);
        let out = resume_soak(
            &mut stream_session(SchedulerKind::Bar),
            ckpt,
            subs[7..].to_vec(),
            &cost,
        );
        assert_eq!(out.jobs, full.jobs);
        assert_eq!(out.last_finish.to_bits(), full.last_finish.to_bits());
        assert_eq!(out.stats.mean_jt.to_bits(), full.stats.mean_jt.to_bits());
        assert_eq!(out.stats.p95_jt.to_bits(), full.stats.p95_jt.to_bits());
        assert_eq!(out.stats.mean_slowdown.to_bits(), full.stats.mean_slowdown.to_bits());
        assert_eq!(out.p95_slowdown.to_bits(), full.p95_slowdown.to_bits());
        assert_eq!(out.compactions, full.compactions);
        assert_eq!(out.compacted_placements, full.compacted_placements);
        assert_eq!(out.peak_live_records, full.peak_live_records);
        assert_eq!(out.peak_calendar_segments, full.peak_calendar_segments);
    }

    #[test]
    #[should_panic(expected = "resume_soak")]
    fn soak_checkpoints_do_not_resume_as_streams() {
        let cost = CostModel::rust_only();
        let subs = vec![sort_at(1.0, 150.0), sort_at(50.0, 150.0)];
        let ckpt = checkpoint_soak(
            &mut stream_session(SchedulerKind::Bass),
            &subs,
            1,
            AdmissionPolicy::default(),
            &cost,
            SoakConfig::defaults(),
        );
        let _ = resume_stream(
            &mut stream_session(SchedulerKind::Bass),
            ckpt,
            subs[1..].to_vec(),
            &cost,
        );
    }

    #[test]
    #[ignore] // the 100k-job soak gate (minutes of runtime): cargo test -- --ignored
    fn hundred_thousand_job_soak_stays_bounded() {
        use crate::workload::{Diurnal, LoadShape, LoadStage, SizeDist};
        let cost = CostModel::rust_only();
        let mut sess = stream_session(SchedulerKind::Bass);
        let shape = LoadShape::new(
            vec![
                LoadStage::ramp(20_000, 120.0, 40.0),
                LoadStage::spike(10_000, 40.0, 4.0),
                LoadStage::soak(70_000, 60.0),
            ],
            SizeDist::Pareto { alpha: 1.3, min_mb: 100.0, cap_mb: 600.0 },
            Some(Diurnal { amplitude: 0.3, period_secs: 86_400.0 }),
        )
        .expect("valid load shape");
        let mut rng = XorShift::new(4242);
        let subs: Vec<Submission> =
            shape.generate(&mut rng).into_iter().map(Submission::from).collect();
        let policy = AdmissionPolicy { max_active: 8, min_free_slots: 0 };
        let out = sess.run_soak(subs, policy, &cost, SoakConfig::defaults());
        assert_eq!(out.jobs, 100_000);
        assert!(
            out.peak_live_records < 10_000,
            "live records must not scale with stream length ({})",
            out.peak_live_records
        );
        assert!(out.retained_samples <= 512);
        assert!(out.compactions > 100);
        assert!(out.sustained_jobs_per_hour >= 0.0);
    }
}
