//! Fault & dynamics injection: [`DynamicsSpec`] → seeded timeline →
//! rescheduling rounds.
//!
//! The paper evaluates BASS on a static cluster, but its premise —
//! bandwidth as a scarce, *time-varying* resource tracked by the SDN
//! controller — only pays off when conditions change mid-job. This layer
//! makes churn a first-class scenario input:
//!
//! * [`DynamicsSpec`] declares *how much* churn: node crash/recovery,
//!   link degradation/restoration, straggler slowdowns, background
//!   cross-traffic. [`DynamicsSpec::compile`] expands it into a sorted,
//!   fully deterministic [`TimedEvent`] timeline from its own seed —
//!   every scheduler compared at the same spec sees the identical
//!   incident sequence.
//! * [`run_dynamic`] plays a session against that timeline in
//!   **rescheduling rounds**: schedule the pending tasks on the live
//!   (non-crashed) node set, execute on a [`Engine`] with the remaining
//!   timeline injected, collect the work orphaned by crashes, and repeat
//!   from the earliest loss instant. BASS re-consults a fresh slot
//!   calendar each round (its lost reservations are gone, degraded links
//!   carry a lowered usable ceiling); HDS/BAR simply re-place. In-flight
//!   fair-share transfers survive events (they just re-rate); only
//!   crashes lose work.
//!
//! Determinism contract: the outcome is a pure function of
//! (`ScenarioSpec`, `DynamicsSpec`) — the scenario seed fixes the
//! cluster/workload, the dynamics seed fixes the incident timeline, and
//! round boundaries derive from crash instants only. With an empty
//! timeline the rounds collapse to one and the records are bit-identical
//! to the static `schedule → execute` path (pinned by the golden-trace
//! tests and `experiments::dynamics` tests).
//!
//! A crashed node's **replicas are unreadable** while it is down: the
//! scheduling round passes the down-set into [`SchedCtx::down`], so
//! source selection (matrix rows and committed pulls alike) skips dead
//! holders; tasks whose every holder is down are *deferred* to the next
//! recovery instant ([`DynamicsOutcome::deferrals`]) and the namenode's
//! under-replication view is surfaced per round
//! ([`DynamicsOutcome::under_replicated_peak`]). Every committed pull is
//! audited as (task, source, decision instant) for the no-pull-from-a-
//! down-node oracle ([`crate::testkit::oracles::pulls_from_live_sources`]).
//!
//! Known simplifications (documented in DESIGN.md): a committed BASS
//! reservation keeps its planned arrival even if a link under it
//! degrades mid-transfer (the violation is detected by
//! [`crate::sdn::Controller::revalidate_transfer`] and counted in
//! [`DynamicsOutcome::stale_reservations`]); a source that crashes
//! *mid-transfer* — after the round committed the pull from it — still
//! delivers (only scheduling-time readability is enforced); and a new
//! round's fresh flow network / calendar does not carry the *surviving*
//! prior round's still-in-flight transfers or reservations, so
//! rescheduled work sees only background contention (node-time
//! double-booking is still impossible — per-host availability carries
//! across rounds).

use std::collections::{HashMap, HashSet};

use crate::cluster::Ledger;
use crate::mapreduce::{TaskId, TaskSpec};
use crate::runtime::CostModel;
use crate::sched::{SchedCtx, Scheduler as _};
use crate::sdn::{BandwidthView, Measured, Oracle, Reservation, Telemetry};
use crate::sim::{ClusterEvent, Engine, TaskRecord, TransferPlan};
use crate::topology::{LinkId, NodeId};
use crate::util::{mbps_to_mb_per_s, Secs, XorShift, BLOCK_MB};

use super::session::SimSession;
use super::spec::WorkloadSpec;

/// Declarative churn description — counts and shapes of injected
/// incidents, compiled into a deterministic timeline from `seed`.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicsSpec {
    /// Node crash incidents (distinct nodes; capped at n-1 so at least
    /// one authorized node survives any instant).
    pub node_failures: usize,
    /// Crash-to-recovery delay (seconds).
    pub mttr_secs: f64,
    /// Link degradation incidents (distinct links).
    pub link_degradations: usize,
    /// Lower bound of the degraded capacity factor, in (0, 1]; factors
    /// are drawn uniformly in `[max(0.05, floor), 1)`.
    pub degrade_floor: f64,
    /// Degradation duration (seconds).
    pub degrade_secs: f64,
    /// Straggler incidents (distinct nodes).
    pub stragglers: usize,
    /// Compute-time multiplier while straggling (>= 1 slows the node).
    pub straggle_factor: f64,
    /// Straggle duration (seconds).
    pub straggle_secs: f64,
    /// Cross-traffic incidents (random distinct host pairs).
    pub cross_flows: usize,
    /// Rate cap per cross flow (MB/s).
    pub cross_rate_mb_s: f64,
    /// Cross-flow duration (seconds).
    pub cross_secs: f64,
    /// Incident start times are drawn uniformly in `[0, horizon)`.
    pub horizon_secs: f64,
    /// Timeline seed — independent of the scenario seed, so schedulers
    /// compared at one spec face the identical incident sequence.
    pub seed: u64,
}

impl DynamicsSpec {
    /// No churn at all (the static cluster), with paper-ish defaults for
    /// every shape knob so partial `[dynamics]` configs stay sensible.
    pub fn none() -> Self {
        Self {
            node_failures: 0,
            mttr_secs: 35.0,
            link_degradations: 0,
            degrade_floor: 0.3,
            degrade_secs: 30.0,
            stragglers: 0,
            straggle_factor: 2.0,
            straggle_secs: 25.0,
            cross_flows: 0,
            cross_rate_mb_s: 4.0,
            cross_secs: 40.0,
            horizon_secs: 90.0,
            seed: 2014,
        }
    }

    /// Churn scaled by a single knob: `level` 0.0 = static, 1.0 = the
    /// experiment family's "heavy" point, >1 heavier still.
    pub fn churn(level: f64) -> Self {
        let l = level.clamp(0.0, 8.0);
        Self {
            node_failures: (l * 3.0).round() as usize,
            link_degradations: (l * 2.0).round() as usize,
            stragglers: (l * 2.0).round() as usize,
            cross_flows: (l * 3.0).round() as usize,
            ..Self::none()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.node_failures == 0
            && self.link_degradations == 0
            && self.stragglers == 0
            && self.cross_flows == 0
    }

    /// Expand into the sorted incident timeline. Every incident is a
    /// begin/end event pair; crash targets are distinct nodes (so at
    /// least one of `nodes` is up at any instant), degraded links are
    /// distinct, and factors are clamped to safe ranges (degradation
    /// never reaches 0 — a zero-capacity link would starve in-flight
    /// transfers forever).
    pub fn compile(&self, nodes: &[NodeId], n_links: usize) -> Vec<TimedEvent> {
        // duration floor: guards programmatic zero/negative durations
        // while honoring sub-second config values (which the config
        // layer has already validated as positive)
        const MIN_SECS: f64 = 1e-3;
        let mut rng = XorShift::new(self.seed);
        let mut evs: Vec<TimedEvent> = Vec::new();
        let horizon = self.horizon_secs.max(MIN_SECS);

        let n_fail = self.node_failures.min(nodes.len().saturating_sub(1));
        if n_fail > 0 {
            for idx in rng.distinct(nodes.len(), n_fail) {
                let at = Secs(rng.uniform(0.0, horizon));
                evs.push(TimedEvent { at, ev: DynEvent::NodeDown(nodes[idx]) });
                evs.push(TimedEvent {
                    at: at + Secs(self.mttr_secs.max(MIN_SECS)),
                    ev: DynEvent::NodeUp(nodes[idx]),
                });
            }
        }
        let n_deg = self.link_degradations.min(n_links);
        if n_deg > 0 {
            // clamp below 1.0: `uniform(lo, hi)` needs a non-empty range
            let floor = self.degrade_floor.clamp(0.05, 0.95);
            for l in rng.distinct(n_links, n_deg) {
                let at = Secs(rng.uniform(0.0, horizon));
                let frac = rng.uniform(floor, 1.0);
                let link = LinkId(l);
                evs.push(TimedEvent { at, ev: DynEvent::LinkDegrade { link, frac } });
                evs.push(TimedEvent {
                    at: at + Secs(self.degrade_secs.max(MIN_SECS)),
                    ev: DynEvent::LinkRestore { link },
                });
            }
        }
        let n_str = self.stragglers.min(nodes.len());
        if n_str > 0 {
            let factor = self.straggle_factor.max(1.0);
            for idx in rng.distinct(nodes.len(), n_str) {
                let at = Secs(rng.uniform(0.0, horizon));
                let node = nodes[idx];
                evs.push(TimedEvent { at, ev: DynEvent::Straggle { node, factor } });
                evs.push(TimedEvent {
                    at: at + Secs(self.straggle_secs.max(MIN_SECS)),
                    ev: DynEvent::StraggleEnd { node },
                });
            }
        }
        if self.cross_flows > 0 && nodes.len() >= 2 {
            for key in 0..self.cross_flows {
                let pair = rng.distinct(nodes.len(), 2);
                let at = Secs(rng.uniform(0.0, horizon));
                evs.push(TimedEvent {
                    at,
                    ev: DynEvent::CrossStart {
                        key,
                        src: nodes[pair[0]],
                        dst: nodes[pair[1]],
                        rate_mb_s: self.cross_rate_mb_s.max(0.1),
                    },
                });
                evs.push(TimedEvent {
                    at: at + Secs(self.cross_secs.max(MIN_SECS)),
                    ev: DynEvent::CrossStop { key },
                });
            }
        }
        // stable sort: same-instant events keep begin-before-end order
        evs.sort_by(|a, b| a.at.cmp(&b.at));
        evs
    }
}

/// One compiled incident edge at an absolute simulation time.
#[derive(Debug, Clone)]
pub struct TimedEvent {
    pub at: Secs,
    pub ev: DynEvent,
}

/// Scenario-level dynamic events (compiled; see [`DynamicsSpec`]).
#[derive(Debug, Clone)]
pub enum DynEvent {
    NodeDown(NodeId),
    NodeUp(NodeId),
    LinkDegrade { link: LinkId, frac: f64 },
    LinkRestore { link: LinkId },
    Straggle { node: NodeId, factor: f64 },
    StraggleEnd { node: NodeId },
    CrossStart { key: usize, src: NodeId, dst: NodeId, rate_mb_s: f64 },
    CrossStop { key: usize },
}

/// Audit record of one committed slot reservation, with the usable
/// capacity fraction of every link at commit time — the invariant
/// oracles re-verify per-slot sums against these independently of the
/// calendar's own bookkeeping.
#[derive(Debug, Clone)]
pub struct ReservationAudit {
    pub round: usize,
    pub links: Vec<LinkId>,
    pub start_slot: usize,
    pub n_slots: usize,
    pub frac: f64,
    /// Usable fraction per link (same order as `links`).
    pub usable: Vec<f64>,
}

/// Audit record of one grant renegotiation by the reallocator (the
/// measured control plane's closed loop, `[telemetry] reallocate`):
/// which grant was swapped for which at which probe epoch. Mirrors the
/// [`super::mitigation::DuelAudit`] idea — enough context for the
/// `reallocation_preserves_grant_accounting` oracle to re-check the
/// release/re-commit chains independently of the calendar. No-op
/// renegotiations (the re-plan re-found the identical window) are not
/// recorded.
#[derive(Debug, Clone)]
pub struct ReallocAudit {
    pub round: usize,
    pub task: TaskId,
    /// The probe epoch the renegotiation ran at.
    pub at: Secs,
    /// The reservation released (row k's `old` must equal row k-1's
    /// `new` for the same task — the chain the oracle walks).
    pub old: Reservation,
    /// The reservation committed in its place.
    pub new: Reservation,
    /// The utility-weighted max-min rate share (MB/s) the task's QoS
    /// class was entitled to at this epoch, from estimated capacity.
    pub class_share_mb_s: f64,
}

/// Audit record of one committed remote pull: which holder served the
/// read, decided at which instant. The oracle layer re-checks each
/// source against the downtime windows independently of the scheduler.
#[derive(Debug, Clone)]
pub struct PullAudit {
    pub task: TaskId,
    pub source: NodeId,
    /// The scheduling instant the source was chosen at.
    pub at: Secs,
}

/// Everything a dynamic run produced, self-describing enough for the
/// invariant oracles (`testkit::oracles`).
#[derive(Debug, Clone)]
pub struct DynamicsOutcome {
    /// Surviving execution records (task order); crash-voided attempts
    /// are gone — each submitted task appears exactly once.
    pub records: Vec<TaskRecord>,
    pub makespan: f64,
    /// Locality over surviving map records (1.0 for empty task sets).
    pub locality: f64,
    /// Orphaned-task reschedules across all rounds.
    pub reassignments: usize,
    /// Scheduling rounds executed (1 = no crash hit live work).
    pub rounds: usize,
    /// Compiled downtime windows: (node, down_at, up_at).
    pub down_intervals: Vec<(NodeId, Secs, Secs)>,
    /// Every committed slot reservation with capacity context.
    pub reservations: Vec<ReservationAudit>,
    /// Committed grants whose window a link degradation later
    /// invalidated ([`crate::sdn::Controller::revalidate_transfer`]);
    /// the engine plays their planned arrival anyway — this counts how
    /// often that documented optimism was exercised.
    pub stale_reservations: usize,
    /// The task ids that were submitted.
    pub submitted: Vec<TaskId>,
    /// Every committed remote pull with its decision instant.
    pub pulls: Vec<PullAudit>,
    /// Task-rounds deferred because every replica holder was down
    /// (the block was unreadable at that instant).
    pub deferrals: usize,
    /// Peak per-round count of under-replicated blocks (some holder
    /// down), the namenode view a real HDFS would re-replicate from.
    pub under_replicated_peak: usize,
    /// Speculative duplicate attempts launched by the mitigation layer
    /// (always 0 on the plain [`run_dynamic`] path).
    pub speculated: usize,
    /// Duels the duplicate attempt won (original was killed).
    pub spec_wins: usize,
    /// Straggling-node evictions performed by the mitigation layer.
    pub evictions: usize,
    /// Per-duel audit trail (see [`super::mitigation::DuelAudit`]); the
    /// no-reservation-leak oracle re-checks every killed attempt here.
    pub duels: Vec<super::mitigation::DuelAudit>,
    /// Probe sweeps the measurement plane executed (0 = clairvoyant).
    pub probes: usize,
    /// Grants actually renegotiated by the reallocator (no-op re-plans
    /// excluded).
    pub reallocations: usize,
    /// Per-renegotiation audit trail; the grant-accounting oracle walks
    /// the release/re-commit chains here.
    pub reallocs: Vec<ReallocAudit>,
}

/// Cluster state at one instant, replayed from the timeline prefix.
pub(super) struct ClusterState {
    pub(super) down: Vec<bool>,
    pub(super) speed: Vec<f64>,
    pub(super) link_frac: Vec<f64>,
    /// Active cross flows: (key, src, dst, rate).
    pub(super) cross: Vec<(usize, NodeId, NodeId, f64)>,
}

pub(super) fn state_at(
    timeline: &[TimedEvent],
    now: Secs,
    n_hosts: usize,
    n_links: usize,
) -> ClusterState {
    let mut st = ClusterState {
        down: vec![false; n_hosts],
        speed: vec![1.0; n_hosts],
        link_frac: vec![1.0; n_links],
        cross: Vec::new(),
    };
    for te in timeline.iter().take_while(|te| te.at <= now) {
        match &te.ev {
            DynEvent::NodeDown(nd) => st.down[nd.0] = true,
            DynEvent::NodeUp(nd) => st.down[nd.0] = false,
            DynEvent::LinkDegrade { link, frac } => st.link_frac[link.0] = *frac,
            DynEvent::LinkRestore { link } => st.link_frac[link.0] = 1.0,
            DynEvent::Straggle { node, factor } => st.speed[node.0] = *factor,
            DynEvent::StraggleEnd { node } => st.speed[node.0] = 1.0,
            DynEvent::CrossStart { key, src, dst, rate_mb_s } => {
                st.cross.push((*key, *src, *dst, *rate_mb_s));
            }
            DynEvent::CrossStop { key } => st.cross.retain(|c| c.0 != *key),
        }
    }
    st
}

/// Downtime windows of a compiled timeline (oracle fodder).
pub fn down_intervals(timeline: &[TimedEvent]) -> Vec<(NodeId, Secs, Secs)> {
    let mut open: HashMap<usize, Secs> = HashMap::new();
    let mut out = Vec::new();
    for te in timeline {
        match te.ev {
            DynEvent::NodeDown(nd) => {
                open.insert(nd.0, te.at);
            }
            DynEvent::NodeUp(nd) => {
                if let Some(t0) = open.remove(&nd.0) {
                    out.push((nd, t0, te.at));
                }
            }
            _ => {}
        }
    }
    for (j, t0) in open {
        out.push((NodeId(j), t0, Secs::INF));
    }
    out.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
    out
}

/// Play a session against its compiled dynamics timeline (see the module
/// docs for the round semantics). Works on the session's task batch
/// (`Example1` / `MapWave` workloads) or, for `Job` workloads, its map
/// wave — the churn experiment family is map-wave based.
pub fn run_dynamic(sess: &SimSession, cost: &CostModel) -> DynamicsOutcome {
    let spec = &sess.spec;
    let dspec = spec.dynamics.clone().unwrap_or_else(DynamicsSpec::none);
    let n_links = sess.link_caps_mbps.len();
    let n_hosts = sess.engine_init.len();
    let timeline = dspec.compile(&sess.nodes, n_links);
    let base_caps_mb_s: Vec<f64> =
        sess.link_caps_mbps.iter().map(|&c| mbps_to_mb_per_s(c)).collect();

    let tasks: Vec<TaskSpec> = if !sess.tasks.is_empty() {
        sess.tasks.clone()
    } else if let Some(job) = &sess.job {
        job.maps().cloned().collect()
    } else {
        Vec::new()
    };
    let submitted: Vec<TaskId> = tasks.iter().map(|t| t.id).collect();
    let intervals = down_intervals(&timeline);

    let mut avail = sess.engine_init.clone();
    let mut pending = tasks.clone();
    let mut now = Secs::ZERO;
    let mut records: Vec<TaskRecord> = Vec::new();
    let mut reservations: Vec<ReservationAudit> = Vec::new();
    let mut reassignments = 0usize;
    let mut rounds = 0usize;
    let mut stale_reservations = 0usize;
    let mut pulls: Vec<PullAudit> = Vec::new();
    let mut deferrals = 0usize;
    let mut under_replicated_peak = 0usize;
    // measurement plane (estimators persist across rounds; the plain
    // dynamics path probes at round starts but never reallocates —
    // closed-loop reallocation needs run_mitigated's checkpoint clock)
    let mut telem =
        spec.telemetry.clone().map(|ts| Telemetry::new(ts, n_links));

    while !pending.is_empty() {
        rounds += 1;
        assert!(
            rounds <= 2 * timeline.len() + 4,
            "dynamics run did not converge in {rounds} rounds"
        );
        let st = state_at(&timeline, now, n_hosts, n_links);
        let up = |nd: NodeId| !st.down[nd.0];
        let next_recovery = |now: Secs| -> Secs {
            timeline
                .iter()
                .find(|te| te.at > now && matches!(te.ev, DynEvent::NodeUp(_)))
                .expect("compiled timelines pair every crash with a recovery")
                .at
        };

        // every authorized node down: fast-forward to the next recovery
        if sess.nodes.iter().all(|nd| st.down[nd.0]) {
            now = next_recovery(now);
            continue;
        }

        // a crashed holder's replicas are unreadable: defer tasks whose
        // every holder is down until a recovery makes the block readable
        under_replicated_peak =
            under_replicated_peak.max(sess.nn.under_replicated(up).len());
        let (ready, blocked): (Vec<TaskSpec>, Vec<TaskSpec>) =
            pending.iter().cloned().partition(|t| match t.input {
                Some(b) => sess.nn.is_readable(b, up),
                None => true,
            });
        deferrals += blocked.len();
        if ready.is_empty() {
            // nothing schedulable: jump to the recovery that unblocks
            now = next_recovery(now);
            continue;
        }

        // ---- scheduling: fresh SDN view (re-consult, re-reserve) ----
        let mut ctrl = sess.ctrl.clone();
        for (l, &f) in st.link_frac.iter().enumerate() {
            if f < 1.0 {
                ctrl.set_link_health(LinkId(l), f);
            }
        }
        for &(_, src, dst, rate) in &st.cross {
            if let Some(path) = ctrl.path(src, dst).map(|p| p.to_vec()) {
                for &l in &path {
                    let cur = ctrl.background_mb_s(l);
                    ctrl.set_background_mb_s(l, cur + rate);
                }
            }
        }
        let mut ledger_init = vec![Secs::INF; n_hosts];
        for &nd in &sess.nodes {
            if !st.down[nd.0] {
                ledger_init[nd.0] = avail[nd.0].max(now);
            }
        }
        let mut ledger = Ledger::with_initial(ledger_init);
        let authorized: Vec<NodeId> =
            sess.nodes.iter().copied().filter(|nd| !st.down[nd.0]).collect();
        let mut sched = spec.scheduler.make();
        if let Some(tm) = telem.as_mut() {
            tm.advance(&ctrl, now);
        }
        let assignment = {
            let measured = telem.as_ref().map(|tm| Measured::at(tm, now));
            let view: &dyn BandwidthView = match measured.as_ref() {
                Some(m) => m,
                None => &Oracle,
            };
            let mut ctx = SchedCtx {
                view,
                controller: &mut ctrl,
                namenode: &sess.nn,
                ledger: &mut ledger,
                authorized,
                now,
                cost,
                node_speed: spec.node_speed.clone(),
                down: st.down.clone(),
                bw_aware_sources: spec.bw_aware_sources,
            };
            sched.schedule(&ready, Some(now), &mut ctx)
        };
        for p in &assignment.placements {
            if let Some(src) = p.source {
                pulls.push(PullAudit { task: p.task, source: src, at: now });
            }
            let tr = match &p.transfer {
                TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => t,
                _ => continue,
            };
            if tr.reservation.n_slots == 0 {
                continue;
            }
            reservations.push(ReservationAudit {
                round: rounds,
                links: tr.reservation.links.clone(),
                start_slot: tr.reservation.start_slot,
                n_slots: tr.reservation.n_slots,
                frac: tr.reservation.frac,
                usable: ctrl.path_health(&tr.reservation.links),
            });
        }

        // revalidate committed grants against the degradations that will
        // fire inside their windows — the SDN controller's "can the
        // promised rate still be honored?" check. The engine plays the
        // planned arrival regardless (documented optimism); the count
        // quantifies how often that optimism was exercised.
        let slot_secs = sess.spec.slot_secs;
        for te in timeline.iter().filter(|te| te.at > now) {
            let DynEvent::LinkDegrade { link, frac } = &te.ev else { continue };
            let restore = te.at + Secs(dspec.degrade_secs.max(1e-3));
            let healthy = ctrl.link_health(*link);
            ctrl.set_link_health(*link, *frac);
            for p in &assignment.placements {
                let tr = match &p.transfer {
                    TransferPlan::Reserved(t) | TransferPlan::Prefetched(t) => t,
                    _ => continue,
                };
                let r = &tr.reservation;
                if r.n_slots == 0
                    || !r.links.contains(link)
                    || te.at >= r.end(slot_secs)
                    || restore <= r.start(slot_secs)
                {
                    continue;
                }
                if !ctrl.revalidate_transfer(tr) {
                    stale_reservations += 1;
                }
            }
            ctrl.set_link_health(*link, healthy);
        }

        // ---- execution: engine carrying the remaining timeline ----
        let mut net = sess.net.clone();
        for (l, &f) in st.link_frac.iter().enumerate() {
            if f < 1.0 {
                net.set_link_capacity_mb_s(LinkId(l), base_caps_mb_s[l] * f);
            }
        }
        let mut engine = Engine::new(net, avail.clone());
        for j in 0..n_hosts {
            if st.down[j] {
                engine.set_node_down(NodeId(j));
            }
            if st.speed[j] != 1.0 {
                engine.set_node_speed(NodeId(j), st.speed[j]);
            }
        }
        for &(key, src, dst, rate) in &st.cross {
            if let Some(path) = sess.ctrl.path(src, dst).map(|p| p.to_vec()) {
                engine.inject(now, ClusterEvent::FlowStart { key, path, rate_mb_s: rate });
            }
        }
        for te in timeline.iter().filter(|te| te.at > now) {
            let ev = match &te.ev {
                DynEvent::NodeDown(nd) => ClusterEvent::NodeDown(*nd),
                DynEvent::NodeUp(nd) => ClusterEvent::NodeUp(*nd),
                DynEvent::LinkDegrade { link, frac } => {
                    ClusterEvent::LinkCapacity(*link, base_caps_mb_s[link.0] * frac)
                }
                DynEvent::LinkRestore { link } => {
                    ClusterEvent::LinkCapacity(*link, base_caps_mb_s[link.0])
                }
                DynEvent::Straggle { node, factor } => ClusterEvent::NodeSpeed(*node, *factor),
                DynEvent::StraggleEnd { node } => ClusterEvent::NodeSpeed(*node, 1.0),
                DynEvent::CrossStart { key, src, dst, rate_mb_s } => {
                    match sess.ctrl.path(*src, *dst) {
                        Some(p) => ClusterEvent::FlowStart {
                            key: *key,
                            path: p.to_vec(),
                            rate_mb_s: *rate_mb_s,
                        },
                        None => continue,
                    }
                }
                DynEvent::CrossStop { key } => ClusterEvent::FlowStop { key: *key },
            };
            engine.inject(te.at, ev);
        }
        engine.load(&assignment);
        records.extend(engine.run());
        let orphans = engine.take_orphans();
        avail = engine.node_free_times().to_vec();
        if orphans.is_empty() && blocked.is_empty() {
            break;
        }
        reassignments += orphans.len();
        // re-enqueue lost and deferred work; `now` strictly grows (orphans
        // only arise from events injected strictly after it, and a
        // blocked-only round jumps to the next recovery instant)
        now = if orphans.is_empty() {
            next_recovery(now)
        } else {
            orphans.iter().map(|(_, at)| *at).fold(Secs::INF, Secs::min)
        };
        let mut carry: HashSet<TaskId> = orphans.iter().map(|(p, _)| p.task).collect();
        carry.extend(blocked.iter().map(|t| t.id));
        pending = tasks.iter().filter(|t| carry.contains(&t.id)).cloned().collect();
    }

    records.sort_by_key(|r| r.task);
    let makespan = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
    let (mut maps, mut local) = (0usize, 0usize);
    for r in &records {
        if r.is_map {
            maps += 1;
            if r.is_local {
                local += 1;
            }
        }
    }
    let locality = if maps == 0 { 1.0 } else { local as f64 / maps as f64 };
    DynamicsOutcome {
        records,
        makespan,
        locality,
        reassignments,
        rounds,
        down_intervals: intervals,
        reservations,
        stale_reservations,
        submitted,
        pulls,
        deferrals,
        under_replicated_peak,
        speculated: 0,
        spec_wins: 0,
        evictions: 0,
        duels: Vec::new(),
        probes: telem.map_or(0, |tm| tm.probes),
        reallocations: 0,
        reallocs: Vec::new(),
    }
}

impl SimSession {
    /// [`run_dynamic`] as a session method.
    pub fn run_dynamic(&self, cost: &CostModel) -> DynamicsOutcome {
        run_dynamic(self, cost)
    }
}

/// One executed cell of a dynamic scenario sweep (the `[dynamics]`
/// config route).
#[derive(Debug, Clone)]
pub struct DynSweepRow {
    pub scenario: String,
    pub scheduler: &'static str,
    pub data_mb: f64,
    pub makespan: f64,
    pub locality: f64,
    pub reassignments: usize,
    pub rounds: usize,
    pub completed: usize,
    pub tasks: usize,
    /// Task-rounds deferred on unreadable blocks (every holder down).
    pub deferrals: usize,
    /// Peak per-round under-replicated block count.
    pub under_replicated_peak: usize,
}

/// Run a grid of dynamic scenarios (each cell: build the session, play
/// its churn timeline — with the mitigation layer active when the spec
/// carries a non-inert `[mitigation]` table; inert specs delegate to the
/// plain [`run_dynamic`] path bit-identically) on up to `threads`
/// workers, rows in grid order.
pub fn run_dynamic_grid(
    specs: Vec<super::spec::ScenarioSpec>,
    threads: usize,
    cost: &CostModel,
) -> Vec<DynSweepRow> {
    super::sweep::parallel_map(specs, threads, |spec| {
        let data_mb = match spec.workload {
            WorkloadSpec::Job { data_mb, .. } => data_mb,
            WorkloadSpec::MapWave { tasks, .. } => tasks as f64 * BLOCK_MB,
            _ => 0.0,
        };
        let scheduler = spec.scheduler.label();
        let scenario = spec.name.clone();
        let sess = SimSession::new(&spec);
        let out = super::mitigation::run_mitigated(&sess, cost);
        DynSweepRow {
            scenario,
            scheduler,
            data_mb,
            makespan: out.makespan,
            locality: out.locality,
            reassignments: out.reassignments,
            rounds: out.rounds,
            completed: out.records.len(),
            tasks: out.submitted.len(),
            deferrals: out.deferrals,
            under_replicated_peak: out.under_replicated_peak,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{InitialLoad, ScenarioSpec, TopologyShape};
    use crate::sched::SchedulerKind;

    fn wave_spec(kind: SchedulerKind, dynamics: Option<DynamicsSpec>) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(
            "dyn-test",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 3,
                edge_mbps: 100.0,
                uplink_mbps: 400.0,
            },
            WorkloadSpec::MapWave { tasks: 10, compute_secs: 12.0, output_mb: 4.0 },
        );
        s.scheduler = kind;
        s.replication = 2;
        s.seed = 99;
        s.initial = InitialLoad::Sampled { max_secs: 8.0 };
        s.dynamics = dynamics;
        s
    }

    #[test]
    fn compile_is_deterministic_and_paired() {
        let d = DynamicsSpec {
            node_failures: 2,
            link_degradations: 2,
            stragglers: 1,
            cross_flows: 2,
            ..DynamicsSpec::none()
        };
        let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
        let a = d.compile(&nodes, 8);
        let b = d.compile(&nodes, 8);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), 2 * (2 + 2 + 1 + 2));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(format!("{:?}", x.ev), format!("{:?}", y.ev));
        }
        // sorted by time
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at));
        // every crash has a recovery
        let downs = a.iter().filter(|e| matches!(e.ev, DynEvent::NodeDown(_))).count();
        let ups = a.iter().filter(|e| matches!(e.ev, DynEvent::NodeUp(_))).count();
        assert_eq!(downs, 2);
        assert_eq!(downs, ups);
        assert_eq!(down_intervals(&a).len(), 2);
    }

    #[test]
    fn crash_targets_are_capped_below_the_cluster_size() {
        let d = DynamicsSpec { node_failures: 50, ..DynamicsSpec::none() };
        let nodes: Vec<NodeId> = (0..4).map(NodeId).collect();
        let t = d.compile(&nodes, 8);
        let downs = t.iter().filter(|e| matches!(e.ev, DynEvent::NodeDown(_))).count();
        assert_eq!(downs, 3, "at most n-1 distinct crash targets");
    }

    #[test]
    fn empty_dynamics_is_one_round_with_no_reassignment() {
        let cost = CostModel::rust_only();
        let sess = SimSession::new(&wave_spec(SchedulerKind::Bass, None));
        let out = sess.run_dynamic(&cost);
        assert_eq!(out.rounds, 1);
        assert_eq!(out.reassignments, 0);
        assert_eq!(out.stale_reservations, 0);
        assert_eq!(out.records.len(), out.submitted.len());
        assert!(out.makespan > 0.0);
    }

    #[test]
    fn churn_level_zero_is_empty() {
        assert!(DynamicsSpec::churn(0.0).is_empty());
        assert!(!DynamicsSpec::churn(1.0).is_empty());
    }

    #[test]
    fn forced_crash_reschedules_the_lost_work() {
        // one node down over the whole likely execution window: its work
        // must re-land elsewhere and every task still completes once
        let cost = CostModel::rust_only();
        let d = DynamicsSpec {
            node_failures: 1,
            mttr_secs: 500.0,
            horizon_secs: 5.0, // crash early, while work is in flight
            ..DynamicsSpec::none()
        };
        for kind in [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass] {
            let sess = SimSession::new(&wave_spec(kind, Some(d.clone())));
            let out = sess.run_dynamic(&cost);
            assert_eq!(
                out.records.len(),
                out.submitted.len(),
                "{}: every task completes exactly once",
                kind.label()
            );
            let mut ids: Vec<TaskId> = out.records.iter().map(|r| r.task).collect();
            ids.dedup();
            assert_eq!(ids.len(), out.submitted.len());
            // the crashed node hosts nothing during its downtime
            let (nd, d0, d1) = out.down_intervals[0];
            for r in &out.records {
                assert!(
                    r.node != nd || r.finish <= d0 || r.picked_at >= d1,
                    "{}: task {:?} overlaps downtime",
                    kind.label(),
                    r.task
                );
            }
        }
    }

    #[test]
    fn dynamic_runs_are_deterministic() {
        let cost = CostModel::rust_only();
        let d = DynamicsSpec::churn(1.0);
        let run = || {
            let sess = SimSession::new(&wave_spec(SchedulerKind::Bass, Some(d.clone())));
            let out = sess.run_dynamic(&cost);
            (out.makespan, out.reassignments, out.rounds, out.records.len())
        };
        assert_eq!(run(), run());
    }
}
