//! [`SimSession`]: one built scenario — every substrate object plus the
//! schedule → execute → metrics drivers.

use crate::cluster::Ledger;
use crate::hdfs::{Namenode, PlacementPolicy};
use crate::mapreduce::{JobSpec, TaskSpec};
use crate::metrics::JobMetrics;
use crate::runtime::CostModel;
use crate::sched::{SchedCtx, Scheduler};
use crate::sdn::{BandwidthView, Controller, Measured, Oracle, Telemetry};
use crate::sim::{Assignment, Engine, FlowNet, TaskRecord};
use crate::topology::builders::{fat_tree, fig2, host_racks, tree_cluster};
use crate::topology::{LinkId, NodeId, Topology};
use crate::util::{Secs, XorShift, BLOCK_MB};
use crate::workload::{BackgroundLoad, WorkloadBuilder};

use super::spec::{InitialLoad, ScenarioSpec, TopologyShape, WorkloadSpec};

/// A built scenario: cluster substrates + workload + scheduler, bundled
/// into one `Send` value so sweep points can move across worker threads.
///
/// Construction is the **only** place in the crate that wires
/// `Controller`/`Namenode`/`Ledger`/`FlowNet` together; experiment
/// drivers consume sessions.
pub struct SimSession {
    pub spec: ScenarioSpec,
    /// Task nodes (the authorized set; excludes Fig. 2's master/controller).
    pub nodes: Vec<NodeId>,
    /// Rack (edge switch) of each task node, parallel to `nodes` — the
    /// rack-aware placement policy's input.
    pub racks: Vec<usize>,
    pub ctrl: Controller,
    /// Pristine flow network: background installed, no job flows yet.
    /// Executions clone it so each phase contends against a fresh copy.
    pub net: FlowNet,
    pub nn: Namenode,
    /// Live availability ledger the schedulers mutate.
    pub ledger: Ledger,
    pub rng: XorShift,
    pub sched: Box<dyn Scheduler + Send>,
    /// Pre-built map wave (Example1 / MapWave workloads; empty otherwise).
    pub tasks: Vec<TaskSpec>,
    /// Generated job (Job workloads; `None` otherwise).
    pub job: Option<JobSpec>,
    /// Initial busy time per task node.
    pub initial_idle: Vec<Secs>,
    /// Engine seed per host (task nodes busy, other hosts free).
    pub engine_init: Vec<Secs>,
    /// Link capacities in Mbps, link-id order.
    pub link_caps_mbps: Vec<f64>,
    /// The measurement plane (`[telemetry]`), probed at every
    /// [`SimSession::schedule`] instant; `None` = clairvoyant Oracle.
    /// Estimators persist across phases of one session (EWMA memory),
    /// mirroring a long-lived controller process.
    pub telemetry: Option<Telemetry>,
}

impl SimSession {
    /// Build the scenario: topology → controller/flownet → background →
    /// namenode/workload → ledger. The construction order (in particular
    /// every RNG draw) is part of the contract: a spec's seed fully
    /// determines the session.
    pub fn new(spec: &ScenarioSpec) -> Self {
        let spec = spec.clone();
        let (topo, nodes) = build_topology(&spec.topology);
        let racks = host_racks(&topo, &nodes);
        let link_caps_mbps: Vec<f64> =
            topo.links.iter().map(|l| l.capacity_mbps).collect();
        let n_hosts = topo.n_hosts();
        let mut ctrl = Controller::new(topo, spec.slot_secs);
        if let Some(n) = spec.shards {
            // schedule-invariant (sharding only regroups candidate scans);
            // no RNG draw, so the seed contract is untouched
            ctrl.set_max_shards(n);
        }
        let mut net = FlowNet::new(&link_caps_mbps);
        if let Some(q) = &spec.qos {
            net.set_qos(q.clone());
        }
        let mut rng = XorShift::new(spec.seed);

        // background: the sample draws per-node idle *then* flow pairs, so
        // it runs whenever either is requested to keep the stream stable
        let sample_bg =
            matches!(spec.initial, InitialLoad::Sampled { .. }) || spec.background.flows > 0;
        let sampled_idle: Option<Vec<Secs>> = if sample_bg {
            let max_idle = match spec.initial {
                InitialLoad::Sampled { max_secs } => max_secs,
                _ => 0.0,
            };
            let bg = BackgroundLoad::sample(
                &nodes,
                max_idle,
                spec.background.flows,
                spec.background.rate_mb_s,
                &mut rng,
            );
            bg.install(&mut ctrl, &mut net);
            Some(bg.initial_idle)
        } else {
            None
        };
        let initial_idle: Vec<Secs> = match &spec.initial {
            InitialLoad::Idle => vec![Secs::ZERO; nodes.len()],
            InitialLoad::Explicit(v) => {
                assert_eq!(v.len(), nodes.len(), "explicit initial load per task node");
                v.iter().map(|&t| Secs(t)).collect()
            }
            InitialLoad::Sampled { .. } => sampled_idle.expect("sampled above"),
        };

        // workload + HDFS layout
        let mut nn = Namenode::new();
        let mut tasks = Vec::new();
        let mut job = None;
        match &spec.workload {
            WorkloadSpec::None => {}
            WorkloadSpec::Example1 => {
                assert!(
                    matches!(spec.topology, TopologyShape::Fig2 { .. }),
                    "Example1 workload requires the Fig2 topology"
                );
                // replica placement reverse-engineered from the paper's
                // Figs. 3(a)-(d) — only TK1's {ND2, ND3} is given
                // explicitly; the rest make HDS/BAR/BASS/Pre-BASS land on
                // the published 39/38/35/34s timelines (see DESIGN.md)
                let layout = PlacementPolicy::Explicit(vec![
                    vec![1, 2], // TK1 {ND2, ND3} — given in the paper
                    vec![0, 3], // TK2 {ND1, ND4}
                    vec![0, 1], // TK3 {ND1, ND2}
                    vec![2, 0], // TK4 {ND3, ND1}
                    vec![3, 1], // TK5 {ND4, ND2}
                    vec![1, 2], // TK6 {ND2, ND3}
                    vec![0, 2], // TK7 {ND1, ND3}
                    vec![3, 0], // TK8 {ND4, ND1}
                    vec![2, 0], // TK9 {ND3, ND1}
                ]);
                let blocks = layout.place(&mut nn, &nodes, &racks, 9, 64.0, 2, &mut rng);
                for (i, &b) in blocks.iter().enumerate() {
                    tasks.push(TaskSpec::map(i, b, 64.0, Secs(9.0), 0.0));
                }
            }
            WorkloadSpec::Job { kind, data_mb } => {
                let mut builder = WorkloadBuilder::new(*kind);
                builder.replication = spec.replication.min(nodes.len());
                builder.reduces = spec.reduces;
                builder.placement = spec.placement.clone();
                builder.racks = racks.clone();
                job = Some(builder.build(0, *data_mb, &nodes, &mut nn, &mut rng));
            }
            WorkloadSpec::MapWave { tasks: m, compute_secs, output_mb } => {
                let blocks = spec.placement.place(
                    &mut nn,
                    &nodes,
                    &racks,
                    *m,
                    BLOCK_MB,
                    spec.replication.min(nodes.len()),
                    &mut rng,
                );
                tasks = blocks
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| {
                        TaskSpec::map(i, b, BLOCK_MB, Secs(*compute_secs), *output_mb)
                    })
                    .collect();
            }
        }

        // ledgers: task nodes carry the initial load; Fig. 2's master and
        // controller hosts are never schedulable (INF) but execute free
        let mut ledger_init = vec![Secs::INF; n_hosts];
        let mut engine_init = vec![Secs::ZERO; n_hosts];
        for (i, &nd) in nodes.iter().enumerate() {
            ledger_init[nd.0] = initial_idle[i];
            engine_init[nd.0] = initial_idle[i];
        }
        let ledger = Ledger::with_initial(ledger_init);
        let sched = spec.scheduler.make();
        // no RNG draw from the scenario stream: the probe RNG is seeded
        // from the [telemetry] table's own seed, so the seed contract
        // (and every telemetry-free session) is untouched
        let spec_telemetry = spec
            .telemetry
            .clone()
            .map(|ts| Telemetry::new(ts, link_caps_mbps.len()));

        Self {
            spec,
            nodes,
            racks,
            ctrl,
            net,
            nn,
            ledger,
            rng,
            sched,
            tasks,
            job,
            initial_idle,
            engine_init,
            telemetry: spec_telemetry,
            link_caps_mbps,
        }
    }

    /// Cached route between two hosts (cluster-construction byproduct the
    /// QoS driver uses to aim its flows).
    pub fn route(&self, src: NodeId, dst: NodeId) -> Option<Vec<LinkId>> {
        self.ctrl.path(src, dst).map(|p| p.to_vec())
    }

    /// Schedule a batch through the session's scheduler, mutating the
    /// live ledger/controller. `gate` is the earliest batch start (reduce
    /// phases); `now` is the scheduling instant.
    pub fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        now: Secs,
        cost: &CostModel,
    ) -> Assignment {
        if let Some(tm) = self.telemetry.as_mut() {
            tm.advance(&self.ctrl, now);
        }
        let measured = self.telemetry.as_ref().map(|tm| Measured::at(tm, now));
        let view: &dyn BandwidthView = match measured.as_ref() {
            Some(m) => m,
            None => &Oracle,
        };
        let mut ctx = SchedCtx {
            view,
            controller: &mut self.ctrl,
            namenode: &self.nn,
            ledger: &mut self.ledger,
            authorized: self.nodes.clone(),
            now,
            cost,
            node_speed: self.spec.node_speed.clone(),
            down: Vec::new(),
            bw_aware_sources: self.spec.bw_aware_sources,
        };
        self.sched.schedule(tasks, gate, &mut ctx)
    }

    /// Scheduler-estimated makespan: latest ledger availability over the
    /// task nodes.
    pub fn estimated_makespan(&self) -> f64 {
        self.nodes.iter().map(|&n| self.ledger.idle(n).0).fold(0.0, f64::max)
    }

    /// Execute an assignment on a fresh engine seeded with the session's
    /// initial per-host state.
    pub fn execute(&self, a: &Assignment) -> Vec<TaskRecord> {
        self.execute_from(a, self.engine_init.clone())
    }

    /// Execute from an explicit per-host availability (phase chaining).
    pub fn execute_from(&self, a: &Assignment, init: Vec<Secs>) -> Vec<TaskRecord> {
        let mut engine = Engine::new(self.net.clone(), init);
        engine.load(a);
        engine.run()
    }

    /// The two-phase MapReduce pipeline over the session's generated job
    /// (Table I / Fig. 5 / online coordinator semantics):
    ///
    /// 1. maps scheduled at t=0 and executed through the DES engine;
    /// 2. reduces gated at the slowstart point, shuffle-source hints set
    ///    to the node holding the most map output, executed from the
    ///    post-map cluster state.
    pub fn run_job(&mut self, cost: &CostModel) -> JobMetrics {
        let job = self.job.clone().expect("run_job requires a Job workload");
        let maps: Vec<TaskSpec> = job.maps().cloned().collect();
        let mut reduces: Vec<TaskSpec> = job.reduces().cloned().collect();

        // ---- phase 1: maps ----
        let map_assignment = self.schedule(&maps, None, Secs::ZERO, cost);
        let lr = map_assignment.locality_ratio();
        let map_records = self.execute(&map_assignment);

        // ---- slowstart gate + shuffle source hints ----
        let gate = slowstart_gate(&map_records, self.spec.slowstart);
        let hint = shuffle_majority_node(&map_records, &maps, self.engine_init.len());
        for r in &mut reduces {
            r.src_hint = Some(hint);
        }

        // ---- phase 2: reduces, from the executed map state ----
        let mut reduce_init = self.engine_init.clone();
        for r in &map_records {
            if reduce_init[r.node.0] < r.finish {
                reduce_init[r.node.0] = r.finish;
            }
        }
        self.ledger = Ledger::with_initial(reduce_init.clone());
        let reduce_assignment = self.schedule(&reduces, Some(gate), gate, cost);
        let reduce_records = self.execute_from(&reduce_assignment, reduce_init);

        let mut all = map_records;
        all.extend(reduce_records);
        let mut m = JobMetrics::from_records(&all, Secs::ZERO, Some(gate));
        m.lr = lr;
        m
    }
}

fn build_topology(shape: &TopologyShape) -> (Topology, Vec<NodeId>) {
    match *shape {
        TopologyShape::Fig2 { link_mbps } => {
            let f = fig2(link_mbps);
            (f.topo, f.task_nodes.to_vec())
        }
        TopologyShape::Tree { switches, hosts_per_switch, edge_mbps, uplink_mbps } => {
            tree_cluster(switches, hosts_per_switch, edge_mbps, uplink_mbps)
        }
        TopologyShape::FatTree {
            edge_switches,
            hosts_per_edge,
            core_switches,
            edge_mbps,
            core_mbps,
        } => fat_tree(edge_switches, hosts_per_edge, core_switches, edge_mbps, core_mbps),
    }
}

/// Time at which `frac` of the maps have finished (Hadoop's reduce
/// slowstart point).
pub fn slowstart_gate(map_records: &[TaskRecord], frac: f64) -> Secs {
    let mut fins: Vec<Secs> = map_records.iter().map(|r| r.finish).collect();
    if fins.is_empty() {
        // map-less jobs: reduces may start immediately (and the clamp
        // below would panic on an empty range)
        return Secs::ZERO;
    }
    fins.sort();
    let k = ((fins.len() as f64 * frac).ceil() as usize).clamp(1, fins.len());
    fins[k - 1]
}

/// Node holding the most map output (the reduces' shuffle source hint).
pub fn shuffle_majority_node(
    map_records: &[TaskRecord],
    maps: &[TaskSpec],
    n_nodes: usize,
) -> NodeId {
    let mut out_mb = vec![0.0f64; n_nodes];
    for r in map_records {
        let t = maps.iter().find(|t| t.id == r.task).expect("map record");
        out_mb[r.node.0] += t.output_mb;
    }
    let best = out_mb
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    NodeId(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::TaskId;
    use crate::sched::SchedulerKind;
    use crate::workload::JobKind;

    fn tree_spec() -> ScenarioSpec {
        let mut s = ScenarioSpec::new(
            "t",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 3,
                edge_mbps: 100.0,
                uplink_mbps: 100.0,
            },
            WorkloadSpec::Job { kind: JobKind::Wordcount, data_mb: 300.0 },
        );
        s.initial = InitialLoad::Sampled { max_secs: 20.0 };
        s.background = super::super::spec::BackgroundSpec { flows: 2, rate_mb_s: 3.0 };
        s
    }

    #[test]
    fn example1_session_matches_the_paper_testbed() {
        let s = SimSession::new(&ScenarioSpec::example1(SchedulerKind::Bass));
        assert_eq!(s.nodes.len(), 4);
        assert_eq!(s.tasks.len(), 9);
        assert_eq!(s.link_caps_mbps.len(), 8);
        assert_eq!(s.initial_idle, vec![Secs(3.0), Secs(9.0), Secs(20.0), Secs(7.0)]);
        // engine hosts: 4 task nodes + master + controller
        assert_eq!(s.engine_init.len(), 6);
        assert_eq!(s.engine_init[4], Secs::ZERO);
        // ledger keeps the non-task hosts unschedulable
        assert!(!s.ledger.idle(NodeId(4)).is_finite());
        // TK1 replicas are the paper's {ND2, ND3}
        let b = s.tasks[0].input.unwrap();
        assert_eq!(s.nn.block(b).replicas, vec![s.nodes[1], s.nodes[2]]);
    }

    #[test]
    fn sessions_are_deterministic_per_seed() {
        let spec = tree_spec();
        let a = SimSession::new(&spec);
        let b = SimSession::new(&spec);
        assert_eq!(a.initial_idle, b.initial_idle);
        let blocks = |s: &SimSession| -> Vec<Vec<NodeId>> {
            (0..s.nn.n_blocks())
                .map(|i| s.nn.block(crate::hdfs::BlockId(i)).replicas.clone())
                .collect()
        };
        assert_eq!(blocks(&a), blocks(&b));
    }

    #[test]
    fn run_job_produces_sane_metrics() {
        let cost = CostModel::rust_only();
        let mut s = SimSession::new(&tree_spec());
        let m = s.run_job(&cost);
        assert!(m.jt > 0.0 && m.mt > 0.0);
        assert!((0.0..=1.0).contains(&m.lr));
        assert!(m.jt >= m.mt);
    }

    #[test]
    fn schedule_then_execute_round_trips() {
        let cost = CostModel::rust_only();
        let mut s = SimSession::new(&ScenarioSpec::example1(SchedulerKind::Bass));
        let tasks = s.tasks.clone();
        let a = s.schedule(&tasks, None, Secs::ZERO, &cost);
        assert_eq!(a.placements.len(), 9);
        let est = s.estimated_makespan();
        let records = s.execute(&a);
        let exec = records.iter().map(|r| r.finish.0).fold(0.0, f64::max);
        assert_eq!(est, 35.0); // the paper's BASS makespan
        assert_eq!(exec, 35.0);
    }

    #[test]
    fn slowstart_gate_quantile() {
        let recs: Vec<TaskRecord> = (0..4)
            .map(|i| TaskRecord {
                task: TaskId(i),
                node: NodeId(0),
                picked_at: Secs::ZERO,
                input_ready: Secs::ZERO,
                compute_start: Secs::ZERO,
                finish: Secs((i + 1) as f64 * 10.0),
                source: None,
                is_local: true,
                is_map: true,
            })
            .collect();
        assert_eq!(slowstart_gate(&recs, 0.5), Secs(20.0));
        assert_eq!(slowstart_gate(&recs, 1.0), Secs(40.0));
        assert_eq!(slowstart_gate(&recs, 0.0), Secs(10.0));
        // empty map set (map-less job): gate opens immediately, no panic
        assert_eq!(slowstart_gate(&[], 0.5), Secs::ZERO);
    }

    #[test]
    fn sessions_move_across_threads() {
        // the whole point of bundling: a session is one Send value
        fn assert_send<T: Send>() {}
        assert_send::<SimSession>();
        let spec = ScenarioSpec::example1(SchedulerKind::Hds);
        let handle = std::thread::spawn(move || {
            let cost = CostModel::rust_only();
            let mut s = SimSession::new(&spec);
            let tasks = s.tasks.clone();
            let a = s.schedule(&tasks, None, Secs::ZERO, &cost);
            s.execute(&a).len()
        });
        assert_eq!(handle.join().unwrap(), 9);
    }
}
