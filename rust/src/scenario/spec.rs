//! [`ScenarioSpec`]: the declarative description a [`super::SimSession`]
//! is built from.

use crate::hdfs::PlacementPolicy;
use crate::sched::SchedulerKind;
use crate::sdn::{QosPolicy, TelemetrySpec};
use crate::workload::JobKind;

use super::dynamics::DynamicsSpec;
use super::mitigation::MitigationSpec;

/// Per-size seed for sweep grids: every scheduler at the same
/// (sweep seed, size) sees the identical layout/background draw, while
/// sizes get distinct streams. The single definition keeps Table I cells
/// and user-defined scenario sweeps on the same guarantee.
pub fn cell_seed(sweep_seed: u64, data_mb: f64) -> u64 {
    sweep_seed ^ (data_mb as u64).wrapping_mul(0x9E37_79B9)
}

/// Cluster topology shape.
#[derive(Debug, Clone)]
pub enum TopologyShape {
    /// The paper's Fig. 2 testbed: 4 task nodes, 2 OpenFlow switches, a
    /// router, plus master and controller hosts. Uniform link rate.
    Fig2 { link_mbps: f64 },
    /// Two-level tree: `switches` edge switches with `hosts_per_switch`
    /// task nodes each, all uplinked to one router.
    Tree { switches: usize, hosts_per_switch: usize, edge_mbps: f64, uplink_mbps: f64 },
    /// Leaf-spine fat tree: `edge_switches` leaves of `hosts_per_edge`
    /// task nodes, each leaf uplinked to all `core_switches` spines
    /// (deterministic ECMP spread — see `topology::builders::fat_tree`).
    /// The datacenter-scale shape for thousand-node sweeps.
    FatTree {
        edge_switches: usize,
        hosts_per_edge: usize,
        core_switches: usize,
        edge_mbps: f64,
        core_mbps: f64,
    },
}

/// Initial per-task-node busy time (the paper's `ΥI` at t=0).
#[derive(Debug, Clone)]
pub enum InitialLoad {
    /// Every node idle at t=0.
    Idle,
    /// Explicit busy times per task node (Example 1's `[3, 9, 20, 7]`).
    Explicit(Vec<f64>),
    /// Sampled uniformly in `[0, max_secs)` from the scenario RNG (the
    /// shared-cluster "background job" regime of Section V-A).
    Sampled { max_secs: f64 },
}

/// Permanent background traffic on random host pairs.
#[derive(Debug, Clone)]
pub struct BackgroundSpec {
    pub flows: usize,
    /// Nominal per-flow rate (MB/s) for the controller's static view.
    pub rate_mb_s: f64,
}

impl BackgroundSpec {
    pub fn none() -> Self {
        Self { flows: 0, rate_mb_s: 0.0 }
    }
}

/// What work the scenario carries.
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// No pre-built work (online drivers submit their own jobs).
    None,
    /// The paper's hand-placed Example 1 layout: 9 map tasks, 2 replicas
    /// each, reverse-engineered from Figs. 3(a)-(d). Requires `Fig2`.
    Example1,
    /// A generated Wordcount/Sort job over `data_mb` of input.
    Job { kind: JobKind, data_mb: f64 },
    /// A bare wave of map tasks over freshly placed 64MB blocks.
    MapWave { tasks: usize, compute_secs: f64, output_mb: f64 },
}

/// Preemption class of a tenant's jobs.
///
/// `Guaranteed` jobs may preempt queued `Spot` work (through the
/// engine's drain/orphan path) when their deadline is at risk; `Spot`
/// work never preempts anything and is the only preemption victim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantClass {
    Guaranteed,
    Spot,
}

/// One tenant of the multi-tenant stream layer (`[tenants]` table).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// DRF weight: a tenant's dominant share is divided by its weight
    /// before admission ordering, so weight 2 sustains twice the share
    /// of weight 1. Must be positive.
    pub weight: f64,
    /// Cap on the tenant's simultaneously admitted task slots (the sum
    /// of task counts over its admitted, unfinished jobs).
    /// `usize::MAX` = unlimited.
    pub slot_quota: usize,
    /// Cap on the tenant's committed calendar bandwidth (summed
    /// `frac x n_slots` reservation area over unfinished jobs).
    /// `f64::INFINITY` = unlimited.
    pub bw_quota: f64,
    pub class: TenantClass,
    /// Relative completion deadline for every job of this tenant
    /// (seconds from submission). Jobs whose deadline is infeasible even
    /// in the best case are rejected at admission; completed jobs count
    /// toward SLO attainment.
    pub deadline_secs: Option<f64>,
}

impl TenantSpec {
    /// A default-weight tenant with no quotas, no deadline, spot class.
    pub fn named(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            weight: 1.0,
            slot_quota: usize::MAX,
            bw_quota: f64::INFINITY,
            class: TenantClass::Spot,
            deadline_secs: None,
        }
    }
}

/// The multi-tenant layer over the online stream driver: DRF-style
/// dominant-resource fairness over (occupied slots, reserved calendar
/// bandwidth) replaces bare FIFO admission. A single default-weight
/// tenant is pinned bit-identical to the FIFO path
/// (`rust/tests/invariants.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct TenancySpec {
    pub tenants: Vec<TenantSpec>,
}

impl TenancySpec {
    /// One default tenant: attribution-only, admission order identical
    /// to FIFO (the differential-pin configuration).
    pub fn single_default() -> Self {
        Self { tenants: vec![TenantSpec::named("default")] }
    }

    pub fn resolve(&self, name: &str) -> Option<usize> {
        self.tenants.iter().position(|t| t.name == name)
    }

    /// Structural validation shared by the config layer and library
    /// constructors: at least one tenant, unique non-empty names,
    /// positive weights/quotas/deadlines.
    pub fn validate(&self) -> Result<(), String> {
        if self.tenants.is_empty() {
            return Err("tenancy needs at least one tenant".into());
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if t.name.is_empty() {
                return Err("tenant names must be non-empty".into());
            }
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(format!("duplicate tenant name '{}'", t.name));
            }
            if !(t.weight > 0.0) {
                return Err(format!("tenant '{}': weight must be positive", t.name));
            }
            if t.slot_quota == 0 {
                return Err(format!("tenant '{}': slot_quota must be positive", t.name));
            }
            if !(t.bw_quota > 0.0) {
                return Err(format!("tenant '{}': bw_quota must be positive", t.name));
            }
            if let Some(d) = t.deadline_secs {
                if !(d > 0.0) {
                    return Err(format!("tenant '{}': deadline_secs must be positive", t.name));
                }
            }
        }
        Ok(())
    }
}

/// A full scenario description. `SimSession::new` consumes one of these
/// and owns all cluster construction; experiment drivers never touch
/// `Controller::new` / `Namenode` wiring directly.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub topology: TopologyShape,
    pub workload: WorkloadSpec,
    pub scheduler: SchedulerKind,
    /// Replica placement for generated workloads.
    pub placement: PlacementPolicy,
    /// Remote pulls read from the holder with the best SDN-reported path
    /// bandwidth (`true`, the default) or from the least-loaded holder
    /// (`false` — the seed's idle-only rule, kept as an ablation; the
    /// `[hdfs] selection` config key and the skew sweep flip it).
    pub bw_aware_sources: bool,
    /// QoS queue policy installed into the flow network (Example 3).
    pub qos: Option<QosPolicy>,
    /// Time-slot duration for the SDN calendar (the paper's TS).
    pub slot_secs: f64,
    /// HDFS replication factor for generated workloads.
    pub replication: usize,
    /// Reduce count for generated jobs.
    pub reduces: usize,
    /// Reduce slowstart fraction for the two-phase pipeline.
    pub slowstart: f64,
    /// Seed for the scenario RNG (placement, background, workload).
    pub seed: u64,
    pub initial: InitialLoad,
    pub background: BackgroundSpec,
    /// Per-node compute-speed factors (empty = homogeneous cluster).
    pub node_speed: Vec<f64>,
    /// Worker threads for sweep drivers expanding this scenario into a
    /// grid of points (1 = serial; results are identical either way).
    pub threads: usize,
    /// Cap on the controller's scheduler-state shard count (`None` = one
    /// shard per rack, the default plan). Any cap yields bit-identical
    /// schedules — sharding only regroups the candidate scans — so this
    /// is purely a perf/memory knob for very wide fat trees.
    pub shards: Option<usize>,
    /// Injected churn (node failures, link degradation, stragglers,
    /// cross traffic) compiled into a seeded timeline by
    /// [`super::dynamics::run_dynamic`]. `None` = static cluster.
    pub dynamics: Option<DynamicsSpec>,
    /// Straggler mitigation (speculative execution, eviction,
    /// rebalancing) applied by [`super::mitigation::run_mitigated`].
    /// `None` (or an inert spec) = today's non-reactive dynamics path.
    pub mitigation: Option<MitigationSpec>,
    /// The measured control plane (probes, EWMA estimates, optional
    /// mid-flow reallocation — DESIGN.md §12). `None` = clairvoyant
    /// `Oracle` bandwidth everywhere, bit-identical to pre-telemetry
    /// behavior.
    pub telemetry: Option<TelemetrySpec>,
    /// Multi-tenant stream admission (DRF over slots + reserved
    /// bandwidth, quotas, deadlines, preemption classes — DESIGN.md
    /// §13). `None` = the FIFO stream path, bit-identical to
    /// pre-tenancy behavior. Only the online stream driver reads this.
    pub tenants: Option<TenancySpec>,
}

impl ScenarioSpec {
    /// Baseline spec: paper defaults everywhere.
    pub fn new(name: impl Into<String>, topology: TopologyShape, workload: WorkloadSpec) -> Self {
        Self {
            name: name.into(),
            topology,
            workload,
            scheduler: SchedulerKind::Bass,
            placement: PlacementPolicy::RandomDistinct,
            bw_aware_sources: true,
            qos: None,
            slot_secs: 1.0,
            replication: 3,
            reduces: 2,
            slowstart: 0.5,
            seed: 2014,
            initial: InitialLoad::Idle,
            background: BackgroundSpec::none(),
            node_speed: Vec::new(),
            threads: 1,
            shards: None,
            dynamics: None,
            mitigation: None,
            telemetry: None,
            tenants: None,
        }
    }

    /// The paper's Example 1 testbed: Fig. 2 at the effective 12.8 MB/s
    /// (the paper rounds 64MB/100Mbps to 5s), TP = 9s, initial loads
    /// `ΥI = [3, 9, 20, 7]`.
    pub fn example1(scheduler: SchedulerKind) -> Self {
        let mut s = Self::new(
            "example1",
            TopologyShape::Fig2 { link_mbps: 102.4 },
            WorkloadSpec::Example1,
        );
        s.scheduler = scheduler;
        s.initial = InitialLoad::Explicit(vec![3.0, 9.0, 20.0, 7.0]);
        s
    }

    /// Builder-style scheduler override.
    pub fn with_scheduler(mut self, k: SchedulerKind) -> Self {
        self.scheduler = k;
        self
    }

    /// Builder-style seed override.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_the_paper() {
        let s = ScenarioSpec::new(
            "t",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 3,
                edge_mbps: 100.0,
                uplink_mbps: 100.0,
            },
            WorkloadSpec::None,
        );
        assert_eq!(s.slot_secs, 1.0);
        assert_eq!(s.replication, 3);
        assert_eq!(s.threads, 1);
        assert!(s.qos.is_none());
    }

    #[test]
    fn example1_preset_carries_the_initial_loads() {
        let s = ScenarioSpec::example1(SchedulerKind::Hds);
        assert_eq!(s.scheduler, SchedulerKind::Hds);
        match &s.initial {
            InitialLoad::Explicit(v) => assert_eq!(v, &vec![3.0, 9.0, 20.0, 7.0]),
            other => panic!("unexpected initial load {other:?}"),
        }
    }

    #[test]
    fn builders_chain() {
        let s = ScenarioSpec::example1(SchedulerKind::Bass)
            .with_scheduler(SchedulerKind::Bar)
            .with_seed(7);
        assert_eq!(s.scheduler, SchedulerKind::Bar);
        assert_eq!(s.seed, 7);
        assert!(s.tenants.is_none(), "tenancy is opt-in");
    }

    #[test]
    fn tenancy_validation_rejects_malformed_specs() {
        assert!(TenancySpec::single_default().validate().is_ok());
        assert!(TenancySpec { tenants: Vec::new() }.validate().is_err());
        let dup = TenancySpec {
            tenants: vec![TenantSpec::named("a"), TenantSpec::named("a")],
        };
        assert!(dup.validate().unwrap_err().contains("duplicate"));
        let mut bad = TenantSpec::named("a");
        bad.weight = 0.0;
        assert!(TenancySpec { tenants: vec![bad.clone()] }.validate().is_err());
        bad.weight = 1.0;
        bad.slot_quota = 0;
        assert!(TenancySpec { tenants: vec![bad.clone()] }.validate().is_err());
        bad.slot_quota = 1;
        bad.deadline_secs = Some(0.0);
        assert!(TenancySpec { tenants: vec![bad] }.validate().is_err());
        let two = TenancySpec {
            tenants: vec![TenantSpec::named("a"), TenantSpec::named("b")],
        };
        assert!(two.validate().is_ok());
        assert_eq!(two.resolve("b"), Some(1));
        assert_eq!(two.resolve("c"), None);
    }
}
