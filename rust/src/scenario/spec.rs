//! [`ScenarioSpec`]: the declarative description a [`super::SimSession`]
//! is built from.

use crate::hdfs::PlacementPolicy;
use crate::sched::SchedulerKind;
use crate::sdn::{QosPolicy, TelemetrySpec};
use crate::workload::JobKind;

use super::dynamics::DynamicsSpec;
use super::mitigation::MitigationSpec;

/// Per-size seed for sweep grids: every scheduler at the same
/// (sweep seed, size) sees the identical layout/background draw, while
/// sizes get distinct streams. The single definition keeps Table I cells
/// and user-defined scenario sweeps on the same guarantee.
pub fn cell_seed(sweep_seed: u64, data_mb: f64) -> u64 {
    sweep_seed ^ (data_mb as u64).wrapping_mul(0x9E37_79B9)
}

/// Cluster topology shape.
#[derive(Debug, Clone)]
pub enum TopologyShape {
    /// The paper's Fig. 2 testbed: 4 task nodes, 2 OpenFlow switches, a
    /// router, plus master and controller hosts. Uniform link rate.
    Fig2 { link_mbps: f64 },
    /// Two-level tree: `switches` edge switches with `hosts_per_switch`
    /// task nodes each, all uplinked to one router.
    Tree { switches: usize, hosts_per_switch: usize, edge_mbps: f64, uplink_mbps: f64 },
    /// Leaf-spine fat tree: `edge_switches` leaves of `hosts_per_edge`
    /// task nodes, each leaf uplinked to all `core_switches` spines
    /// (deterministic ECMP spread — see `topology::builders::fat_tree`).
    /// The datacenter-scale shape for thousand-node sweeps.
    FatTree {
        edge_switches: usize,
        hosts_per_edge: usize,
        core_switches: usize,
        edge_mbps: f64,
        core_mbps: f64,
    },
}

/// Initial per-task-node busy time (the paper's `ΥI` at t=0).
#[derive(Debug, Clone)]
pub enum InitialLoad {
    /// Every node idle at t=0.
    Idle,
    /// Explicit busy times per task node (Example 1's `[3, 9, 20, 7]`).
    Explicit(Vec<f64>),
    /// Sampled uniformly in `[0, max_secs)` from the scenario RNG (the
    /// shared-cluster "background job" regime of Section V-A).
    Sampled { max_secs: f64 },
}

/// Permanent background traffic on random host pairs.
#[derive(Debug, Clone)]
pub struct BackgroundSpec {
    pub flows: usize,
    /// Nominal per-flow rate (MB/s) for the controller's static view.
    pub rate_mb_s: f64,
}

impl BackgroundSpec {
    pub fn none() -> Self {
        Self { flows: 0, rate_mb_s: 0.0 }
    }
}

/// What work the scenario carries.
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    /// No pre-built work (online drivers submit their own jobs).
    None,
    /// The paper's hand-placed Example 1 layout: 9 map tasks, 2 replicas
    /// each, reverse-engineered from Figs. 3(a)-(d). Requires `Fig2`.
    Example1,
    /// A generated Wordcount/Sort job over `data_mb` of input.
    Job { kind: JobKind, data_mb: f64 },
    /// A bare wave of map tasks over freshly placed 64MB blocks.
    MapWave { tasks: usize, compute_secs: f64, output_mb: f64 },
}

/// A full scenario description. `SimSession::new` consumes one of these
/// and owns all cluster construction; experiment drivers never touch
/// `Controller::new` / `Namenode` wiring directly.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub topology: TopologyShape,
    pub workload: WorkloadSpec,
    pub scheduler: SchedulerKind,
    /// Replica placement for generated workloads.
    pub placement: PlacementPolicy,
    /// Remote pulls read from the holder with the best SDN-reported path
    /// bandwidth (`true`, the default) or from the least-loaded holder
    /// (`false` — the seed's idle-only rule, kept as an ablation; the
    /// `[hdfs] selection` config key and the skew sweep flip it).
    pub bw_aware_sources: bool,
    /// QoS queue policy installed into the flow network (Example 3).
    pub qos: Option<QosPolicy>,
    /// Time-slot duration for the SDN calendar (the paper's TS).
    pub slot_secs: f64,
    /// HDFS replication factor for generated workloads.
    pub replication: usize,
    /// Reduce count for generated jobs.
    pub reduces: usize,
    /// Reduce slowstart fraction for the two-phase pipeline.
    pub slowstart: f64,
    /// Seed for the scenario RNG (placement, background, workload).
    pub seed: u64,
    pub initial: InitialLoad,
    pub background: BackgroundSpec,
    /// Per-node compute-speed factors (empty = homogeneous cluster).
    pub node_speed: Vec<f64>,
    /// Worker threads for sweep drivers expanding this scenario into a
    /// grid of points (1 = serial; results are identical either way).
    pub threads: usize,
    /// Cap on the controller's scheduler-state shard count (`None` = one
    /// shard per rack, the default plan). Any cap yields bit-identical
    /// schedules — sharding only regroups the candidate scans — so this
    /// is purely a perf/memory knob for very wide fat trees.
    pub shards: Option<usize>,
    /// Injected churn (node failures, link degradation, stragglers,
    /// cross traffic) compiled into a seeded timeline by
    /// [`super::dynamics::run_dynamic`]. `None` = static cluster.
    pub dynamics: Option<DynamicsSpec>,
    /// Straggler mitigation (speculative execution, eviction,
    /// rebalancing) applied by [`super::mitigation::run_mitigated`].
    /// `None` (or an inert spec) = today's non-reactive dynamics path.
    pub mitigation: Option<MitigationSpec>,
    /// The measured control plane (probes, EWMA estimates, optional
    /// mid-flow reallocation — DESIGN.md §12). `None` = clairvoyant
    /// `Oracle` bandwidth everywhere, bit-identical to pre-telemetry
    /// behavior.
    pub telemetry: Option<TelemetrySpec>,
}

impl ScenarioSpec {
    /// Baseline spec: paper defaults everywhere.
    pub fn new(name: impl Into<String>, topology: TopologyShape, workload: WorkloadSpec) -> Self {
        Self {
            name: name.into(),
            topology,
            workload,
            scheduler: SchedulerKind::Bass,
            placement: PlacementPolicy::RandomDistinct,
            bw_aware_sources: true,
            qos: None,
            slot_secs: 1.0,
            replication: 3,
            reduces: 2,
            slowstart: 0.5,
            seed: 2014,
            initial: InitialLoad::Idle,
            background: BackgroundSpec::none(),
            node_speed: Vec::new(),
            threads: 1,
            shards: None,
            dynamics: None,
            mitigation: None,
            telemetry: None,
        }
    }

    /// The paper's Example 1 testbed: Fig. 2 at the effective 12.8 MB/s
    /// (the paper rounds 64MB/100Mbps to 5s), TP = 9s, initial loads
    /// `ΥI = [3, 9, 20, 7]`.
    pub fn example1(scheduler: SchedulerKind) -> Self {
        let mut s = Self::new(
            "example1",
            TopologyShape::Fig2 { link_mbps: 102.4 },
            WorkloadSpec::Example1,
        );
        s.scheduler = scheduler;
        s.initial = InitialLoad::Explicit(vec![3.0, 9.0, 20.0, 7.0]);
        s
    }

    /// Builder-style scheduler override.
    pub fn with_scheduler(mut self, k: SchedulerKind) -> Self {
        self.scheduler = k;
        self
    }

    /// Builder-style seed override.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_the_paper() {
        let s = ScenarioSpec::new(
            "t",
            TopologyShape::Tree {
                switches: 2,
                hosts_per_switch: 3,
                edge_mbps: 100.0,
                uplink_mbps: 100.0,
            },
            WorkloadSpec::None,
        );
        assert_eq!(s.slot_secs, 1.0);
        assert_eq!(s.replication, 3);
        assert_eq!(s.threads, 1);
        assert!(s.qos.is_none());
    }

    #[test]
    fn example1_preset_carries_the_initial_loads() {
        let s = ScenarioSpec::example1(SchedulerKind::Hds);
        assert_eq!(s.scheduler, SchedulerKind::Hds);
        match &s.initial {
            InitialLoad::Explicit(v) => assert_eq!(v, &vec![3.0, 9.0, 20.0, 7.0]),
            other => panic!("unexpected initial load {other:?}"),
        }
    }

    #[test]
    fn builders_chain() {
        let s = ScenarioSpec::example1(SchedulerKind::Bass)
            .with_scheduler(SchedulerKind::Bar)
            .with_seed(7);
        assert_eq!(s.scheduler, SchedulerKind::Bar);
        assert_eq!(s.seed, 7);
    }
}
