//! L3 runtime: load and execute the AOT-compiled JAX/Pallas cost model.
//!
//! `make artifacts` lowers the L2 model (python/compile/) to HLO **text**
//! once at build time; this module loads `artifacts/*.hlo.txt` through the
//! `xla` crate's PJRT CPU client and executes it on the scheduling hot
//! path. Python never runs at request time.
//!
//! [`CostModel`] is the scheduler-facing API: it picks the smallest
//! artifact variant that fits the live (m, n), pads, executes, slices —
//! or falls back to the bit-identical pure-Rust evaluator when artifacts
//! are absent (tests, artifact-less builds).

pub mod exec;
pub mod loader;

pub use exec::{CostInputs, CostModel, CostOutputs};
pub use loader::{default_artifacts_dir, Artifacts};
