//! The scheduler-facing cost model: XLA execution + pure-Rust fallback.
//!
//! Semantics are defined by `python/compile/kernels/ref.py` (Eq. 1-3 of
//! the paper); the Rust fallback mirrors it **in f32** so both backends
//! agree bit-for-bit and property tests can cross-check them.

use anyhow::Result;

use super::loader::{default_artifacts_dir, Artifacts};

/// f32 constants matching kernels/ref.py.
pub const INF: f32 = 3.0e38;
pub const EPS: f32 = 1e-9;

/// Finite stand-in for "infinite" bandwidth — the local `src == dst` case
/// the controller reports as `f64::INFINITY`, which the f32 cost kernel
/// cannot carry. The value is pinned here (the single definition both the
/// cost bridge and `Controller::bw_matrix` use) with two saturation
/// guarantees, property-tested in `rust/tests/proptests.rs`:
///
/// * `TM = sz / BW_SENTINEL_MB_S` stays strictly below any remote TM at
///   a physical bandwidth (`<= 1e6 MB/s`), so an infinite-bandwidth cell
///   always beats a remote cell on Eq. 1 — no f32 rounding collapse;
/// * it sits ~26 binary orders of magnitude under `f32::MAX`, so the
///   downstream sums (`TM + TP + ΥI`) and the slot ceil cannot overflow
///   to `inf` and corrupt the argmin.
pub const BW_SENTINEL_MB_S: f32 = 1e12;

/// Row-major (m x n) problem for the cost model.
#[derive(Debug, Clone)]
pub struct CostInputs {
    pub m: usize,
    pub n: usize,
    /// split sizes, MB — len m
    pub sz: Vec<f32>,
    /// effective bandwidth source->node, MB/s — len m*n; <= 0 = no path
    pub bw: Vec<f32>,
    /// compute times TP, s — len m*n
    pub tp: Vec<f32>,
    /// replica locality mask (1.0 local) — len m*n
    pub local: Vec<f32>,
    /// node idle times ΥI, s — len n
    pub idle: Vec<f32>,
    /// time-slot duration, s
    pub ts: f32,
}

impl CostInputs {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.sz.len() == self.m, "sz len");
        anyhow::ensure!(self.bw.len() == self.m * self.n, "bw len");
        anyhow::ensure!(self.tp.len() == self.m * self.n, "tp len");
        anyhow::ensure!(self.local.len() == self.m * self.n, "local len");
        anyhow::ensure!(self.idle.len() == self.n, "idle len");
        anyhow::ensure!(self.ts > 0.0, "ts must be positive");
        Ok(())
    }
}

/// Outputs (see ref.py): YC/TM/slot matrices + per-task argmin.
#[derive(Debug, Clone)]
pub struct CostOutputs {
    pub m: usize,
    pub n: usize,
    pub yc: Vec<f32>,
    pub tm: Vec<f32>,
    pub slots: Vec<f32>,
    pub best_idx: Vec<i32>,
    pub best_cost: Vec<f32>,
}

impl CostOutputs {
    pub fn yc_at(&self, i: usize, j: usize) -> f32 {
        self.yc[i * self.n + j]
    }

    pub fn tm_at(&self, i: usize, j: usize) -> f32 {
        self.tm[i * self.n + j]
    }

    pub fn slots_at(&self, i: usize, j: usize) -> f32 {
        self.slots[i * self.n + j]
    }

    /// Task `i`'s TM row as one contiguous slice — the cache-friendly
    /// view for per-task scans over all nodes (BASS's minnow loop walks
    /// this instead of issuing an indexed `tm_at` per node; the node
    /// axis is the matrix's fast axis, so the scan is a linear read).
    pub fn tm_row(&self, i: usize) -> &[f32] {
        &self.tm[i * self.n..(i + 1) * self.n]
    }

    /// Task `i`'s ΥC row as one contiguous slice (same layout guarantee
    /// as [`CostOutputs::tm_row`]).
    pub fn yc_row(&self, i: usize) -> &[f32] {
        &self.yc[i * self.n..(i + 1) * self.n]
    }
}

/// Which engine computed the result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Xla,
    RustFallback,
}

/// The cost model: tries the XLA artifacts, falls back to Rust.
pub struct CostModel {
    artifacts: Option<Artifacts>,
}

impl CostModel {
    /// Load from the default artifacts dir; silently falls back to the
    /// Rust evaluator when artifacts are missing.
    pub fn auto() -> Self {
        let artifacts = Artifacts::open(&default_artifacts_dir()).ok();
        Self { artifacts }
    }

    /// Force the pure-Rust backend (unit tests, what-if copies).
    pub fn rust_only() -> Self {
        Self { artifacts: None }
    }

    /// Load from an explicit directory (errors if unusable).
    pub fn from_dir(dir: &std::path::Path) -> Result<Self> {
        Ok(Self { artifacts: Some(Artifacts::open(dir)?) })
    }

    pub fn backend_for(&self, m: usize, n: usize) -> Backend {
        match &self.artifacts {
            Some(a) if a.pick(m, n).is_some() => Backend::Xla,
            _ => Backend::RustFallback,
        }
    }

    /// Evaluate Eq. 1-3 for the batch.
    pub fn eval(&self, inp: &CostInputs) -> Result<CostOutputs> {
        inp.validate()?;
        match &self.artifacts {
            Some(a) => match a.pick(inp.m, inp.n) {
                Some(v) => self.eval_xla(a, v.clone(), inp),
                None => Ok(Self::eval_rust(inp)),
            },
            None => Ok(Self::eval_rust(inp)),
        }
    }

    /// Pure-Rust mirror of kernels/ref.py, f32 arithmetic.
    pub fn eval_rust(inp: &CostInputs) -> CostOutputs {
        let (m, n) = (inp.m, inp.n);
        let mut yc = vec![0f32; m * n];
        let mut tm = vec![0f32; m * n];
        let mut slots = vec![0f32; m * n];
        let mut best_idx = vec![0i32; m];
        let mut best_cost = vec![INF; m];
        for i in 0..m {
            let mut bi = 0usize;
            let mut bc = f32::INFINITY;
            for j in 0..n {
                let k = i * n + j;
                let mut t = inp.sz[i] / inp.bw[k].max(EPS);
                if inp.bw[k] <= 0.0 {
                    t = INF;
                }
                if inp.local[k] > 0.0 {
                    t = 0.0;
                }
                tm[k] = t;
                let c = t + inp.tp[k] + inp.idle[j];
                yc[k] = c;
                slots[k] = if t >= INF { INF } else { (t / inp.ts.max(EPS)).ceil() };
                if c < bc {
                    bc = c;
                    bi = j;
                }
            }
            best_idx[i] = bi as i32;
            best_cost[i] = bc;
        }
        CostOutputs { m, n, yc, tm, slots, best_idx, best_cost }
    }

    /// Pad to the artifact variant, execute via PJRT, slice back.
    fn eval_xla(
        &self,
        arts: &Artifacts,
        v: super::loader::Variant,
        inp: &CostInputs,
    ) -> Result<CostOutputs> {
        let (m, n) = (inp.m, inp.n);
        let (pm, pn) = (v.m, v.n);
        // padding: extra nodes get idle=INF so they never win the argmin;
        // extra tasks produce junk rows that are sliced away.
        let mut sz = vec![0f32; pm];
        sz[..m].copy_from_slice(&inp.sz);
        let mut idle = vec![INF; pn];
        idle[..n].copy_from_slice(&inp.idle);
        let pad_mat = |src: &[f32], fill: f32| -> Vec<f32> {
            let mut out = vec![fill; pm * pn];
            for i in 0..m {
                out[i * pn..i * pn + n].copy_from_slice(&src[i * n..(i + 1) * n]);
            }
            out
        };
        let bw = pad_mat(&inp.bw, 1.0);
        let tp = pad_mat(&inp.tp, 0.0);
        let local = pad_mat(&inp.local, 0.0);

        let exe = arts.executable(&v)?;
        let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("literal reshape: {e}"))
        };
        let args = [
            lit(&sz, &[pm as i64])?,
            lit(&bw, &[pm as i64, pn as i64])?,
            lit(&tp, &[pm as i64, pn as i64])?,
            lit(&local, &[pm as i64, pn as i64])?,
            lit(&idle, &[pn as i64])?,
            lit(&[inp.ts], &[1])?,
        ];
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        anyhow::ensure!(parts.len() == 5, "expected 5 outputs, got {}", parts.len());
        let f32v = |l: &xla::Literal| -> Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("f32 out: {e}"))
        };
        let yc_p = f32v(&parts[0])?;
        let tm_p = f32v(&parts[1])?;
        let slots_p = f32v(&parts[2])?;
        let idx_p: Vec<i32> =
            parts[3].to_vec::<i32>().map_err(|e| anyhow::anyhow!("i32 out: {e}"))?;
        let cost_p = f32v(&parts[4])?;

        // slice padded (pm x pn) back to (m x n)
        let unpad = |src: &[f32]| -> Vec<f32> {
            let mut out = Vec::with_capacity(m * n);
            for i in 0..m {
                out.extend_from_slice(&src[i * pn..i * pn + n]);
            }
            out
        };
        Ok(CostOutputs {
            m,
            n,
            yc: unpad(&yc_p),
            tm: unpad(&tm_p),
            slots: unpad(&slots_p),
            best_idx: idx_p[..m].to_vec(),
            best_cost: cost_p[..m].to_vec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn example1_tk1() -> CostInputs {
        // the paper's canonical TK1 decision (see python tests)
        CostInputs {
            m: 1,
            n: 4,
            sz: vec![64.0],
            bw: vec![12.8; 4],
            tp: vec![9.0; 4],
            local: vec![0.0, 1.0, 1.0, 0.0],
            idle: vec![3.0, 9.0, 20.0, 7.0],
            ts: 1.0,
        }
    }

    pub fn random_inputs(m: usize, n: usize, seed: u64) -> CostInputs {
        let mut r = XorShift::new(seed);
        CostInputs {
            m,
            n,
            sz: (0..m).map(|_| r.uniform(0.0, 5000.0) as f32).collect(),
            bw: (0..m * n).map(|_| r.uniform(-5.0, 120.0) as f32).collect(),
            tp: (0..m * n).map(|_| r.uniform(0.0, 900.0) as f32).collect(),
            local: (0..m * n).map(|_| if r.chance(0.3) { 1.0 } else { 0.0 }).collect(),
            idle: (0..n).map(|_| r.uniform(0.0, 200.0) as f32).collect(),
            ts: 1.0,
        }
    }

    #[test]
    fn rust_eval_paper_tk1() {
        let out = CostModel::eval_rust(&example1_tk1());
        assert_eq!(out.yc_at(0, 0), 17.0); // remote ND1: 5+9+3
        assert_eq!(out.yc_at(0, 1), 18.0); // local ND2: 0+9+9
        assert_eq!(out.best_idx[0], 0);
        assert_eq!(out.slots_at(0, 0), 5.0);
        assert_eq!(out.tm_at(0, 1), 0.0);
    }

    #[test]
    fn rust_eval_unreachable() {
        let mut inp = example1_tk1();
        inp.bw = vec![-1.0; 4];
        inp.local = vec![0.0; 4];
        let out = CostModel::eval_rust(&inp);
        for j in 0..4 {
            assert!(out.yc_at(0, j) >= INF);
        }
    }

    #[test]
    fn validate_rejects_bad_lengths() {
        let mut inp = example1_tk1();
        inp.idle.pop();
        assert!(inp.validate().is_err());
    }

    #[test]
    fn xla_matches_rust_bitwise() {
        let model = CostModel::auto();
        if model.backend_for(9, 4) != Backend::Xla {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        for seed in 1..=8u64 {
            let inp = random_inputs(9, 4, seed);
            let xla_out = model.eval(&inp).unwrap();
            let rust_out = CostModel::eval_rust(&inp);
            assert_eq!(xla_out.yc, rust_out.yc, "yc mismatch seed={seed}");
            assert_eq!(xla_out.tm, rust_out.tm, "tm mismatch seed={seed}");
            assert_eq!(xla_out.slots, rust_out.slots, "slots mismatch seed={seed}");
            assert_eq!(xla_out.best_idx, rust_out.best_idx, "idx mismatch seed={seed}");
            assert_eq!(xla_out.best_cost, rust_out.best_cost, "cost mismatch seed={seed}");
        }
    }

    #[test]
    fn xla_padding_never_picks_padded_node() {
        let model = CostModel::auto();
        if model.backend_for(3, 3) != Backend::Xla {
            eprintln!("skipping: no artifacts");
            return;
        }
        // 3 nodes in a 16x8 artifact: 5 padded node columns
        let inp = random_inputs(3, 3, 99);
        let out = model.eval(&inp).unwrap();
        for i in 0..3 {
            assert!((out.best_idx[i] as usize) < 3, "picked padded node");
        }
    }

    #[test]
    fn xla_variant_boundary_exact_fit() {
        let model = CostModel::auto();
        if model.backend_for(16, 8) != Backend::Xla {
            eprintln!("skipping: no artifacts");
            return;
        }
        let inp = random_inputs(16, 8, 5);
        let a = model.eval(&inp).unwrap();
        let b = CostModel::eval_rust(&inp);
        assert_eq!(a.yc, b.yc);
        assert_eq!(a.best_idx, b.best_idx);
    }
}
