//! Artifact discovery and PJRT compilation cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// Artifact variants as listed in `artifacts/manifest.txt`
/// (`cost M N file` / `idle 0 N file` rows emitted by aot.py).
#[derive(Debug, Clone)]
pub struct Variant {
    pub m: usize,
    pub n: usize,
    pub path: PathBuf,
}

/// The artifacts directory: manifest + lazily compiled executables.
pub struct Artifacts {
    client: xla::PjRtClient,
    cost_variants: Vec<Variant>,
    /// (m, n) -> compiled executable, compiled on first use.
    compiled: Mutex<HashMap<(usize, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

/// Default artifacts dir: `$BASS_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("BASS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

impl Artifacts {
    /// Open a directory produced by `make artifacts`. Fails if the
    /// manifest is missing or empty (callers then use the Rust fallback).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut cost_variants = Vec::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            let (Some(kind), Some(m), Some(n), Some(file)) =
                (it.next(), it.next(), it.next(), it.next())
            else {
                continue;
            };
            if kind != "cost" {
                continue;
            }
            cost_variants.push(Variant {
                m: m.parse().context("manifest m")?,
                n: n.parse().context("manifest n")?,
                path: dir.join(file),
            });
        }
        anyhow::ensure!(!cost_variants.is_empty(), "no cost artifacts in manifest");
        // smallest first so pick() finds the tightest fit
        cost_variants.sort_by_key(|v| (v.m, v.n));
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Self { client, cost_variants, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn variants(&self) -> &[Variant] {
        &self.cost_variants
    }

    /// Smallest variant with `m >= tasks` and `n >= nodes`.
    pub fn pick(&self, tasks: usize, nodes: usize) -> Option<&Variant> {
        self.cost_variants.iter().find(|v| v.m >= tasks && v.n >= nodes)
    }

    /// Compile (or fetch cached) the executable for a variant.
    pub fn executable(
        &self,
        v: &Variant,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.compiled.lock().unwrap();
        if let Some(e) = cache.get(&(v.m, v.n)) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&v.path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", v.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", v.path.display()))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert((v.m, v.n), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts dir for tests: repo-root relative.
    pub fn test_dir() -> PathBuf {
        let mut d = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        d.push("artifacts");
        d
    }

    #[test]
    fn open_and_pick() {
        let dir = test_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let a = Artifacts::open(&dir).unwrap();
        assert!(!a.variants().is_empty());
        let v = a.pick(9, 4).expect("16x8 variant should fit 9x4");
        assert!(v.m >= 9 && v.n >= 4);
        // smallest-fit: 16x8 if present
        assert_eq!((v.m, v.n), (16, 8));
        assert!(a.pick(10_000, 4).is_none());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Artifacts::open(Path::new("/nonexistent")).is_err());
    }
}
