//! Small shared utilities: deterministic RNG, time arithmetic, unit helpers.

pub mod rng;
pub mod time;
pub mod units;

pub use rng::XorShift;
pub use time::Secs;
pub use units::{mb_per_s, mbps_to_mb_per_s, BLOCK_MB};
