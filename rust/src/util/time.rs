//! Simulation time: f64 seconds with a total order for event queues.

use std::cmp::Ordering;

/// Simulation timestamp / duration in seconds.
///
/// Wraps `f64` so it can carry a total order (`total_cmp`) and be used as
/// a `BinaryHeap` key. All paper quantities (`TM`, `TP`, `ΥI`, `ΥC`) are
/// seconds, so no unit conversions leak into the schedulers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Secs(pub f64);

impl Secs {
    pub const ZERO: Secs = Secs(0.0);
    /// Sentinel "never" / unreachable (matches the f32 INF of the L1/L2
    /// cost model when cast down).
    pub const INF: Secs = Secs(3.0e38);

    pub fn max(self, other: Secs) -> Secs {
        Secs(self.0.max(other.0))
    }

    pub fn min(self, other: Secs) -> Secs {
        Secs(self.0.min(other.0))
    }

    pub fn is_finite(self) -> bool {
        self.0.is_finite() && self.0 < Self::INF.0
    }
}

impl Eq for Secs {}

impl Ord for Secs {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl PartialOrd for Secs {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl std::ops::Add for Secs {
    type Output = Secs;
    fn add(self, rhs: Secs) -> Secs {
        Secs(self.0 + rhs.0)
    }
}

impl std::ops::Sub for Secs {
    type Output = Secs;
    fn sub(self, rhs: Secs) -> Secs {
        Secs(self.0 - rhs.0)
    }
}

impl std::ops::AddAssign for Secs {
    fn add_assign(&mut self, rhs: Secs) {
        self.0 += rhs.0;
    }
}

impl std::fmt::Display for Secs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2}s", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_total() {
        let mut v = vec![Secs(3.0), Secs(1.0), Secs(2.0), Secs::ZERO];
        v.sort();
        assert_eq!(v, vec![Secs(0.0), Secs(1.0), Secs(2.0), Secs(3.0)]);
    }

    #[test]
    fn arithmetic() {
        assert_eq!(Secs(1.5) + Secs(2.5), Secs(4.0));
        assert_eq!(Secs(5.0) - Secs(2.0), Secs(3.0));
        assert_eq!(Secs(1.0).max(Secs(2.0)), Secs(2.0));
        assert_eq!(Secs(1.0).min(Secs(2.0)), Secs(1.0));
    }

    #[test]
    fn inf_is_not_finite() {
        assert!(!Secs::INF.is_finite());
        assert!(Secs(12.0).is_finite());
    }
}
