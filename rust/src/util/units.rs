//! Unit conversions shared by the paper's formulas.
//!
//! The paper quotes link rates in Mbps and block sizes in MB; Eq. 1
//! (`TM = SZ / BW`) needs both in consistent units. We standardize on
//! **MB and MB/s**, and we use the paper's own simplification: Example 1
//! rounds 64MB / 100Mbps = 5.12s down to 5s, i.e. it treats 100 Mbps as
//! 12.8 MB/s and 64/12.8 = 5.0 exactly. We therefore convert with the
//! decimal factor 8 (1 MB/s = 8 Mbps), matching the paper's arithmetic.

/// HDFS block size used throughout the paper (MB).
pub const BLOCK_MB: f64 = 64.0;

/// Mbps -> MB/s (decimal, paper-consistent: 100 Mbps = 12.5 MB/s).
///
/// Note: with 12.5 MB/s a 64MB block takes 5.12s; the paper's Example 1
/// then rounds to 5s. Experiment configs that must hit the example's
/// integer arithmetic use [`mb_per_s`] with an explicit rate instead.
pub fn mbps_to_mb_per_s(mbps: f64) -> f64 {
    mbps / 8.0
}

/// Explicit MB/s constructor for calibrated experiment configs.
pub fn mb_per_s(v: f64) -> f64 {
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_block_transfer_time() {
        // 64MB over 100Mbps = 5.12s (the paper's pre-rounding figure).
        let t = BLOCK_MB / mbps_to_mb_per_s(100.0);
        assert!((t - 5.12).abs() < 1e-9);
    }

    #[test]
    fn example1_simplified_rate() {
        // Example 1 uses TM = 5s for a 64MB block -> 12.8 MB/s effective.
        let t = BLOCK_MB / mb_per_s(12.8);
        assert!((t - 5.0).abs() < 1e-12);
    }
}
