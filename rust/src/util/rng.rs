//! Deterministic xorshift64* RNG.
//!
//! No `rand` crate is vendored in the offline image, and the experiments
//! must be exactly reproducible anyway, so every randomized component
//! (workload generation, HDFS placement, HDS's random remote pick, the
//! property-test generators in [`crate::testkit`]) draws from this one
//! seeded generator.

/// xorshift64* — tiny, fast, passes BigCrush on the high bits.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Seed must be non-zero; 0 is mapped to a fixed odd constant.
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform in `[lo, hi)` as f64. `hi > lo` required.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi > lo);
        let unit = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        lo + unit * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform(0.0, 1.0) < p
    }

    /// Pick `k` distinct indices out of `n` (k <= n), Floyd's algorithm.
    pub fn distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot pick {k} distinct out of {n}");
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn uniform_in_range_and_covers() {
        let mut r = XorShift::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..5000 {
            let x = r.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&x));
            lo_seen |= x < 2.2;
            hi_seen |= x > 3.8;
        }
        assert!(lo_seen && hi_seen, "samples should cover the range");
    }

    #[test]
    fn distinct_are_distinct_and_in_range() {
        let mut r = XorShift::new(3);
        for _ in 0..200 {
            let ks = r.distinct(10, 4);
            assert_eq!(ks.len(), 4);
            for &k in &ks {
                assert!(k < 10);
            }
            let mut s = ks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 4, "duplicates in {ks:?}");
        }
    }

    #[test]
    fn distinct_full_set() {
        let mut r = XorShift::new(5);
        let mut ks = r.distinct(6, 6);
        ks.sort_unstable();
        assert_eq!(ks, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift::new(11);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }
}
