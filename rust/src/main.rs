//! `bass` CLI — see `bass help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(bass::cli::run(args));
}
