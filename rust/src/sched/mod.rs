//! The paper's contribution: task schedulers.
//!
//! * [`hds`] — Hadoop Default Scheduler: node-driven greedy locality.
//! * [`bar`] — BAlance-Reduce (Jin et al., CCGrid'11): HDS first phase +
//!   global tuning of the latest task.
//! * [`bass`] — **BASS** (Algorithm 1): bandwidth-aware local/remote
//!   tradeoff with SDN time-slot reservations.
//! * [`pre_bass`] — Pre-BASS (Discussion 2): BASS + input prefetching.
//!
//! All schedulers consume the same [`SchedCtx`] and emit a
//! [`crate::sim::Assignment`] the engine can execute.

pub mod bar;
pub mod bass;
pub mod cost;
pub mod hds;
pub mod kind;
pub mod pre_bass;
pub mod types;

pub use bar::Bar;
pub use bass::Bass;
pub use hds::Hds;
pub use kind::SchedulerKind;
pub use pre_bass::PreBass;
pub use types::{SchedCtx, Scheduler};
