//! Scheduler trait and shared context.

use crate::cluster::Ledger;
use crate::hdfs::{BlockId, Namenode};
use crate::mapreduce::TaskSpec;
use crate::runtime::CostModel;
use crate::sdn::{BandwidthView, Controller};
use crate::sim::Assignment;
use crate::topology::NodeId;
use crate::util::Secs;

/// Everything a scheduler may look at / mutate while assigning one batch
/// of tasks. The ledger and controller are *live*: placements update them
/// so subsequent batches (e.g. the reduce phase) see the load.
pub struct SchedCtx<'a> {
    pub controller: &'a mut Controller,
    /// The bandwidth knowledge the scheduler is allowed: `Oracle` (the
    /// clairvoyant default, bit-identical to reading the controller
    /// directly) or a `Measured` view over probe estimates (DESIGN.md
    /// §12). Reservation *grants* still go through the controller — the
    /// view only shapes what the scheduler believes about capacity.
    pub view: &'a dyn BandwidthView,
    pub namenode: &'a Namenode,
    pub ledger: &'a mut Ledger,
    /// Nodes this job may use (the paper's shared-cluster subset; Case 2
    /// locality-starvation arises when replicas fall outside this set).
    pub authorized: Vec<NodeId>,
    pub now: Secs,
    pub cost: &'a CostModel,
    /// Per-node compute-speed factors (Guo & Fox [14]-style heterogeneous
    /// clusters): `TP_{i,j} = t.compute * speed[j]`. Empty = homogeneous.
    pub node_speed: Vec<f64>,
    /// Per-host "currently crashed" flags (dynamics rounds set this from
    /// the incident timeline). Empty = every host healthy. A down host
    /// can neither run tasks (the authorized set excludes it) nor *serve
    /// replica reads* — transfer sources are filtered through it.
    pub down: Vec<bool>,
    /// Replica-selection rule for remote pulls: `true` (the default) asks
    /// the SDN controller for the holder with the best current path
    /// bandwidth to the destination (the paper's thesis — the bandwidth
    /// view, not node load, drives source choice); `false` replays the
    /// seed's idle-only rule (Discussion 2 taken literally), kept as an
    /// ablation and as the 1-replica equivalence reference.
    pub bw_aware_sources: bool,
}

impl<'a> SchedCtx<'a> {
    /// `TP_{i,j}` for a task on a node (the heterogeneity hook).
    pub fn effective_compute(&self, t: &TaskSpec, node: NodeId) -> Secs {
        match self.node_speed.get(node.0) {
            Some(&f) if f > 0.0 => Secs(t.compute.0 * f),
            _ => t.compute,
        }
    }

    /// Per-authorized-column compute-speed factors, hoisted once per
    /// scheduling round so per-(task, node) loops multiply a cached
    /// factor instead of re-resolving `node_speed` (Perf L4). `None`
    /// means the homogeneous default; applying `speed_cols()[j]` to
    /// `t.compute` reproduces [`SchedCtx::effective_compute`] exactly.
    pub fn speed_cols(&self) -> Vec<Option<f64>> {
        self.authorized
            .iter()
            .map(|nd| match self.node_speed.get(nd.0) {
                Some(&f) if f > 0.0 => Some(f),
                _ => None,
            })
            .collect()
    }

    /// Host-id → authorized-column reverse map (`usize::MAX` = not
    /// authorized), hoisted once per scheduling round — the shared O(1)
    /// replacement for per-decision `cost::col_of` scans.
    pub fn authorized_cols(&self) -> Vec<usize> {
        let mut cols = vec![usize::MAX; self.ledger.n_nodes()];
        for (c, &nd) in self.authorized.iter().enumerate() {
            cols[nd.0] = c;
        }
        cols
    }

    /// Can `node` currently serve replica reads? (not crashed)
    pub fn is_readable(&self, node: NodeId) -> bool {
        !self.down.get(node.0).copied().unwrap_or(false)
    }

    /// Local candidates of a task within the authorized set.
    pub fn local_nodes(&self, t: &TaskSpec) -> Vec<NodeId> {
        match t.input {
            Some(b) => self.namenode.local_candidates(b, &self.authorized).collect(),
            None => match t.src_hint {
                // a reduce is "local" where its shuffle majority sits
                Some(s) if self.authorized.contains(&s) => vec![s],
                _ => vec![],
            },
        }
    }

    /// The replica to pull from when `t` runs remotely **on `dst`**.
    /// Under the bandwidth-aware rule this is the readable holder with
    /// the maximum current path bandwidth to `dst` (`BW_rl` from the SDN
    /// controller at `now`), ties broken by minimum idle time, then by
    /// replica order; under the legacy rule it is the least-loaded
    /// readable holder regardless of `dst`. Reduces use their shuffle
    /// hint. `None` = no readable source at all (block unreadable, or a
    /// hint-less reduce).
    pub fn transfer_source_for(&self, t: &TaskSpec, dst: NodeId) -> Option<NodeId> {
        match t.input {
            Some(b) => {
                if self.bw_aware_sources {
                    self.best_replica(b, dst)
                } else {
                    self.min_idle_replica(b)
                }
            }
            None => t.src_hint.filter(|&s| self.is_readable(s)),
        }
    }

    /// Argmax-path-bandwidth readable holder for a block, pulling toward
    /// `dst`. A holder that *is* `dst` wins outright (infinite local
    /// bandwidth), which keeps the matrix and the sequential pass
    /// consistent with the locality mask.
    pub fn best_replica(&self, b: BlockId, dst: NodeId) -> Option<NodeId> {
        let mut best: Option<(NodeId, f64, f64)> = None; // (holder, bw, idle)
        for r in self.namenode.readable_replicas(b, |n| self.is_readable(n)) {
            // unreachable holders price as 0.0 (not skipped): with *no*
            // routable holder the historical argmax still returns one and
            // the transfer fails downstream, which callers already handle
            let bw = self.view.path_bw_mb_s(self.controller, r, dst, self.now);
            let idle = self.ledger.idle(r).0;
            let better = match best {
                None => true,
                Some((_, bbw, bidle)) => bw > bbw || (bw == bbw && idle < bidle),
            };
            if better {
                best = Some((r, bw, idle));
            }
        }
        best.map(|(r, _, _)| r)
    }

    /// The legacy idle-only source (Discussion 2 taken literally), health
    /// filtered.
    pub fn min_idle_replica(&self, b: BlockId) -> Option<NodeId> {
        self.namenode
            .least_loaded_replica(b, |n| self.is_readable(n), |n| self.ledger.idle(n).0)
    }

    /// Nominal transfer time estimate at current line rates (no slot
    /// reservation; what HDS/BAR reason with). `None` if unroutable.
    pub fn tm_estimate(&self, src: NodeId, dst: NodeId, size_mb: f64) -> Option<Secs> {
        if src == dst || size_mb <= 0.0 {
            return Some(Secs::ZERO);
        }
        let links = self.controller.path(src, dst)?;
        let cap = self.view.path_capacity_mb_s(self.controller, &links);
        if cap <= 0.0 {
            return None;
        }
        Some(Secs(size_mb / cap))
    }
}

/// A task scheduler (one of the paper's four).
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Assign `tasks`, mutating the ledger/controller through `ctx`.
    /// `gate` carries the earliest start for this batch (reduce phases).
    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment;
}
