//! Scheduler trait and shared context.

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::TaskSpec;
use crate::runtime::CostModel;
use crate::sdn::Controller;
use crate::sim::Assignment;
use crate::topology::NodeId;
use crate::util::Secs;

/// Everything a scheduler may look at / mutate while assigning one batch
/// of tasks. The ledger and controller are *live*: placements update them
/// so subsequent batches (e.g. the reduce phase) see the load.
pub struct SchedCtx<'a> {
    pub controller: &'a mut Controller,
    pub namenode: &'a Namenode,
    pub ledger: &'a mut Ledger,
    /// Nodes this job may use (the paper's shared-cluster subset; Case 2
    /// locality-starvation arises when replicas fall outside this set).
    pub authorized: Vec<NodeId>,
    pub now: Secs,
    pub cost: &'a CostModel,
    /// Per-node compute-speed factors (Guo & Fox [14]-style heterogeneous
    /// clusters): `TP_{i,j} = t.compute * speed[j]`. Empty = homogeneous.
    pub node_speed: Vec<f64>,
}

impl<'a> SchedCtx<'a> {
    /// `TP_{i,j}` for a task on a node (the heterogeneity hook).
    pub fn effective_compute(&self, t: &TaskSpec, node: NodeId) -> Secs {
        match self.node_speed.get(node.0) {
            Some(&f) if f > 0.0 => Secs(t.compute.0 * f),
            _ => t.compute,
        }
    }

    /// Per-authorized-column compute-speed factors, hoisted once per
    /// scheduling round so per-(task, node) loops multiply a cached
    /// factor instead of re-resolving `node_speed` (Perf L4). `None`
    /// means the homogeneous default; applying `speed_cols()[j]` to
    /// `t.compute` reproduces [`SchedCtx::effective_compute`] exactly.
    pub fn speed_cols(&self) -> Vec<Option<f64>> {
        self.authorized
            .iter()
            .map(|nd| match self.node_speed.get(nd.0) {
                Some(&f) if f > 0.0 => Some(f),
                _ => None,
            })
            .collect()
    }

    /// Host-id → authorized-column reverse map (`usize::MAX` = not
    /// authorized), hoisted once per scheduling round — the shared O(1)
    /// replacement for per-decision `cost::col_of` scans.
    pub fn authorized_cols(&self) -> Vec<usize> {
        let mut cols = vec![usize::MAX; self.ledger.n_nodes()];
        for (c, &nd) in self.authorized.iter().enumerate() {
            cols[nd.0] = c;
        }
        cols
    }

    /// Local candidates of a task within the authorized set.
    pub fn local_nodes(&self, t: &TaskSpec) -> Vec<NodeId> {
        match t.input {
            Some(b) => self.namenode.local_candidates(b, &self.authorized).collect(),
            None => match t.src_hint {
                // a reduce is "local" where its shuffle majority sits
                Some(s) if self.authorized.contains(&s) => vec![s],
                _ => vec![],
            },
        }
    }

    /// The replica to pull from when running remotely (Discussion 2:
    /// least-loaded holder). Reduces use their src_hint.
    pub fn transfer_source(&self, t: &TaskSpec) -> Option<NodeId> {
        match t.input {
            Some(b) => {
                Some(self.namenode.least_loaded_replica(b, |n| self.ledger.idle(n).0))
            }
            None => t.src_hint,
        }
    }

    /// Nominal transfer time estimate at current line rates (no slot
    /// reservation; what HDS/BAR reason with). `None` if unroutable.
    pub fn tm_estimate(&self, src: NodeId, dst: NodeId, size_mb: f64) -> Option<Secs> {
        if src == dst || size_mb <= 0.0 {
            return Some(Secs::ZERO);
        }
        let links = self.controller.path(src, dst)?;
        let cap = self.controller.path_capacity_mb_s(links);
        if cap <= 0.0 {
            return None;
        }
        Some(Secs(size_mb / cap))
    }
}

/// A task scheduler (one of the paper's four).
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Assign `tasks`, mutating the ledger/controller through `ctx`.
    /// `gate` carries the earliest start for this batch (reduce phases).
    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment;
}
