//! Bridge between scheduler state and the L1/L2 cost model.
//!
//! [`build_inputs`] assembles the (m x n) [`CostInputs`] batch from the
//! SDN bandwidth snapshot, the namenode's locality map and the ledger —
//! the exact tensor the AOT JAX/Pallas artifact consumes. BASS calls this
//! once per scheduling round (the XLA hot path); the per-task sequential
//! refinement then works off the returned TM matrix.
//!
//! The bandwidth row of a map task is the **element-wise best over its
//! readable replica holders**: `bw[i][j] = max_s path_bw(s, j)` — each
//! candidate node is costed against the holder it would actually pull
//! from ([`SchedCtx::transfer_source_for`] resolves the same argmax for
//! the committed pull). The seed resolved one idle-chosen holder per
//! task, so the matrix never saw a better-connected replica; the legacy
//! rule is preserved under `ctx.bw_aware_sources = false` (single
//! min-idle source row), which 1-replica layouts make bit-identical.

use crate::mapreduce::TaskSpec;
use crate::runtime::exec::BW_SENTINEL_MB_S;
use crate::runtime::{CostInputs, CostOutputs};
use crate::topology::NodeId;

use super::types::SchedCtx;

/// One holder's bandwidth row over the authorized columns, f32-capped.
fn bw_row(ctx: &SchedCtx<'_>, src: NodeId) -> Vec<f32> {
    ctx.authorized
        .iter()
        .map(|&nd| {
            let b = ctx.view.path_bw_mb_s(ctx.controller, src, nd, ctx.now);
            if b.is_infinite() {
                BW_SENTINEL_MB_S
            } else {
                b as f32
            }
        })
        .collect()
}

/// Cross-chunk bandwidth-row memo: rows depend only on the (immutable)
/// context, so one memo may serve every chunk of a blocked evaluation.
#[derive(Default)]
struct RowMemo {
    /// One bandwidth row per holder.
    holder_rows: std::collections::HashMap<NodeId, Vec<f32>>,
    /// One element-wise-best row per block (bw-aware rule).
    block_rows: std::collections::HashMap<crate::hdfs::BlockId, Option<Vec<f32>>>,
}

/// The batched kernel behind [`build_inputs`]: three blocked passes over
/// the flat row-major buffers (the `python/compile` cost-matrix layout)
/// instead of one interleaved per-cell loop.
///
/// * **TP** — each task's compute time broadcast through the hoisted
///   per-column speed factors (same expression per cell as the rowwise
///   reference, so bit-identical).
/// * **local** — zero-filled, then 1.0 scattered at each task's local
///   columns via the hoisted host→column map. Local candidates are
///   authorized by construction, so the scatter marks exactly the
///   columns the rowwise `contains` test marked.
/// * **bw** — one combined row per block (or per source holder under
///   the legacy/reduce rules), computed once through the memo and
///   **copied** into every task row sharing it; copies, not
///   recomputation, keep the pass bitwise equal.
fn fill_inputs(tasks: &[TaskSpec], ctx: &SchedCtx<'_>, memo: &mut RowMemo) -> CostInputs {
    let m = tasks.len();
    let nodes = &ctx.authorized;
    let n = nodes.len();
    let mut sz = Vec::with_capacity(m);
    let mut bw = vec![0f32; m * n];
    let mut tp = vec![0f32; m * n];
    let mut local = vec![0f32; m * n];
    let speed = ctx.speed_cols();
    let cols = ctx.authorized_cols();
    for (i, t) in tasks.iter().enumerate() {
        sz.push(t.input_mb as f32);
        let row = &mut tp[i * n..(i + 1) * n];
        for (j, cell) in row.iter_mut().enumerate() {
            *cell = match speed[j] {
                Some(f) => (t.compute.0 * f) as f32,
                None => t.compute.0 as f32,
            };
        }
    }
    for (i, t) in tasks.iter().enumerate() {
        for nd in ctx.local_nodes(t) {
            local[i * n + cols[nd.0]] = 1.0;
        }
    }
    let RowMemo { holder_rows, block_rows } = memo;
    for (i, t) in tasks.iter().enumerate() {
        let row: Option<&[f32]> = match t.input {
            Some(b) if ctx.bw_aware_sources => block_rows
                .entry(b)
                .or_insert_with(|| {
                    let mut combined: Option<Vec<f32>> = None;
                    for s in
                        ctx.namenode.readable_replicas(b, |nd| ctx.is_readable(nd))
                    {
                        let r = holder_rows
                            .entry(s)
                            .or_insert_with(|| bw_row(ctx, s))
                            .clone();
                        combined = Some(match combined {
                            None => r,
                            Some(mut c) => {
                                for (cv, rv) in c.iter_mut().zip(&r) {
                                    if *rv > *cv {
                                        *cv = *rv;
                                    }
                                }
                                c
                            }
                        });
                    }
                    combined
                })
                .as_deref(),
            // legacy idle-only rule, and reduces (single hinted source)
            _ => {
                let src = match t.input {
                    Some(b) => ctx.min_idle_replica(b),
                    None => t.src_hint.filter(|&s| ctx.is_readable(s)),
                };
                src.map(|s| holder_rows.entry(s).or_insert_with(|| bw_row(ctx, s)).as_slice())
            }
        };
        if let Some(r) = row {
            bw[i * n..(i + 1) * n].copy_from_slice(r);
        }
    }
    let idle: Vec<f32> = nodes.iter().map(|&nd| ctx.ledger.idle(nd).0 as f32).collect();
    CostInputs { m, n, sz, bw, tp, local, idle, ts: ctx.controller.calendar.slot_secs() as f32 }
}

/// Build the batched cost-model inputs for `tasks` over the authorized
/// node set, in authorized-set column order.
pub fn build_inputs(tasks: &[TaskSpec], ctx: &SchedCtx<'_>) -> CostInputs {
    fill_inputs(tasks, ctx, &mut RowMemo::default())
}

/// Reference per-task builder: the pre-batching implementation, kept
/// verbatim so property tests can pin the batched [`build_inputs`]
/// bitwise against it (`rust/tests/proptests.rs`).
pub fn build_inputs_rowwise(tasks: &[TaskSpec], ctx: &SchedCtx<'_>) -> CostInputs {
    let m = tasks.len();
    let nodes = &ctx.authorized;
    let n = nodes.len();
    let mut sz = Vec::with_capacity(m);
    let mut bw = vec![0f32; m * n];
    let mut tp = vec![0f32; m * n];
    let mut local = vec![0f32; m * n];
    let speed = ctx.speed_cols();
    let mut holder_rows: std::collections::HashMap<NodeId, Vec<f32>> =
        std::collections::HashMap::new();
    let mut block_rows: std::collections::HashMap<crate::hdfs::BlockId, Option<Vec<f32>>> =
        std::collections::HashMap::new();
    for (i, t) in tasks.iter().enumerate() {
        sz.push(t.input_mb as f32);
        let row: Option<&[f32]> = match t.input {
            Some(b) if ctx.bw_aware_sources => block_rows
                .entry(b)
                .or_insert_with(|| {
                    let mut combined: Option<Vec<f32>> = None;
                    for s in
                        ctx.namenode.readable_replicas(b, |nd| ctx.is_readable(nd))
                    {
                        let r = holder_rows
                            .entry(s)
                            .or_insert_with(|| bw_row(ctx, s))
                            .clone();
                        combined = Some(match combined {
                            None => r,
                            Some(mut c) => {
                                for (cv, rv) in c.iter_mut().zip(&r) {
                                    if *rv > *cv {
                                        *cv = *rv;
                                    }
                                }
                                c
                            }
                        });
                    }
                    combined
                })
                .as_deref(),
            _ => {
                let src = match t.input {
                    Some(b) => ctx.min_idle_replica(b),
                    None => t.src_hint.filter(|&s| ctx.is_readable(s)),
                };
                src.map(|s| holder_rows.entry(s).or_insert_with(|| bw_row(ctx, s)).as_slice())
            }
        };
        let locals = ctx.local_nodes(t);
        for (j, &nd) in nodes.iter().enumerate() {
            let k = i * n + j;
            tp[k] = match speed[j] {
                Some(f) => (t.compute.0 * f) as f32,
                None => t.compute.0 as f32,
            };
            local[k] = if locals.contains(&nd) { 1.0 } else { 0.0 };
            bw[k] = row.map_or(0.0, |r| r[j]);
        }
    }
    let idle: Vec<f32> = nodes.iter().map(|&nd| ctx.ledger.idle(nd).0 as f32).collect();
    CostInputs { m, n, sz, bw, tp, local, idle, ts: ctx.controller.calendar.slot_secs() as f32 }
}

/// Above this many matrix cells, [`eval_batch`] switches to row-blocked
/// evaluation: at the ten-kilonode tier one monolithic f32 input matrix
/// is ~840 MB, while 4M-cell blocks stay ~16 MB apiece. Every golden and
/// test workload sits far below the threshold and takes the unchanged
/// monolithic path, so backend selection by (m, n) cannot flip.
const CHUNK_CELLS: usize = 1 << 22;

/// Evaluate the batch through the configured backend (XLA artifact when
/// available, Rust mirror otherwise). Oversized batches are evaluated in
/// row blocks — bitwise safe because the kernel is strictly
/// row-independent (each task's outputs depend only on its own input row
/// plus the shared idle/ts vectors, which chunking leaves untouched).
pub fn eval_batch(tasks: &[TaskSpec], ctx: &SchedCtx<'_>) -> CostOutputs {
    let n = ctx.authorized.len();
    if n == 0 || tasks.len().saturating_mul(n) <= CHUNK_CELLS {
        let inputs = build_inputs(tasks, ctx);
        return ctx.cost.eval(&inputs).expect("cost model evaluation");
    }
    eval_batch_chunked(tasks, ctx, (CHUNK_CELLS / n).max(1))
}

/// Row-blocked evaluation: split `tasks` into `chunk_rows`-row blocks,
/// evaluate each, and concatenate the row-major outputs. Public so the
/// property tests can pin it against the monolithic evaluation on small
/// batches.
pub fn eval_batch_chunked(
    tasks: &[TaskSpec],
    ctx: &SchedCtx<'_>,
    chunk_rows: usize,
) -> CostOutputs {
    let m = tasks.len();
    let n = ctx.authorized.len();
    let mut out = CostOutputs {
        m,
        n,
        yc: Vec::with_capacity(m * n),
        tm: Vec::with_capacity(m * n),
        slots: Vec::with_capacity(m * n),
        best_idx: Vec::with_capacity(m),
        best_cost: Vec::with_capacity(m),
    };
    let mut memo = RowMemo::default();
    for chunk in tasks.chunks(chunk_rows.max(1)) {
        let inputs = fill_inputs(chunk, ctx, &mut memo);
        let o = ctx.cost.eval(&inputs).expect("cost model evaluation");
        out.yc.extend_from_slice(&o.yc);
        out.tm.extend_from_slice(&o.tm);
        out.slots.extend_from_slice(&o.slots);
        out.best_idx.extend_from_slice(&o.best_idx);
        out.best_cost.extend_from_slice(&o.best_cost);
    }
    out
}

/// Column index of `node` in the authorized set (cost-matrix order).
pub fn col_of(ctx: &SchedCtx<'_>, node: NodeId) -> usize {
    ctx.authorized.iter().position(|&n| n == node).expect("node not authorized")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Ledger;
    use crate::runtime::CostModel;
    use crate::hdfs::Namenode;
    use crate::mapreduce::TaskSpec;
    use crate::sdn::Controller;
    use crate::topology::builders::fig2;
    use crate::util::Secs;

    fn fixture() -> (Controller, Namenode, Ledger, Vec<NodeId>) {
        let f = fig2(102.4);
        let ctrl = Controller::new(f.topo, 1.0);
        let mut nn = Namenode::new();
        // TK1's block: replicas ND2, ND3 (paper Example 1)
        nn.add_block(64.0, vec![f.task_nodes[1], f.task_nodes[2]]);
        let ledger = Ledger::with_initial(vec![
            Secs(3.0),
            Secs(9.0),
            Secs(20.0),
            Secs(7.0),
            Secs::INF,
            Secs::INF,
        ]);
        (ctrl, nn, ledger, f.task_nodes.to_vec())
    }

    #[test]
    fn build_inputs_matches_paper_tk1() {
        let (mut ctrl, nn, mut ledger, nodes) = fixture();
        let cost = CostModel::rust_only();
        let ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let tasks =
            vec![TaskSpec::map(0, crate::hdfs::BlockId(0), 64.0, Secs(9.0), 0.0)];
        let inp = build_inputs(&tasks, &ctx);
        assert_eq!((inp.m, inp.n), (1, 4));
        assert_eq!(inp.local, vec![0.0, 1.0, 1.0, 0.0]);
        assert_eq!(inp.idle, vec![3.0, 9.0, 20.0, 7.0]);
        // element-wise best over {ND2, ND3}: both paths to ND1 run at the
        // full 12.8, and the holder columns see themselves (sentinel)
        assert!((inp.bw[0] - 12.8).abs() < 1e-6);
        assert!(inp.bw[1] >= BW_SENTINEL_MB_S); // ND2 is a holder
        assert!(inp.bw[2] >= BW_SENTINEL_MB_S); // ND3 is a holder

        let out = eval_batch(&tasks, &ctx);
        assert_eq!(out.best_idx[0], 0); // the canonical BASS pick: ND1
        assert_eq!(out.yc_at(0, 0), 17.0);
        assert_eq!(out.yc_at(0, 1), 18.0);
    }

    #[test]
    fn legacy_rule_reproduces_the_single_idle_source_row() {
        let (mut ctrl, nn, mut ledger, nodes) = fixture();
        let cost = CostModel::rust_only();
        let ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: false,
        };
        let tasks =
            vec![TaskSpec::map(0, crate::hdfs::BlockId(0), 64.0, Secs(9.0), 0.0)];
        let inp = build_inputs(&tasks, &ctx);
        // source = least-loaded replica = ND2 (idle 9 < 20); the ND3
        // column is costed from ND2 (12.8), not from itself
        assert!((inp.bw[0] - 12.8).abs() < 1e-6);
        assert!(inp.bw[1] >= BW_SENTINEL_MB_S); // src == dst
        assert!((inp.bw[2] - 12.8).abs() < 1e-6);
    }

    #[test]
    fn down_holders_are_not_costed() {
        let (mut ctrl, nn, mut ledger, nodes) = fixture();
        let cost = CostModel::rust_only();
        // ND2 (the idle-chosen holder) is down: rows come from ND3 only
        let mut down = vec![false; 6];
        down[nodes[1].0] = true;
        let ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: down.clone(),
            bw_aware_sources: true,
        };
        let tasks =
            vec![TaskSpec::map(0, crate::hdfs::BlockId(0), 64.0, Secs(9.0), 0.0)];
        let inp = build_inputs(&tasks, &ctx);
        assert!((inp.bw[0] - 12.8).abs() < 1e-6); // still reachable via ND3
        assert!((inp.bw[1] - 12.8).abs() < 1e-6, "ND2 must not see itself");
        assert!(inp.bw[2] >= BW_SENTINEL_MB_S); // ND3 sees itself
        // both holders down: the row is all zeros (unreachable)
        let mut both = down;
        both[nodes[2].0] = true;
        let ctx2 = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes,
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: both,
            bw_aware_sources: true,
        };
        let inp2 = build_inputs(&tasks, &ctx2);
        assert!(inp2.bw.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn reduce_src_hint_is_local_column() {
        let (mut ctrl, nn, mut ledger, nodes) = fixture();
        let cost = CostModel::rust_only();
        let ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let tasks = vec![TaskSpec::reduce(0, 128.0, Secs(12.0)).with_src_hint(nodes[2])];
        let inp = build_inputs(&tasks, &ctx);
        assert_eq!(inp.local, vec![0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn reduce_without_hint_is_unreachable_everywhere() {
        let (mut ctrl, nn, mut ledger, nodes) = fixture();
        let cost = CostModel::rust_only();
        let ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes,
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let tasks = vec![TaskSpec::reduce(0, 128.0, Secs(12.0))];
        let inp = build_inputs(&tasks, &ctx);
        assert!(inp.bw.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn batched_matches_rowwise_bitwise() {
        // bw-aware, legacy, and bw-aware-with-a-down-holder variants, on a
        // mixed batch (shared-block maps, hinted + hint-less reduces) over
        // a heterogeneous cluster
        for (bw_aware, holder_down) in [(true, false), (false, false), (true, true)] {
            let (mut ctrl, nn, mut ledger, nodes) = fixture();
            let cost = CostModel::rust_only();
            let mut down = vec![false; 6];
            if holder_down {
                down[nodes[1].0] = true;
            }
            let ctx = SchedCtx {
                view: &crate::sdn::Oracle,
                controller: &mut ctrl,
                namenode: &nn,
                ledger: &mut ledger,
                authorized: nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: vec![1.0, 0.5, 2.0, 1.5, 1.0, 1.0],
                down,
                bw_aware_sources: bw_aware,
            };
            let tasks = vec![
                TaskSpec::map(0, crate::hdfs::BlockId(0), 64.0, Secs(9.0), 0.0),
                TaskSpec::map(1, crate::hdfs::BlockId(0), 64.0, Secs(4.0), 0.0),
                TaskSpec::reduce(2, 128.0, Secs(12.0)).with_src_hint(nodes[2]),
                TaskSpec::reduce(3, 32.0, Secs(5.0)),
            ];
            let a = build_inputs(&tasks, &ctx);
            let b = build_inputs_rowwise(&tasks, &ctx);
            assert_eq!((a.m, a.n), (b.m, b.n));
            assert_eq!(a.sz, b.sz);
            assert_eq!(a.bw, b.bw);
            assert_eq!(a.tp, b.tp);
            assert_eq!(a.local, b.local);
            assert_eq!(a.idle, b.idle);
            assert_eq!(a.ts, b.ts);
        }
    }

    #[test]
    fn chunked_eval_matches_monolithic() {
        let (mut ctrl, nn, mut ledger, nodes) = fixture();
        let cost = CostModel::rust_only();
        let ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ctrl,
            namenode: &nn,
            ledger: &mut ledger,
            authorized: nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let tasks: Vec<TaskSpec> = (0..5)
            .map(|i| {
                TaskSpec::map(i, crate::hdfs::BlockId(0), 64.0, Secs(3.0 + i as f64), 0.0)
            })
            .collect();
        let mono = eval_batch(&tasks, &ctx); // well under CHUNK_CELLS
        for chunk_rows in [1usize, 2, 3, 7] {
            let chunked = eval_batch_chunked(&tasks, &ctx, chunk_rows);
            assert_eq!((chunked.m, chunked.n), (mono.m, mono.n));
            assert_eq!(chunked.yc, mono.yc);
            assert_eq!(chunked.tm, mono.tm);
            assert_eq!(chunked.slots, mono.slots);
            assert_eq!(chunked.best_idx, mono.best_idx);
            assert_eq!(chunked.best_cost, mono.best_cost);
        }
    }
}
