//! HDS — the Hadoop Default Scheduler (baseline).
//!
//! Node-driven greedy locality: whenever a node frees up, it grabs the
//! first pending task that is data-local to it; if none exists it grabs
//! the first pending task outright and pulls the split over the network
//! ("if no data local task is available, HDS will choose a task
//! randomly" — we use the deterministic lowest-id choice so the paper's
//! Example 1 trace is exactly reproducible).
//!
//! Perf L4: the seed's loop was O(m·n) ledger scans plus O(m²) locality
//! probes (each probing allocated a fresh `local_nodes` vector). The
//! loop now runs off a [`ShardedIdleHeap`] (per-rack heaps, O(log
//! n_shard) per round plus an O(n_shards) merge that preserves the flat
//! heap's `(avail, node id)` order exactly) and per-node pending-local
//! queues built once up front; the non-local fallback is a
//! lowest-unplaced-id cursor. Pick order is bit-identical to the seed —
//! property-tested against a verbatim port in `rust/tests/proptests.rs`.

use crate::cluster::ShardedIdleHeap;
use crate::mapreduce::TaskSpec;
use crate::sdn::TrafficClass;
use crate::sim::{Assignment, Placement, TransferPlan};
use crate::util::Secs;

use super::types::{SchedCtx, Scheduler};

/// The Hadoop default scheduler.
#[derive(Debug, Default)]
pub struct Hds;

impl Hds {
    pub fn new() -> Self {
        Self
    }
}

impl Scheduler for Hds {
    fn name(&self) -> &'static str {
        "HDS"
    }

    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment {
        let mut placements = Vec::with_capacity(tasks.len());
        let floor = gate.unwrap_or(ctx.now).max(ctx.now);
        // column index per host id (usize::MAX = not authorized)
        let col_of = ctx.authorized_cols();
        // per-node pending-local queues, ascending task index (matching
        // the seed's "first pending task local to j" probe order)
        let mut local_q: Vec<Vec<usize>> = vec![Vec::new(); ctx.authorized.len()];
        for (i, t) in tasks.iter().enumerate() {
            for nd in ctx.local_nodes(t) {
                let c = col_of[nd.0];
                if c != usize::MAX {
                    local_q[c].push(i);
                }
            }
        }
        let mut local_head = vec![0usize; ctx.authorized.len()];
        let mut placed = vec![false; tasks.len()];
        let mut cursor = 0usize; // lowest unplaced task index
        let mut heap =
            ShardedIdleHeap::new(ctx.controller.shard_plan(), ctx.ledger, &ctx.authorized);
        for _ in 0..tasks.len() {
            let (c, j, idle) = heap.min(ctx.ledger).expect("no authorized nodes");
            let t0 = idle.max(floor);
            // first unplaced task local to j (queues stay sorted)
            let q = &local_q[c];
            let head = &mut local_head[c];
            while *head < q.len() && placed[q[*head]] {
                *head += 1;
            }
            let (i, is_local) = if *head < q.len() {
                (q[*head], true)
            } else {
                while placed[cursor] {
                    cursor += 1;
                }
                (cursor, false)
            };
            placed[i] = true;
            let t = &tasks[i];
            let tp = ctx.effective_compute(t, j);
            let finish;
            if is_local || t.input_mb <= 0.0 {
                finish = t0 + tp;
                placements.push(Placement {
                    task: t.id,
                    node: j,
                    compute: tp,
                    transfer: TransferPlan::None,
                    gate,
                    source: None,
                    is_local,
                    is_map: t.is_map(),
                });
            } else {
                let src =
                    ctx.transfer_source_for(t, j).expect("remote task needs a readable source");
                let tm = ctx.tm_estimate(src, j, t.input_mb).unwrap_or(Secs::INF);
                finish = t0 + tm + tp;
                let path = ctx
                    .controller
                    .path(src, j)
                    .map(|p| p.to_vec())
                    .unwrap_or_default();
                let class =
                    if t.is_map() { TrafficClass::HadoopOther } else { TrafficClass::Shuffle };
                placements.push(Placement {
                    task: t.id,
                    node: j,
                    compute: tp,
                    transfer: TransferPlan::FairShare { path, size_mb: t.input_mb, class },
                    gate,
                    source: Some(src),
                    is_local: false,
                    is_map: t.is_map(),
                });
            }
            ctx.ledger.occupy_until(j, finish);
            heap.update(c, j, ctx.ledger.idle(j));
        }
        Assignment { placements }
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::cluster::Ledger;
    use crate::hdfs::Namenode;
    use crate::mapreduce::TaskId;
    use crate::runtime::CostModel;
    use crate::sdn::Controller;
    use crate::topology::builders::fig2;
    use crate::topology::NodeId;

    /// Canonical Example 1 fixture + helpers (shared with examples and
    /// benches) — see [`crate::experiments::fixtures`].
    pub use crate::experiments::fixtures::{
        example1_fixture as example1, makespan, Example1Fixture as Example1,
    };

    #[test]
    fn hds_reproduces_paper_39s() {
        let mut ex = example1();
        let cost = CostModel::rust_only();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let a = Hds::new().schedule(&ex.tasks, None, &mut ctx);
        assert_eq!(a.placements.len(), 9);
        // paper Fig 3(b): ND1 x3 (TK2,TK3,TK7), ND2 x2 (TK1,TK6),
        // ND3 x1 (TK4), ND4 x3 (TK5,TK8,TK9-remote)
        let on = |n: usize| -> Vec<usize> {
            a.placements.iter().filter(|p| p.node == ex.nodes[n]).map(|p| p.task.0).collect()
        };
        assert_eq!(on(0), vec![1, 2, 6]);
        assert_eq!(on(1), vec![0, 5]);
        assert_eq!(on(2), vec![3]);
        assert_eq!(on(3), vec![4, 7, 8]);
        // TK9 is the only remote task
        let remote: Vec<usize> =
            a.placements.iter().filter(|p| !p.is_local).map(|p| p.task.0).collect();
        assert_eq!(remote, vec![8]);
        // makespan estimate = 39s
        assert!((makespan(ctx.ledger, &ex.nodes) - 39.0).abs() < 1e-9);
    }

    #[test]
    fn hds_all_local_when_possible() {
        // single node holding every replica: everything is local
        let mut ex = example1();
        let cost = CostModel::rust_only();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        // tasks 0..8 minus TK9 are placeable locally under HDS
        let a = Hds::new().schedule(&ex.tasks[..8], None, &mut ctx);
        assert!(a.placements.iter().all(|p| p.is_local));
    }

    #[test]
    fn hds_respects_gate() {
        let mut ex = example1();
        let cost = CostModel::rust_only();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let a = Hds::new().schedule(&ex.tasks[..1], Some(Secs(50.0)), &mut ctx);
        assert_eq!(a.placements[0].gate, Some(Secs(50.0)));
        // ledger reflects the gate: finish >= 59
        let n = a.placements[0].node;
        assert!(ctx.ledger.idle(n).0 >= 59.0);
    }
}
