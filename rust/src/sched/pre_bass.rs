//! Pre-BASS — BASS + input prefetching (Discussion 2 / Example 2).
//!
//! The allocation is exactly BASS's; afterwards every data-remote task
//! has its input transfer re-planned **as early as the residual slots
//! allow** (instead of waiting for the node's idle point). The paper's
//! Example 2: TK1's transfer moves from TS_4..TS_8 to TS_1..TS_5, ND_1's
//! chain finishes at 32 instead of 35 and the job at 34 instead of 35.

use crate::mapreduce::TaskSpec;
use crate::sim::{Assignment, TransferPlan};
use crate::util::Secs;

use super::bass::Bass;
use super::types::{SchedCtx, Scheduler};

/// The prefetching extension of BASS.
#[derive(Debug, Default)]
pub struct PreBass {
    inner: Bass,
    /// How many transfers were successfully moved earlier.
    pub prefetched: usize,
}

impl PreBass {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for PreBass {
    fn name(&self) -> &'static str {
        "Pre-BASS"
    }

    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment {
        let mut a = self.inner.schedule(tasks, gate, ctx);
        let floor = gate.unwrap_or(ctx.now).max(ctx.now);
        for p in &mut a.placements {
            let TransferPlan::Reserved(tr) = &p.transfer else { continue };
            let task = tasks.iter().find(|t| t.id == p.task).expect("task of placement");
            // the flow entry remembers the source BASS pulled from
            let Some(entry) = ctx.controller.flows.get(tr.flow_id).cloned() else {
                continue;
            };
            // release the on-demand window, re-plan from `now`
            ctx.controller.calendar.release(&tr.reservation);
            ctx.controller.flows.remove(tr.flow_id);
            let plan = ctx
                .controller
                .plan_transfer(entry.src, p.node, task.input_mb, floor)
                .expect("window freed by release must be replannable");
            let earlier = plan.2 < tr.arrival;
            let new_tr = ctx
                .controller
                .commit_transfer(entry.src, p.node, entry.class, plan, ctx.now)
                .expect("planned reservation must commit");
            if earlier {
                self.prefetched += 1;
            }
            p.transfer = TransferPlan::Prefetched(new_tr);
        }
        // NOTE: the ledger keeps BASS's (conservative) estimates; the
        // engine re-times everything, and Example 2's 34s comes out of
        // execution, not the ledger.
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::hds::tests::example1;
    use crate::runtime::CostModel;

    #[test]
    fn pre_bass_prefetches_tk1_to_slot_0() {
        let mut ex = example1();
        let cost_model = CostModel::rust_only();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost_model,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let mut pb = PreBass::new();
        let a = pb.schedule(&ex.tasks, None, &mut ctx);
        assert_eq!(pb.prefetched, 1);
        let tk1 = a.placements.iter().find(|p| p.task.0 == 0).unwrap();
        match &tk1.transfer {
            TransferPlan::Prefetched(tr) => {
                // Example 2: slots TS_1..TS_5 (0-based 0..5), data by t=5
                assert_eq!(tr.reservation.start_slot, 0);
                assert_eq!(tr.reservation.n_slots, 5);
                assert!((tr.arrival.0 - 5.0).abs() < 1e-9);
            }
            other => panic!("expected prefetched transfer, got {other:?}"),
        }
    }

    #[test]
    fn pre_bass_allocation_matches_bass() {
        // same node assignment as BASS, only transfer timing differs
        let cost_model = CostModel::rust_only();
        let mut ex1 = example1();
        let mut ctx1 = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex1.ctrl,
            namenode: &ex1.nn,
            ledger: &mut ex1.ledger,
            authorized: ex1.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost_model,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let a_bass = Bass::new().schedule(&ex1.tasks, None, &mut ctx1);
        let mut ex2 = example1();
        let mut ctx2 = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex2.ctrl,
            namenode: &ex2.nn,
            ledger: &mut ex2.ledger,
            authorized: ex2.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost_model,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let a_pre = PreBass::new().schedule(&ex2.tasks, None, &mut ctx2);
        for (b, p) in a_bass.placements.iter().zip(a_pre.placements.iter()) {
            assert_eq!(b.task, p.task);
            assert_eq!(b.node, p.node);
        }
    }
}
