//! The scheduler registry: one name per paper algorithm.
//!
//! Lives in `sched` (not `experiments`) so every layer — scenario
//! construction, the coordinator, config files, the CLI — selects
//! schedulers through the same registry without depending on the
//! experiment drivers.

use super::bar::Bar;
use super::bass::Bass;
use super::hds::Hds;
use super::pre_bass::PreBass;
use super::types::Scheduler;

/// Selector for the paper's four schedulers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Hds,
    Bar,
    Bass,
    PreBass,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 4] =
        [SchedulerKind::Hds, SchedulerKind::Bar, SchedulerKind::Bass, SchedulerKind::PreBass];

    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Hds => "HDS",
            SchedulerKind::Bar => "BAR",
            SchedulerKind::Bass => "BASS",
            SchedulerKind::PreBass => "Pre-BASS",
        }
    }

    /// Instantiate. The trait object is `Send` so a whole scheduling
    /// session can move across sweep worker threads.
    pub fn make(&self) -> Box<dyn Scheduler + Send> {
        match self {
            SchedulerKind::Hds => Box::new(Hds::new()),
            SchedulerKind::Bar => Box::new(Bar::new()),
            SchedulerKind::Bass => Box::new(Bass::new()),
            SchedulerKind::PreBass => Box::new(PreBass::new()),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hds" => Some(SchedulerKind::Hds),
            "bar" => Some(SchedulerKind::Bar),
            "bass" => Some(SchedulerKind::Bass),
            "pre-bass" | "prebass" | "pre_bass" => Some(SchedulerKind::PreBass),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(k.label()), Some(k));
        }
        assert_eq!(SchedulerKind::parse("nope"), None);
    }

    #[test]
    fn made_schedulers_report_their_label() {
        for k in SchedulerKind::ALL {
            assert_eq!(k.make().name(), k.label());
        }
    }
}
