//! BAR — the BAlance-Reduce scheduler (Jin et al., CCGrid 2011), the
//! paper's state-of-the-art baseline.
//!
//! Phase 1 produces the same data-locality-first allocation as HDS
//! (the paper: "BAR allocates tasks obeying the data locality principle
//! with the same result"). Phase 2 then globally tunes: repeatedly take
//! the task with the **latest** estimated completion time and move it to
//! whichever node yields an earlier `ΥC` (network state = nominal line
//! rates), until no move improves (Discussion 1: Example 1 goes
//! 39s -> 38s by moving TK9 from ND4 to ND3).

use std::collections::HashMap;

use crate::mapreduce::TaskSpec;
use crate::sdn::TrafficClass;
use crate::sim::{Assignment, Placement, TransferPlan};
use crate::topology::NodeId;
use crate::util::Secs;

use super::hds::Hds;
use super::types::{SchedCtx, Scheduler};

/// The BAR scheduler.
#[derive(Debug)]
pub struct Bar {
    /// Safety cap on tuning iterations (default m*n is plenty).
    pub max_iters: usize,
}

impl Default for Bar {
    fn default() -> Self {
        Self { max_iters: 10_000 }
    }
}

impl Bar {
    pub fn new() -> Self {
        Self::default()
    }
}

#[derive(Debug, Clone)]
struct Item {
    idx: usize,
    node: NodeId,
    is_local: bool,
    /// Nominal TM on the current node.
    tm: Secs,
    /// The replica holder the TM estimate priced the pull from (kept so
    /// materialization commits the same source the tuning loop costed).
    src: Option<NodeId>,
}

impl Scheduler for Bar {
    fn name(&self) -> &'static str {
        "BAR"
    }

    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment {
        let floor = gate.unwrap_or(ctx.now).max(ctx.now);
        // ---- phase 1: HDS allocation on a scratch ledger ----
        let base_ledger = ctx.ledger.clone();
        let phase1 = Hds::new().schedule(tasks, gate, ctx);
        // rebuild per-node item queues from the phase-1 placements; the
        // host->column map and a task-id index replace the seed's O(n)
        // and O(m) scans per placement (Perf L4)
        let mut queues: Vec<Vec<Item>> = vec![Vec::new(); ctx.authorized.len()];
        let col_of_host = ctx.authorized_cols();
        let slice_idx: HashMap<usize, usize> =
            tasks.iter().enumerate().map(|(i, t)| (t.id.0, i)).collect();
        for p in &phase1.placements {
            // p.task ids are global; recover the slice index
            let sidx = slice_idx[&p.task.0];
            let (tm, src) = match &p.transfer {
                TransferPlan::None => (Secs::ZERO, None),
                _ => {
                    let src = ctx
                        .transfer_source_for(&tasks[sidx], p.node)
                        .expect("phase-1 remote placement needs a readable source");
                    (
                        ctx.tm_estimate(src, p.node, tasks[sidx].input_mb)
                            .unwrap_or(Secs::INF),
                        Some(src),
                    )
                }
            };
            queues[col_of_host[p.node.0]].push(Item {
                idx: sidx,
                node: p.node,
                is_local: p.is_local,
                tm,
                src,
            });
        }
        // restore the ledger: phase 2 recomputes its own estimates
        *ctx.ledger = base_ledger.clone();

        // completion estimate per queue position
        let finish_times = |queues: &[Vec<Item>], ctx: &SchedCtx| -> Vec<Vec<Secs>> {
            queues
                .iter()
                .enumerate()
                .map(|(c, q)| {
                    let mut t = base_ledger.idle(ctx.authorized[c]).max(floor);
                    q.iter()
                        .map(|it| {
                            t = t + it.tm
                                + ctx.effective_compute(&tasks[it.idx], ctx.authorized[c]);
                            t
                        })
                        .collect()
                })
                .collect()
        };

        // ---- phase 2: move the latest task while it helps ----
        // (task, candidate column) -> (TM, source): the controller and
        // the restored ledger are invariant across tuning iterations, so
        // the per-candidate source argmax and path walk resolve once —
        // the loop revisits the same pairs up to max_iters times
        let mut cand: HashMap<(usize, usize), (Secs, Option<NodeId>)> = HashMap::new();
        for _ in 0..self.max_iters {
            let fins = finish_times(&queues, ctx);
            // latest task overall
            let mut latest: Option<(usize, usize, Secs)> = None; // (queue, pos, yc)
            for (c, f) in fins.iter().enumerate() {
                for (pos, &yc) in f.iter().enumerate() {
                    if latest.map_or(true, |(_, _, byc)| yc > byc) {
                        latest = Some((c, pos, yc));
                    }
                }
            }
            let Some((qc, qpos, yc_lat)) = latest else { break };
            let item = queues[qc][qpos].clone();
            let t = &tasks[item.idx];
            // candidate target: append to any other node's queue; each
            // candidate prices the pull from its own best-connected holder
            let locals = ctx.local_nodes(t);
            let mut best: Option<(usize, Secs, Secs, bool, Option<NodeId>)> = None;
            for (c, nd) in ctx.authorized.iter().enumerate() {
                if c == qc {
                    continue;
                }
                let tail = fins[c]
                    .last()
                    .copied()
                    .unwrap_or(base_ledger.idle(*nd).max(floor));
                let is_local = locals.contains(nd);
                let (tm, src) = if is_local || t.input_mb <= 0.0 {
                    (Secs::ZERO, None)
                } else {
                    *cand.entry((item.idx, c)).or_insert_with(|| {
                        match ctx.transfer_source_for(t, *nd) {
                            Some(src) => (
                                ctx.tm_estimate(src, *nd, t.input_mb).unwrap_or(Secs::INF),
                                Some(src),
                            ),
                            None => (Secs::INF, None),
                        }
                    })
                };
                if !tm.is_finite() {
                    continue;
                }
                let yc_new = tail + tm + ctx.effective_compute(t, *nd);
                if yc_new < yc_lat && best.map_or(true, |(_, byc, _, _, _)| yc_new < byc) {
                    best = Some((c, yc_new, tm, is_local, src));
                }
            }
            match best {
                Some((c, _, tm, is_local, src)) => {
                    queues[qc].remove(qpos);
                    queues[c].push(Item {
                        idx: item.idx,
                        node: ctx.authorized[c],
                        is_local,
                        tm,
                        src,
                    });
                }
                None => break,
            }
        }

        // ---- materialize: placements in per-node queue order ----
        let fins = finish_times(&queues, ctx);
        let mut placements: Vec<Placement> = Vec::with_capacity(tasks.len());
        for (c, q) in queues.iter().enumerate() {
            for (pos, it) in q.iter().enumerate() {
                let t = &tasks[it.idx];
                let (transfer, source) = if it.is_local || t.input_mb <= 0.0 {
                    (TransferPlan::None, None)
                } else {
                    let src = it
                        .src
                        .expect("remote items carry the source their TM was priced from");
                    let path = ctx
                        .controller
                        .path(src, ctx.authorized[c])
                        .map(|p| p.to_vec())
                        .unwrap_or_default();
                    let class = if t.is_map() {
                        TrafficClass::HadoopOther
                    } else {
                        TrafficClass::Shuffle
                    };
                    (
                        TransferPlan::FairShare { path, size_mb: t.input_mb, class },
                        Some(src),
                    )
                };
                placements.push(Placement {
                    task: t.id,
                    node: ctx.authorized[c],
                    compute: ctx.effective_compute(t, ctx.authorized[c]),
                    transfer,
                    gate,
                    source,
                    is_local: it.is_local,
                    is_map: t.is_map(),
                });
                ctx.ledger.occupy_until(ctx.authorized[c], fins[c][pos]);
            }
        }
        // NOTE: placements stay in per-node queue order — the engine derives
        // each node's execution order from placement order, and a remote
        // pick can carry a lower task id than an earlier local pick.
        Assignment { placements }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::hds::tests::{example1, makespan};
    use crate::runtime::CostModel;

    #[test]
    fn bar_reproduces_paper_38s() {
        let mut ex = example1();
        let cost = CostModel::rust_only();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let a = Bar::new().schedule(&ex.tasks, None, &mut ctx);
        assert_eq!(a.placements.len(), 9);
        // Discussion 1: TK9 moves from ND4 to ND3 (local there), 38s
        let tk9 = a.placements.iter().find(|p| p.task.0 == 8).unwrap();
        assert_eq!(tk9.node, ex.nodes[2]);
        assert!(tk9.is_local);
        assert!((makespan(ctx.ledger, &ex.nodes) - 38.0).abs() < 1e-9);
    }

    #[test]
    fn bar_never_worse_than_hds_estimate() {
        let mut ex = example1();
        let cost = CostModel::rust_only();
        // HDS estimate
        let mut hds_ledger = ex.ledger.clone();
        {
            let mut ctx = SchedCtx {
                view: &crate::sdn::Oracle,
                controller: &mut ex.ctrl,
                namenode: &ex.nn,
                ledger: &mut hds_ledger,
                authorized: ex.nodes.clone(),
                now: Secs::ZERO,
                cost: &cost,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            Hds::new().schedule(&ex.tasks, None, &mut ctx);
        }
        let hds_ms = makespan(&hds_ledger, &ex.nodes);
        // fresh controller for BAR (HDS made no reservations, but be safe)
        let mut ex2 = example1();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex2.ctrl,
            namenode: &ex2.nn,
            ledger: &mut ex2.ledger,
            authorized: ex2.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        Bar::new().schedule(&ex2.tasks, None, &mut ctx);
        assert!(makespan(ctx.ledger, &ex2.nodes) <= hds_ms + 1e-9);
    }
}
