//! BASS — Bandwidth-Aware Scheduling with Sdn in hadoop (Algorithm 1).
//!
//! For each task `TK_i` (in submission order, exactly as the paper's
//! `for i = 1..m` loop):
//!
//! * **Case 1** — a data-local node `ND_loc` exists (the authorized
//!   replica holder with minimum idle time).
//!   * **1.1** if `ND_loc == ND_minnow` or `ΥI_loc <= ΥI_minnow`:
//!     assign locally — zero transfer cost (Eq. 1).
//!   * **1.2** otherwise ask the SDN controller for a slot-reserved
//!     transfer to `ND_minnow`; if the reserved completion time beats the
//!     local one (`ΥC_minnow < ΥC_loc`, i.e. `BW_needed <= BW_rl`),
//!     commit the reservation and go remote.
//!   * **1.3** if bandwidth is insufficient, stay local.
//! * **Case 2** — no local node (locality starvation, shared clusters):
//!   go to `ND_minnow` with a slot reservation.
//!
//! The batched (m x n) cost matrix is evaluated **once per scheduling
//! round through the AOT XLA artifact** (L1 Pallas kernel + L2 JAX model;
//! see `runtime::CostModel`) and pre-filters unreachable placements; the
//! per-task sequential pass then confirms each remote decision against
//! the live slot calendar (`Controller::plan_transfer`), which is the
//! paper's `BW_{i,minnow} <= BW_rl` test in time-slot form.
//!
//! Remote pulls read from the replica holder with the **best current
//! path bandwidth to the chosen node** ([`SchedCtx::transfer_source_for`]
//! — the cost matrix rows are the element-wise best over all readable
//! holders, and the committed reservation runs on the winning holder's
//! path). The seed resolved one idle-chosen holder per task, which hid
//! better-connected replicas from the whole round.

use crate::cluster::ShardedIdleHeap;
use crate::mapreduce::TaskSpec;
use crate::sdn::TrafficClass;
use crate::sim::{Assignment, Placement, TransferPlan};
use crate::util::Secs;

use super::cost;
use super::types::{SchedCtx, Scheduler};

/// The BASS scheduler.
#[derive(Debug, Default)]
pub struct Bass {
    /// Statistics: how many decisions went remote via reservation.
    pub remote_assignments: usize,
    /// Statistics: cost-model batch evaluations (XLA hot-path calls).
    pub batch_evals: usize,
}

impl Bass {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for Bass {
    fn name(&self) -> &'static str {
        "BASS"
    }

    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        ctx: &mut SchedCtx<'_>,
    ) -> Assignment {
        let floor = gate.unwrap_or(ctx.now).max(ctx.now);
        // L1/L2 hot path: one batched Eq.1-3 evaluation for the round.
        let batch = cost::eval_batch(tasks, ctx);
        self.batch_evals += 1;

        // Perf L4 hoists: per-column compute-speed factors and a host->
        // column map resolved once per round (not per task), plus a
        // sharded idle-min heap that seeds each minnow scan's prune bound.
        let speed = ctx.speed_cols();
        let col_of_host = ctx.authorized_cols();
        let mut idle_heap =
            ShardedIdleHeap::new(ctx.controller.shard_plan(), ctx.ledger, &ctx.authorized);
        // Shard-local candidate groups: authorized columns bucketed by the
        // controller's shard plan. Each minnow scan walks one shard at a
        // time (shard-local pick, then a global compare of shard winners).
        let shard_cols: Vec<Vec<usize>> = {
            let plan = ctx.controller.shard_plan();
            let mut v = vec![Vec::new(); plan.n_shards()];
            for (j, &nd) in ctx.authorized.iter().enumerate() {
                v[plan.shard_of(nd)].push(j);
            }
            v
        };

        let mut placements = Vec::with_capacity(tasks.len());
        for (i, t) in tasks.iter().enumerate() {
            let class =
                if t.is_map() { TrafficClass::HadoopOther } else { TrafficClass::Shuffle };
            let locals = ctx.local_nodes(t);
            let tp_col = |c: usize| -> f64 {
                match speed[c] {
                    Some(f) => t.compute.0 * f,
                    None => t.compute.0,
                }
            };
            // ND_minnow per the Objective Function (Eq. 4): the node with
            // the minimum predicted ΥC = TM + TP + ΥI, using the batched
            // TM matrix (XLA hot path) and the *live* ledger idle times.
            // TP enters per node (heterogeneous clusters scale it). The
            // scan walks the TM row one shard at a time and skips any node
            // whose idle time alone exceeds the best score seen so far
            // (the min-idle node's full score seeds that bound): TM and TP
            // are nonnegative, so a pruned node can neither win nor tie.
            // The winner carries an explicit (score, column) tie-break,
            // which makes the shard-grouped visit order immaterial — the
            // pick equals the flat scan's first strict minimum in column
            // order for any shard plan.
            let tm_row = batch.tm_row(i);
            let (minnow, mcol, yi_minnow) = {
                let (sc, snd, _) = idle_heap.min(ctx.ledger).expect("no authorized nodes");
                let mut bound = tm_row[sc] as f64 + ctx.ledger.idle(snd).0 + tp_col(sc);
                let mut best: Option<(usize, crate::topology::NodeId, f64)> = None;
                for cols in &shard_cols {
                    for &j in cols {
                        let nd = ctx.authorized[j];
                        let idle = ctx.ledger.idle(nd).0;
                        if idle > bound {
                            continue;
                        }
                        let score = tm_row[j] as f64 + idle + tp_col(j);
                        let wins = match best {
                            None => true,
                            Some((bj, _, b)) => score < b || (score == b && j < bj),
                        };
                        if wins {
                            best = Some((j, nd, score));
                            bound = bound.min(score);
                        }
                    }
                }
                let (c, nd, _) = best.expect("seed node is never pruned");
                (nd, c, ctx.ledger.idle(nd))
            };
            let loc = ctx.ledger.min_idle_among(locals.iter().copied());

            let assign_local =
                |ctx: &mut SchedCtx, placements: &mut Vec<Placement>, heap: &mut ShardedIdleHeap| {
                    let (loc_nd, yi_loc) = loc.unwrap();
                    let start = yi_loc.max(floor);
                    let tp = ctx.effective_compute(t, loc_nd);
                    ctx.ledger.occupy_until(loc_nd, start + tp);
                    heap.update(col_of_host[loc_nd.0], loc_nd, ctx.ledger.idle(loc_nd));
                    placements.push(Placement {
                        task: t.id,
                        node: loc_nd,
                        compute: tp,
                        transfer: TransferPlan::None,
                        gate,
                        source: None,
                        is_local: true,
                        is_map: t.is_map(),
                    });
                };

            match loc {
                Some((loc_nd, yi_loc)) => {
                    // Case 1.1 — local node is (tied-)optimal by idle time
                    if loc_nd == minnow || yi_loc <= yi_minnow {
                        assign_local(ctx, &mut placements, &mut idle_heap);
                        continue;
                    }
                    // batched pre-filter: remote unreachable => local
                    if tm_row[mcol] >= crate::runtime::exec::INF {
                        assign_local(ctx, &mut placements, &mut idle_heap);
                        continue;
                    }
                    // Case 1.2 / 1.3 — ask the controller for a reserved
                    // window from the holder best connected to ND_minnow
                    let src = match ctx.transfer_source_for(t, minnow) {
                        Some(s) => s,
                        None => {
                            assign_local(ctx, &mut placements, &mut idle_heap);
                            continue;
                        }
                    };
                    let earliest = yi_minnow.max(floor);
                    let plan =
                        ctx.controller.plan_transfer(src, minnow, t.input_mb, earliest);
                    let tp_loc = ctx.effective_compute(t, loc_nd);
                    let tp_min = ctx.effective_compute(t, minnow);
                    let yc_loc = yi_loc.max(floor) + tp_loc;
                    match plan {
                        Some(p) if p.2 + tp_min < yc_loc => {
                            let tr = ctx
                                .controller
                                .commit_transfer(src, minnow, class, p, ctx.now)
                                .expect("planned reservation must commit");
                            ctx.ledger.occupy_until(minnow, tr.arrival + tp_min);
                            idle_heap.update(mcol, minnow, ctx.ledger.idle(minnow));
                            self.remote_assignments += 1;
                            placements.push(Placement {
                                task: t.id,
                                node: minnow,
                                compute: tp_min,
                                transfer: TransferPlan::Reserved(tr),
                                gate,
                                source: Some(src),
                                is_local: false,
                                is_map: t.is_map(),
                            });
                        }
                        // Case 1.3: bandwidth-starved remote — stay local
                        _ => assign_local(ctx, &mut placements, &mut idle_heap),
                    }
                }
                None => {
                    // Case 2 — locality starvation: reserved remote on minnow
                    let start = yi_minnow.max(floor);
                    let tp_min = ctx.effective_compute(t, minnow);
                    match ctx.transfer_source_for(t, minnow).filter(|_| t.input_mb > 0.0) {
                        None => {
                            // no input to move (or sourceless): plain compute
                            ctx.ledger.occupy_until(minnow, start + tp_min);
                            idle_heap.update(mcol, minnow, ctx.ledger.idle(minnow));
                            placements.push(Placement {
                                task: t.id,
                                node: minnow,
                                compute: tp_min,
                                transfer: TransferPlan::None,
                                gate,
                                source: None,
                                is_local: false,
                                is_map: t.is_map(),
                            });
                        }
                        Some(src) => {
                            match ctx.controller.plan_transfer(src, minnow, t.input_mb, start)
                            {
                                Some(p) => {
                                    let tr = ctx
                                        .controller
                                        .commit_transfer(src, minnow, class, p, ctx.now)
                                        .expect("planned reservation must commit");
                                    ctx.ledger
                                        .occupy_until(minnow, tr.arrival + tp_min);
                                    idle_heap.update(mcol, minnow, ctx.ledger.idle(minnow));
                                    self.remote_assignments += 1;
                                    placements.push(Placement {
                                        task: t.id,
                                        node: minnow,
                                        compute: tp_min,
                                        transfer: TransferPlan::Reserved(tr),
                                        gate,
                                        source: Some(src),
                                        is_local: false,
                                        is_map: t.is_map(),
                                    });
                                }
                                None => {
                                    // no reservable window at all: fall back to
                                    // a fair-share pull (degraded mode)
                                    let path = ctx
                                        .controller
                                        .path(src, minnow)
                                        .map(|p| p.to_vec())
                                        .unwrap_or_default();
                                    let tm = ctx
                                        .tm_estimate(src, minnow, t.input_mb)
                                        .unwrap_or(Secs::INF);
                                    ctx.ledger
                                        .occupy_until(minnow, start + tm + tp_min);
                                    idle_heap.update(mcol, minnow, ctx.ledger.idle(minnow));
                                    placements.push(Placement {
                                        task: t.id,
                                        node: minnow,
                                        compute: tp_min,
                                        transfer: TransferPlan::FairShare {
                                            path,
                                            size_mb: t.input_mb,
                                            class,
                                        },
                                        gate,
                                        source: Some(src),
                                        is_local: false,
                                        is_map: t.is_map(),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        Assignment { placements }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::hds::tests::{example1, makespan};
    use crate::runtime::CostModel;
    use crate::sim::TransferPlan;

    #[test]
    fn bass_reproduces_paper_35s() {
        let mut ex = example1();
        let cost_model = CostModel::rust_only();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &cost_model,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let mut bass = Bass::new();
        let a = bass.schedule(&ex.tasks, None, &mut ctx);
        assert_eq!(a.placements.len(), 9);
        // Example 1 allocation: ND1 {TK1 remote, TK4, TK9}, ND2 {TK3, TK6},
        // ND3 {TK7}, ND4 {TK2, TK5, TK8}; makespan 35 via ΥC_{9,1}=35.
        let on = |n: usize| -> Vec<usize> {
            a.placements.iter().filter(|p| p.node == ex.nodes[n]).map(|p| p.task.0).collect()
        };
        assert_eq!(on(0), vec![0, 3, 8]);
        assert_eq!(on(1), vec![2, 5]);
        assert_eq!(on(2), vec![6]);
        assert_eq!(on(3), vec![1, 4, 7]);
        assert!((makespan(ctx.ledger, &ex.nodes) - 35.0).abs() < 1e-9);
        assert_eq!(ctx.ledger.idle(ex.nodes[0]), Secs(35.0)); // ΥC_{9,1} = 35
        // exactly one reserved remote transfer (TK1), per the paper's walk-through
        assert_eq!(bass.remote_assignments, 1);
        let tk1 = a.placements.iter().find(|p| p.task.0 == 0).unwrap();
        match &tk1.transfer {
            TransferPlan::Reserved(tr) => {
                // slots TS_4..TS_8 (0-based 3..8) on Link2->Link1 at full rate
                assert_eq!(tr.reservation.start_slot, 3);
                assert_eq!(tr.reservation.n_slots, 5);
                assert!((tr.arrival.0 - 8.0).abs() < 1e-9);
            }
            other => panic!("TK1 should be a reserved transfer, got {other:?}"),
        }
        assert_eq!(bass.batch_evals, 1);
    }

    #[test]
    fn bass_uses_xla_backend_when_artifacts_present() {
        let model = CostModel::auto();
        if model.backend_for(9, 4) != crate::runtime::exec::Backend::Xla {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut ex = example1();
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: ex.nodes.clone(),
            now: Secs::ZERO,
            cost: &model,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        let a = Bass::new().schedule(&ex.tasks, None, &mut ctx);
        // identical decision trace through the XLA path
        assert!((makespan(ctx.ledger, &ex.nodes) - 35.0).abs() < 1e-9);
        assert_eq!(a.placements.len(), 9);
    }

    #[test]
    fn bass_case2_locality_starvation_reserves() {
        let mut ex = example1();
        let cost_model = CostModel::rust_only();
        // authorize only ND4: every replica set that excludes ND4 starves
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut ex.ctrl,
            namenode: &ex.nn,
            ledger: &mut ex.ledger,
            authorized: vec![ex.nodes[3]],
            now: Secs::ZERO,
            cost: &cost_model,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: true,
        };
        // TK1 replicas {ND2, ND3}: starved under {ND4}
        let a = Bass::new().schedule(&ex.tasks[..1], None, &mut ctx);
        let p = &a.placements[0];
        assert_eq!(p.node, ex.nodes[3]);
        assert!(!p.is_local);
        assert!(matches!(p.transfer, TransferPlan::Reserved(_)));
    }

    #[test]
    fn bass_makespan_beats_baselines_on_example1() {
        // the paper's headline: BASS(35) < BAR(38) < HDS(39)
        let cost_model = CostModel::rust_only();
        let mut results = Vec::new();
        for which in ["hds", "bar", "bass"] {
            let mut ex = example1();
            let mut ctx = SchedCtx {
                view: &crate::sdn::Oracle,
                controller: &mut ex.ctrl,
                namenode: &ex.nn,
                ledger: &mut ex.ledger,
                authorized: ex.nodes.clone(),
                now: Secs::ZERO,
                cost: &cost_model,
                node_speed: Vec::new(),
                down: Vec::new(),
                bw_aware_sources: true,
            };
            match which {
                "hds" => {
                    super::super::hds::Hds::new().schedule(&ex.tasks, None, &mut ctx);
                }
                "bar" => {
                    super::super::bar::Bar::new().schedule(&ex.tasks, None, &mut ctx);
                }
                _ => {
                    Bass::new().schedule(&ex.tasks, None, &mut ctx);
                }
            }
            results.push(makespan(ctx.ledger, &ex.nodes));
        }
        assert_eq!(results, vec![39.0, 38.0, 35.0]);
    }
}
