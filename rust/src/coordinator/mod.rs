//! The leader event loop: online job stream -> scheduler -> execution.
//!
//! Architecture note (DESIGN.md): the offline image vendors no tokio, so
//! the coordinator uses std threads + mpsc channels — a submitter thread
//! feeds [`JobRequest`]s into the leader. The leader plays the whole
//! trace as one **online stream** (`scenario::online`): overlapping jobs
//! share the node slots, the SDN bandwidth calendar and the flow
//! network, so later jobs genuinely contend with earlier ones.
//! [`Coordinator::handle`] / [`Coordinator::run_trace_isolated`] keep
//! the pre-stream run-to-completion semantics as the static reference
//! path (differential pins, slowdown baselines).

pub mod leader;

pub use leader::{ClusterSetup, Coordinator, JobRequest, JobResult};
