//! The leader event loop: online job stream -> scheduler -> execution.
//!
//! Architecture note (DESIGN.md): the offline image vendors no tokio, so
//! the coordinator uses std threads + mpsc channels — a submitter thread
//! feeds [`JobRequest`]s into the leader, which schedules each job
//! against the live cluster state and executes it on the DES engine,
//! streaming [`JobResult`]s back.

pub mod leader;

pub use leader::{ClusterSetup, Coordinator, JobRequest, JobResult};
