//! Leader/worker coordination over std mpsc channels.

use std::sync::mpsc;
use std::thread;

use crate::cluster::Ledger;
use crate::hdfs::Namenode;
use crate::mapreduce::{JobId, TaskSpec};
use crate::metrics::JobMetrics;
use crate::runtime::CostModel;
use crate::sched::{SchedCtx, Scheduler};
use crate::sdn::Controller;
use crate::sim::{Engine, FlowNet, TaskRecord};
use crate::topology::builders::tree_cluster;
use crate::topology::NodeId;
use crate::util::{Secs, XorShift};
use crate::workload::{BackgroundLoad, JobArrival, WorkloadBuilder};

use super::super::experiments::SchedulerKind;

/// One job submission into the coordinator.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub arrival: JobArrival,
    pub id: usize,
}

/// Executed-job report streamed back to the submitter.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: JobId,
    pub name: String,
    pub submitted_at: f64,
    pub metrics: JobMetrics,
}

/// Cluster construction parameters for the coordinator.
#[derive(Debug, Clone)]
pub struct ClusterSetup {
    pub n_switches: usize,
    pub hosts_per_switch: usize,
    pub link_mbps: f64,
    pub slot_secs: f64,
    pub replication: usize,
    pub reduces: usize,
    pub bg_flows: usize,
    pub bg_rate_mb_s: f64,
    pub seed: u64,
}

impl Default for ClusterSetup {
    fn default() -> Self {
        Self {
            n_switches: 2,
            hosts_per_switch: 3,
            link_mbps: 100.0,
            slot_secs: 1.0,
            replication: 3,
            reduces: 2,
            bg_flows: 2,
            bg_rate_mb_s: 2.0,
            seed: 7,
        }
    }
}

/// The long-lived leader: owns cluster state across jobs.
pub struct Coordinator {
    setup: ClusterSetup,
    scheduler_kind: SchedulerKind,
    nodes: Vec<NodeId>,
    ctrl: Controller,
    net: FlowNet,
    nn: Namenode,
    /// Actual node availability, carried across jobs.
    node_free: Vec<Secs>,
    rng: XorShift,
    cost: CostModel,
    sched: Box<dyn Scheduler>,
}

impl Coordinator {
    pub fn new(setup: ClusterSetup, kind: SchedulerKind, cost: CostModel) -> Self {
        let (topo, nodes) = tree_cluster(
            setup.n_switches,
            setup.hosts_per_switch,
            setup.link_mbps,
            setup.link_mbps,
        );
        let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_mbps).collect();
        let mut ctrl = Controller::new(topo, setup.slot_secs);
        let mut net = FlowNet::new(&caps);
        let mut rng = XorShift::new(setup.seed);
        let bg = BackgroundLoad::sample(
            &nodes,
            0.0 + 1e-9, // jobs arrive online; no synthetic initial idle
            setup.bg_flows,
            setup.bg_rate_mb_s,
            &mut rng,
        );
        bg.install(&mut ctrl, &mut net);
        let node_free = vec![Secs::ZERO; nodes.len()];
        let sched = kind.make();
        Self {
            setup,
            scheduler_kind: kind,
            nodes,
            ctrl,
            net,
            nn: Namenode::new(),
            node_free,
            rng,
            cost,
            sched,
        }
    }

    pub fn scheduler_label(&self) -> &'static str {
        self.scheduler_kind.label()
    }

    /// Handle one job end-to-end at its arrival time.
    pub fn handle(&mut self, req: &JobRequest) -> JobResult {
        let now = Secs(req.arrival.at_secs);
        let mut builder = WorkloadBuilder::new(req.arrival.kind);
        builder.replication = self.setup.replication.min(self.nodes.len());
        builder.reduces = self.setup.reduces;
        let job =
            builder.build(req.id, req.arrival.data_mb, &self.nodes, &mut self.nn, &mut self.rng);
        let maps: Vec<TaskSpec> = job.maps().cloned().collect();
        let mut reduces: Vec<TaskSpec> = job.reduces().cloned().collect();

        // node availability as of this arrival
        let init: Vec<Secs> = self.node_free.iter().map(|&f| f.max(now)).collect();
        let mut ledger = Ledger::with_initial(init.clone());

        // map phase
        let map_assignment = {
            let mut ctx = SchedCtx {
                controller: &mut self.ctrl,
                namenode: &self.nn,
                ledger: &mut ledger,
                authorized: self.nodes.clone(),
                now,
                cost: &self.cost,
            node_speed: Vec::new(),
            };
            self.sched.schedule(&maps, Some(now), &mut ctx)
        };
        let lr = map_assignment.locality_ratio();
        let mut engine = Engine::new(self.net.clone(), init.clone());
        engine.load(&map_assignment);
        let map_records = engine.run();

        // reduce phase at slowstart
        let gate = slowstart(&map_records, job.slowstart).max(now);
        let hint = majority_node(&map_records, &maps, self.nodes.len());
        for r in &mut reduces {
            r.src_hint = Some(hint);
        }
        let mut reduce_init = init;
        for r in &map_records {
            if reduce_init[r.node.0] < r.finish {
                reduce_init[r.node.0] = r.finish;
            }
        }
        let mut ledger2 = Ledger::with_initial(reduce_init.clone());
        let reduce_assignment = {
            let mut ctx = SchedCtx {
                controller: &mut self.ctrl,
                namenode: &self.nn,
                ledger: &mut ledger2,
                authorized: self.nodes.clone(),
                now: gate,
                cost: &self.cost,
            node_speed: Vec::new(),
            };
            self.sched.schedule(&reduces, Some(gate), &mut ctx)
        };
        let mut engine2 = Engine::new(self.net.clone(), reduce_init);
        engine2.load(&reduce_assignment);
        let reduce_records = engine2.run();

        // update the cluster's availability for subsequent jobs
        let mut all = map_records;
        all.extend(reduce_records);
        for r in &all {
            if self.node_free[r.node.0] < r.finish {
                self.node_free[r.node.0] = r.finish;
            }
        }
        let mut m = JobMetrics::from_records(&all, now, Some(gate));
        m.lr = lr;
        JobResult { job: job.id, name: job.name.clone(), submitted_at: now.0, metrics: m }
    }

    /// Run a whole trace through a submitter thread + this leader,
    /// demonstrating the channel architecture. Results come back in
    /// submission order.
    pub fn run_trace(mut self, arrivals: Vec<JobArrival>) -> Vec<JobResult> {
        let (tx, rx) = mpsc::channel::<JobRequest>();
        let submitter = thread::spawn(move || {
            for (id, arrival) in arrivals.into_iter().enumerate() {
                if tx.send(JobRequest { arrival, id }).is_err() {
                    return;
                }
            }
        });
        let mut results = Vec::new();
        while let Ok(req) = rx.recv() {
            results.push(self.handle(&req));
        }
        submitter.join().expect("submitter thread");
        results
    }
}

fn slowstart(map_records: &[TaskRecord], frac: f64) -> Secs {
    let mut fins: Vec<Secs> = map_records.iter().map(|r| r.finish).collect();
    fins.sort();
    let k = ((fins.len() as f64 * frac).ceil() as usize).clamp(1, fins.len());
    fins[k - 1]
}

fn majority_node(map_records: &[TaskRecord], maps: &[TaskSpec], n: usize) -> NodeId {
    let mut out = vec![0.0f64; n];
    for r in map_records {
        if let Some(t) = maps.iter().find(|t| t.id == r.task) {
            out[r.node.0] += t.output_mb;
        }
    }
    let mut best = 0;
    for (i, &v) in out.iter().enumerate() {
        if v > out[best] {
            best = i;
        }
    }
    NodeId(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{JobKind, TraceGen};

    fn trace(n: usize) -> Vec<JobArrival> {
        let mut rng = XorShift::new(11);
        TraceGen { mean_interarrival_secs: 120.0, sizes_mb: vec![150.0, 300.0] }
            .generate(n, &mut rng)
    }

    #[test]
    fn coordinator_processes_trace_in_order() {
        let c = Coordinator::new(ClusterSetup::default(), SchedulerKind::Bass, CostModel::rust_only());
        let results = c.run_trace(trace(5));
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.job.0, i);
            assert!(r.metrics.jt > 0.0);
        }
        // arrivals are increasing
        for w in results.windows(2) {
            assert!(w[0].submitted_at < w[1].submitted_at);
        }
    }

    #[test]
    fn cluster_state_carries_between_jobs() {
        let mut c =
            Coordinator::new(ClusterSetup::default(), SchedulerKind::Bass, CostModel::rust_only());
        let a1 = JobRequest {
            arrival: JobArrival { at_secs: 1.0, kind: JobKind::Sort, data_mb: 300.0 },
            id: 0,
        };
        // same job arriving immediately after: must queue behind the first
        let a2 = JobRequest {
            arrival: JobArrival { at_secs: 2.0, kind: JobKind::Sort, data_mb: 300.0 },
            id: 1,
        };
        let r1 = c.handle(&a1);
        let r2 = c.handle(&a2);
        assert!(
            r2.metrics.jt > r1.metrics.jt * 0.8,
            "second job should feel the first's load: {} vs {}",
            r2.metrics.jt,
            r1.metrics.jt
        );
    }

    #[test]
    fn bass_trace_beats_hds_trace() {
        let mk = |k| {
            Coordinator::new(ClusterSetup::default(), k, CostModel::rust_only())
                .run_trace(trace(6))
        };
        let bass: f64 = mk(SchedulerKind::Bass).iter().map(|r| r.metrics.jt).sum();
        let hds: f64 = mk(SchedulerKind::Hds).iter().map(|r| r.metrics.jt).sum();
        assert!(bass <= hds + 1e-6, "BASS total {bass} vs HDS total {hds}");
    }
}
