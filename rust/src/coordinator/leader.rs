//! Leader/worker coordination over std mpsc channels.

use std::sync::mpsc;
use std::thread;

use crate::cluster::Ledger;
use crate::mapreduce::{JobId, TaskSpec};
use crate::metrics::JobMetrics;
use crate::runtime::CostModel;
use crate::scenario::{
    shuffle_majority_node, slowstart_gate, AdmissionPolicy, BackgroundSpec, InitialLoad,
    ScenarioSpec, SimSession, StreamOutcome, Submission, TopologyShape, WorkloadSpec,
};
use crate::sched::{SchedCtx, SchedulerKind};
use crate::sim::{Engine, TaskRecord};
use crate::util::Secs;
use crate::workload::{JobArrival, WorkloadBuilder};

/// One job submission into the coordinator.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub arrival: JobArrival,
    pub id: usize,
}

/// Executed-job report streamed back to the submitter.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: JobId,
    pub name: String,
    pub submitted_at: f64,
    pub metrics: JobMetrics,
}

/// Cluster construction parameters for the coordinator.
#[derive(Debug, Clone)]
pub struct ClusterSetup {
    pub n_switches: usize,
    pub hosts_per_switch: usize,
    pub link_mbps: f64,
    pub slot_secs: f64,
    pub replication: usize,
    pub reduces: usize,
    pub bg_flows: usize,
    pub bg_rate_mb_s: f64,
    pub seed: u64,
}

impl Default for ClusterSetup {
    fn default() -> Self {
        Self {
            n_switches: 2,
            hosts_per_switch: 3,
            link_mbps: 100.0,
            slot_secs: 1.0,
            replication: 3,
            reduces: 2,
            bg_flows: 2,
            bg_rate_mb_s: 2.0,
            seed: 7,
        }
    }
}

impl ClusterSetup {
    /// The scenario this setup describes: an online cluster with
    /// background traffic and no pre-built workload (jobs arrive live).
    pub fn scenario(&self, kind: SchedulerKind) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(
            "coordinator",
            TopologyShape::Tree {
                switches: self.n_switches,
                hosts_per_switch: self.hosts_per_switch,
                edge_mbps: self.link_mbps,
                uplink_mbps: self.link_mbps,
            },
            WorkloadSpec::None,
        );
        s.scheduler = kind;
        s.slot_secs = self.slot_secs;
        s.replication = self.replication;
        s.reduces = self.reduces;
        s.seed = self.seed;
        // jobs arrive online; no synthetic initial idle
        s.initial = InitialLoad::Sampled { max_secs: 0.0 };
        s.background = BackgroundSpec { flows: self.bg_flows, rate_mb_s: self.bg_rate_mb_s };
        s
    }
}

/// The long-lived leader: owns the cluster session across jobs.
pub struct Coordinator {
    setup: ClusterSetup,
    scheduler_kind: SchedulerKind,
    /// The live cluster (controller, flow net, namenode, RNG, scheduler)
    /// built once through the scenario layer.
    sess: SimSession,
    /// Actual node availability, carried across jobs (isolated path).
    node_free: Vec<Secs>,
    cost: CostModel,
    /// Admission policy for the online stream path.
    policy: AdmissionPolicy,
}

impl Coordinator {
    pub fn new(setup: ClusterSetup, kind: SchedulerKind, cost: CostModel) -> Self {
        let sess = SimSession::new(&setup.scenario(kind));
        let node_free = vec![Secs::ZERO; sess.nodes.len()];
        Self {
            setup,
            scheduler_kind: kind,
            sess,
            node_free,
            cost,
            policy: AdmissionPolicy::default(),
        }
    }

    /// Builder-style admission-policy override for the stream path.
    pub fn with_admission(mut self, policy: AdmissionPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn scheduler_label(&self) -> &'static str {
        self.scheduler_kind.label()
    }

    /// Handle one job end-to-end at its arrival time — the **isolated
    /// static path**: two phase-split engines against the carried node
    /// availability, each job run to completion before the next. This is
    /// the reference the online stream's differential pin compares
    /// against (`rust/tests/proptests.rs`); live traces go through
    /// [`Coordinator::run_trace`] instead.
    pub fn handle(&mut self, req: &JobRequest) -> JobResult {
        self.handle_with_records(req).0
    }

    /// [`Coordinator::handle`], also returning the execution records
    /// (the record-level differential pin needs them).
    pub fn handle_with_records(&mut self, req: &JobRequest) -> (JobResult, Vec<TaskRecord>) {
        let now = Secs(req.arrival.at_secs);
        let mut builder = WorkloadBuilder::new(req.arrival.kind);
        builder.replication = self.setup.replication.min(self.sess.nodes.len());
        builder.reduces = self.setup.reduces;
        let job = builder.build(
            req.id,
            req.arrival.data_mb,
            &self.sess.nodes,
            &mut self.sess.nn,
            &mut self.sess.rng,
        );
        let maps: Vec<TaskSpec> = job.maps().cloned().collect();
        let mut reduces: Vec<TaskSpec> = job.reduces().cloned().collect();

        // node availability as of this arrival
        let init: Vec<Secs> = self.node_free.iter().map(|&f| f.max(now)).collect();
        self.sess.ledger = Ledger::with_initial(init.clone());

        // map phase
        let map_assignment = self.schedule(&maps, Some(now), now);
        let lr = map_assignment.locality_ratio();
        let mut engine = Engine::new(self.sess.net.clone(), init.clone());
        engine.load(&map_assignment);
        let map_records = engine.run();

        // reduce phase at slowstart
        let gate = slowstart_gate(&map_records, job.slowstart).max(now);
        let hint = shuffle_majority_node(&map_records, &maps, self.sess.nodes.len());
        for r in &mut reduces {
            r.src_hint = Some(hint);
        }
        let mut reduce_init = init;
        for r in &map_records {
            if reduce_init[r.node.0] < r.finish {
                reduce_init[r.node.0] = r.finish;
            }
        }
        self.sess.ledger = Ledger::with_initial(reduce_init.clone());
        let reduce_assignment = self.schedule(&reduces, Some(gate), gate);
        let mut engine2 = Engine::new(self.sess.net.clone(), reduce_init);
        engine2.load(&reduce_assignment);
        let reduce_records = engine2.run();

        // update the cluster's availability for subsequent jobs
        let mut all = map_records;
        all.extend(reduce_records);
        for r in &all {
            if self.node_free[r.node.0] < r.finish {
                self.node_free[r.node.0] = r.finish;
            }
        }
        let mut m = JobMetrics::from_records(&all, now, Some(gate));
        m.lr = lr;
        (JobResult { job: job.id, name: job.name.clone(), submitted_at: now.0, metrics: m }, all)
    }

    fn schedule(
        &mut self,
        tasks: &[TaskSpec],
        gate: Option<Secs>,
        now: Secs,
    ) -> crate::sim::Assignment {
        let mut ctx = SchedCtx {
            view: &crate::sdn::Oracle,
            controller: &mut self.sess.ctrl,
            namenode: &self.sess.nn,
            ledger: &mut self.sess.ledger,
            authorized: self.sess.nodes.clone(),
            now,
            cost: &self.cost,
            node_speed: Vec::new(),
            down: Vec::new(),
            bw_aware_sources: self.sess.spec.bw_aware_sources,
        };
        self.sess.sched.schedule(tasks, gate, &mut ctx)
    }

    /// Run a whole trace as an **online stream**: requests flow through a
    /// submitter thread (the channel architecture), and the leader plays
    /// the time-ordered submissions as one shared-cluster session —
    /// overlapping jobs contend for slots, calendar windows and the flow
    /// network (`scenario::online`). Results come back in submission
    /// order.
    ///
    /// Errs if the submitter disconnected mid-stream: a short count used
    /// to be silently truncated to however many requests arrived, which
    /// made a lost submission indistinguishable from a short trace.
    pub fn run_trace(self, arrivals: Vec<JobArrival>) -> anyhow::Result<Vec<JobResult>> {
        let outcome = self.run_stream(arrivals)?;
        Ok(outcome
            .jobs
            .iter()
            .map(|j| JobResult {
                job: j.job,
                name: j.name.clone(),
                submitted_at: j.submitted_at,
                metrics: j.metrics,
            })
            .collect())
    }

    /// [`Coordinator::run_trace`] returning the full [`StreamOutcome`]
    /// (per-job slowdowns, tagged records, reservation audits).
    pub fn run_stream(mut self, arrivals: Vec<JobArrival>) -> anyhow::Result<StreamOutcome> {
        let expected = arrivals.len();
        let (tx, rx) = mpsc::channel::<JobRequest>();
        let submitter = thread::spawn(move || -> usize {
            let mut sent = 0;
            for (id, arrival) in arrivals.into_iter().enumerate() {
                if tx.send(JobRequest { arrival, id }).is_err() {
                    return sent;
                }
                sent += 1;
            }
            sent
        });
        let mut subs: Vec<Submission> = Vec::with_capacity(expected);
        while let Ok(req) = rx.recv() {
            subs.push(Submission::from(req.arrival));
        }
        let sent = submitter.join().expect("submitter thread");
        anyhow::ensure!(
            sent == expected && subs.len() == expected,
            "job stream truncated: {} of {expected} submissions arrived ({sent} sent)",
            subs.len()
        );
        Ok(self.sess.run_stream(subs, self.policy, &self.cost))
    }

    /// The pre-stream sequential loop — every job handled end-to-end in
    /// isolation at its arrival. Kept as the static reference for the
    /// differential pin tests and slowdown baselines.
    pub fn run_trace_isolated(mut self, arrivals: Vec<JobArrival>) -> Vec<JobResult> {
        arrivals
            .into_iter()
            .enumerate()
            .map(|(id, arrival)| self.handle(&JobRequest { arrival, id }))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;
    use crate::workload::{JobKind, TraceGen};

    fn trace(n: usize) -> Vec<JobArrival> {
        let mut rng = XorShift::new(11);
        TraceGen { mean_interarrival_secs: 120.0, sizes_mb: vec![150.0, 300.0] }
            .generate(n, &mut rng)
    }

    #[test]
    fn coordinator_processes_trace_in_order() {
        let c =
            Coordinator::new(ClusterSetup::default(), SchedulerKind::Bass, CostModel::rust_only());
        let results = c.run_trace(trace(5)).expect("no submissions lost");
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.job.0, i);
            assert!(r.metrics.jt > 0.0);
        }
        // arrivals are increasing
        for w in results.windows(2) {
            assert!(w[0].submitted_at < w[1].submitted_at);
        }
    }

    #[test]
    fn stream_trace_matches_isolated_for_sparse_arrivals() {
        // gaps far beyond every makespan: the online stream must collapse
        // to the sequential static path exactly
        let mk = || {
            Coordinator::new(ClusterSetup::default(), SchedulerKind::Bass, CostModel::rust_only())
        };
        let mut rng = XorShift::new(9);
        let arrivals = TraceGen { mean_interarrival_secs: 10_000.0, sizes_mb: vec![150.0, 300.0] }
            .generate(4, &mut rng);
        let stream = mk().run_trace(arrivals.clone()).expect("stream");
        let isolated = mk().run_trace_isolated(arrivals);
        assert_eq!(stream.len(), isolated.len());
        for (s, i) in stream.iter().zip(&isolated) {
            assert_eq!(s.submitted_at, i.submitted_at);
            assert_eq!(s.metrics, i.metrics, "sparse stream must match the static path");
        }
    }

    #[test]
    fn stream_outcome_reports_contention() {
        // a burst of arrivals on one cluster: slowdown must be visible
        let c =
            Coordinator::new(ClusterSetup::default(), SchedulerKind::Bass, CostModel::rust_only());
        let arrivals: Vec<JobArrival> = (0..3)
            .map(|i| JobArrival {
                at_secs: 1.0 + i as f64,
                kind: JobKind::Sort,
                data_mb: 600.0,
            })
            .collect();
        let out = c.run_stream(arrivals).expect("stream");
        assert_eq!(out.jobs.len(), 3);
        assert!(out.stats.mean_slowdown > 1.0, "mean slowdown {}", out.stats.mean_slowdown);
        assert!(!out.records.is_empty());
    }

    #[test]
    fn cluster_state_carries_between_jobs() {
        let mut c =
            Coordinator::new(ClusterSetup::default(), SchedulerKind::Bass, CostModel::rust_only());
        let a1 = JobRequest {
            arrival: JobArrival { at_secs: 1.0, kind: JobKind::Sort, data_mb: 300.0 },
            id: 0,
        };
        // same job arriving immediately after: must queue behind the first
        let a2 = JobRequest {
            arrival: JobArrival { at_secs: 2.0, kind: JobKind::Sort, data_mb: 300.0 },
            id: 1,
        };
        let r1 = c.handle(&a1);
        let r2 = c.handle(&a2);
        assert!(
            r2.metrics.jt > r1.metrics.jt * 0.8,
            "second job should feel the first's load: {} vs {}",
            r2.metrics.jt,
            r1.metrics.jt
        );
    }

    #[test]
    fn bass_trace_beats_hds_trace() {
        let mk = |k| {
            Coordinator::new(ClusterSetup::default(), k, CostModel::rust_only())
                .run_trace(trace(6))
                .expect("stream")
        };
        let bass: f64 = mk(SchedulerKind::Bass).iter().map(|r| r.metrics.jt).sum();
        let hds: f64 = mk(SchedulerKind::Hds).iter().map(|r| r.metrics.jt).sum();
        assert!(bass <= hds + 1e-6, "BASS total {bass} vs HDS total {hds}");
    }
}
