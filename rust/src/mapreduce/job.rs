//! Job model: a bag of map tasks plus reduce tasks and shuffle geometry.

use crate::util::Secs;

use super::task::{TaskKind, TaskSpec};

/// Job identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub usize);

/// A submitted MapReduce job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    /// All tasks, maps first then reduces (ids are indices).
    pub tasks: Vec<TaskSpec>,
    /// Fraction of maps that must finish before reduces are scheduled
    /// (Hadoop's `mapreduce.job.reduce.slowstart.completedmaps`).
    pub slowstart: f64,
    pub submitted_at: Secs,
}

impl JobSpec {
    pub fn new(id: usize, name: impl Into<String>, tasks: Vec<TaskSpec>) -> Self {
        let job = Self {
            id: JobId(id),
            name: name.into(),
            tasks,
            slowstart: 0.5,
            submitted_at: Secs::ZERO,
        };
        job.validate();
        job
    }

    fn validate(&self) {
        let mut seen_reduce = false;
        for (i, t) in self.tasks.iter().enumerate() {
            assert_eq!(t.id.0, i, "task ids must be dense indices");
            match t.kind {
                TaskKind::Map => assert!(!seen_reduce, "maps must precede reduces"),
                TaskKind::Reduce => seen_reduce = true,
            }
        }
    }

    pub fn maps(&self) -> impl Iterator<Item = &TaskSpec> {
        self.tasks.iter().filter(|t| t.is_map())
    }

    pub fn reduces(&self) -> impl Iterator<Item = &TaskSpec> {
        self.tasks.iter().filter(|t| !t.is_map())
    }

    pub fn n_maps(&self) -> usize {
        self.maps().count()
    }

    pub fn n_reduces(&self) -> usize {
        self.reduces().count()
    }

    /// Total map output feeding the shuffle (MB).
    pub fn shuffle_volume_mb(&self) -> f64 {
        self.maps().map(|t| t.output_mb).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::BlockId;

    fn job() -> JobSpec {
        JobSpec::new(
            0,
            "wc",
            vec![
                TaskSpec::map(0, BlockId(0), 64.0, Secs(9.0), 16.0),
                TaskSpec::map(1, BlockId(1), 64.0, Secs(9.0), 16.0),
                TaskSpec::reduce(2, 32.0, Secs(12.0)),
            ],
        )
    }

    #[test]
    fn counts_and_volume() {
        let j = job();
        assert_eq!(j.n_maps(), 2);
        assert_eq!(j.n_reduces(), 1);
        assert!((j.shuffle_volume_mb() - 32.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "dense")]
    fn non_dense_ids_rejected() {
        JobSpec::new(0, "bad", vec![TaskSpec::map(1, BlockId(0), 64.0, Secs(1.0), 0.0)]);
    }

    #[test]
    #[should_panic(expected = "precede")]
    fn reduce_before_map_rejected() {
        JobSpec::new(
            0,
            "bad",
            vec![
                TaskSpec::reduce(0, 1.0, Secs(1.0)),
                TaskSpec::map(1, BlockId(0), 64.0, Secs(1.0), 0.0),
            ],
        );
    }
}
