//! Task model: the paper's `TK_i`.

use crate::hdfs::BlockId;
use crate::topology::NodeId;
use crate::util::Secs;

/// Task identifier, unique within a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// Map or reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// One schedulable task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Input split block — `Some` for maps (drives locality), `None` for
    /// reduces (input comes from the shuffle).
    pub input: Option<BlockId>,
    /// Bytes the task must pull before computing (MB). For maps this is
    /// the split size (0 when run data-locally); for reduces the total
    /// shuffle volume destined to this reduce.
    pub input_mb: f64,
    /// `TP_{i,j}` — computation time (homogeneous nodes, as the paper
    /// assumes; heterogeneity would make this per-node).
    pub compute: Secs,
    /// Map output size (MB) feeding the shuffle; 0 for reduces.
    pub output_mb: f64,
    /// Where the input actually sits for tasks without a block (reduces):
    /// the node holding the plurality of map output. Schedulers use it as
    /// the shuffle source and treat placement *on* it as transfer-free.
    pub src_hint: Option<NodeId>,
}

impl TaskSpec {
    pub fn map(id: usize, input: BlockId, input_mb: f64, compute: Secs, output_mb: f64) -> Self {
        Self {
            id: TaskId(id),
            kind: TaskKind::Map,
            input: Some(input),
            input_mb,
            compute,
            output_mb,
            src_hint: None,
        }
    }

    pub fn reduce(id: usize, input_mb: f64, compute: Secs) -> Self {
        Self {
            id: TaskId(id),
            kind: TaskKind::Reduce,
            input: None,
            input_mb,
            compute,
            output_mb: 0.0,
            src_hint: None,
        }
    }

    /// Attach a shuffle-source hint (builder style).
    pub fn with_src_hint(mut self, src: NodeId) -> Self {
        self.src_hint = Some(src);
        self
    }

    pub fn is_map(&self) -> bool {
        self.kind == TaskKind::Map
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let m = TaskSpec::map(0, BlockId(3), 64.0, Secs(9.0), 20.0);
        assert!(m.is_map());
        assert_eq!(m.input, Some(BlockId(3)));
        let r = TaskSpec::reduce(1, 128.0, Secs(12.0));
        assert!(!r.is_map());
        assert_eq!(r.input, None);
        assert_eq!(r.output_mb, 0.0);
    }
}
