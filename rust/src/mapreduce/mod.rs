//! MapReduce job model: tasks, jobs, phases, shuffle volumes.

pub mod job;
pub mod task;

pub use job::{JobId, JobSpec};
pub use task::{TaskId, TaskKind, TaskSpec};
