//! Fluid flow model with max-min fair sharing and optional QoS queues.
//!
//! Flows are (path, remaining MB, class). Rates are recomputed by
//! progressive filling whenever the flow set changes:
//!
//! * shared mode — classic max-min over every link's full capacity;
//! * QoS mode (Example 3) — the switch queues partition each link into
//!   per-class capacities (Q1/Q2/Q3), and max-min runs within each class.
//!
//! Static background load is modeled as ever-running flows with infinite
//! remaining volume, so foreground Hadoop traffic feels the contention.

use std::collections::HashMap;

use crate::sdn::qos::QosPolicy;
use crate::sdn::TrafficClass;
use crate::topology::LinkId;
use crate::util::{mbps_to_mb_per_s, Secs};

/// Flow identifier within a [`FlowNet`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub u64);

#[derive(Debug, Clone)]
struct Flow {
    path: Vec<LinkId>,
    remaining_mb: f64,
    class: TrafficClass,
    rate_mb_s: f64,
    /// SDN-enforced rate cap (background flows are rate-limited by the
    /// controller so the static `BW_rl` view stays truthful).
    max_rate_mb_s: f64,
}

/// The fluid network.
#[derive(Debug, Clone)]
pub struct FlowNet {
    /// Per-link capacity, MB/s.
    link_cap_mb_s: Vec<f64>,
    qos: Option<QosPolicy>,
    flows: HashMap<FlowId, Flow>,
    next_id: u64,
    /// Last time `settle` ran; rates are valid from here.
    clock: Secs,
}

impl FlowNet {
    pub fn new(link_caps_mbps: &[f64]) -> Self {
        Self {
            link_cap_mb_s: link_caps_mbps.iter().map(|&c| mbps_to_mb_per_s(c)).collect(),
            qos: None,
            flows: HashMap::new(),
            next_id: 0,
            clock: Secs::ZERO,
        }
    }

    /// Install a QoS policy (per-class link partitions).
    pub fn set_qos(&mut self, policy: QosPolicy) {
        self.qos = Some(policy);
        self.recompute();
    }

    pub fn clock(&self) -> Secs {
        self.clock
    }

    pub fn n_flows(&self) -> usize {
        self.flows.len()
    }

    pub fn rate_of(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.rate_mb_s)
    }

    pub fn remaining_of(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.remaining_mb)
    }

    /// Advance all flows to `now` at their current rates. `now` must be
    /// monotone. Flows that hit zero are NOT removed here — the engine
    /// decides completion order; use [`FlowNet::finished`].
    pub fn settle(&mut self, now: Secs) {
        assert!(now >= self.clock, "time went backwards: {now} < {}", self.clock);
        let dt = (now - self.clock).0;
        if dt > 0.0 {
            for f in self.flows.values_mut() {
                if f.remaining_mb.is_finite() {
                    f.remaining_mb = (f.remaining_mb - f.rate_mb_s * dt).max(0.0);
                    // snap float residue below one byte to zero, otherwise
                    // completion events converge on `now` without firing
                    if f.remaining_mb < 1e-6 {
                        f.remaining_mb = 0.0;
                    }
                }
            }
        }
        self.clock = now;
    }

    /// Add a flow at the current clock; rates are recomputed.
    pub fn add_flow(&mut self, path: Vec<LinkId>, size_mb: f64, class: TrafficClass) -> FlowId {
        self.add_flow_capped(path, size_mb, class, f64::INFINITY)
    }

    /// Add a flow with an SDN-enforced rate cap (MB/s).
    pub fn add_flow_capped(
        &mut self,
        path: Vec<LinkId>,
        size_mb: f64,
        class: TrafficClass,
        max_rate_mb_s: f64,
    ) -> FlowId {
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow { path, remaining_mb: size_mb, class, rate_mb_s: 0.0, max_rate_mb_s },
        );
        self.recompute();
        id
    }

    /// Permanent background flow (infinite volume, unlimited appetite).
    pub fn add_background(&mut self, path: Vec<LinkId>, class: TrafficClass) -> FlowId {
        self.add_flow(path, f64::INFINITY, class)
    }

    /// Permanent background flow rate-limited by the controller to
    /// `cap_mb_s` — keeps execution consistent with the static `BW_rl`
    /// view the schedulers plan against.
    pub fn add_background_capped(
        &mut self,
        path: Vec<LinkId>,
        class: TrafficClass,
        cap_mb_s: f64,
    ) -> FlowId {
        self.add_flow_capped(path, f64::INFINITY, class, cap_mb_s)
    }

    /// Remove a flow (finished or cancelled); rates are recomputed.
    pub fn remove_flow(&mut self, id: FlowId) -> Option<f64> {
        let f = self.flows.remove(&id)?;
        self.recompute();
        Some(f.remaining_mb)
    }

    /// Finite flows with zero remaining volume at the current clock.
    pub fn finished(&self) -> Vec<FlowId> {
        let mut v: Vec<FlowId> = self
            .flows
            .iter()
            .filter(|(_, f)| f.remaining_mb <= 0.0)
            .map(|(&id, _)| id)
            .collect();
        v.sort_by_key(|id| id.0);
        v
    }

    /// Earliest (time, flow) at which a finite flow completes if rates
    /// stay fixed; `None` when no finite flows are active or all rates 0.
    pub fn next_completion(&self) -> Option<(Secs, FlowId)> {
        let mut best: Option<(Secs, FlowId)> = None;
        for (&id, f) in &self.flows {
            if !f.remaining_mb.is_finite() {
                continue;
            }
            if f.rate_mb_s <= 0.0 {
                continue;
            }
            let t = Secs(self.clock.0 + f.remaining_mb / f.rate_mb_s);
            best = match best {
                None => Some((t, id)),
                Some((bt, bid)) => {
                    if t < bt || (t == bt && id.0 < bid.0) {
                        Some((t, id))
                    } else {
                        Some((bt, bid))
                    }
                }
            };
        }
        best
    }

    /// Max-min progressive filling. With QoS, fill each class against its
    /// per-link queue capacity; classes are strictly partitioned so they
    /// do not interact (the paper's HTB-style queue config).
    fn recompute(&mut self) {
        match self.qos.clone() {
            None => {
                let caps = self.link_cap_mb_s.clone();
                let ids: Vec<FlowId> = self.flows.keys().copied().collect();
                self.fill(&ids, &caps);
            }
            Some(policy) => {
                for class in
                    [TrafficClass::Shuffle, TrafficClass::HadoopOther, TrafficClass::Background]
                {
                    let qrate = match policy.classify(class) {
                        None => None, // shared policy object but no queues
                        Some(qid) => Some(mbps_to_mb_per_s(policy.queues[qid.0].rate_mbps)),
                    };
                    let caps: Vec<f64> = self
                        .link_cap_mb_s
                        .iter()
                        .map(|&c| qrate.map_or(c, |q| q.min(c)))
                        .collect();
                    let ids: Vec<FlowId> = self
                        .flows
                        .iter()
                        .filter(|(_, f)| f.class == class)
                        .map(|(&id, _)| id)
                        .collect();
                    self.fill(&ids, &caps);
                }
            }
        }
    }

    /// Progressive filling of `ids` against `caps` (indexed by link).
    ///
    /// Perf note (§Perf L3): works on a flat snapshot (id, path, cap) —
    /// no per-access FlowId hashing, no O(F²) retains — then writes the
    /// computed rates back in one pass. ~100x on 200-flow recomputes.
    fn fill(&mut self, ids: &[FlowId], caps: &[f64]) {
        let mut order: Vec<FlowId> = ids.to_vec();
        order.sort_by_key(|id| id.0);
        // snapshot: (id, path, cap, computed rate)
        let mut snap: Vec<(FlowId, Vec<LinkId>, f64, f64)> = order
            .iter()
            .map(|id| {
                let f = &self.flows[id];
                (*id, f.path.clone(), f.max_rate_mb_s, 0.0)
            })
            .collect();
        // empty-path flows (src == dst) are instantaneous
        let mut active: Vec<usize> = Vec::with_capacity(snap.len());
        for (i, e) in snap.iter_mut().enumerate() {
            if e.1.is_empty() {
                e.3 = f64::INFINITY;
            } else {
                active.push(i);
            }
        }
        let mut remaining_cap = caps.to_vec();
        let mut count = vec![0usize; caps.len()];
        while !active.is_empty() {
            count.iter_mut().for_each(|c| *c = 0);
            for &i in &active {
                for l in &snap[i].1 {
                    count[l.0] += 1;
                }
            }
            let mut bottleneck: Option<(f64, usize)> = None;
            for (l, &c) in count.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let share = remaining_cap[l] / c as f64;
                if bottleneck.map_or(true, |(s, _)| share < s) {
                    bottleneck = Some((share, l));
                }
            }
            let Some((share, bl)) = bottleneck else { break };
            // flows rate-capped below the would-be share freeze at their
            // cap first (classic max-min with per-flow caps)
            let any_capped = active.iter().any(|&i| snap[i].2 < share);
            let mut still_active = Vec::with_capacity(active.len());
            for &i in &active {
                let freeze = if any_capped {
                    snap[i].2 < share
                } else {
                    snap[i].1.contains(&LinkId(bl))
                };
                if freeze {
                    let rate = if any_capped { snap[i].2 } else { share };
                    snap[i].3 = rate;
                    for l in &snap[i].1 {
                        remaining_cap[l.0] = (remaining_cap[l.0] - rate).max(0.0);
                    }
                } else {
                    still_active.push(i);
                }
            }
            active = still_active;
        }
        for (id, _, _, rate) in snap {
            self.flows.get_mut(&id).unwrap().rate_mb_s = rate;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3 links of 80 Mbps = 10 MB/s each.
    fn net() -> FlowNet {
        FlowNet::new(&[80.0, 80.0, 80.0])
    }

    #[test]
    fn single_flow_gets_bottleneck_rate() {
        let mut n = net();
        let f = n.add_flow(vec![LinkId(0), LinkId(1)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(f).unwrap() - 10.0).abs() < 1e-9);
        let (t, id) = n.next_completion().unwrap();
        assert_eq!(id, f);
        assert!((t.0 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        let b = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(a).unwrap() - 5.0).abs() < 1e-9);
        assert!((n.rate_of(b).unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_reallocates_after_bottleneck() {
        // a: links 0,1; b: link 0; c: link 1.
        // round 1: link0 and link1 both have 2 flows -> share 5; freeze all.
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0), LinkId(1)], 1e3, TrafficClass::HadoopOther);
        let b = n.add_flow(vec![LinkId(0)], 1e3, TrafficClass::HadoopOther);
        let c = n.add_flow(vec![LinkId(1)], 1e3, TrafficClass::HadoopOther);
        let (ra, rb, rc) =
            (n.rate_of(a).unwrap(), n.rate_of(b).unwrap(), n.rate_of(c).unwrap());
        assert!((ra - 5.0).abs() < 1e-9);
        assert!((rb - 5.0).abs() < 1e-9);
        assert!((rc - 5.0).abs() < 1e-9);
        // remove a: b and c each get the full 10
        n.remove_flow(a);
        assert!((n.rate_of(b).unwrap() - 10.0).abs() < 1e-9);
        assert!((n.rate_of(c).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn settle_drains_remaining() {
        let mut n = net();
        let f = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        n.settle(Secs(4.0));
        assert!((n.remaining_of(f).unwrap() - 60.0).abs() < 1e-9);
        n.settle(Secs(10.0));
        assert_eq!(n.remaining_of(f).unwrap(), 0.0);
        assert_eq!(n.finished(), vec![f]);
    }

    #[test]
    fn background_flow_never_finishes_but_contends() {
        let mut n = net();
        let _bg = n.add_background(vec![LinkId(0)], TrafficClass::Background);
        let f = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(f).unwrap() - 5.0).abs() < 1e-9);
        n.settle(Secs(100.0));
        assert_eq!(n.finished(), vec![f]); // background not in finished()
    }

    #[test]
    fn qos_isolates_shuffle_from_background() {
        // Example 3: 150 Mbps switch, Q1=100 (shuffle), Q3=10 (background).
        let mut n = FlowNet::new(&[150.0]);
        let sh = n.add_flow(vec![LinkId(0)], 1e3, TrafficClass::Shuffle);
        for _ in 0..5 {
            n.add_background(vec![LinkId(0)], TrafficClass::Background);
        }
        // shared: shuffle gets 150/6 Mbps = 3.125 MB/s
        assert!((n.rate_of(sh).unwrap() - mbps_to_mb_per_s(25.0)).abs() < 1e-9);
        // queued: shuffle keeps Q1's full 100 Mbps = 12.5 MB/s
        n.set_qos(QosPolicy::example3());
        assert!((n.rate_of(sh).unwrap() - 12.5).abs() < 1e-9);
    }

    #[test]
    fn empty_path_flow_is_instant() {
        let mut n = net();
        let f = n.add_flow(vec![], 100.0, TrafficClass::HadoopOther);
        assert!(n.rate_of(f).unwrap().is_infinite());
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn settle_rejects_time_reversal() {
        let mut n = net();
        n.settle(Secs(5.0));
        n.settle(Secs(4.0));
    }
}
