//! Fluid flow model with max-min fair sharing and optional QoS queues.
//!
//! Flows are (path, remaining MB, class). Rates come from progressive
//! filling whenever the flow set changes:
//!
//! * shared mode — classic max-min over every link's full capacity;
//! * QoS mode (Example 3) — the switch queues partition each link into
//!   per-class capacities (Q1/Q2/Q3), and max-min runs within each class.
//!
//! Static background load is modeled as ever-running flows with infinite
//! remaining volume, so foreground Hadoop traffic feels the contention.
//!
//! ## Perf L4: incremental data structures (see DESIGN.md)
//!
//! The seed recomputed *every* flow's rate from scratch on every
//! add/remove — O(F·L) with per-flow path clones, tripled under QoS —
//! which made execution quadratic in flow count. This version is built
//! for churn:
//!
//! * flows live in a **slab arena** (`Vec<Option<Flow>>` + free list);
//!   a [`FlowId`] packs `(creation seq << 32) | slot`, so lookups are
//!   O(1) array probes (no hashing) while id *order* still equals
//!   creation order, preserving every tie-break of the old code;
//! * a **per-link flow index** makes membership changes local: an
//!   add/remove only marks its links dirty, and the next read refills
//!   just the link-connected component (per traffic class in QoS mode)
//!   whose membership actually changed — progressive filling decomposes
//!   exactly across components because disjoint components share no
//!   links (rates match the from-scratch fill to f64 dust; see the
//!   `flownet` property tests);
//! * recomputation is **lazy**: membership changes accumulate and one
//!   refill runs at the next `settle`/`rate_of`/`next_completion`, so a
//!   burst of same-instant adds/removes (the engine's `FlowCheck`
//!   batches) costs one refill instead of one per flow;
//! * a **completion heap** of `(predicted finish, id)` entries, lazily
//!   invalidated by per-slot versions, makes [`FlowNet::next_completion`]
//!   O(log F) amortized; predictions are settle-invariant while a flow's
//!   rate is unchanged, and the rare nonlinear states (remaining snapped
//!   to zero, empty-path flows with infinite rate) fall back to the
//!   seed's exact scan;
//! * all traversal/refill buffers are reused scratch; released path
//!   vectors return to a pool ([`FlowNet::add_flow_slice`] recycles
//!   them), so steady-state churn allocates nothing.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sdn::qos::QosPolicy;
use crate::sdn::TrafficClass;
use crate::topology::LinkId;
use crate::util::{mbps_to_mb_per_s, Secs};

/// Flow identifier within a [`FlowNet`]. The raw value packs the slab
/// slot in the low 32 bits and a monotone creation sequence in the high
/// bits, so comparing `id.0` compares creation order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub u64);

impl FlowId {
    fn slot(self) -> usize {
        (self.0 & 0xFFFF_FFFF) as usize
    }
}

#[derive(Debug, Clone)]
struct Flow {
    id: FlowId,
    path: Vec<LinkId>,
    remaining_mb: f64,
    class: TrafficClass,
    rate_mb_s: f64,
    /// SDN-enforced rate cap (background flows are rate-limited by the
    /// controller so the static `BW_rl` view stays truthful).
    max_rate_mb_s: f64,
    /// Bumped on every rate change; stale completion-heap entries carry
    /// an older version and are discarded lazily.
    version: u32,
}

/// A queued completion prediction: valid while the slot still holds the
/// same flow at the same version. Field order gives the (time, id)
/// ordering the seed used: earliest completion first, lowest id on ties.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct CompletionEntry {
    at: Secs,
    id: u64,
    slot: u32,
    version: u32,
}

fn class_index(class: TrafficClass) -> usize {
    match class {
        TrafficClass::Shuffle => 0,
        TrafficClass::HadoopOther => 1,
        TrafficClass::Background => 2,
    }
}

const CLASSES: [TrafficClass; 3] =
    [TrafficClass::Shuffle, TrafficClass::HadoopOther, TrafficClass::Background];

/// A class's share of one link under `policy`: the queue rate capped at
/// the link rate, or the full link when the class has no queue. The one
/// definition both `set_qos` and capacity changes derive partitions from.
fn class_link_cap(policy: &QosPolicy, class: TrafficClass, link_cap_mb_s: f64) -> f64 {
    match policy.classify(class) {
        Some(qid) => mbps_to_mb_per_s(policy.queues[qid.0].rate_mbps).min(link_cap_mb_s),
        None => link_cap_mb_s,
    }
}

/// The fluid network.
#[derive(Debug, Clone)]
pub struct FlowNet {
    /// Per-link capacity, MB/s.
    link_cap_mb_s: Vec<f64>,
    qos: Option<QosPolicy>,
    /// Per-class link capacities when a QoS policy is installed
    /// (`min(queue rate, link rate)` per link); empty in shared mode.
    class_caps: Vec<Vec<f64>>,
    /// Slab arena: `FlowId::slot` indexes here.
    slots: Vec<Option<Flow>>,
    free: Vec<u32>,
    /// Per-link index of occupied slots.
    link_flows: Vec<Vec<u32>>,
    n_live: usize,
    seq: u32,
    /// Last time `settle` ran; rates are valid from here.
    clock: Secs,
    /// Lazily-invalidated completion predictions.
    heap: BinaryHeap<Reverse<CompletionEntry>>,
    /// Links whose flow membership changed since the last refill, per
    /// traffic class (unioned in shared mode).
    pending: [Vec<usize>; 3],
    /// Set by `set_qos`: every partition must refill.
    full_dirty: bool,
    /// Finite flows currently at zero remaining volume; while any exist
    /// `next_completion` uses the exact scan (their prediction is "the
    /// current clock", which a stored entry cannot track).
    n_zero: usize,
    /// Live empty-path (infinite-rate) flows; same exact-scan fallback.
    n_instant: usize,
    /// Recycled path vectors from removed flows.
    path_pool: Vec<Vec<LinkId>>,
    // ---- reusable scratch (meaningless between calls) ----
    members: Vec<(u64, u32)>,
    member_links: Vec<usize>,
    seen_link: Vec<bool>,
    seen_slot: Vec<bool>,
    stack: Vec<usize>,
    active: Vec<u32>,
    still_active: Vec<u32>,
    rates: Vec<f64>,
    remaining_cap: Vec<f64>,
    count: Vec<u32>,
}

impl FlowNet {
    pub fn new(link_caps_mbps: &[f64]) -> Self {
        let n = link_caps_mbps.len();
        Self {
            link_cap_mb_s: link_caps_mbps.iter().map(|&c| mbps_to_mb_per_s(c)).collect(),
            qos: None,
            class_caps: Vec::new(),
            slots: Vec::new(),
            free: Vec::new(),
            link_flows: vec![Vec::new(); n],
            n_live: 0,
            seq: 0,
            clock: Secs::ZERO,
            heap: BinaryHeap::new(),
            pending: [Vec::new(), Vec::new(), Vec::new()],
            full_dirty: false,
            n_zero: 0,
            n_instant: 0,
            path_pool: Vec::new(),
            members: Vec::new(),
            member_links: Vec::new(),
            seen_link: vec![false; n],
            seen_slot: Vec::new(),
            stack: Vec::new(),
            active: Vec::new(),
            still_active: Vec::new(),
            rates: Vec::new(),
            remaining_cap: vec![0.0; n],
            count: vec![0; n],
        }
    }

    /// Install a QoS policy (per-class link partitions).
    pub fn set_qos(&mut self, policy: QosPolicy) {
        self.class_caps = CLASSES
            .iter()
            .map(|&class| {
                self.link_cap_mb_s
                    .iter()
                    .map(|&c| class_link_cap(&policy, class, c))
                    .collect()
            })
            .collect();
        self.qos = Some(policy);
        self.full_dirty = true;
        for p in &mut self.pending {
            p.clear();
        }
    }

    /// Dynamics hook: change one link's usable capacity (MB/s) in place —
    /// degradation or restoration. In QoS mode the per-class partitions of
    /// the link are re-derived from the installed policy. Rates refresh
    /// lazily on the next read, exactly like a membership change; flows
    /// currently crossing the link re-rate from the current instant
    /// (callers settle to "now" first — the engine's event loop does).
    pub fn set_link_capacity_mb_s(&mut self, link: LinkId, cap_mb_s: f64) {
        let l = link.0;
        self.link_cap_mb_s[l] = cap_mb_s.max(0.0);
        if let Some(policy) = &self.qos {
            let cap = self.link_cap_mb_s[l];
            for (ci, &class) in CLASSES.iter().enumerate() {
                self.class_caps[ci][l] = class_link_cap(policy, class, cap);
            }
        }
        if !self.full_dirty {
            for p in &mut self.pending {
                p.push(l);
            }
        }
    }

    pub fn link_capacity_mb_s(&self, link: LinkId) -> f64 {
        self.link_cap_mb_s[link.0]
    }

    pub fn clock(&self) -> Secs {
        self.clock
    }

    pub fn n_flows(&self) -> usize {
        self.n_live
    }

    fn flow(&self, id: FlowId) -> Option<&Flow> {
        match self.slots.get(id.slot()) {
            Some(Some(f)) if f.id == id => Some(f),
            _ => None,
        }
    }

    pub fn rate_of(&mut self, id: FlowId) -> Option<f64> {
        self.flush();
        self.flow(id).map(|f| f.rate_mb_s)
    }

    pub fn remaining_of(&self, id: FlowId) -> Option<f64> {
        self.flow(id).map(|f| f.remaining_mb)
    }

    /// Advance all flows to `now` at their current rates. `now` must be
    /// monotone. Flows that hit zero are NOT removed here — the engine
    /// decides completion order; use [`FlowNet::finished`].
    pub fn settle(&mut self, now: Secs) {
        assert!(now >= self.clock, "time went backwards: {now} < {}", self.clock);
        self.flush();
        let dt = (now - self.clock).0;
        if dt > 0.0 {
            for f in self.slots.iter_mut().flatten() {
                if f.remaining_mb.is_finite() && f.remaining_mb > 0.0 {
                    f.remaining_mb = (f.remaining_mb - f.rate_mb_s * dt).max(0.0);
                    // snap float residue below one byte to zero, otherwise
                    // completion events converge on `now` without firing
                    if f.remaining_mb < 1e-6 {
                        f.remaining_mb = 0.0;
                    }
                    if f.remaining_mb == 0.0 {
                        self.n_zero += 1;
                    }
                }
            }
        }
        self.clock = now;
    }

    /// Add a flow at the current clock; rates refresh on the next read.
    pub fn add_flow(&mut self, path: Vec<LinkId>, size_mb: f64, class: TrafficClass) -> FlowId {
        self.insert(path, size_mb, class, f64::INFINITY)
    }

    /// [`FlowNet::add_flow`] without handing over a path allocation: the
    /// path is copied into a recycled vector from the removal pool.
    pub fn add_flow_slice(&mut self, path: &[LinkId], size_mb: f64, class: TrafficClass) -> FlowId {
        let mut p = self.path_pool.pop().unwrap_or_default();
        p.clear();
        p.extend_from_slice(path);
        self.insert(p, size_mb, class, f64::INFINITY)
    }

    /// Add a flow with an SDN-enforced rate cap (MB/s).
    pub fn add_flow_capped(
        &mut self,
        path: Vec<LinkId>,
        size_mb: f64,
        class: TrafficClass,
        max_rate_mb_s: f64,
    ) -> FlowId {
        self.insert(path, size_mb, class, max_rate_mb_s)
    }

    /// Permanent background flow (infinite volume, unlimited appetite).
    pub fn add_background(&mut self, path: Vec<LinkId>, class: TrafficClass) -> FlowId {
        self.add_flow(path, f64::INFINITY, class)
    }

    /// Permanent background flow rate-limited by the controller to
    /// `cap_mb_s` — keeps execution consistent with the static `BW_rl`
    /// view the schedulers plan against.
    pub fn add_background_capped(
        &mut self,
        path: Vec<LinkId>,
        class: TrafficClass,
        cap_mb_s: f64,
    ) -> FlowId {
        self.add_flow_capped(path, f64::INFINITY, class, cap_mb_s)
    }

    fn insert(
        &mut self,
        path: Vec<LinkId>,
        size_mb: f64,
        class: TrafficClass,
        max_rate_mb_s: f64,
    ) -> FlowId {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(None);
                self.seen_slot.push(false);
                (self.slots.len() - 1) as u32
            }
        };
        let id = FlowId(((self.seq as u64) << 32) | slot as u64);
        self.seq = self.seq.checked_add(1).expect("flow id space exhausted");
        for &l in &path {
            self.link_flows[l.0].push(slot);
        }
        let instant = path.is_empty();
        if instant {
            self.n_instant += 1;
        } else {
            self.mark_dirty(class, &path);
        }
        if size_mb.is_finite() && size_mb <= 0.0 {
            self.n_zero += 1;
        }
        self.slots[slot as usize] = Some(Flow {
            id,
            path,
            remaining_mb: size_mb,
            class,
            // empty-path flows (src == dst) are instantaneous
            rate_mb_s: if instant { f64::INFINITY } else { 0.0 },
            max_rate_mb_s,
            version: 0,
        });
        self.n_live += 1;
        id
    }

    /// Remove a flow (finished or cancelled); rates refresh lazily.
    pub fn remove_flow(&mut self, id: FlowId) -> Option<f64> {
        self.flow(id)?;
        let f = self.slots[id.slot()].take().expect("checked above");
        let slot = id.slot() as u32;
        for &l in &f.path {
            let v = &mut self.link_flows[l.0];
            let pos = v.iter().position(|&s| s == slot).expect("indexed flow");
            v.swap_remove(pos);
        }
        self.mark_dirty(f.class, &f.path);
        if f.path.is_empty() {
            self.n_instant -= 1;
        }
        if f.remaining_mb.is_finite() && f.remaining_mb <= 0.0 {
            self.n_zero -= 1;
        }
        self.free.push(slot);
        self.n_live -= 1;
        let mut path = f.path;
        path.clear();
        self.path_pool.push(path);
        Some(f.remaining_mb)
    }

    /// Finite flows with zero remaining volume at the current clock,
    /// written into a caller-reused buffer (sorted by id).
    pub fn finished_into(&self, out: &mut Vec<FlowId>) {
        out.clear();
        for f in self.slots.iter().flatten() {
            if f.remaining_mb <= 0.0 {
                out.push(f.id);
            }
        }
        out.sort_by_key(|id| id.0);
    }

    /// Allocating convenience wrapper around [`FlowNet::finished_into`].
    pub fn finished(&self) -> Vec<FlowId> {
        let mut v = Vec::new();
        self.finished_into(&mut v);
        v
    }

    /// Earliest (time, flow) at which a finite flow completes if rates
    /// stay fixed; `None` when no finite flows are active or all rates 0.
    pub fn next_completion(&mut self) -> Option<(Secs, FlowId)> {
        self.flush();
        if self.n_zero > 0 || self.n_instant > 0 {
            // zero-remaining and infinite-rate flows predict "the current
            // clock", which stored entries cannot represent: exact scan
            let mut best: Option<(Secs, FlowId)> = None;
            for f in self.slots.iter().flatten() {
                if !f.remaining_mb.is_finite() || f.rate_mb_s <= 0.0 {
                    continue;
                }
                let t = Secs(self.clock.0 + f.remaining_mb / f.rate_mb_s);
                let better = match best {
                    None => true,
                    Some((bt, bid)) => t < bt || (t == bt && f.id.0 < bid.0),
                };
                if better {
                    best = Some((t, f.id));
                }
            }
            return best;
        }
        while let Some(&Reverse(e)) = self.heap.peek() {
            let valid = match &self.slots[e.slot as usize] {
                Some(f) => f.id.0 == e.id && f.version == e.version,
                None => false,
            };
            if valid {
                return Some((e.at, FlowId(e.id)));
            }
            self.heap.pop();
        }
        None
    }

    // ---- incremental recomputation ------------------------------------

    fn mark_dirty(&mut self, class: TrafficClass, path: &[LinkId]) {
        if self.full_dirty || path.is_empty() {
            return;
        }
        let p = &mut self.pending[class_index(class)];
        for &l in path {
            p.push(l.0);
        }
    }

    /// Refill every partition whose membership changed since the last
    /// read. Shared mode treats all classes as one partition.
    fn flush(&mut self) {
        if self.full_dirty {
            self.full_dirty = false;
            for p in &mut self.pending {
                p.clear();
            }
            if self.qos.is_none() {
                self.collect_all(None);
                self.refill(None);
            } else {
                for ci in 0..3 {
                    self.collect_all(Some(ci));
                    self.refill(Some(ci));
                }
            }
            return;
        }
        if self.pending.iter().all(|p| p.is_empty()) {
            return;
        }
        if self.qos.is_none() {
            self.stack.clear();
            for p in &mut self.pending {
                self.stack.append(p);
            }
            self.collect_component(None);
            self.refill(None);
        } else {
            for ci in 0..self.pending.len() {
                if self.pending[ci].is_empty() {
                    continue;
                }
                self.stack.clear();
                let mut seeds = std::mem::take(&mut self.pending[ci]);
                self.stack.append(&mut seeds);
                self.pending[ci] = seeds;
                self.collect_component(Some(ci));
                self.refill(Some(ci));
            }
        }
    }

    /// Gather every (routed) flow of a partition into the member scratch.
    fn collect_all(&mut self, class: Option<usize>) {
        self.members.clear();
        self.member_links.clear();
        for (slot, f) in self.slots.iter().enumerate() {
            let Some(f) = f else { continue };
            if f.path.is_empty() {
                continue;
            }
            if let Some(ci) = class {
                if class_index(f.class) != ci {
                    continue;
                }
            }
            self.members.push((f.id.0, slot as u32));
            for &l in &f.path {
                if !self.seen_link[l.0] {
                    self.seen_link[l.0] = true;
                    self.member_links.push(l.0);
                }
            }
        }
        for &l in &self.member_links {
            self.seen_link[l] = false;
        }
        self.member_links.sort_unstable();
        self.members.sort_unstable();
    }

    /// BFS over the per-link index from the seed links in `self.stack`,
    /// collecting the link-connected component of the partition.
    fn collect_component(&mut self, class: Option<usize>) {
        self.members.clear();
        self.member_links.clear();
        while let Some(l) = self.stack.pop() {
            if self.seen_link[l] {
                continue;
            }
            self.seen_link[l] = true;
            self.member_links.push(l);
            for &slot in &self.link_flows[l] {
                if self.seen_slot[slot as usize] {
                    continue;
                }
                let f = self.slots[slot as usize].as_ref().expect("indexed flow");
                if let Some(ci) = class {
                    if class_index(f.class) != ci {
                        continue;
                    }
                }
                self.seen_slot[slot as usize] = true;
                self.members.push((f.id.0, slot));
                for &l2 in &f.path {
                    if !self.seen_link[l2.0] {
                        self.stack.push(l2.0);
                    }
                }
            }
        }
        for &(_, slot) in &self.members {
            self.seen_slot[slot as usize] = false;
        }
        for &l in &self.member_links {
            self.seen_link[l] = false;
        }
        self.member_links.sort_unstable();
        self.members.sort_unstable();
    }

    /// Progressive filling of the member flows against the partition's
    /// capacities. Semantics mirror the seed's from-scratch `fill` —
    /// identical bottleneck selection (ascending link id, strict min),
    /// identical cap-freeze rule, identical id-ordered freeze passes —
    /// restricted to one link-connected component, with counts maintained
    /// incrementally instead of recounted per round.
    fn refill(&mut self, class: Option<usize>) {
        let m = self.members.len();
        self.rates.clear();
        self.rates.resize(m, 0.0);
        self.active.clear();
        self.active.extend(0..m as u32);
        for &l in &self.member_links {
            self.remaining_cap[l] = match class {
                None => self.link_cap_mb_s[l],
                Some(ci) => self.class_caps[ci][l],
            };
        }
        for &(_, slot) in &self.members {
            let f = self.slots[slot as usize].as_ref().expect("member flow");
            for &l in &f.path {
                self.count[l.0] += 1;
            }
        }
        while !self.active.is_empty() {
            let mut bottleneck: Option<(f64, usize)> = None;
            for &l in &self.member_links {
                let c = self.count[l];
                if c == 0 {
                    continue;
                }
                let share = self.remaining_cap[l] / c as f64;
                if bottleneck.map_or(true, |(s, _)| share < s) {
                    bottleneck = Some((share, l));
                }
            }
            let Some((share, bl)) = bottleneck else { break };
            // flows rate-capped below the would-be share freeze at their
            // cap first (classic max-min with per-flow caps)
            let mut any_capped = false;
            for &k in &self.active {
                let slot = self.members[k as usize].1 as usize;
                if self.slots[slot].as_ref().expect("member flow").max_rate_mb_s < share {
                    any_capped = true;
                    break;
                }
            }
            self.still_active.clear();
            for &k in &self.active {
                let slot = self.members[k as usize].1 as usize;
                let f = self.slots[slot].as_ref().expect("member flow");
                let freeze = if any_capped {
                    f.max_rate_mb_s < share
                } else {
                    f.path.contains(&LinkId(bl))
                };
                if freeze {
                    let rate = if any_capped { f.max_rate_mb_s } else { share };
                    self.rates[k as usize] = rate;
                    for &l in &f.path {
                        self.remaining_cap[l.0] = (self.remaining_cap[l.0] - rate).max(0.0);
                        self.count[l.0] -= 1;
                    }
                } else {
                    self.still_active.push(k);
                }
            }
            std::mem::swap(&mut self.active, &mut self.still_active);
        }
        // restore the all-zero count invariant (break leaves leftovers)
        for &l in &self.member_links {
            self.count[l] = 0;
        }
        // write back; push fresh completion predictions on rate changes
        let clock = self.clock;
        for (&(_, slot), &rate) in self.members.iter().zip(&self.rates) {
            let f = self.slots[slot as usize].as_mut().expect("member flow");
            if rate != f.rate_mb_s {
                f.rate_mb_s = rate;
                f.version = f.version.wrapping_add(1);
                if f.remaining_mb.is_finite() && rate > 0.0 {
                    let e = CompletionEntry {
                        at: Secs(clock.0 + f.remaining_mb / rate),
                        id: f.id.0,
                        slot,
                        version: f.version,
                    };
                    self.heap.push(Reverse(e));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3 links of 80 Mbps = 10 MB/s each.
    fn net() -> FlowNet {
        FlowNet::new(&[80.0, 80.0, 80.0])
    }

    #[test]
    fn single_flow_gets_bottleneck_rate() {
        let mut n = net();
        let f = n.add_flow(vec![LinkId(0), LinkId(1)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(f).unwrap() - 10.0).abs() < 1e-9);
        let (t, id) = n.next_completion().unwrap();
        assert_eq!(id, f);
        assert!((t.0 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        let b = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(a).unwrap() - 5.0).abs() < 1e-9);
        assert!((n.rate_of(b).unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn max_min_reallocates_after_bottleneck() {
        // a: links 0,1; b: link 0; c: link 1.
        // round 1: link0 and link1 both have 2 flows -> share 5; freeze all.
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0), LinkId(1)], 1e3, TrafficClass::HadoopOther);
        let b = n.add_flow(vec![LinkId(0)], 1e3, TrafficClass::HadoopOther);
        let c = n.add_flow(vec![LinkId(1)], 1e3, TrafficClass::HadoopOther);
        let (ra, rb, rc) =
            (n.rate_of(a).unwrap(), n.rate_of(b).unwrap(), n.rate_of(c).unwrap());
        assert!((ra - 5.0).abs() < 1e-9);
        assert!((rb - 5.0).abs() < 1e-9);
        assert!((rc - 5.0).abs() < 1e-9);
        // remove a: b and c each get the full 10
        n.remove_flow(a);
        assert!((n.rate_of(b).unwrap() - 10.0).abs() < 1e-9);
        assert!((n.rate_of(c).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn settle_drains_remaining() {
        let mut n = net();
        let f = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        n.settle(Secs(4.0));
        assert!((n.remaining_of(f).unwrap() - 60.0).abs() < 1e-9);
        n.settle(Secs(10.0));
        assert_eq!(n.remaining_of(f).unwrap(), 0.0);
        assert_eq!(n.finished(), vec![f]);
    }

    #[test]
    fn background_flow_never_finishes_but_contends() {
        let mut n = net();
        let _bg = n.add_background(vec![LinkId(0)], TrafficClass::Background);
        let f = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(f).unwrap() - 5.0).abs() < 1e-9);
        n.settle(Secs(100.0));
        assert_eq!(n.finished(), vec![f]); // background not in finished()
    }

    #[test]
    fn qos_isolates_shuffle_from_background() {
        // Example 3: 150 Mbps switch, Q1=100 (shuffle), Q3=10 (background).
        let mut n = FlowNet::new(&[150.0]);
        let sh = n.add_flow(vec![LinkId(0)], 1e3, TrafficClass::Shuffle);
        for _ in 0..5 {
            n.add_background(vec![LinkId(0)], TrafficClass::Background);
        }
        // shared: shuffle gets 150/6 Mbps = 3.125 MB/s
        assert!((n.rate_of(sh).unwrap() - mbps_to_mb_per_s(25.0)).abs() < 1e-9);
        // queued: shuffle keeps Q1's full 100 Mbps = 12.5 MB/s
        n.set_qos(QosPolicy::example3());
        assert!((n.rate_of(sh).unwrap() - 12.5).abs() < 1e-9);
    }

    #[test]
    fn empty_path_flow_is_instant() {
        let mut n = net();
        let f = n.add_flow(vec![], 100.0, TrafficClass::HadoopOther);
        assert!(n.rate_of(f).unwrap().is_infinite());
        // an instantaneous flow completes "now"
        let (t, id) = n.next_completion().unwrap();
        assert_eq!(id, f);
        assert_eq!(t, Secs::ZERO);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn settle_rejects_time_reversal() {
        let mut n = net();
        n.settle(Secs(5.0));
        n.settle(Secs(4.0));
    }

    #[test]
    fn slab_reuse_keeps_ids_distinct_and_ordered() {
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0)], 10.0, TrafficClass::HadoopOther);
        n.remove_flow(a);
        let b = n.add_flow(vec![LinkId(0)], 10.0, TrafficClass::HadoopOther);
        assert_ne!(a, b);
        assert!(b.0 > a.0, "later flows must compare greater");
        assert!(n.rate_of(a).is_none(), "stale id must not resolve");
        assert!((n.rate_of(b).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn batched_removals_settle_to_scratch_rates() {
        // three same-instant removals cost one deferred refill; the
        // surviving flow sees the full link afterwards
        let mut n = net();
        let mut ids = Vec::new();
        for _ in 0..3 {
            ids.push(n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther));
        }
        let keep = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(keep).unwrap() - 2.5).abs() < 1e-9);
        for id in ids {
            n.remove_flow(id);
        }
        assert!((n.rate_of(keep).unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(n.n_flows(), 1);
    }

    #[test]
    fn disjoint_components_keep_their_rates() {
        // removing a flow on link 0 must not disturb link 2's flows
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        let b = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        let c = n.add_flow(vec![LinkId(2)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(c).unwrap() - 10.0).abs() < 1e-9);
        n.remove_flow(a);
        assert!((n.rate_of(b).unwrap() - 10.0).abs() < 1e-9);
        assert!((n.rate_of(c).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn completion_order_breaks_ties_by_id() {
        let mut n = net();
        let a = n.add_flow(vec![LinkId(0)], 50.0, TrafficClass::HadoopOther);
        let _b = n.add_flow(vec![LinkId(1)], 50.0, TrafficClass::HadoopOther);
        let (t, id) = n.next_completion().unwrap();
        assert_eq!(id, a);
        assert!((t.0 - 5.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_change_rerates_live_flows() {
        let mut n = net();
        let f = n.add_flow(vec![LinkId(0)], 100.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(f).unwrap() - 10.0).abs() < 1e-9);
        n.settle(Secs(4.0)); // 40MB moved
        n.set_link_capacity_mb_s(LinkId(0), 5.0);
        assert!((n.rate_of(f).unwrap() - 5.0).abs() < 1e-9);
        let (t, id) = n.next_completion().unwrap();
        assert_eq!(id, f);
        assert!((t.0 - 16.0).abs() < 1e-9); // 4 + 60/5
        n.set_link_capacity_mb_s(LinkId(0), 10.0); // restoration
        assert!((n.rate_of(f).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_change_respects_qos_partitions() {
        let mut n = FlowNet::new(&[150.0]);
        n.set_qos(QosPolicy::example3());
        let sh = n.add_flow(vec![LinkId(0)], 1e3, TrafficClass::Shuffle);
        assert!((n.rate_of(sh).unwrap() - 12.5).abs() < 1e-9); // Q1 = 100Mbps
        // degrading below Q1 shrinks the class partition with the link
        n.set_link_capacity_mb_s(LinkId(0), 5.0);
        assert!((n.rate_of(sh).unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn rate_cap_holds_under_churn() {
        let mut n = net();
        let bg = n.add_background_capped(vec![LinkId(0)], TrafficClass::Background, 2.0);
        let f = n.add_flow(vec![LinkId(0)], 40.0, TrafficClass::HadoopOther);
        assert!((n.rate_of(bg).unwrap() - 2.0).abs() < 1e-9);
        assert!((n.rate_of(f).unwrap() - 8.0).abs() < 1e-9);
        n.remove_flow(f);
        assert!((n.rate_of(bg).unwrap() - 2.0).abs() < 1e-9);
    }
}
