//! Event-driven executor: plays a scheduler's assignment on the cluster.
//!
//! Each node runs its placements serially in assignment order (the
//! paper's single-slot node model). A placement may carry a transfer:
//!
//! * [`TransferPlan::None`] — data-local, compute starts when the node is
//!   free (Eq. 1's `TM = 0` case).
//! * [`TransferPlan::Reserved`] — BASS: the SDN controller already
//!   reserved time slots; arrival time is deterministic.
//! * [`TransferPlan::Prefetched`] — Pre-BASS: like Reserved, but the data
//!   may land *before* the node frees up; compute starts at
//!   `max(node_free, arrival)`.
//! * [`TransferPlan::FairShare`] — HDS/BAR (and shuffle traffic): the
//!   transfer contends in the [`FlowNet`] and takes however long max-min
//!   sharing allows.
//!
//! The engine produces [`TaskRecord`]s; the metrics layer derives MT/RT/
//! JT/LR (Table I) and per-node timelines (Fig. 3) from them.
//!
//! Perf L4 (see DESIGN.md): placements are loaded once into an
//! engine-owned arena and flow through the node queues / waiting map as
//! indices — no per-event `Placement` clones — and all events sharing a
//! timestamp are drained as one batch so a `FlowCheck` that completes k
//! flows (or a wave of same-instant `NodeReady` adds) triggers a single
//! rate recompute and a single completion reschedule instead of one per
//! flow. Intermediate recomputes were dead work in the seed: their
//! `FlowCheck` events were superseded by the generation guard anyway.
//!
//! # Dynamics (fault & churn injection)
//!
//! [`Engine::inject`] schedules [`ClusterEvent`]s at absolute times.
//! A `NodeDown` voids the record of the task running on the node,
//! cancels its in-flight fair-share pull, and drains the node's queue —
//! all the lost work lands in the orphan list ([`Engine::take_orphans`])
//! with the crash timestamp, for the dynamics layer to reschedule.
//! `NodeUp` re-arms the node; `LinkCapacity` re-rates the flow network
//! in place (in-flight fair-share transfers slow down or speed up
//! mid-flight); `NodeSpeed` is a compute multiplier applied at compute
//! *start* (stragglers surprise the scheduler: placements keep their
//! planned compute, the engine stretches it); `FlowStart`/`FlowStop`
//! inject cross-traffic background flows. With no injected events and
//! all multipliers at 1.0 the engine is bit-identical to the static
//! path. Degrading a link that carries a pending fair-share transfer to
//! exactly 0 MB/s starves it forever (the quiescence assert fires); the
//! dynamics compiler clamps degradation factors above zero.
//!
//! # Online streams (concurrent multi-job execution)
//!
//! The engine is no longer one-shot: [`Engine::run_until`] plays the
//! cluster forward to a horizon and leaves later events queued, so the
//! online layer (`scenario::online`) can interleave execution with new
//! [`Engine::load`] calls as jobs arrive. Tasks from distinct jobs share
//! the node queues and the flow network — a later job's fair-share pull
//! re-rates an earlier job's in-flight transfer exactly as same-job
//! flows do. [`Engine::tag_job`] attributes records to jobs and
//! [`Engine::watch`] registers completion watches (a job's map wave, a
//! whole job): `run_until` stops at the batch where a watch fires so the
//! driver can schedule the dependent phase at that instant. With a
//! single `load` and no watches, `run` behaves exactly as before.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};

use crate::mapreduce::{JobId, TaskId};
use crate::sdn::controller::Transfer;
use crate::sdn::TrafficClass;
use crate::topology::{LinkId, NodeId};
use crate::util::Secs;

use super::flownet::{FlowId, FlowNet};

/// How a placement's input gets to the node.
#[derive(Debug, Clone)]
pub enum TransferPlan {
    /// Data-local (or zero input).
    None,
    /// Slot-reserved transfer (BASS): deterministic window.
    Reserved(Transfer),
    /// Slot-reserved prefetch (Pre-BASS): may complete before node frees.
    Prefetched(Transfer),
    /// Contended transfer through the flow network (HDS/BAR, shuffle).
    /// `path` is the route the scheduler resolved for src -> node.
    FairShare { path: Vec<LinkId>, size_mb: f64, class: TrafficClass },
}

/// One task placed on one node.
#[derive(Debug, Clone)]
pub struct Placement {
    pub task: TaskId,
    pub node: NodeId,
    pub compute: Secs,
    pub transfer: TransferPlan,
    /// Earliest time the placement may *start* (used to gate reduces on
    /// the map phase / slowstart point). `None` = no gate.
    pub gate: Option<Secs>,
    /// The replica holder the input is pulled from (`None` = data-local
    /// or no input). Threaded into [`TaskRecord::source`] so traces and
    /// oracles can audit which holder actually served the read.
    pub source: Option<NodeId>,
    /// Whether this counts as data-local for the LR metric.
    pub is_local: bool,
    /// Map task? (for MT vs RT attribution)
    pub is_map: bool,
}

/// A full job assignment: per-node execution queues are derived from the
/// placement order.
#[derive(Debug, Clone, Default)]
pub struct Assignment {
    pub placements: Vec<Placement>,
}

impl Assignment {
    /// Data-locality ratio over map placements (Table I's `LR`).
    pub fn locality_ratio(&self) -> f64 {
        let (mut maps, mut local) = (0usize, 0usize);
        for p in &self.placements {
            if p.is_map {
                maps += 1;
                if p.is_local {
                    local += 1;
                }
            }
        }
        if maps == 0 {
            return 1.0;
        }
        local as f64 / maps as f64
    }
}

/// Execution record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: TaskId,
    pub node: NodeId,
    /// When the node picked the placement up.
    pub picked_at: Secs,
    /// When its input was fully present.
    pub input_ready: Secs,
    /// Compute start.
    pub compute_start: Secs,
    /// Completion time (`ΥC`).
    pub finish: Secs,
    /// The replica holder the input was pulled from (see
    /// [`Placement::source`]).
    pub source: Option<NodeId>,
    pub is_local: bool,
    pub is_map: bool,
}

/// Snapshot of one compute-phase attempt, the mitigation layer's
/// detector input ([`Engine::running_snapshot`]). `finish` already
/// reflects the node's speed multiplier at compute start, so
/// `(finish - compute_start) / nominal` is the realized stretch a
/// LATE-style detector thresholds on.
#[derive(Debug, Clone)]
pub struct RunningTask {
    pub task: TaskId,
    pub node: NodeId,
    pub compute_start: Secs,
    /// Estimated finish under the speed multiplier in force at start.
    pub finish: Secs,
    /// The placement's planned (unstretched) compute time.
    pub nominal: Secs,
}

/// Externally injected cluster dynamics, delivered at an absolute time
/// through the event queue. The `scenario::dynamics` layer compiles a
/// `DynamicsSpec` timeline into these.
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// Node crashes: its running task, in-flight transfer and queued
    /// placements are orphaned for rescheduling.
    NodeDown(NodeId),
    /// Node rejoins the cluster (empty-handed: its queue was drained).
    NodeUp(NodeId),
    /// A link's usable capacity changes to the given MB/s value
    /// (degradation or restoration); live flow rates re-settle.
    LinkCapacity(LinkId, f64),
    /// Compute-time multiplier for tasks *starting* after this instant
    /// (>= 1.0 slows the node down: a straggler). 1.0 restores.
    NodeSpeed(NodeId, f64),
    /// Cross-traffic appears: an infinite background flow rate-capped at
    /// `rate_mb_s`, keyed so a later [`ClusterEvent::FlowStop`] can end it.
    FlowStart { key: usize, path: Vec<LinkId>, rate_mb_s: f64 },
    /// Cross-traffic keyed by `FlowStart` disappears.
    FlowStop { key: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EvKind {
    NodeReady(usize),
    FlowCheck(u64),
    /// Index into the engine's injected cluster-event list.
    Cluster(u32),
    /// A task's finish instant (pure bookkeeping: job completion counts
    /// and watches tick at *finish* time, while records are created at
    /// compute start with a future finish). Ignored if the record was
    /// crash-voided in the meantime.
    TaskDone(TaskId),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Ev {
    at: Secs,
    seq: u64,
    kind: EvKind,
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The executor. `Clone` supports the online layer's forecast probes: a
/// cloned engine is run ahead to a job's map completion to recover the
/// actual finish times the static path reads off executed records.
#[derive(Clone)]
pub struct Engine {
    pub net: FlowNet,
    now: Secs,
    seq: u64,
    events: BinaryHeap<Reverse<Ev>>,
    /// Placement arena: queues and the waiting map hold indices into it,
    /// so nothing clones `Placement`s after `load`.
    placements: Vec<Placement>,
    /// Per-node pending placement queues (arena indices).
    queues: Vec<VecDeque<u32>>,
    node_free: Vec<Secs>,
    /// True while the node is driving a fair-share transfer.
    blocked: Vec<bool>,
    /// Flow -> (node, placement index, picked_at) waiting on that flow.
    waiting: HashMap<FlowId, (usize, u32, Secs)>,
    records: Vec<TaskRecord>,
    flow_gen: u64,
    /// Flow membership changed during the current event batch; one
    /// reschedule runs when the batch drains.
    net_dirty: bool,
    finished_buf: Vec<FlowId>,
    // ---- dynamics state (inert on the static path) ----
    /// Injected cluster events, indexed by `EvKind::Cluster`.
    cluster_events: Vec<ClusterEvent>,
    /// Crashed nodes ignore wake-ups until their `NodeUp`.
    down: Vec<bool>,
    /// Compute-time multiplier applied at compute start (1.0 = nominal).
    speed: Vec<f64>,
    /// Latest started placement per node: (placement idx, record idx).
    running: Vec<Option<(u32, usize)>>,
    /// Work lost to crashes: (placement idx, when it was lost).
    orphans: Vec<(u32, Secs)>,
    /// Live injected cross-traffic flows by `FlowStart` key.
    dyn_flows: HashMap<usize, FlowId>,
    // ---- multi-job stream state (inert for single-job runs) ----
    /// Task -> owning job (streams attribute records through these tags).
    job_tags: HashMap<TaskId, JobId>,
    /// Surviving-record count per tagged job.
    job_done: HashMap<JobId, usize>,
    /// Completion watches: key -> watched tasks still unrecorded.
    watch_left: HashMap<u64, usize>,
    /// Task -> watch keys counting it.
    watch_of: HashMap<TaskId, Vec<u64>>,
    /// Watches that reached zero and have not been handed out yet.
    fired: Vec<u64>,
    /// Started-but-unfinished records: task -> expected finish. The
    /// `TaskDone` event completes the entry; a crash-void drops it (so a
    /// stale `TaskDone` for the voided attempt is ignored).
    done_pending: HashMap<TaskId, Secs>,
    /// Tasks whose finish instant has passed (fed by `TaskDone`; watch
    /// registration consults it in O(1) per task).
    finished: HashSet<TaskId>,
    /// Completion bookkeeping is armed lazily by the first tag/watch, so
    /// single-job runs pay no `TaskDone` events, no hash traffic, and
    /// keep a byte-identical event stream.
    track_done: bool,
}

impl Engine {
    /// `initial_free[j]` is node j's initial workload (`ΥI_j` at t=0).
    pub fn new(net: FlowNet, initial_free: Vec<Secs>) -> Self {
        let n = initial_free.len();
        Self {
            net,
            now: Secs::ZERO,
            seq: 0,
            events: BinaryHeap::new(),
            placements: Vec::new(),
            queues: vec![VecDeque::new(); n],
            node_free: initial_free,
            blocked: vec![false; n],
            waiting: HashMap::new(),
            records: Vec::new(),
            flow_gen: 0,
            net_dirty: false,
            finished_buf: Vec::new(),
            cluster_events: Vec::new(),
            down: vec![false; n],
            speed: vec![1.0; n],
            running: vec![None; n],
            orphans: Vec::new(),
            dyn_flows: HashMap::new(),
            job_tags: HashMap::new(),
            job_done: HashMap::new(),
            watch_left: HashMap::new(),
            watch_of: HashMap::new(),
            fired: Vec::new(),
            done_pending: HashMap::new(),
            finished: HashSet::new(),
            track_done: false,
        }
    }

    pub fn now(&self) -> Secs {
        self.now
    }

    /// Schedule a [`ClusterEvent`] at absolute time `at` (>= the current
    /// clock). Events injected before [`Engine::load`] win ties against
    /// node wake-ups at the same instant.
    pub fn inject(&mut self, at: Secs, ev: ClusterEvent) {
        assert!(at >= self.now, "cluster event in the past: {at} < {}", self.now);
        let idx = u32::try_from(self.cluster_events.len()).expect("event budget");
        self.cluster_events.push(ev);
        self.push(at, EvKind::Cluster(idx));
    }

    /// Mark a node as down from the start of the run (crash carried over
    /// from a previous scheduling round).
    pub fn set_node_down(&mut self, node: NodeId) {
        self.down[node.0] = true;
    }

    /// Initial compute-speed multiplier (straggler carried over).
    pub fn set_node_speed(&mut self, node: NodeId, factor: f64) {
        self.speed[node.0] = if factor > 0.0 { factor } else { 1.0 };
    }

    /// Per-node availability after a run (crash resets to the crash
    /// instant) — the cluster state the next scheduling round starts from.
    pub fn node_free_times(&self) -> &[Secs] {
        &self.node_free
    }

    /// Drain the work lost to crashes during the run: each orphan is the
    /// lost placement plus the instant it was lost, in crash order.
    pub fn take_orphans(&mut self) -> Vec<(Placement, Secs)> {
        std::mem::take(&mut self.orphans)
            .into_iter()
            .map(|(pidx, at)| (self.placements[pidx as usize].clone(), at))
            .collect()
    }

    /// Streams: does the node still hold queued placements or an
    /// in-flight input transfer? When true, its `node_free_times` entry
    /// alone understates its commitment (queued work has not touched it
    /// yet) — the online layer falls back to the planned ledger then.
    pub fn has_pending(&self, node: NodeId) -> bool {
        !self.queues[node.0].is_empty() || self.blocked[node.0]
    }

    /// Mitigation: any work left at the current instant — queued
    /// placements, an in-flight input pull, or a compute-phase attempt
    /// whose finish lies in the future? Remaining injected cluster
    /// events do not count (they carry no work). The mitigation drive
    /// loop checkpoints `run_until` as long as this holds.
    pub fn work_left(&self) -> bool {
        self.blocked.iter().any(|&b| b)
            || self.queues.iter().any(|q| !q.is_empty())
            || self
                .running
                .iter()
                .flatten()
                .any(|&(_, rec)| self.records[rec].finish > self.now)
    }

    /// Mitigation: the compute-phase attempts still running at the
    /// current instant (attempts mid-transfer are not yet measurable —
    /// the detector only thresholds realized compute stretch).
    pub fn running_snapshot(&self) -> Vec<RunningTask> {
        let mut out = Vec::new();
        for slot in self.running.iter().flatten() {
            let (pidx, rec) = *slot;
            let r = &self.records[rec];
            if r.finish > self.now {
                out.push(RunningTask {
                    task: r.task,
                    node: r.node,
                    compute_start: r.compute_start,
                    finish: r.finish,
                    nominal: self.placements[pidx as usize].compute,
                });
            }
        }
        out.sort_by_key(|r| r.task);
        out
    }

    /// Void the in-flight record at `rec`, keeping every other node's
    /// `running` index valid (records are swap-removed; the moved entry
    /// may be another node's running task).
    fn void_record(&mut self, rec: usize) {
        let voided = self.records[rec].task;
        let last = self.records.len() - 1;
        self.records.swap_remove(rec);
        if rec != last {
            for slot in self.running.iter_mut().flatten() {
                if slot.1 == last {
                    slot.1 = rec;
                }
            }
        }
        // the voided attempt never finishes: drop its pending
        // completion so the queued `TaskDone` is ignored
        self.done_pending.remove(&voided);
    }

    /// Mitigation: kill one attempt of `task` on `node`, wherever it
    /// currently is — queued, mid-transfer, or computing. Unlike a
    /// crash, the killed attempt is *discarded* (first-finisher-wins
    /// speculation: the loser must not re-enter the orphan path) and
    /// the node stays up, freed at the current instant. Returns whether
    /// an attempt was found. Never called on the static path.
    pub fn kill_attempt(&mut self, node: NodeId, task: TaskId) -> bool {
        let j = node.0;
        // computing?
        if let Some((_, rec)) = self.running[j] {
            if self.records[rec].task == task && self.records[rec].finish > self.now {
                self.running[j] = None;
                self.void_record(rec);
                self.node_free[j] = self.now;
                self.push(self.now, EvKind::NodeReady(j));
                return true;
            }
        }
        // mid input pull?
        if self.blocked[j] {
            let flow = self
                .waiting
                .iter()
                .find(|(_, &(n, pidx, _))| {
                    n == j && self.placements[pidx as usize].task == task
                })
                .map(|(&id, _)| id);
            if let Some(id) = flow {
                self.waiting.remove(&id);
                self.net.remove_flow(id);
                self.net_dirty = true;
                self.blocked[j] = false;
                self.node_free[j] = self.now;
                self.push(self.now, EvKind::NodeReady(j));
                return true;
            }
        }
        // still queued?
        if let Some(pos) =
            self.queues[j].iter().position(|&pidx| self.placements[pidx as usize].task == task)
        {
            self.queues[j].remove(pos);
            return true;
        }
        false
    }

    /// Is this task still waiting in `node`'s pending queue? (Running
    /// attempts and in-flight pulls are *not* queued.)
    pub fn queued(&self, node: NodeId, task: TaskId) -> bool {
        self.queues[node.0]
            .iter()
            .any(|&pidx| self.placements[pidx as usize].task == task)
    }

    /// Reallocation: rewrite the reserved transfer of a placement still
    /// *queued* on `node` so a renegotiated grant replaces the old one
    /// before the engine prices the pull. Running or mid-transfer
    /// attempts are never retimed — their grant has already converted to
    /// wall time. Returns whether a queued reserved placement was found.
    pub fn retime_transfer(&mut self, node: NodeId, task: TaskId, t: Transfer) -> bool {
        let Some(pos) = self.queues[node.0]
            .iter()
            .position(|&pidx| self.placements[pidx as usize].task == task)
        else {
            return false;
        };
        let pidx = self.queues[node.0][pos] as usize;
        match &mut self.placements[pidx].transfer {
            TransferPlan::Reserved(old) | TransferPlan::Prefetched(old) => {
                *old = t;
                true
            }
            _ => false,
        }
    }

    /// Mitigation: evict a node's work without crashing it — the running
    /// attempt is voided, an in-flight pull cancelled, the queue drained,
    /// and everything lands in the orphan list for the next rescheduling
    /// round. The node itself stays up (it may receive new work later).
    /// Returns the number of orphaned placements.
    pub fn evict_node(&mut self, node: NodeId) -> usize {
        let j = node.0;
        let mut n = 0usize;
        if let Some((pidx, rec)) = self.running[j] {
            if self.records[rec].finish > self.now {
                self.running[j] = None;
                self.void_record(rec);
                self.orphans.push((pidx, self.now));
                n += 1;
            }
        }
        if self.blocked[j] {
            let flow = self
                .waiting
                .iter()
                .find(|(_, &(node, _, _))| node == j)
                .map(|(&id, _)| id);
            if let Some(id) = flow {
                let (_, pidx, _) = self.waiting.remove(&id).expect("found above");
                self.net.remove_flow(id);
                self.orphans.push((pidx, self.now));
                self.net_dirty = true;
                n += 1;
            }
            self.blocked[j] = false;
        }
        n += self.drain_node_queue(node);
        self.node_free[j] = self.now;
        n
    }

    /// Mitigation (stream rebalancer): orphan only the node's *pending*
    /// queue — the running attempt and any in-flight pull are left to
    /// finish. Returns the number of orphaned placements.
    pub fn drain_node_queue(&mut self, node: NodeId) -> usize {
        let mut n = 0usize;
        while let Some(pidx) = self.queues[node.0].pop_front() {
            self.orphans.push((pidx, self.now));
            n += 1;
        }
        n
    }

    /// Tenancy (stream preemption): orphan every *queued* placement
    /// tagged with one of `jobs`, across all node queues. Running
    /// attempts and in-flight pulls are untouched — preemption only
    /// reclaims capacity work hasn't started consuming, so exactly-once
    /// completion is preserved by construction. Untagged placements are
    /// never drained. Returns the number of orphaned placements.
    pub fn drain_jobs_queued(&mut self, jobs: &[JobId]) -> usize {
        let mut n = 0usize;
        for j in 0..self.queues.len() {
            let mut kept = VecDeque::with_capacity(self.queues[j].len());
            while let Some(pidx) = self.queues[j].pop_front() {
                let task = self.placements[pidx as usize].task;
                let owned = self.job_tags.get(&task).map_or(false, |jb| jobs.contains(jb));
                if owned {
                    self.orphans.push((pidx, self.now));
                    n += 1;
                } else {
                    kept.push_back(pidx);
                }
            }
            self.queues[j] = kept;
        }
        n
    }

    /// Arm the completion bookkeeping (first tag/watch): records already
    /// in flight are backfilled — finished ones into the finished set,
    /// running ones get their `TaskDone` scheduled — so watches observe
    /// them correctly. Before this, the engine emits no `TaskDone`
    /// events at all (the static single-job paths stay byte-identical
    /// and overhead-free).
    fn arm_tracking(&mut self) {
        if self.track_done {
            return;
        }
        self.track_done = true;
        let recs: Vec<(TaskId, Secs)> =
            self.records.iter().map(|r| (r.task, r.finish)).collect();
        for (t, f) in recs {
            if f <= self.now {
                self.finished.insert(t);
            } else {
                self.done_pending.insert(t, f);
                self.push(f, EvKind::TaskDone(t));
            }
        }
    }

    /// Streams: tag tasks as belonging to `job`. Tags attribute records
    /// to jobs (`job_of`) and drive per-job completion counts (finishes
    /// *after* the first tag/watch; streams tag before loading).
    pub fn tag_job(&mut self, job: JobId, tasks: impl IntoIterator<Item = TaskId>) {
        self.arm_tracking();
        for t in tasks {
            self.job_tags.insert(t, job);
        }
        self.job_done.entry(job).or_insert(0);
    }

    /// The job a task was tagged with (None = untagged single-job run).
    pub fn job_of(&self, task: TaskId) -> Option<JobId> {
        self.job_tags.get(&task).copied()
    }

    /// Surviving-record count of a tagged job (crash-voided attempts do
    /// not count).
    pub fn job_completed(&self, job: JobId) -> usize {
        self.job_done.get(&job).copied().unwrap_or(0)
    }

    /// Register a completion watch: [`Engine::run_until`] stops at the
    /// event batch where every watched task has *finished* and returns
    /// `key`. Tasks already finished count immediately; a watch that is
    /// complete at registration fires on the next `run_until`.
    pub fn watch(&mut self, key: u64, tasks: &[TaskId]) {
        self.watch_threshold(key, tasks, tasks.len());
    }

    /// Threshold watch: fires once `need` of `tasks` carry surviving
    /// records (the reduce-slowstart trigger — the stream layer watches
    /// `ceil(frac * maps)` of a job's map wave, so the engine clock sits
    /// exactly at the slowstart gate when the watch fires). `need` is
    /// clamped to the set size; an already-met threshold fires on the
    /// next `run_until`.
    pub fn watch_threshold(&mut self, key: u64, tasks: &[TaskId], need: usize) {
        self.arm_tracking();
        let mut left = need.min(tasks.len());
        for t in tasks {
            self.watch_of.entry(*t).or_default().push(key);
            // tasks that already finished count immediately
            // (started-but-unfinished ones tick at their TaskDone)
            if self.finished.contains(t) {
                left = left.saturating_sub(1);
            }
        }
        self.watch_left.insert(key, left);
        if left == 0 {
            self.fired.push(key);
        }
    }

    /// Watched tasks still unrecorded (None = unknown key).
    pub fn watch_remaining(&self, key: u64) -> Option<usize> {
        self.watch_left.get(&key).copied()
    }

    /// The records produced so far, in completion order (unsorted; the
    /// online layer reads a finished map wave's records mid-run).
    pub fn records_so_far(&self) -> &[TaskRecord] {
        &self.records
    }

    /// Soak streams: hand the *finished* records (finish <= now) to the
    /// caller and keep only in-flight ones, so record memory tracks the
    /// live working set instead of every task ever run. Running-slot
    /// indices are remapped; a slot whose record finished is cleared
    /// (every consumer already filters on `finish > now`, so this is
    /// observationally identical). The classic `run()`/`finish` path
    /// never calls this — it returns the full sorted record set.
    pub fn drain_finished_records(&mut self) -> Vec<TaskRecord> {
        let total = self.records.len();
        let mut kept: Vec<TaskRecord> = Vec::new();
        let mut out = Vec::with_capacity(total);
        let mut remap = vec![usize::MAX; total];
        for (i, r) in std::mem::take(&mut self.records).into_iter().enumerate() {
            if r.finish > self.now {
                remap[i] = kept.len();
                kept.push(r);
            } else {
                out.push(r);
            }
        }
        for slot in self.running.iter_mut() {
            if let Some((_, rec)) = slot.as_mut() {
                match remap[*rec] {
                    usize::MAX => *slot = None,
                    m => *rec = m,
                }
            }
        }
        self.records = kept;
        out
    }

    /// Soak streams: periodic placement-arena compaction. Placements
    /// whose task has finished and which are no longer queued, running,
    /// mid-pull, or orphaned have their transfer plan (the per-grant
    /// reservation/path vectors) dropped in place — indices stay valid
    /// and each completed slot shrinks to a constant skeleton. Returns
    /// how many placements were compacted this pass.
    pub fn compact_finished_placements(&mut self) -> usize {
        let mut live: HashSet<u32> = HashSet::new();
        for q in &self.queues {
            live.extend(q.iter().copied());
        }
        for &(_, pidx, _) in self.waiting.values() {
            live.insert(pidx);
        }
        for &(pidx, _) in self.running.iter().flatten() {
            live.insert(pidx);
        }
        for &(pidx, _) in &self.orphans {
            live.insert(pidx);
        }
        let mut n = 0usize;
        for (i, p) in self.placements.iter_mut().enumerate() {
            if matches!(p.transfer, TransferPlan::None) || live.contains(&(i as u32)) {
                continue;
            }
            if self.finished.contains(&p.task) {
                p.transfer = TransferPlan::None;
                n += 1;
            }
        }
        n
    }

    /// Soak streams: drop a fully accounted job's completion
    /// bookkeeping — task tags, the finished set, watch membership and
    /// the job's watch keys — so the tag/watch maps track live jobs
    /// instead of every job ever admitted. Only call once the job is
    /// complete and its watches have fired; later jobs use fresh task
    /// ids, so nothing can resurrect the forgotten entries.
    pub fn forget_job(
        &mut self,
        job: JobId,
        tasks: impl IntoIterator<Item = TaskId>,
        watch_keys: &[u64],
    ) {
        for t in tasks {
            self.job_tags.remove(&t);
            self.finished.remove(&t);
            self.done_pending.remove(&t);
            self.watch_of.remove(&t);
        }
        for k in watch_keys {
            self.watch_left.remove(k);
        }
        self.job_done.remove(&job);
    }

    fn push(&mut self, at: Secs, kind: EvKind) {
        self.seq += 1;
        self.events.push(Reverse(Ev { at, seq: self.seq, kind }));
    }

    /// Load an assignment: placements are appended to their node queues in
    /// order, and every node gets a wake-up at its free time.
    pub fn load(&mut self, a: &Assignment) {
        for p in &a.placements {
            assert!(p.node.0 < self.queues.len(), "placement on unknown node");
            let idx = self.placements.len() as u32;
            self.placements.push(p.clone());
            self.queues[p.node.0].push_back(idx);
        }
        for j in 0..self.queues.len() {
            let at = self.node_free[j].max(self.now);
            self.push(at, EvKind::NodeReady(j));
        }
    }

    fn reschedule_flow_check(&mut self) {
        if let Some((t, _)) = self.net.next_completion() {
            self.flow_gen += 1;
            self.push(t.max(self.now), EvKind::FlowCheck(self.flow_gen));
        }
    }

    /// Process every queued event batch with `at <= horizon`, leaving
    /// later events queued. Stops early — `now` staying at the batch
    /// instant — as soon as a completion watch fires.
    fn drain_until(&mut self, horizon: Secs) {
        loop {
            if !self.fired.is_empty() {
                return;
            }
            match self.events.peek() {
                Some(&Reverse(ev)) if ev.at <= horizon => {}
                _ => return,
            }
            let Reverse(ev) = self.events.pop().expect("peeked");
            self.now = self.now.max(ev.at);
            self.net.settle(self.now);
            self.dispatch(ev.kind);
            // drain every event sharing this instant, then recompute flow
            // rates / completion schedule once for the whole batch
            while let Some(&Reverse(nxt)) = self.events.peek() {
                if nxt.at > self.now {
                    break;
                }
                let Reverse(nxt) = self.events.pop().expect("peeked");
                self.dispatch(nxt.kind);
            }
            if self.net_dirty {
                self.net_dirty = false;
                self.reschedule_flow_check();
            }
        }
    }

    /// Online streams: play the cluster forward to `t`, stopping early
    /// when a completion watch fires (the returned keys; `now` is then
    /// the firing instant). An empty return means the horizon was
    /// reached and `now == t`, so a subsequent [`Engine::load`] lands
    /// exactly at the horizon.
    pub fn run_until(&mut self, t: Secs) -> Vec<u64> {
        assert!(t >= self.now, "run_until going backwards: {t} < {}", self.now);
        self.drain_until(t);
        if self.fired.is_empty() {
            self.now = t;
            self.net.settle(t);
        }
        std::mem::take(&mut self.fired)
    }

    /// Run until quiescent; returns the records (sorted by task id).
    /// Watches do not pause this path (they stay queryable afterwards).
    pub fn run(&mut self) -> Vec<TaskRecord> {
        loop {
            self.drain_until(Secs::INF);
            if self.fired.is_empty() {
                break;
            }
            self.fired.clear();
        }
        assert!(
            self.waiting.is_empty() && self.queues.iter().all(|q| q.is_empty()),
            "engine quiesced with pending work (starved transfer?)"
        );
        let mut recs = std::mem::take(&mut self.records);
        recs.sort_by_key(|r| r.task);
        recs
    }

    fn dispatch(&mut self, kind: EvKind) {
        match kind {
            EvKind::NodeReady(j) => self.node_ready(j),
            EvKind::FlowCheck(gen) => {
                if gen == self.flow_gen {
                    self.flow_check();
                }
            }
            EvKind::Cluster(i) => self.cluster_event(i as usize),
            EvKind::TaskDone(t) => self.task_done(t),
        }
    }

    fn cluster_event(&mut self, i: usize) {
        match self.cluster_events[i].clone() {
            ClusterEvent::NodeDown(nd) => self.node_down(nd.0),
            ClusterEvent::NodeUp(nd) => {
                let j = nd.0;
                if self.down[j] {
                    self.down[j] = false;
                    self.node_free[j] = self.node_free[j].max(self.now);
                    self.push(self.now, EvKind::NodeReady(j));
                }
            }
            ClusterEvent::LinkCapacity(link, mb_s) => {
                self.net.set_link_capacity_mb_s(link, mb_s);
                self.net_dirty = true;
            }
            ClusterEvent::NodeSpeed(nd, factor) => {
                self.speed[nd.0] = if factor > 0.0 { factor } else { 1.0 };
            }
            ClusterEvent::FlowStart { key, path, rate_mb_s } => {
                let id = self.net.add_background_capped(path, TrafficClass::Background, rate_mb_s);
                self.dyn_flows.insert(key, id);
                self.net_dirty = true;
            }
            ClusterEvent::FlowStop { key } => {
                if let Some(id) = self.dyn_flows.remove(&key) {
                    self.net.remove_flow(id);
                    self.net_dirty = true;
                }
            }
        }
    }

    /// Crash a node: void its unfinished record, cancel its in-flight
    /// pull, drain its queue — everything lost becomes an orphan.
    fn node_down(&mut self, j: usize) {
        if self.down[j] {
            return;
        }
        self.down[j] = true;
        if let Some((pidx, rec)) = self.running[j].take() {
            if self.records[rec].finish > self.now {
                self.void_record(rec);
                self.orphans.push((pidx, self.now));
            }
        }
        if self.blocked[j] {
            let flow = self
                .waiting
                .iter()
                .find(|(_, &(node, _, _))| node == j)
                .map(|(&id, _)| id);
            if let Some(id) = flow {
                let (_, pidx, _) = self.waiting.remove(&id).expect("found above");
                self.net.remove_flow(id);
                self.orphans.push((pidx, self.now));
                self.net_dirty = true;
            }
            self.blocked[j] = false;
        }
        while let Some(pidx) = self.queues[j].pop_front() {
            self.orphans.push((pidx, self.now));
        }
        self.node_free[j] = self.now;
    }

    /// A node may be able to start its next placement.
    fn node_ready(&mut self, j: usize) {
        if self.down[j] {
            return; // crashed; NodeUp re-arms the wake-up
        }
        if self.blocked[j] {
            return; // transfer in flight; flow completion will resume us
        }
        if self.node_free[j] > self.now {
            // stale wake-up — re-arm at the true free time
            let at = self.node_free[j];
            self.push(at, EvKind::NodeReady(j));
            return;
        }
        let Some(&pidx) = self.queues[j].front() else { return };
        if let Some(g) = self.placements[pidx as usize].gate {
            if g > self.now {
                self.push(g, EvKind::NodeReady(j));
                return;
            }
        }
        self.queues[j].pop_front();
        let picked = self.now;
        let (ready, start) = match &self.placements[pidx as usize].transfer {
            TransferPlan::None => (picked, picked),
            TransferPlan::Reserved(t) => {
                // transfer occupies the node from pick-up until arrival
                let ready = t.arrival.max(picked);
                (ready, ready)
            }
            TransferPlan::Prefetched(t) => {
                // data may already be there; node only waits if not
                (t.arrival, t.arrival.max(picked))
            }
            TransferPlan::FairShare { path, size_mb, class } => {
                if *size_mb > 0.0 && !path.is_empty() {
                    let id = self.net.add_flow_slice(path, *size_mb, *class);
                    self.blocked[j] = true;
                    self.waiting.insert(id, (j, pidx, picked));
                    self.net_dirty = true;
                    return;
                }
                (picked, picked)
            }
        };
        self.finish_compute(j, pidx, picked, ready, start);
    }

    fn finish_compute(&mut self, j: usize, pidx: u32, picked: Secs, ready: Secs, start: Secs) {
        let p = &self.placements[pidx as usize];
        // straggler multiplier; the 1.0 branch keeps the static path
        // bit-identical (no float multiply on the common case)
        let compute = if self.speed[j] == 1.0 {
            p.compute
        } else {
            Secs(p.compute.0 * self.speed[j])
        };
        let finish = start + compute;
        let record = TaskRecord {
            task: p.task,
            node: p.node,
            picked_at: picked,
            input_ready: ready,
            compute_start: start,
            finish,
            source: p.source,
            is_local: p.is_local,
            is_map: p.is_map,
        };
        let task = record.task;
        self.node_free[j] = finish;
        self.running[j] = Some((pidx, self.records.len()));
        self.records.push(record);
        if self.track_done {
            self.done_pending.insert(task, finish);
            self.push(finish, EvKind::TaskDone(task));
        }
        self.push(finish, EvKind::NodeReady(j));
    }

    /// A task's finish instant: bump its job's completion count and tick
    /// any watches counting it. Stale events (the record was voided by a
    /// crash, or the task re-ran with a different finish) are ignored.
    fn task_done(&mut self, task: TaskId) {
        if self.done_pending.get(&task) != Some(&self.now) {
            return;
        }
        self.done_pending.remove(&task);
        self.finished.insert(task);
        if let Some(&job) = self.job_tags.get(&task) {
            *self.job_done.entry(job).or_insert(0) += 1;
        }
        if let Some(keys) = self.watch_of.get(&task) {
            for &k in keys {
                if let Some(left) = self.watch_left.get_mut(&k) {
                    if *left > 0 {
                        *left -= 1;
                        if *left == 0 {
                            self.fired.push(k);
                        }
                    }
                }
            }
        }
    }

    /// Handle completed flows: all removals land in one deferred rate
    /// recompute (the flow net is lazy and the batch reschedules once).
    fn flow_check(&mut self) {
        let mut buf = std::mem::take(&mut self.finished_buf);
        self.net.finished_into(&mut buf);
        for &id in &buf {
            self.net.remove_flow(id);
            if let Some((j, pidx, picked)) = self.waiting.remove(&id) {
                self.blocked[j] = false;
                self.node_free[j] = self.now;
                self.finish_compute(j, pidx, picked, self.now, self.now);
            }
        }
        self.finished_buf = buf;
        self.net_dirty = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdn::controller::Transfer;
    use crate::sdn::calendar::Reservation;

    fn placement(task: usize, node: usize, compute: f64, transfer: TransferPlan) -> Placement {
        let is_local = matches!(transfer, TransferPlan::None);
        Placement {
            task: TaskId(task),
            node: NodeId(node),
            compute: Secs(compute),
            transfer,
            gate: None,
            source: None,
            is_local,
            is_map: true,
        }
    }

    fn reserved(arrival: f64) -> TransferPlan {
        TransferPlan::Reserved(Transfer {
            flow_id: 0,
            reservation: Reservation { links: vec![], start_slot: 0, n_slots: 0, frac: 1.0 },
            rate_mb_s: 12.8,
            arrival: Secs(arrival),
            start: Secs(arrival - 5.0),
        })
    }

    #[test]
    fn local_tasks_run_serially_from_initial_load() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs(3.0)]);
        let a = Assignment {
            placements: vec![
                placement(0, 0, 9.0, TransferPlan::None),
                placement(1, 0, 9.0, TransferPlan::None),
            ],
        };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].compute_start, Secs(3.0));
        assert_eq!(recs[0].finish, Secs(12.0));
        assert_eq!(recs[1].finish, Secs(21.0));
    }

    #[test]
    fn drain_jobs_queued_orphans_only_the_named_jobs_pending_work() {
        let net = FlowNet::new(&[100.0, 100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO, Secs::ZERO]);
        let a = Assignment {
            placements: vec![
                placement(0, 0, 5.0, TransferPlan::None),
                placement(1, 0, 5.0, TransferPlan::None),
                placement(2, 1, 5.0, TransferPlan::None),
                placement(3, 1, 5.0, TransferPlan::None),
            ],
        };
        e.tag_job(JobId(0), [TaskId(0), TaskId(2)]);
        e.tag_job(JobId(1), [TaskId(1), TaskId(3)]);
        e.load(&a);
        assert_eq!(e.drain_jobs_queued(&[JobId(1)]), 2);
        let orphans = e.take_orphans();
        let mut ids: Vec<usize> = orphans.iter().map(|(p, _)| p.task.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 3]);
        // the untouched job's queued work still runs exactly once
        let recs = e.run();
        let mut done: Vec<usize> = recs.iter().map(|r| r.task.0).collect();
        done.sort_unstable();
        assert_eq!(done, vec![0, 2]);
    }

    #[test]
    fn reserved_transfer_blocks_node_until_arrival() {
        // Example 1 TK1 on ND1: idle 3, transfer lands at 8, compute 9 -> 17
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs(3.0)]);
        let a = Assignment { placements: vec![placement(0, 0, 9.0, reserved(8.0))] };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(8.0));
        assert_eq!(recs[0].finish, Secs(17.0));
    }

    #[test]
    fn prefetched_data_saves_wait() {
        // Example 2: data prefetched by t=5; node idle at 3 -> start at 5
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs(3.0)]);
        let mut p = placement(0, 0, 9.0, TransferPlan::Prefetched(match reserved(5.0) {
            TransferPlan::Reserved(t) => t,
            _ => unreachable!(),
        }));
        p.is_local = false;
        let a = Assignment { placements: vec![p] };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(5.0));
        assert_eq!(recs[0].finish, Secs(14.0));
    }

    #[test]
    fn fair_share_transfer_contends() {
        // two nodes each pull 50MB over the same 80Mbps (10MB/s) link:
        // shared 5MB/s each -> both flows end at t=10, compute 1s -> 11
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO, Secs::ZERO]);
        let fs = |_n: usize| TransferPlan::FairShare {
            path: vec![LinkId(0)],
            size_mb: 50.0,
            class: TrafficClass::HadoopOther,
        };
        let a = Assignment {
            placements: vec![placement(0, 0, 1.0, fs(0)), placement(1, 1, 1.0, fs(1))],
        };
        e.load(&a);
        let recs = e.run();
        assert!((recs[0].input_ready.0 - 10.0).abs() < 1e-9);
        assert!((recs[1].input_ready.0 - 10.0).abs() < 1e-9);
        assert!((recs[0].finish.0 - 11.0).abs() < 1e-9);
    }

    #[test]
    fn fair_share_solo_gets_full_rate() {
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        let a = Assignment {
            placements: vec![placement(0, 0, 2.0, TransferPlan::FairShare {
                path: vec![LinkId(0)],
                size_mb: 50.0,
                class: TrafficClass::HadoopOther,
            })],
        };
        e.load(&a);
        let recs = e.run();
        assert!((recs[0].input_ready.0 - 5.0).abs() < 1e-9);
        assert!((recs[0].finish.0 - 7.0).abs() < 1e-9);
    }

    #[test]
    fn gate_delays_start() {
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        let mut p = placement(0, 0, 2.0, TransferPlan::None);
        p.gate = Some(Secs(10.0));
        e.load(&Assignment { placements: vec![p] });
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(10.0));
        assert_eq!(recs[0].finish, Secs(12.0));
    }

    #[test]
    fn gate_blocks_queue_order() {
        // gated head placement holds back the one behind it (FIFO node)
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        let mut p0 = placement(0, 0, 2.0, TransferPlan::None);
        p0.gate = Some(Secs(5.0));
        let p1 = placement(1, 0, 2.0, TransferPlan::None);
        e.load(&Assignment { placements: vec![p0, p1] });
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(5.0));
        assert_eq!(recs[1].compute_start, Secs(7.0));
    }

    #[test]
    fn crash_orphans_running_and_queued_work() {
        // node 0: two 9s tasks from t=0; the crash at t=4 voids the
        // running task and drains the queue; recovery finds nothing left
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.inject(Secs(4.0), ClusterEvent::NodeDown(NodeId(0)));
        e.inject(Secs(30.0), ClusterEvent::NodeUp(NodeId(0)));
        let a = Assignment {
            placements: vec![
                placement(0, 0, 9.0, TransferPlan::None),
                placement(1, 0, 9.0, TransferPlan::None),
            ],
        };
        e.load(&a);
        let recs = e.run();
        assert!(recs.is_empty(), "both tasks were lost: {recs:?}");
        let orphans = e.take_orphans();
        assert_eq!(orphans.len(), 2);
        assert!(orphans.iter().all(|(_, at)| *at == Secs(4.0)));
        let ids: Vec<usize> = orphans.iter().map(|(p, _)| p.task.0).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn crash_after_finish_keeps_the_record() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.inject(Secs(10.0), ClusterEvent::NodeDown(NodeId(0)));
        e.load(&Assignment { placements: vec![placement(0, 0, 9.0, TransferPlan::None)] });
        let recs = e.run();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].finish, Secs(9.0));
        assert!(e.take_orphans().is_empty());
    }

    #[test]
    fn crash_leaves_other_nodes_untouched() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO, Secs::ZERO]);
        e.inject(Secs(1.0), ClusterEvent::NodeDown(NodeId(1)));
        e.inject(Secs(100.0), ClusterEvent::NodeUp(NodeId(1)));
        let a = Assignment {
            placements: vec![
                placement(0, 0, 5.0, TransferPlan::None),
                placement(1, 1, 5.0, TransferPlan::None),
            ],
        };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].task, TaskId(0));
        assert_eq!(recs[0].finish, Secs(5.0));
        assert_eq!(e.take_orphans().len(), 1);
    }

    #[test]
    fn crash_cancels_in_flight_fair_share_pull() {
        // 50MB at 10MB/s: the crash at t=2 kills the transfer mid-flight
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.inject(Secs(2.0), ClusterEvent::NodeDown(NodeId(0)));
        let a = Assignment {
            placements: vec![placement(0, 0, 1.0, TransferPlan::FairShare {
                path: vec![LinkId(0)],
                size_mb: 50.0,
                class: TrafficClass::HadoopOther,
            })],
        };
        e.load(&a);
        let recs = e.run();
        assert!(recs.is_empty());
        assert_eq!(e.net.n_flows(), 0, "cancelled flow must leave the net");
        assert_eq!(e.take_orphans().len(), 1);
    }

    #[test]
    fn straggler_stretches_compute_from_start() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.set_node_speed(NodeId(0), 2.0);
        e.load(&Assignment { placements: vec![placement(0, 0, 4.0, TransferPlan::None)] });
        let recs = e.run();
        assert_eq!(recs[0].finish, Secs(8.0));
    }

    #[test]
    fn mid_run_speed_change_applies_to_later_tasks_only() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.inject(Secs(2.0), ClusterEvent::NodeSpeed(NodeId(0), 3.0));
        let a = Assignment {
            placements: vec![
                placement(0, 0, 4.0, TransferPlan::None),
                placement(1, 0, 4.0, TransferPlan::None),
            ],
        };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs[0].finish, Secs(4.0)); // started before the event
        assert_eq!(recs[1].finish, Secs(16.0)); // 4 + 4 * 3
    }

    #[test]
    fn link_capacity_event_rerates_in_flight_transfers() {
        // 50MB on a 10MB/s link; at t=2 (20MB moved) it degrades to
        // 5MB/s: the remaining 30MB takes 6s -> ready at 8, finish 9
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.inject(Secs(2.0), ClusterEvent::LinkCapacity(LinkId(0), 5.0));
        let a = Assignment {
            placements: vec![placement(0, 0, 1.0, TransferPlan::FairShare {
                path: vec![LinkId(0)],
                size_mb: 50.0,
                class: TrafficClass::HadoopOther,
            })],
        };
        e.load(&a);
        let recs = e.run();
        assert!((recs[0].input_ready.0 - 8.0).abs() < 1e-9);
        assert!((recs[0].finish.0 - 9.0).abs() < 1e-9);
    }

    #[test]
    fn injected_cross_traffic_contends_then_releases() {
        // 60MB on a 10MB/s link; a 5MB/s-capped cross flow runs t=0..6:
        // fair share leaves 5MB/s (30MB moved), then full rate for the
        // remaining 30MB -> ready at 9
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.inject(
            Secs::ZERO,
            ClusterEvent::FlowStart { key: 7, path: vec![LinkId(0)], rate_mb_s: 5.0 },
        );
        e.inject(Secs(6.0), ClusterEvent::FlowStop { key: 7 });
        let a = Assignment {
            placements: vec![placement(0, 0, 1.0, TransferPlan::FairShare {
                path: vec![LinkId(0)],
                size_mb: 60.0,
                class: TrafficClass::HadoopOther,
            })],
        };
        e.load(&a);
        let recs = e.run();
        assert!((recs[0].input_ready.0 - 9.0).abs() < 1e-9);
        assert!((recs[0].finish.0 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn run_until_interleaves_incremental_loads() {
        // run_until leaves later events queued; a load at the horizon
        // queues FIFO behind the in-flight work (the stream model)
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.load(&Assignment { placements: vec![placement(0, 0, 4.0, TransferPlan::None)] });
        let fired = e.run_until(Secs(2.0));
        assert!(fired.is_empty());
        assert_eq!(e.now(), Secs(2.0));
        // the first task is mid-flight: running, but nothing queued
        assert!(!e.has_pending(NodeId(0)));
        e.load(&Assignment { placements: vec![placement(1, 0, 1.0, TransferPlan::None)] });
        assert!(e.has_pending(NodeId(0)));
        let recs = e.run();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].finish, Secs(4.0));
        assert_eq!(recs[1].compute_start, Secs(4.0));
        assert_eq!(recs[1].finish, Secs(5.0));
    }

    #[test]
    fn watches_fire_at_thresholds_and_stop_run_until() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO, Secs::ZERO]);
        let a = Assignment {
            placements: vec![
                placement(0, 0, 2.0, TransferPlan::None),
                placement(1, 0, 2.0, TransferPlan::None),
                placement(2, 1, 9.0, TransferPlan::None),
            ],
        };
        let all = [TaskId(0), TaskId(1), TaskId(2)];
        e.tag_job(JobId(7), all);
        e.watch_threshold(11, &all, 2);
        e.watch(12, &all);
        e.load(&a);
        // threshold 2 fires at t=4 (tasks 0 and 1 recorded)
        let fired = e.run_until(Secs(100.0));
        assert_eq!(fired, vec![11]);
        assert_eq!(e.now(), Secs(4.0));
        assert_eq!(e.job_completed(JobId(7)), 2);
        assert_eq!(e.watch_remaining(12), Some(1));
        let fired = e.run_until(Secs(100.0));
        assert_eq!(fired, vec![12]);
        assert_eq!(e.now(), Secs(9.0));
        let recs = e.run();
        assert_eq!(recs.len(), 3);
        assert_eq!(e.job_of(TaskId(2)), Some(JobId(7)));
        assert_eq!(e.job_of(TaskId(9)), None);
    }

    #[test]
    fn cloned_engine_forecasts_without_disturbing_the_original() {
        // the online layer's probe: clone, run ahead, read finishes
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.load(&Assignment {
            placements: vec![
                placement(0, 0, 3.0, TransferPlan::None),
                placement(1, 0, 5.0, TransferPlan::None),
            ],
        });
        e.watch(21, &[TaskId(0), TaskId(1)]);
        assert!(e.run_until(Secs(1.0)).is_empty());
        let mut probe = e.clone();
        let fired = probe.run_until(Secs::INF);
        assert_eq!(fired, vec![21]);
        assert_eq!(probe.node_free_times()[0], Secs(8.0));
        // the original is still at t=1 with everything pending
        assert_eq!(e.now(), Secs(1.0));
        assert_eq!(e.watch_remaining(21), Some(2));
        assert_eq!(e.run().len(), 2);
    }

    #[test]
    fn kill_attempt_discards_running_work_without_orphaning() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.load(&Assignment {
            placements: vec![
                placement(0, 0, 9.0, TransferPlan::None),
                placement(1, 0, 9.0, TransferPlan::None),
            ],
        });
        assert!(e.run_until(Secs(2.0)).is_empty());
        assert!(e.work_left());
        assert!(e.kill_attempt(NodeId(0), TaskId(0)), "task 0 is computing");
        assert!(!e.kill_attempt(NodeId(0), TaskId(7)), "unknown task");
        let recs = e.run();
        // the killed attempt is gone, the queued task starts at the kill
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].task, TaskId(1));
        assert_eq!(recs[0].compute_start, Secs(2.0));
        assert!(e.take_orphans().is_empty(), "kills never orphan");
        assert!(!e.work_left());
    }

    #[test]
    fn kill_attempt_cancels_queued_and_in_flight_attempts() {
        // 50MB over 10MB/s: task 0 is mid-pull at t=2; task 1 queued
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.load(&Assignment {
            placements: vec![
                placement(0, 0, 1.0, TransferPlan::FairShare {
                    path: vec![LinkId(0)],
                    size_mb: 50.0,
                    class: TrafficClass::HadoopOther,
                }),
                placement(1, 0, 3.0, TransferPlan::None),
            ],
        });
        assert!(e.run_until(Secs(2.0)).is_empty());
        assert!(e.kill_attempt(NodeId(0), TaskId(1)), "queued attempt");
        assert!(e.kill_attempt(NodeId(0), TaskId(0)), "in-flight pull");
        assert_eq!(e.net.n_flows(), 0, "cancelled pull must leave the net");
        let recs = e.run();
        assert!(recs.is_empty());
        assert!(e.take_orphans().is_empty());
    }

    #[test]
    fn evict_node_orphans_everything_but_keeps_the_node_up() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.load(&Assignment {
            placements: vec![
                placement(0, 0, 9.0, TransferPlan::None),
                placement(1, 0, 9.0, TransferPlan::None),
            ],
        });
        assert!(e.run_until(Secs(3.0)).is_empty());
        assert_eq!(e.evict_node(NodeId(0)), 2);
        assert_eq!(e.node_free_times()[0], Secs(3.0));
        let orphans = e.take_orphans();
        assert_eq!(orphans.len(), 2);
        assert!(orphans.iter().all(|(_, at)| *at == Secs(3.0)));
        // the node is still up: new work runs on it
        e.load(&Assignment { placements: vec![placement(2, 0, 2.0, TransferPlan::None)] });
        let recs = e.run();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].compute_start, Secs(3.0));
    }

    #[test]
    fn drain_node_queue_spares_the_running_attempt() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        e.load(&Assignment {
            placements: vec![
                placement(0, 0, 9.0, TransferPlan::None),
                placement(1, 0, 9.0, TransferPlan::None),
            ],
        });
        assert!(e.run_until(Secs(3.0)).is_empty());
        assert_eq!(e.drain_node_queue(NodeId(0)), 1);
        let recs = e.run();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].task, TaskId(0));
        assert_eq!(e.take_orphans().len(), 1);
    }

    #[test]
    fn running_snapshot_reports_realized_stretch() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO, Secs::ZERO]);
        e.set_node_speed(NodeId(1), 3.0);
        e.load(&Assignment {
            placements: vec![
                placement(0, 0, 4.0, TransferPlan::None),
                placement(1, 1, 4.0, TransferPlan::None),
            ],
        });
        assert!(e.run_until(Secs(1.0)).is_empty());
        let snap = e.running_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].task, TaskId(0));
        assert_eq!(snap[0].finish, Secs(4.0));
        assert_eq!(snap[0].nominal, Secs(4.0));
        assert_eq!(snap[1].finish, Secs(12.0), "straggler stretch visible");
        assert_eq!(snap[1].nominal, Secs(4.0));
        // finished attempts drop out of the snapshot
        assert!(e.run_until(Secs(5.0)).is_empty());
        let snap = e.running_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].task, TaskId(1));
        e.run();
    }

    #[test]
    fn locality_ratio() {
        let mut p0 = placement(0, 0, 1.0, TransferPlan::None);
        p0.is_local = true;
        let mut p1 = placement(1, 0, 1.0, TransferPlan::None);
        p1.is_local = false;
        let a = Assignment { placements: vec![p0, p1] };
        assert!((a.locality_ratio() - 0.5).abs() < 1e-12);
        // reduce-only / empty assignments count as fully local
        assert_eq!(Assignment::default().locality_ratio(), 1.0);
        let mut r = placement(2, 0, 1.0, TransferPlan::None);
        r.is_map = false;
        r.is_local = false;
        let reduce_only = Assignment { placements: vec![r] };
        assert_eq!(reduce_only.locality_ratio(), 1.0);
    }
}
