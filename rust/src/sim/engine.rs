//! Event-driven executor: plays a scheduler's assignment on the cluster.
//!
//! Each node runs its placements serially in assignment order (the
//! paper's single-slot node model). A placement may carry a transfer:
//!
//! * [`TransferPlan::None`] — data-local, compute starts when the node is
//!   free (Eq. 1's `TM = 0` case).
//! * [`TransferPlan::Reserved`] — BASS: the SDN controller already
//!   reserved time slots; arrival time is deterministic.
//! * [`TransferPlan::Prefetched`] — Pre-BASS: like Reserved, but the data
//!   may land *before* the node frees up; compute starts at
//!   `max(node_free, arrival)`.
//! * [`TransferPlan::FairShare`] — HDS/BAR (and shuffle traffic): the
//!   transfer contends in the [`FlowNet`] and takes however long max-min
//!   sharing allows.
//!
//! The engine produces [`TaskRecord`]s; the metrics layer derives MT/RT/
//! JT/LR (Table I) and per-node timelines (Fig. 3) from them.
//!
//! Perf L4 (see DESIGN.md): placements are loaded once into an
//! engine-owned arena and flow through the node queues / waiting map as
//! indices — no per-event `Placement` clones — and all events sharing a
//! timestamp are drained as one batch so a `FlowCheck` that completes k
//! flows (or a wave of same-instant `NodeReady` adds) triggers a single
//! rate recompute and a single completion reschedule instead of one per
//! flow. Intermediate recomputes were dead work in the seed: their
//! `FlowCheck` events were superseded by the generation guard anyway.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::mapreduce::TaskId;
use crate::sdn::controller::Transfer;
use crate::sdn::TrafficClass;
use crate::topology::{LinkId, NodeId};
use crate::util::Secs;

use super::flownet::{FlowId, FlowNet};

/// How a placement's input gets to the node.
#[derive(Debug, Clone)]
pub enum TransferPlan {
    /// Data-local (or zero input).
    None,
    /// Slot-reserved transfer (BASS): deterministic window.
    Reserved(Transfer),
    /// Slot-reserved prefetch (Pre-BASS): may complete before node frees.
    Prefetched(Transfer),
    /// Contended transfer through the flow network (HDS/BAR, shuffle).
    /// `path` is the route the scheduler resolved for src -> node.
    FairShare { path: Vec<LinkId>, size_mb: f64, class: TrafficClass },
}

/// One task placed on one node.
#[derive(Debug, Clone)]
pub struct Placement {
    pub task: TaskId,
    pub node: NodeId,
    pub compute: Secs,
    pub transfer: TransferPlan,
    /// Earliest time the placement may *start* (used to gate reduces on
    /// the map phase / slowstart point). `None` = no gate.
    pub gate: Option<Secs>,
    /// Whether this counts as data-local for the LR metric.
    pub is_local: bool,
    /// Map task? (for MT vs RT attribution)
    pub is_map: bool,
}

/// A full job assignment: per-node execution queues are derived from the
/// placement order.
#[derive(Debug, Clone, Default)]
pub struct Assignment {
    pub placements: Vec<Placement>,
}

impl Assignment {
    /// Data-locality ratio over map placements (Table I's `LR`).
    pub fn locality_ratio(&self) -> f64 {
        let (mut maps, mut local) = (0usize, 0usize);
        for p in &self.placements {
            if p.is_map {
                maps += 1;
                if p.is_local {
                    local += 1;
                }
            }
        }
        if maps == 0 {
            return 1.0;
        }
        local as f64 / maps as f64
    }
}

/// Execution record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: TaskId,
    pub node: NodeId,
    /// When the node picked the placement up.
    pub picked_at: Secs,
    /// When its input was fully present.
    pub input_ready: Secs,
    /// Compute start.
    pub compute_start: Secs,
    /// Completion time (`ΥC`).
    pub finish: Secs,
    pub is_local: bool,
    pub is_map: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EvKind {
    NodeReady(usize),
    FlowCheck(u64),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Ev {
    at: Secs,
    seq: u64,
    kind: EvKind,
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The executor.
pub struct Engine {
    pub net: FlowNet,
    now: Secs,
    seq: u64,
    events: BinaryHeap<Reverse<Ev>>,
    /// Placement arena: queues and the waiting map hold indices into it,
    /// so nothing clones `Placement`s after `load`.
    placements: Vec<Placement>,
    /// Per-node pending placement queues (arena indices).
    queues: Vec<VecDeque<u32>>,
    node_free: Vec<Secs>,
    /// True while the node is driving a fair-share transfer.
    blocked: Vec<bool>,
    /// Flow -> (node, placement index, picked_at) waiting on that flow.
    waiting: HashMap<FlowId, (usize, u32, Secs)>,
    records: Vec<TaskRecord>,
    flow_gen: u64,
    /// Flow membership changed during the current event batch; one
    /// reschedule runs when the batch drains.
    net_dirty: bool,
    finished_buf: Vec<FlowId>,
}

impl Engine {
    /// `initial_free[j]` is node j's initial workload (`ΥI_j` at t=0).
    pub fn new(net: FlowNet, initial_free: Vec<Secs>) -> Self {
        let n = initial_free.len();
        Self {
            net,
            now: Secs::ZERO,
            seq: 0,
            events: BinaryHeap::new(),
            placements: Vec::new(),
            queues: vec![VecDeque::new(); n],
            node_free: initial_free,
            blocked: vec![false; n],
            waiting: HashMap::new(),
            records: Vec::new(),
            flow_gen: 0,
            net_dirty: false,
            finished_buf: Vec::new(),
        }
    }

    pub fn now(&self) -> Secs {
        self.now
    }

    fn push(&mut self, at: Secs, kind: EvKind) {
        self.seq += 1;
        self.events.push(Reverse(Ev { at, seq: self.seq, kind }));
    }

    /// Load an assignment: placements are appended to their node queues in
    /// order, and every node gets a wake-up at its free time.
    pub fn load(&mut self, a: &Assignment) {
        for p in &a.placements {
            assert!(p.node.0 < self.queues.len(), "placement on unknown node");
            let idx = self.placements.len() as u32;
            self.placements.push(p.clone());
            self.queues[p.node.0].push_back(idx);
        }
        for j in 0..self.queues.len() {
            let at = self.node_free[j].max(self.now);
            self.push(at, EvKind::NodeReady(j));
        }
    }

    fn reschedule_flow_check(&mut self) {
        if let Some((t, _)) = self.net.next_completion() {
            self.flow_gen += 1;
            self.push(t.max(self.now), EvKind::FlowCheck(self.flow_gen));
        }
    }

    /// Run until quiescent; returns the records (sorted by task id).
    pub fn run(&mut self) -> Vec<TaskRecord> {
        while let Some(Reverse(ev)) = self.events.pop() {
            self.now = self.now.max(ev.at);
            self.net.settle(self.now);
            self.dispatch(ev.kind);
            // drain every event sharing this instant, then recompute flow
            // rates / completion schedule once for the whole batch
            while let Some(&Reverse(nxt)) = self.events.peek() {
                if nxt.at > self.now {
                    break;
                }
                let Reverse(nxt) = self.events.pop().expect("peeked");
                self.dispatch(nxt.kind);
            }
            if self.net_dirty {
                self.net_dirty = false;
                self.reschedule_flow_check();
            }
        }
        assert!(
            self.waiting.is_empty() && self.queues.iter().all(|q| q.is_empty()),
            "engine quiesced with pending work (starved transfer?)"
        );
        let mut recs = std::mem::take(&mut self.records);
        recs.sort_by_key(|r| r.task);
        recs
    }

    fn dispatch(&mut self, kind: EvKind) {
        match kind {
            EvKind::NodeReady(j) => self.node_ready(j),
            EvKind::FlowCheck(gen) => {
                if gen == self.flow_gen {
                    self.flow_check();
                }
            }
        }
    }

    /// A node may be able to start its next placement.
    fn node_ready(&mut self, j: usize) {
        if self.blocked[j] {
            return; // transfer in flight; flow completion will resume us
        }
        if self.node_free[j] > self.now {
            // stale wake-up — re-arm at the true free time
            let at = self.node_free[j];
            self.push(at, EvKind::NodeReady(j));
            return;
        }
        let Some(&pidx) = self.queues[j].front() else { return };
        if let Some(g) = self.placements[pidx as usize].gate {
            if g > self.now {
                self.push(g, EvKind::NodeReady(j));
                return;
            }
        }
        self.queues[j].pop_front();
        let picked = self.now;
        let (ready, start) = match &self.placements[pidx as usize].transfer {
            TransferPlan::None => (picked, picked),
            TransferPlan::Reserved(t) => {
                // transfer occupies the node from pick-up until arrival
                let ready = t.arrival.max(picked);
                (ready, ready)
            }
            TransferPlan::Prefetched(t) => {
                // data may already be there; node only waits if not
                (t.arrival, t.arrival.max(picked))
            }
            TransferPlan::FairShare { path, size_mb, class } => {
                if *size_mb > 0.0 && !path.is_empty() {
                    let id = self.net.add_flow_slice(path, *size_mb, *class);
                    self.blocked[j] = true;
                    self.waiting.insert(id, (j, pidx, picked));
                    self.net_dirty = true;
                    return;
                }
                (picked, picked)
            }
        };
        self.finish_compute(j, pidx, picked, ready, start);
    }

    fn finish_compute(&mut self, j: usize, pidx: u32, picked: Secs, ready: Secs, start: Secs) {
        let p = &self.placements[pidx as usize];
        let finish = start + p.compute;
        let record = TaskRecord {
            task: p.task,
            node: p.node,
            picked_at: picked,
            input_ready: ready,
            compute_start: start,
            finish,
            is_local: p.is_local,
            is_map: p.is_map,
        };
        self.node_free[j] = finish;
        self.records.push(record);
        self.push(finish, EvKind::NodeReady(j));
    }

    /// Handle completed flows: all removals land in one deferred rate
    /// recompute (the flow net is lazy and the batch reschedules once).
    fn flow_check(&mut self) {
        let mut buf = std::mem::take(&mut self.finished_buf);
        self.net.finished_into(&mut buf);
        for &id in &buf {
            self.net.remove_flow(id);
            if let Some((j, pidx, picked)) = self.waiting.remove(&id) {
                self.blocked[j] = false;
                self.node_free[j] = self.now;
                self.finish_compute(j, pidx, picked, self.now, self.now);
            }
        }
        self.finished_buf = buf;
        self.net_dirty = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdn::controller::Transfer;
    use crate::sdn::calendar::Reservation;

    fn placement(task: usize, node: usize, compute: f64, transfer: TransferPlan) -> Placement {
        let is_local = matches!(transfer, TransferPlan::None);
        Placement {
            task: TaskId(task),
            node: NodeId(node),
            compute: Secs(compute),
            transfer,
            gate: None,
            is_local,
            is_map: true,
        }
    }

    fn reserved(arrival: f64) -> TransferPlan {
        TransferPlan::Reserved(Transfer {
            flow_id: 0,
            reservation: Reservation { links: vec![], start_slot: 0, n_slots: 0, frac: 1.0 },
            rate_mb_s: 12.8,
            arrival: Secs(arrival),
            start: Secs(arrival - 5.0),
        })
    }

    #[test]
    fn local_tasks_run_serially_from_initial_load() {
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs(3.0)]);
        let a = Assignment {
            placements: vec![
                placement(0, 0, 9.0, TransferPlan::None),
                placement(1, 0, 9.0, TransferPlan::None),
            ],
        };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].compute_start, Secs(3.0));
        assert_eq!(recs[0].finish, Secs(12.0));
        assert_eq!(recs[1].finish, Secs(21.0));
    }

    #[test]
    fn reserved_transfer_blocks_node_until_arrival() {
        // Example 1 TK1 on ND1: idle 3, transfer lands at 8, compute 9 -> 17
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs(3.0)]);
        let a = Assignment { placements: vec![placement(0, 0, 9.0, reserved(8.0))] };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(8.0));
        assert_eq!(recs[0].finish, Secs(17.0));
    }

    #[test]
    fn prefetched_data_saves_wait() {
        // Example 2: data prefetched by t=5; node idle at 3 -> start at 5
        let net = FlowNet::new(&[100.0]);
        let mut e = Engine::new(net, vec![Secs(3.0)]);
        let mut p = placement(0, 0, 9.0, TransferPlan::Prefetched(match reserved(5.0) {
            TransferPlan::Reserved(t) => t,
            _ => unreachable!(),
        }));
        p.is_local = false;
        let a = Assignment { placements: vec![p] };
        e.load(&a);
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(5.0));
        assert_eq!(recs[0].finish, Secs(14.0));
    }

    #[test]
    fn fair_share_transfer_contends() {
        // two nodes each pull 50MB over the same 80Mbps (10MB/s) link:
        // shared 5MB/s each -> both flows end at t=10, compute 1s -> 11
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO, Secs::ZERO]);
        let fs = |_n: usize| TransferPlan::FairShare {
            path: vec![LinkId(0)],
            size_mb: 50.0,
            class: TrafficClass::HadoopOther,
        };
        let a = Assignment {
            placements: vec![placement(0, 0, 1.0, fs(0)), placement(1, 1, 1.0, fs(1))],
        };
        e.load(&a);
        let recs = e.run();
        assert!((recs[0].input_ready.0 - 10.0).abs() < 1e-9);
        assert!((recs[1].input_ready.0 - 10.0).abs() < 1e-9);
        assert!((recs[0].finish.0 - 11.0).abs() < 1e-9);
    }

    #[test]
    fn fair_share_solo_gets_full_rate() {
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        let a = Assignment {
            placements: vec![placement(0, 0, 2.0, TransferPlan::FairShare {
                path: vec![LinkId(0)],
                size_mb: 50.0,
                class: TrafficClass::HadoopOther,
            })],
        };
        e.load(&a);
        let recs = e.run();
        assert!((recs[0].input_ready.0 - 5.0).abs() < 1e-9);
        assert!((recs[0].finish.0 - 7.0).abs() < 1e-9);
    }

    #[test]
    fn gate_delays_start() {
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        let mut p = placement(0, 0, 2.0, TransferPlan::None);
        p.gate = Some(Secs(10.0));
        e.load(&Assignment { placements: vec![p] });
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(10.0));
        assert_eq!(recs[0].finish, Secs(12.0));
    }

    #[test]
    fn gate_blocks_queue_order() {
        // gated head placement holds back the one behind it (FIFO node)
        let net = FlowNet::new(&[80.0]);
        let mut e = Engine::new(net, vec![Secs::ZERO]);
        let mut p0 = placement(0, 0, 2.0, TransferPlan::None);
        p0.gate = Some(Secs(5.0));
        let p1 = placement(1, 0, 2.0, TransferPlan::None);
        e.load(&Assignment { placements: vec![p0, p1] });
        let recs = e.run();
        assert_eq!(recs[0].compute_start, Secs(5.0));
        assert_eq!(recs[1].compute_start, Secs(7.0));
    }

    #[test]
    fn locality_ratio() {
        let mut p0 = placement(0, 0, 1.0, TransferPlan::None);
        p0.is_local = true;
        let mut p1 = placement(1, 0, 1.0, TransferPlan::None);
        p1.is_local = false;
        let a = Assignment { placements: vec![p0, p1] };
        assert!((a.locality_ratio() - 0.5).abs() < 1e-12);
        // reduce-only / empty assignments count as fully local
        assert_eq!(Assignment::default().locality_ratio(), 1.0);
        let mut r = placement(2, 0, 1.0, TransferPlan::None);
        r.is_map = false;
        r.is_local = false;
        let reduce_only = Assignment { placements: vec![r] };
        assert_eq!(reduce_only.locality_ratio(), 1.0);
    }
}
