//! Discrete-event execution substrate.
//!
//! Two pieces:
//!
//! * [`flownet`] — a fluid flow model: concurrent transfers share links
//!   max-min fairly (per QoS class when a policy is installed). This is
//!   what makes HDS/BAR suffer contention that BASS avoids via slot
//!   reservations.
//! * [`engine`] — an event-driven executor that plays a scheduler's
//!   [`engine::Assignment`] on the simulated cluster and produces per-task
//!   records for the metrics layer.

pub mod engine;
pub mod flownet;

pub use engine::{
    Assignment, ClusterEvent, Engine, Placement, RunningTask, TaskRecord, TransferPlan,
};
pub use flownet::{FlowId, FlowNet};
