//! The availability ledger: `ΥI_j` for every node.
//!
//! The paper's schedulers all reason over "when does node j next become
//! idle". The ledger is the working copy each scheduler mutates while
//! assigning a job's m tasks (Algorithm 1 walks tasks sequentially,
//! updating `ΥI` after each placement).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::topology::NodeId;
use crate::util::Secs;

/// Per-node next-available times.
#[derive(Debug, Clone, PartialEq)]
pub struct Ledger {
    avail: Vec<Secs>,
}

impl Ledger {
    /// All nodes idle at t=0.
    pub fn new(n: usize) -> Self {
        Self { avail: vec![Secs::ZERO; n] }
    }

    /// Explicit initial loads (Example 1: `[3, 9, 20, 7]`).
    pub fn with_initial(avail: Vec<Secs>) -> Self {
        Self { avail }
    }

    pub fn n_nodes(&self) -> usize {
        self.avail.len()
    }

    /// `ΥI_j`.
    pub fn idle(&self, node: NodeId) -> Secs {
        self.avail[node.0]
    }

    /// Record that `node` is now busy until `until` (monotone: the ledger
    /// never moves backwards).
    pub fn occupy_until(&mut self, node: NodeId, until: Secs) {
        let a = &mut self.avail[node.0];
        *a = (*a).max(until);
    }

    /// Overwrite (used when reverting what-if copies).
    pub fn set(&mut self, node: NodeId, at: Secs) {
        self.avail[node.0] = at;
    }

    /// `ND_minnow`: the node with minimum idle time; lowest id wins ties
    /// (deterministic, matching the paper's examples).
    pub fn min_idle(&self) -> (NodeId, Secs) {
        let mut best = (NodeId(0), self.avail[0]);
        for (i, &a) in self.avail.iter().enumerate().skip(1) {
            if a < best.1 {
                best = (NodeId(i), a);
            }
        }
        best
    }

    /// Min idle restricted to a candidate subset; `None` if empty.
    pub fn min_idle_among(
        &self,
        nodes: impl IntoIterator<Item = NodeId>,
    ) -> Option<(NodeId, Secs)> {
        let mut best: Option<(NodeId, Secs)> = None;
        for n in nodes {
            let a = self.avail[n.0];
            best = match best {
                None => Some((n, a)),
                Some((bn, ba)) => {
                    if a < ba || (a == ba && n.0 < bn.0) {
                        Some((n, a))
                    } else {
                        Some((bn, ba))
                    }
                }
            };
        }
        best
    }

    /// Raise every node's availability to at least `floor` (online
    /// streams: a scheduler invoked at time `t` must not plan starts in
    /// the past, so its per-invocation ledger view is floored at `t`).
    pub fn raise_all(&mut self, floor: Secs) {
        for a in &mut self.avail {
            if *a < floor {
                *a = floor;
            }
        }
    }

    /// Makespan view: the latest availability across all nodes.
    pub fn max_idle(&self) -> Secs {
        self.avail.iter().copied().fold(Secs::ZERO, Secs::max)
    }

    pub fn as_slice(&self) -> &[Secs] {
        &self.avail
    }
}

/// O(log n) min-idle view over a node subset (Perf L4, see DESIGN.md).
///
/// The paper's inner loops ask "which authorized node is idle first?"
/// once per task; a linear `min_idle_among` scan made that O(m·n). An
/// `IdleHeap` is a lazily-invalidated min-heap over `(ΥI, node)` that a
/// scheduler builds once per round and nudges after each `occupy_until`:
/// stale entries (the ledger moved past them) pop off on the next query.
/// Ordering matches [`Ledger::min_idle_among`] exactly — earliest
/// availability first, lowest node id on ties — so HDS/BAR/BASS pick the
/// same node the linear scan picked.
#[derive(Debug, Clone)]
pub struct IdleHeap {
    /// `(avail, node id, position in the scheduler's node list)`.
    heap: BinaryHeap<Reverse<(Secs, usize, usize)>>,
}

impl IdleHeap {
    /// Build over `nodes` (a scheduler's authorized set, in its order).
    pub fn new(ledger: &Ledger, nodes: &[NodeId]) -> Self {
        let mut heap = BinaryHeap::with_capacity(nodes.len());
        for (col, &nd) in nodes.iter().enumerate() {
            heap.push(Reverse((ledger.idle(nd), nd.0, col)));
        }
        Self { heap }
    }

    /// Current minimum `(column, node, ΥI)`; `None` when built empty.
    /// Amortized O(log n): entries invalidated by ledger movement are
    /// discarded here.
    pub fn min(&mut self, ledger: &Ledger) -> Option<(usize, NodeId, Secs)> {
        while let Some(&Reverse((avail, nd, col))) = self.heap.peek() {
            if ledger.idle(NodeId(nd)) == avail {
                return Some((col, NodeId(nd), avail));
            }
            self.heap.pop();
        }
        None
    }

    /// Record a node's new availability after `occupy_until`/`set`.
    pub fn update(&mut self, col: usize, node: NodeId, avail: Secs) {
        self.heap.push(Reverse((avail, node.0, col)));
    }

    fn empty() -> Self {
        Self { heap: BinaryHeap::new() }
    }
}

/// Host → shard assignment for sharded scheduler state (ten-kilonode
/// tier, DESIGN.md §10).
///
/// The default plan groups hosts by their edge switch (rack), the same
/// partition [`crate::topology::host_racks`] reports; rackless hosts
/// (no edge-switch link) collect in one trailing shard so every host is
/// covered. The plan carries no behavior by itself: sharded structures
/// ([`ShardedIdleHeap`], the controller's per-shard calendar views) are
/// pinned bit-identical to their flat counterparts, so the plan only
/// bounds working-set size per shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    shard_of: Vec<usize>,
    n_shards: usize,
}

impl ShardPlan {
    /// Degenerate plan: every host in shard 0 (the flat baseline the
    /// property tests compare against).
    pub fn single(n_hosts: usize) -> Self {
        Self { shard_of: vec![0; n_hosts], n_shards: 1 }
    }

    /// One shard per rack id, as reported by `host_racks` (`usize::MAX`
    /// marks rackless hosts, which share one trailing shard).
    pub fn by_rack(racks: &[usize]) -> Self {
        let max_rack = racks.iter().copied().filter(|&r| r != usize::MAX).max();
        let Some(max_rack) = max_rack else {
            return Self::single(racks.len());
        };
        let tail = max_rack + 1; // the rackless shard
        let shard_of: Vec<usize> =
            racks.iter().map(|&r| if r == usize::MAX { tail } else { r }).collect();
        let n_shards = if racks.contains(&usize::MAX) { tail + 1 } else { max_rack + 1 };
        Self { shard_of, n_shards }
    }

    /// Fold this plan down to at most `max_shards` shards (shard id
    /// modulo the cap). `regrouped(1)` is [`ShardPlan::single`].
    pub fn regrouped(&self, max_shards: usize) -> Self {
        assert!(max_shards >= 1, "shard count must be positive");
        let n = self.n_shards.min(max_shards);
        Self { shard_of: self.shard_of.iter().map(|&s| s % n).collect(), n_shards: n }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Number of hosts the plan covers.
    pub fn n_hosts(&self) -> usize {
        self.shard_of.len()
    }

    pub fn shard_of(&self, node: NodeId) -> usize {
        self.shard_of[node.0]
    }
}

/// Per-shard [`IdleHeap`]s with a global merge (DESIGN.md §10).
///
/// Each shard holds a private heap over its slice of the authorized
/// set; [`ShardedIdleHeap::min`] asks every shard for its valid minimum
/// and merges the winners by `(ΥI, node id)`. Because a node lives in
/// exactly one shard, that merge is a total order identical to the flat
/// heap's `(ΥI, node id, column)` order — the sharded pick is
/// bit-identical to [`IdleHeap`] for any plan, which is what keeps the
/// scheduler goldens unchanged while the per-shard working sets shrink
/// to rack size.
#[derive(Debug, Clone)]
pub struct ShardedIdleHeap {
    shards: Vec<IdleHeap>,
    /// node id → shard, copied from the plan so no controller borrow is
    /// held across scheduler mutation.
    shard_of_node: Vec<usize>,
}

impl ShardedIdleHeap {
    /// Build over `nodes` (a scheduler's authorized set, in its order),
    /// distributing each entry to its plan shard.
    pub fn new(plan: &ShardPlan, ledger: &Ledger, nodes: &[NodeId]) -> Self {
        let mut shards: Vec<IdleHeap> = (0..plan.n_shards()).map(|_| IdleHeap::empty()).collect();
        for (col, &nd) in nodes.iter().enumerate() {
            shards[plan.shard_of(nd)].heap.push(Reverse((ledger.idle(nd), nd.0, col)));
        }
        Self { shards, shard_of_node: plan.shard_of.clone() }
    }

    /// Global minimum `(column, node, ΥI)`: the merge of per-shard
    /// minima, earliest availability first, lowest node id on ties.
    pub fn min(&mut self, ledger: &Ledger) -> Option<(usize, NodeId, Secs)> {
        let mut best: Option<(usize, NodeId, Secs)> = None;
        for shard in &mut self.shards {
            let Some((col, nd, avail)) = shard.min(ledger) else { continue };
            let better = match best {
                None => true,
                Some((_, bn, ba)) => avail < ba || (avail == ba && nd.0 < bn.0),
            };
            if better {
                best = Some((col, nd, avail));
            }
        }
        best
    }

    /// Record a node's new availability after `occupy_until`/`set`.
    pub fn update(&mut self, col: usize, node: NodeId, avail: Secs) {
        self.shards[self.shard_of_node[node.0]].update(col, node, avail);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example1() -> Ledger {
        Ledger::with_initial(vec![Secs(3.0), Secs(9.0), Secs(20.0), Secs(7.0)])
    }

    #[test]
    fn min_idle_is_nd1() {
        let l = example1();
        assert_eq!(l.min_idle(), (NodeId(0), Secs(3.0)));
    }

    #[test]
    fn min_idle_among_subset() {
        let l = example1();
        let got = l.min_idle_among([NodeId(1), NodeId(2)]).unwrap();
        assert_eq!(got, (NodeId(1), Secs(9.0)));
        assert!(l.min_idle_among([]).is_none());
    }

    #[test]
    fn tie_break_prefers_lower_id() {
        let l = Ledger::with_initial(vec![Secs(5.0), Secs(5.0)]);
        assert_eq!(l.min_idle().0, NodeId(0));
        assert_eq!(l.min_idle_among([NodeId(1), NodeId(0)]).unwrap().0, NodeId(0));
    }

    #[test]
    fn occupy_is_monotone() {
        let mut l = example1();
        l.occupy_until(NodeId(0), Secs(17.0));
        assert_eq!(l.idle(NodeId(0)), Secs(17.0));
        l.occupy_until(NodeId(0), Secs(10.0)); // earlier: ignored
        assert_eq!(l.idle(NodeId(0)), Secs(17.0));
    }

    #[test]
    fn max_idle_is_makespan() {
        let l = example1();
        assert_eq!(l.max_idle(), Secs(20.0));
    }

    #[test]
    fn idle_heap_tracks_linear_scan() {
        let mut l = example1();
        let nodes: Vec<NodeId> = (0..4).map(NodeId).collect();
        let mut h = IdleHeap::new(&l, &nodes);
        let (col, nd, at) = h.min(&l).unwrap();
        assert_eq!((col, nd, at), (0, NodeId(0), Secs(3.0)));
        l.occupy_until(NodeId(0), Secs(12.0));
        h.update(0, NodeId(0), Secs(12.0));
        let want = l.min_idle_among(nodes.iter().copied()).unwrap();
        let (_, nd, at) = h.min(&l).unwrap();
        assert_eq!((nd, at), want);
    }

    #[test]
    fn idle_heap_breaks_ties_by_node_id() {
        let l = Ledger::with_initial(vec![Secs(5.0), Secs(5.0)]);
        // authorized order reversed: the heap must still pick node 0
        let nodes = [NodeId(1), NodeId(0)];
        let mut h = IdleHeap::new(&l, &nodes);
        let (col, nd, _) = h.min(&l).unwrap();
        assert_eq!(nd, NodeId(0));
        assert_eq!(col, 1);
    }

    #[test]
    fn idle_heap_empty_set() {
        let l = example1();
        let mut h = IdleHeap::new(&l, &[]);
        assert!(h.min(&l).is_none());
    }

    #[test]
    fn shard_plan_by_rack_covers_rackless_tail() {
        let p = ShardPlan::by_rack(&[0, 0, 1, usize::MAX, 1]);
        assert_eq!(p.n_shards(), 3);
        assert_eq!(p.n_hosts(), 5);
        assert_eq!(p.shard_of(NodeId(1)), 0);
        assert_eq!(p.shard_of(NodeId(4)), 1);
        assert_eq!(p.shard_of(NodeId(3)), 2); // rackless → trailing shard
    }

    #[test]
    fn shard_plan_all_rackless_is_single() {
        let p = ShardPlan::by_rack(&[usize::MAX, usize::MAX]);
        assert_eq!(p, ShardPlan::single(2));
        assert_eq!(p.n_shards(), 1);
    }

    #[test]
    fn shard_plan_regrouped_folds_modulo() {
        let p = ShardPlan::by_rack(&[0, 1, 2, 3]);
        let g = p.regrouped(2);
        assert_eq!(g.n_shards(), 2);
        assert_eq!(g.shard_of(NodeId(0)), 0);
        assert_eq!(g.shard_of(NodeId(2)), 0);
        assert_eq!(g.shard_of(NodeId(3)), 1);
        // a cap above the shard count changes nothing
        assert_eq!(p.regrouped(16), p);
        assert_eq!(p.regrouped(1), ShardPlan::single(4));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn shard_plan_regrouped_rejects_zero() {
        ShardPlan::single(4).regrouped(0);
    }

    #[test]
    fn sharded_heap_matches_flat_heap() {
        // random-ish mutation sequence: the sharded and flat heaps must
        // report the same (col, node, avail) at every step.
        let nodes: Vec<NodeId> = (0..8).map(NodeId).collect();
        let plan = ShardPlan::by_rack(&[0, 0, 1, 1, 2, 2, 3, 3]);
        for plan in [ShardPlan::single(8), plan.clone(), plan.regrouped(3)] {
            let mut l = Ledger::with_initial(
                [7.0, 3.0, 3.0, 11.0, 2.0, 9.0, 2.0, 5.0].iter().map(|&s| Secs(s)).collect(),
            );
            let mut flat = IdleHeap::new(&l, &nodes);
            let mut sharded = ShardedIdleHeap::new(&plan, &l, &nodes);
            for step in 0..32 {
                let want = flat.min(&l);
                assert_eq!(sharded.min(&l), want, "step {step}");
                let (col, nd, at) = want.unwrap();
                let until = Secs(at.0 + 1.5 + (step % 3) as f64);
                l.occupy_until(nd, until);
                flat.update(col, nd, until);
                sharded.update(col, nd, until);
            }
        }
    }

    #[test]
    fn sharded_heap_merges_ties_by_node_id() {
        let l = Ledger::with_initial(vec![Secs(5.0), Secs(5.0), Secs(5.0)]);
        // two shards tie on ΥI; the lower node id must win the merge
        let plan = ShardPlan::by_rack(&[1, 0, 1]);
        let nodes = [NodeId(2), NodeId(1), NodeId(0)];
        let mut h = ShardedIdleHeap::new(&plan, &l, &nodes);
        let (col, nd, _) = h.min(&l).unwrap();
        assert_eq!(nd, NodeId(0));
        assert_eq!(col, 2);
    }

    #[test]
    fn sharded_heap_empty_set() {
        let l = example1();
        let mut h = ShardedIdleHeap::new(&ShardPlan::single(4), &l, &[]);
        assert!(h.min(&l).is_none());
    }
}
