//! The availability ledger: `ΥI_j` for every node.
//!
//! The paper's schedulers all reason over "when does node j next become
//! idle". The ledger is the working copy each scheduler mutates while
//! assigning a job's m tasks (Algorithm 1 walks tasks sequentially,
//! updating `ΥI` after each placement).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::topology::NodeId;
use crate::util::Secs;

/// Per-node next-available times.
#[derive(Debug, Clone, PartialEq)]
pub struct Ledger {
    avail: Vec<Secs>,
}

impl Ledger {
    /// All nodes idle at t=0.
    pub fn new(n: usize) -> Self {
        Self { avail: vec![Secs::ZERO; n] }
    }

    /// Explicit initial loads (Example 1: `[3, 9, 20, 7]`).
    pub fn with_initial(avail: Vec<Secs>) -> Self {
        Self { avail }
    }

    pub fn n_nodes(&self) -> usize {
        self.avail.len()
    }

    /// `ΥI_j`.
    pub fn idle(&self, node: NodeId) -> Secs {
        self.avail[node.0]
    }

    /// Record that `node` is now busy until `until` (monotone: the ledger
    /// never moves backwards).
    pub fn occupy_until(&mut self, node: NodeId, until: Secs) {
        let a = &mut self.avail[node.0];
        *a = (*a).max(until);
    }

    /// Overwrite (used when reverting what-if copies).
    pub fn set(&mut self, node: NodeId, at: Secs) {
        self.avail[node.0] = at;
    }

    /// `ND_minnow`: the node with minimum idle time; lowest id wins ties
    /// (deterministic, matching the paper's examples).
    pub fn min_idle(&self) -> (NodeId, Secs) {
        let mut best = (NodeId(0), self.avail[0]);
        for (i, &a) in self.avail.iter().enumerate().skip(1) {
            if a < best.1 {
                best = (NodeId(i), a);
            }
        }
        best
    }

    /// Min idle restricted to a candidate subset; `None` if empty.
    pub fn min_idle_among(
        &self,
        nodes: impl IntoIterator<Item = NodeId>,
    ) -> Option<(NodeId, Secs)> {
        let mut best: Option<(NodeId, Secs)> = None;
        for n in nodes {
            let a = self.avail[n.0];
            best = match best {
                None => Some((n, a)),
                Some((bn, ba)) => {
                    if a < ba || (a == ba && n.0 < bn.0) {
                        Some((n, a))
                    } else {
                        Some((bn, ba))
                    }
                }
            };
        }
        best
    }

    /// Raise every node's availability to at least `floor` (online
    /// streams: a scheduler invoked at time `t` must not plan starts in
    /// the past, so its per-invocation ledger view is floored at `t`).
    pub fn raise_all(&mut self, floor: Secs) {
        for a in &mut self.avail {
            if *a < floor {
                *a = floor;
            }
        }
    }

    /// Makespan view: the latest availability across all nodes.
    pub fn max_idle(&self) -> Secs {
        self.avail.iter().copied().fold(Secs::ZERO, Secs::max)
    }

    pub fn as_slice(&self) -> &[Secs] {
        &self.avail
    }
}

/// O(log n) min-idle view over a node subset (Perf L4, see DESIGN.md).
///
/// The paper's inner loops ask "which authorized node is idle first?"
/// once per task; a linear `min_idle_among` scan made that O(m·n). An
/// `IdleHeap` is a lazily-invalidated min-heap over `(ΥI, node)` that a
/// scheduler builds once per round and nudges after each `occupy_until`:
/// stale entries (the ledger moved past them) pop off on the next query.
/// Ordering matches [`Ledger::min_idle_among`] exactly — earliest
/// availability first, lowest node id on ties — so HDS/BAR/BASS pick the
/// same node the linear scan picked.
#[derive(Debug, Clone)]
pub struct IdleHeap {
    /// `(avail, node id, position in the scheduler's node list)`.
    heap: BinaryHeap<Reverse<(Secs, usize, usize)>>,
}

impl IdleHeap {
    /// Build over `nodes` (a scheduler's authorized set, in its order).
    pub fn new(ledger: &Ledger, nodes: &[NodeId]) -> Self {
        let mut heap = BinaryHeap::with_capacity(nodes.len());
        for (col, &nd) in nodes.iter().enumerate() {
            heap.push(Reverse((ledger.idle(nd), nd.0, col)));
        }
        Self { heap }
    }

    /// Current minimum `(column, node, ΥI)`; `None` when built empty.
    /// Amortized O(log n): entries invalidated by ledger movement are
    /// discarded here.
    pub fn min(&mut self, ledger: &Ledger) -> Option<(usize, NodeId, Secs)> {
        while let Some(&Reverse((avail, nd, col))) = self.heap.peek() {
            if ledger.idle(NodeId(nd)) == avail {
                return Some((col, NodeId(nd), avail));
            }
            self.heap.pop();
        }
        None
    }

    /// Record a node's new availability after `occupy_until`/`set`.
    pub fn update(&mut self, col: usize, node: NodeId, avail: Secs) {
        self.heap.push(Reverse((avail, node.0, col)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example1() -> Ledger {
        Ledger::with_initial(vec![Secs(3.0), Secs(9.0), Secs(20.0), Secs(7.0)])
    }

    #[test]
    fn min_idle_is_nd1() {
        let l = example1();
        assert_eq!(l.min_idle(), (NodeId(0), Secs(3.0)));
    }

    #[test]
    fn min_idle_among_subset() {
        let l = example1();
        let got = l.min_idle_among([NodeId(1), NodeId(2)]).unwrap();
        assert_eq!(got, (NodeId(1), Secs(9.0)));
        assert!(l.min_idle_among([]).is_none());
    }

    #[test]
    fn tie_break_prefers_lower_id() {
        let l = Ledger::with_initial(vec![Secs(5.0), Secs(5.0)]);
        assert_eq!(l.min_idle().0, NodeId(0));
        assert_eq!(l.min_idle_among([NodeId(1), NodeId(0)]).unwrap().0, NodeId(0));
    }

    #[test]
    fn occupy_is_monotone() {
        let mut l = example1();
        l.occupy_until(NodeId(0), Secs(17.0));
        assert_eq!(l.idle(NodeId(0)), Secs(17.0));
        l.occupy_until(NodeId(0), Secs(10.0)); // earlier: ignored
        assert_eq!(l.idle(NodeId(0)), Secs(17.0));
    }

    #[test]
    fn max_idle_is_makespan() {
        let l = example1();
        assert_eq!(l.max_idle(), Secs(20.0));
    }

    #[test]
    fn idle_heap_tracks_linear_scan() {
        let mut l = example1();
        let nodes: Vec<NodeId> = (0..4).map(NodeId).collect();
        let mut h = IdleHeap::new(&l, &nodes);
        let (col, nd, at) = h.min(&l).unwrap();
        assert_eq!((col, nd, at), (0, NodeId(0), Secs(3.0)));
        l.occupy_until(NodeId(0), Secs(12.0));
        h.update(0, NodeId(0), Secs(12.0));
        let want = l.min_idle_among(nodes.iter().copied()).unwrap();
        let (_, nd, at) = h.min(&l).unwrap();
        assert_eq!((nd, at), want);
    }

    #[test]
    fn idle_heap_breaks_ties_by_node_id() {
        let l = Ledger::with_initial(vec![Secs(5.0), Secs(5.0)]);
        // authorized order reversed: the heap must still pick node 0
        let nodes = [NodeId(1), NodeId(0)];
        let mut h = IdleHeap::new(&l, &nodes);
        let (col, nd, _) = h.min(&l).unwrap();
        assert_eq!(nd, NodeId(0));
        assert_eq!(col, 1);
    }

    #[test]
    fn idle_heap_empty_set() {
        let l = example1();
        let mut h = IdleHeap::new(&l, &[]);
        assert!(h.min(&l).is_none());
    }
}
