//! The availability ledger: `ΥI_j` for every node.
//!
//! The paper's schedulers all reason over "when does node j next become
//! idle". The ledger is the working copy each scheduler mutates while
//! assigning a job's m tasks (Algorithm 1 walks tasks sequentially,
//! updating `ΥI` after each placement).

use crate::topology::NodeId;
use crate::util::Secs;

/// Per-node next-available times.
#[derive(Debug, Clone, PartialEq)]
pub struct Ledger {
    avail: Vec<Secs>,
}

impl Ledger {
    /// All nodes idle at t=0.
    pub fn new(n: usize) -> Self {
        Self { avail: vec![Secs::ZERO; n] }
    }

    /// Explicit initial loads (Example 1: `[3, 9, 20, 7]`).
    pub fn with_initial(avail: Vec<Secs>) -> Self {
        Self { avail }
    }

    pub fn n_nodes(&self) -> usize {
        self.avail.len()
    }

    /// `ΥI_j`.
    pub fn idle(&self, node: NodeId) -> Secs {
        self.avail[node.0]
    }

    /// Record that `node` is now busy until `until` (monotone: the ledger
    /// never moves backwards).
    pub fn occupy_until(&mut self, node: NodeId, until: Secs) {
        let a = &mut self.avail[node.0];
        *a = (*a).max(until);
    }

    /// Overwrite (used when reverting what-if copies).
    pub fn set(&mut self, node: NodeId, at: Secs) {
        self.avail[node.0] = at;
    }

    /// `ND_minnow`: the node with minimum idle time; lowest id wins ties
    /// (deterministic, matching the paper's examples).
    pub fn min_idle(&self) -> (NodeId, Secs) {
        let mut best = (NodeId(0), self.avail[0]);
        for (i, &a) in self.avail.iter().enumerate().skip(1) {
            if a < best.1 {
                best = (NodeId(i), a);
            }
        }
        best
    }

    /// Min idle restricted to a candidate subset; `None` if empty.
    pub fn min_idle_among(&self, nodes: impl IntoIterator<Item = NodeId>) -> Option<(NodeId, Secs)> {
        let mut best: Option<(NodeId, Secs)> = None;
        for n in nodes {
            let a = self.avail[n.0];
            best = match best {
                None => Some((n, a)),
                Some((bn, ba)) => {
                    if a < ba || (a == ba && n.0 < bn.0) {
                        Some((n, a))
                    } else {
                        Some((bn, ba))
                    }
                }
            };
        }
        best
    }

    /// Makespan view: the latest availability across all nodes.
    pub fn max_idle(&self) -> Secs {
        self.avail.iter().copied().fold(Secs::ZERO, Secs::max)
    }

    pub fn as_slice(&self) -> &[Secs] {
        &self.avail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example1() -> Ledger {
        Ledger::with_initial(vec![Secs(3.0), Secs(9.0), Secs(20.0), Secs(7.0)])
    }

    #[test]
    fn min_idle_is_nd1() {
        let l = example1();
        assert_eq!(l.min_idle(), (NodeId(0), Secs(3.0)));
    }

    #[test]
    fn min_idle_among_subset() {
        let l = example1();
        let got = l.min_idle_among([NodeId(1), NodeId(2)]).unwrap();
        assert_eq!(got, (NodeId(1), Secs(9.0)));
        assert!(l.min_idle_among([]).is_none());
    }

    #[test]
    fn tie_break_prefers_lower_id() {
        let l = Ledger::with_initial(vec![Secs(5.0), Secs(5.0)]);
        assert_eq!(l.min_idle().0, NodeId(0));
        assert_eq!(l.min_idle_among([NodeId(1), NodeId(0)]).unwrap().0, NodeId(0));
    }

    #[test]
    fn occupy_is_monotone() {
        let mut l = example1();
        l.occupy_until(NodeId(0), Secs(17.0));
        assert_eq!(l.idle(NodeId(0)), Secs(17.0));
        l.occupy_until(NodeId(0), Secs(10.0)); // earlier: ignored
        assert_eq!(l.idle(NodeId(0)), Secs(17.0));
    }

    #[test]
    fn max_idle_is_makespan() {
        let l = example1();
        assert_eq!(l.max_idle(), Secs(20.0));
    }
}
