//! ProgressRate estimation (Section V-A).
//!
//! "The progress rate of each task is calculated by ProgressRate =
//! ProgressScore / T, where ProgressScore represents the task progress
//! between 0 and 1; T is the amount of time the task has been running.
//! The time to complete is then estimated by
//! ΥI = (1 - ProgressScore) / ProgressRate."

use crate::topology::NodeId;
use crate::util::Secs;

/// Remaining-time estimate from a progress score and elapsed runtime.
///
/// Returns [`Secs::INF`] when no signal exists yet (t <= 0 or score <= 0),
/// matching the L2 `idle_estimate` artifact semantics bit-for-bit in f64.
pub fn estimate_idle(progress_score: f64, running_for: Secs) -> Secs {
    let ps = progress_score.clamp(0.0, 1.0);
    if running_for.0 <= 0.0 || ps <= 0.0 {
        return Secs::INF;
    }
    let rate = ps / running_for.0;
    Secs((1.0 - ps) / rate)
}

/// Progress snapshot of one running task.
#[derive(Debug, Clone, Copy)]
pub struct TaskProgress {
    pub node: NodeId,
    /// 0..=1.
    pub score: f64,
    pub started_at: Secs,
}

/// Aggregates task progress reports into per-node `ΥI` estimates — the
/// "initial workload" view the experiments feed the schedulers.
#[derive(Debug, Clone)]
pub struct NodeMonitor {
    n: usize,
    running: Vec<TaskProgress>,
}

impl NodeMonitor {
    pub fn new(n_nodes: usize) -> Self {
        Self { n: n_nodes, running: Vec::new() }
    }

    pub fn report(&mut self, p: TaskProgress) {
        assert!(p.node.0 < self.n, "unknown node {:?}", p.node);
        self.running.push(p);
    }

    /// Per-node idle-time estimate at `now`: queue the remaining time of
    /// every running task on the node (serial execution, as the paper's
    /// single-slot model assumes). Nodes with no running work are idle at
    /// `now` (estimate 0 from now).
    pub fn idle_estimates(&self, now: Secs) -> Vec<Secs> {
        let mut idle = vec![now; self.n];
        for p in &self.running {
            let remaining = estimate_idle(p.score, now - p.started_at);
            let r = if remaining.is_finite() { remaining } else { Secs::ZERO };
            idle[p.node.0] = idle[p.node.0] + r;
        }
        idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_formula() {
        // 40% done after 8s -> rate 0.05/s -> 12s remaining
        let e = estimate_idle(0.4, Secs(8.0));
        assert!((e.0 - 12.0).abs() < 1e-12);
    }

    #[test]
    fn no_signal_is_inf() {
        assert!(!estimate_idle(0.0, Secs(10.0)).is_finite());
        assert!(!estimate_idle(0.5, Secs(0.0)).is_finite());
    }

    #[test]
    fn complete_task_has_zero_remaining() {
        assert_eq!(estimate_idle(1.0, Secs(5.0)), Secs::ZERO);
    }

    #[test]
    fn score_clamped() {
        assert_eq!(estimate_idle(1.7, Secs(5.0)), Secs::ZERO);
    }

    #[test]
    fn monitor_accumulates_serially() {
        let mut m = NodeMonitor::new(2);
        // node 0: two tasks, 50% done after 5s each -> 5s remaining each
        m.report(TaskProgress { node: NodeId(0), score: 0.5, started_at: Secs(5.0) });
        m.report(TaskProgress { node: NodeId(0), score: 0.5, started_at: Secs(5.0) });
        let idle = m.idle_estimates(Secs(10.0));
        assert!((idle[0].0 - 20.0).abs() < 1e-12); // now=10 + 5 + 5
        assert_eq!(idle[1], Secs(10.0)); // idle now
    }
}
