//! Cluster substrate: per-node availability ledger + ProgressRate
//! estimation of `ΥI_j` (Section V-A of the paper).

pub mod ledger;
pub mod progress;

pub use ledger::{IdleHeap, Ledger, ShardPlan, ShardedIdleHeap};
pub use progress::{estimate_idle, NodeMonitor, TaskProgress};
