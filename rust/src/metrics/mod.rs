//! Metrics: MT / RT / JT / LR (Table I) and per-node timelines (Fig. 3).

pub mod job;
pub mod timeline;

pub use job::JobMetrics;
pub use timeline::{NodeTimeline, TimelineEntry};
