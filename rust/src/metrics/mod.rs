//! Metrics: MT / RT / JT / LR (Table I), per-node timelines (Fig. 3),
//! and stream-level aggregates (online multi-job runs).

pub mod job;
pub mod stream;
pub mod timeline;

pub use job::JobMetrics;
pub use stream::{
    jain_index, jobs_per_hour, percentile, sustained_jobs_per_hour, QuantileSketch, StreamAccum,
    StreamStats, TenantStats,
};
pub use timeline::{NodeTimeline, TimelineEntry};
