//! Per-node execution timelines — the Fig. 3 Gantt view.

use crate::sim::TaskRecord;
use crate::topology::NodeId;

/// One bar in the Gantt chart.
#[derive(Debug, Clone)]
pub struct TimelineEntry {
    pub task: usize,
    pub transfer_start: f64,
    pub compute_start: f64,
    pub finish: f64,
    pub is_local: bool,
}

/// All entries of one node, in execution order.
#[derive(Debug, Clone)]
pub struct NodeTimeline {
    pub node: NodeId,
    pub entries: Vec<TimelineEntry>,
}

impl NodeTimeline {
    /// Build timelines for `n_nodes` from execution records.
    pub fn build(records: &[TaskRecord], n_nodes: usize) -> Vec<NodeTimeline> {
        let mut out: Vec<NodeTimeline> =
            (0..n_nodes).map(|i| NodeTimeline { node: NodeId(i), entries: Vec::new() }).collect();
        let mut sorted: Vec<&TaskRecord> = records.iter().collect();
        sorted.sort_by(|a, b| a.compute_start.cmp(&b.compute_start));
        for r in sorted {
            if r.node.0 < n_nodes {
                out[r.node.0].entries.push(TimelineEntry {
                    task: r.task.0,
                    transfer_start: r.picked_at.0,
                    compute_start: r.compute_start.0,
                    finish: r.finish.0,
                    is_local: r.is_local,
                });
            }
        }
        out
    }

    /// ASCII rendering (1 column per `scale` seconds) for examples/CLI.
    pub fn render(timelines: &[NodeTimeline], scale: f64) -> String {
        let mut s = String::new();
        for tl in timelines {
            if tl.entries.is_empty() {
                continue;
            }
            s.push_str(&format!("ND{} |", tl.node.0 + 1));
            let mut cursor = 0.0;
            for e in &tl.entries {
                let gap = ((e.transfer_start - cursor) / scale).round() as usize;
                s.push_str(&".".repeat(gap));
                let xfer = ((e.compute_start - e.transfer_start) / scale).round() as usize;
                s.push_str(&"~".repeat(xfer));
                let comp = ((e.finish - e.compute_start) / scale).round() as usize;
                let label = format!("[TK{}{}", e.task + 1, if e.is_local { "" } else { "*" });
                let fill = comp.saturating_sub(label.len() + 1);
                s.push_str(&label);
                s.push_str(&"=".repeat(fill));
                s.push(']');
                cursor = e.finish;
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::TaskId;
    use crate::util::Secs;

    #[test]
    fn build_orders_by_start() {
        let recs = vec![
            TaskRecord {
                task: TaskId(1),
                node: NodeId(0),
                picked_at: Secs(10.0),
                input_ready: Secs(10.0),
                compute_start: Secs(10.0),
                finish: Secs(19.0),
                source: None,
                is_local: true,
                is_map: true,
            },
            TaskRecord {
                task: TaskId(0),
                node: NodeId(0),
                picked_at: Secs(1.0),
                input_ready: Secs(1.0),
                compute_start: Secs(1.0),
                finish: Secs(10.0),
                source: None,
                is_local: false,
                is_map: true,
            },
        ];
        let tls = NodeTimeline::build(&recs, 2);
        assert_eq!(tls[0].entries.len(), 2);
        assert_eq!(tls[0].entries[0].task, 0);
        assert_eq!(tls[0].entries[1].task, 1);
        assert!(tls[1].entries.is_empty());
        let txt = NodeTimeline::render(&tls, 1.0);
        assert!(txt.contains("TK1*")); // remote marker
        assert!(txt.contains("TK2"));
    }
}
