//! Stream-level aggregates: distribution statistics over the per-job
//! metrics of an online multi-job run (`scenario::online`).

/// Nearest-rank percentile of an unsorted sample (p in [0, 100]).
/// Deterministic: ties and ordering are resolved by `total_cmp`.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Aggregate statistics over one stream's per-job completion times and
/// slowdowns (completion time divided by the job's isolated run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamStats {
    pub jobs: usize,
    pub mean_jt: f64,
    pub p50_jt: f64,
    pub p95_jt: f64,
    pub mean_slowdown: f64,
    pub max_slowdown: f64,
}

impl StreamStats {
    /// `jts[i]` is job i's stream completion time, `slowdowns[i]` its
    /// slowdown vs. the isolated run (1.0 = uncontended).
    pub fn from_jobs(jts: &[f64], slowdowns: &[f64]) -> Self {
        assert_eq!(jts.len(), slowdowns.len(), "one slowdown per job");
        let n = jts.len();
        if n == 0 {
            return Self {
                jobs: 0,
                mean_jt: 0.0,
                p50_jt: 0.0,
                p95_jt: 0.0,
                mean_slowdown: 1.0,
                max_slowdown: 1.0,
            };
        }
        Self {
            jobs: n,
            mean_jt: jts.iter().sum::<f64>() / n as f64,
            p50_jt: percentile(jts, 50.0),
            p95_jt: percentile(jts, 95.0),
            mean_slowdown: slowdowns.iter().sum::<f64>() / n as f64,
            max_slowdown: slowdowns.iter().copied().fold(1.0, f64::max),
        }
    }
}

/// Jain's fairness index over a sample of per-tenant allocations (or
/// mean slowdowns): `(sum x)^2 / (n * sum x^2)`, in `(0, 1]` with 1 =
/// perfectly even. Degenerate samples (empty, single, or all-zero) are
/// reported as perfectly fair.
pub fn jain_index(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 1.0;
    }
    let sum: f64 = values.iter().sum();
    let sq: f64 = values.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (values.len() as f64 * sq)
}

/// Per-tenant aggregates over one multi-tenant stream run: the
/// "millions of users" story is many tenants, so slowdown tails and SLO
/// attainment are reported per tenant, not only stream-wide.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    pub tenant: String,
    pub weight: f64,
    /// Jobs the tenant submitted (completed + rejected).
    pub jobs: usize,
    /// Jobs rejected at admission (infeasible deadline or impossible
    /// quota).
    pub rejected: usize,
    /// Mean slowdown over the tenant's *completed* jobs (1.0 if none).
    pub mean_slowdown: f64,
    /// Nearest-rank p95 slowdown over completed jobs (1.0 if none).
    pub p95_slowdown: f64,
    /// Fraction of deadline-carrying jobs that met their deadline
    /// (rejected jobs count as missed); 1.0 when the tenant has no
    /// deadline.
    pub slo_attainment: f64,
}

impl TenantStats {
    /// `slowdowns` covers completed jobs only; `slo_met`/`slo_total`
    /// count deadline-carrying jobs (total includes rejected ones).
    pub fn from_jobs(
        tenant: impl Into<String>,
        weight: f64,
        slowdowns: &[f64],
        rejected: usize,
        slo_met: usize,
        slo_total: usize,
    ) -> Self {
        let n = slowdowns.len();
        Self {
            tenant: tenant.into(),
            weight,
            jobs: n + rejected,
            rejected,
            mean_slowdown: if n == 0 {
                1.0
            } else {
                slowdowns.iter().sum::<f64>() / n as f64
            },
            p95_slowdown: if n == 0 { 1.0 } else { percentile(slowdowns, 95.0) },
            slo_attainment: if slo_total == 0 {
                1.0
            } else {
                slo_met as f64 / slo_total as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 95.0), 4.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn stats_shape() {
        let s = StreamStats::from_jobs(&[10.0, 20.0, 30.0], &[1.0, 2.0, 3.0]);
        assert_eq!(s.jobs, 3);
        assert_eq!(s.mean_jt, 20.0);
        assert_eq!(s.p50_jt, 20.0);
        assert_eq!(s.p95_jt, 30.0);
        assert_eq!(s.mean_slowdown, 2.0);
        assert_eq!(s.max_slowdown, 3.0);
        let empty = StreamStats::from_jobs(&[], &[]);
        assert_eq!(empty.jobs, 0);
        assert_eq!(empty.mean_slowdown, 1.0);
    }

    #[test]
    fn jain_index_shape() {
        // even allocation is perfectly fair
        assert_eq!(jain_index(&[2.0, 2.0, 2.0]), 1.0);
        // one tenant starved: (3)^2 / (2 * 9) = 0.5
        assert_eq!(jain_index(&[3.0, 0.0]), 0.5);
        // n tenants, one served: index -> 1/n
        assert!((jain_index(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // degenerate samples are fair by convention
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[5.0]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn tenant_stats_aggregate_slowdowns_and_slo() {
        let t = TenantStats::from_jobs("prod", 2.0, &[1.0, 2.0, 3.0], 1, 2, 4);
        assert_eq!(t.tenant, "prod");
        assert_eq!(t.weight, 2.0);
        assert_eq!(t.jobs, 4);
        assert_eq!(t.rejected, 1);
        assert_eq!(t.mean_slowdown, 2.0);
        assert_eq!(t.p95_slowdown, 3.0);
        assert_eq!(t.slo_attainment, 0.5);
        // no completed jobs, no deadlines: neutral aggregates
        let idle = TenantStats::from_jobs("batch", 1.0, &[], 0, 0, 0);
        assert_eq!(idle.jobs, 0);
        assert_eq!(idle.mean_slowdown, 1.0);
        assert_eq!(idle.p95_slowdown, 1.0);
        assert_eq!(idle.slo_attainment, 1.0);
    }
}
