//! Stream-level aggregates: distribution statistics over the per-job
//! metrics of an online multi-job run (`scenario::online`).

/// Nearest-rank percentile of an unsorted sample (p in [0, 100]).
/// Deterministic: ties and ordering are resolved by `total_cmp`.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Aggregate statistics over one stream's per-job completion times and
/// slowdowns (completion time divided by the job's isolated run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamStats {
    pub jobs: usize,
    pub mean_jt: f64,
    pub p50_jt: f64,
    pub p95_jt: f64,
    pub mean_slowdown: f64,
    pub max_slowdown: f64,
}

impl StreamStats {
    /// `jts[i]` is job i's stream completion time, `slowdowns[i]` its
    /// slowdown vs. the isolated run (1.0 = uncontended).
    pub fn from_jobs(jts: &[f64], slowdowns: &[f64]) -> Self {
        assert_eq!(jts.len(), slowdowns.len(), "one slowdown per job");
        let n = jts.len();
        if n == 0 {
            return Self {
                jobs: 0,
                mean_jt: 0.0,
                p50_jt: 0.0,
                p95_jt: 0.0,
                mean_slowdown: 1.0,
                max_slowdown: 1.0,
            };
        }
        Self {
            jobs: n,
            mean_jt: jts.iter().sum::<f64>() / n as f64,
            p50_jt: percentile(jts, 50.0),
            p95_jt: percentile(jts, 95.0),
            mean_slowdown: slowdowns.iter().sum::<f64>() / n as f64,
            max_slowdown: slowdowns.iter().copied().fold(1.0, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 95.0), 4.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn stats_shape() {
        let s = StreamStats::from_jobs(&[10.0, 20.0, 30.0], &[1.0, 2.0, 3.0]);
        assert_eq!(s.jobs, 3);
        assert_eq!(s.mean_jt, 20.0);
        assert_eq!(s.p50_jt, 20.0);
        assert_eq!(s.p95_jt, 30.0);
        assert_eq!(s.mean_slowdown, 2.0);
        assert_eq!(s.max_slowdown, 3.0);
        let empty = StreamStats::from_jobs(&[], &[]);
        assert_eq!(empty.jobs, 0);
        assert_eq!(empty.mean_slowdown, 1.0);
    }
}
