//! Stream-level aggregates: distribution statistics over the per-job
//! metrics of an online multi-job run (`scenario::online`).

/// Nearest-rank percentile of an unsorted sample (p in [0, 100]).
/// Deterministic: ties and ordering are resolved by `total_cmp`.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Aggregate statistics over one stream's per-job completion times and
/// slowdowns (completion time divided by the job's isolated run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamStats {
    pub jobs: usize,
    pub mean_jt: f64,
    pub p50_jt: f64,
    pub p95_jt: f64,
    pub mean_slowdown: f64,
    pub max_slowdown: f64,
}

impl StreamStats {
    /// `jts[i]` is job i's stream completion time, `slowdowns[i]` its
    /// slowdown vs. the isolated run (1.0 = uncontended).
    pub fn from_jobs(jts: &[f64], slowdowns: &[f64]) -> Self {
        assert_eq!(jts.len(), slowdowns.len(), "one slowdown per job");
        let n = jts.len();
        if n == 0 {
            return Self {
                jobs: 0,
                mean_jt: 0.0,
                p50_jt: 0.0,
                p95_jt: 0.0,
                mean_slowdown: 1.0,
                max_slowdown: 1.0,
            };
        }
        Self {
            jobs: n,
            mean_jt: jts.iter().sum::<f64>() / n as f64,
            p50_jt: percentile(jts, 50.0),
            p95_jt: percentile(jts, 95.0),
            mean_slowdown: slowdowns.iter().sum::<f64>() / n as f64,
            max_slowdown: slowdowns.iter().copied().fold(1.0, f64::max),
        }
    }
}

/// Jain's fairness index over a sample of per-tenant allocations (or
/// mean slowdowns): `(sum x)^2 / (n * sum x^2)`, in `(0, 1]` with 1 =
/// perfectly even. Degenerate samples (empty, single, or all-zero) are
/// reported as perfectly fair.
pub fn jain_index(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 1.0;
    }
    let sum: f64 = values.iter().sum();
    let sq: f64 = values.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (values.len() as f64 * sq)
}

/// Per-tenant aggregates over one multi-tenant stream run: the
/// "millions of users" story is many tenants, so slowdown tails and SLO
/// attainment are reported per tenant, not only stream-wide.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    pub tenant: String,
    pub weight: f64,
    /// Jobs the tenant submitted (completed + rejected).
    pub jobs: usize,
    /// Jobs rejected at admission (infeasible deadline or impossible
    /// quota).
    pub rejected: usize,
    /// Mean slowdown over the tenant's *completed* jobs (1.0 if none).
    pub mean_slowdown: f64,
    /// Nearest-rank p95 slowdown over completed jobs (1.0 if none).
    pub p95_slowdown: f64,
    /// Fraction of deadline-carrying jobs that met their deadline
    /// (rejected jobs count as missed); 1.0 when the tenant has no
    /// deadline.
    pub slo_attainment: f64,
}

impl TenantStats {
    /// `slowdowns` covers completed jobs only; `slo_met`/`slo_total`
    /// count deadline-carrying jobs (total includes rejected ones).
    pub fn from_jobs(
        tenant: impl Into<String>,
        weight: f64,
        slowdowns: &[f64],
        rejected: usize,
        slo_met: usize,
        slo_total: usize,
    ) -> Self {
        let n = slowdowns.len();
        Self {
            tenant: tenant.into(),
            weight,
            jobs: n + rejected,
            rejected,
            mean_slowdown: if n == 0 {
                1.0
            } else {
                slowdowns.iter().sum::<f64>() / n as f64
            },
            p95_slowdown: if n == 0 { 1.0 } else { percentile(slowdowns, 95.0) },
            slo_attainment: if slo_total == 0 {
                1.0
            } else {
                slo_met as f64 / slo_total as f64
            },
        }
    }
}

/// Fixed-size streaming quantile sketch for soak-scale streams, where
/// retaining every `JobOutcome` would grow linearly in jobs.
///
/// Below `cap` samples the sketch stores the sorted sample exactly, so
/// every quantile is **bit-identical** to [`percentile`] over the same
/// values. Past `cap` it degrades to a Ben-Haim/Tom-Yom-Tov-style
/// streaming histogram: each new value becomes a unit-weight centroid
/// and the two adjacent centroids closest in value merge into their
/// weighted mean. Memory is O(cap) forever; the reported quantile is
/// the value of the centroid containing the nearest-rank position, so
/// the *rank* error is bounded by the heaviest centroid's weight
/// (merging nearest neighbours keeps centroids narrow where the
/// distribution is dense — see DESIGN.md §14 for the bound).
///
/// Deterministic: insertion order fully determines the state, so a
/// restored checkpoint replays to the same bits.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    cap: usize,
    count: u64,
    /// Sorted exact sample while `count <= cap`, else empty.
    exact: Vec<f64>,
    /// Sorted (value, weight) centroids once compaction has begun.
    centroids: Vec<(f64, u64)>,
}

impl QuantileSketch {
    /// `cap` is the retained-state bound (exact below it, O(cap)
    /// centroids above it); clamped to at least 8.
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(8), count: 0, exact: Vec::new(), centroids: Vec::new() }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Still holding the exact sample (quantiles bit-identical to
    /// [`percentile`])?
    pub fn is_exact(&self) -> bool {
        self.centroids.is_empty()
    }

    /// Retained boundaries (exact values or centroids) — the peak-size
    /// check of the soak tests.
    pub fn retained(&self) -> usize {
        self.exact.len().max(self.centroids.len())
    }

    /// Heaviest centroid weight: the nearest-rank error bound once the
    /// sketch has compacted (0 while exact).
    pub fn max_centroid_weight(&self) -> u64 {
        self.centroids.iter().map(|&(_, c)| c).max().unwrap_or(0)
    }

    pub fn insert(&mut self, v: f64) {
        self.count += 1;
        if self.centroids.is_empty() {
            let at = self.exact.partition_point(|x| x.total_cmp(&v).is_le());
            self.exact.insert(at, v);
            if self.exact.len() <= self.cap {
                return;
            }
            // overflow: seed the histogram with unit-weight centroids
            self.centroids = self.exact.drain(..).map(|x| (x, 1)).collect();
        } else {
            let at = self.centroids.partition_point(|&(x, _)| x.total_cmp(&v).is_le());
            self.centroids.insert(at, (v, 1));
        }
        while self.centroids.len() > self.cap {
            // merge the adjacent pair closest in value (ties: lowest
            // index) into its weighted mean — deterministic compaction
            let mut best = 0usize;
            let mut best_gap = f64::INFINITY;
            for i in 0..self.centroids.len() - 1 {
                let gap = self.centroids[i + 1].0 - self.centroids[i].0;
                if gap < best_gap {
                    best_gap = gap;
                    best = i;
                }
            }
            let (v1, c1) = self.centroids[best];
            let (v2, c2) = self.centroids[best + 1];
            let w = c1 + c2;
            self.centroids[best] = ((v1 * c1 as f64 + v2 * c2 as f64) / w as f64, w);
            self.centroids.remove(best + 1);
        }
    }

    /// Nearest-rank quantile (p in [0, 100]); exact — bit-identical to
    /// [`percentile`] — until the sketch compacts.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        if self.centroids.is_empty() {
            return self.exact[rank as usize - 1];
        }
        let mut cum = 0u64;
        for &(v, c) in &self.centroids {
            cum += c;
            if cum >= rank {
                return v;
            }
        }
        self.centroids.last().expect("non-empty").0
    }
}

/// Incremental replacement for collecting every job's numbers and
/// calling [`StreamStats::from_jobs`] at the end: O(sketch cap) memory
/// regardless of stream length. While both sketches are still exact
/// (streams up to the cap) the produced [`StreamStats`] is bit-identical
/// to the batch path fed in the same order — the soak driver's
/// small-stream equivalence pin.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamAccum {
    jobs: usize,
    sum_jt: f64,
    sum_slowdown: f64,
    max_slowdown: f64,
    jt: QuantileSketch,
    slowdown: QuantileSketch,
}

impl StreamAccum {
    pub fn new(sketch_cap: usize) -> Self {
        Self {
            jobs: 0,
            sum_jt: 0.0,
            sum_slowdown: 0.0,
            max_slowdown: 1.0,
            jt: QuantileSketch::new(sketch_cap),
            slowdown: QuantileSketch::new(sketch_cap),
        }
    }

    pub fn push(&mut self, jt: f64, slowdown: f64) {
        self.jobs += 1;
        self.sum_jt += jt;
        self.sum_slowdown += slowdown;
        self.max_slowdown = self.max_slowdown.max(slowdown);
        self.jt.insert(jt);
        self.slowdown.insert(slowdown);
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Retained state across both sketches (peak-size checks).
    pub fn retained(&self) -> usize {
        self.jt.retained() + self.slowdown.retained()
    }

    pub fn p95_slowdown(&self) -> f64 {
        if self.jobs == 0 {
            1.0
        } else {
            self.slowdown.quantile(95.0)
        }
    }

    pub fn stats(&self) -> StreamStats {
        if self.jobs == 0 {
            return StreamStats::from_jobs(&[], &[]);
        }
        StreamStats {
            jobs: self.jobs,
            mean_jt: self.sum_jt / self.jobs as f64,
            p50_jt: self.jt.quantile(50.0),
            p95_jt: self.jt.quantile(95.0),
            mean_slowdown: self.sum_slowdown / self.jobs as f64,
            max_slowdown: self.max_slowdown,
        }
    }
}

/// Completed jobs per hour over a wall-clock span of seconds (0 for an
/// empty span — nothing sustained).
pub fn jobs_per_hour(jobs: usize, span_secs: f64) -> f64 {
    if span_secs <= 0.0 {
        return 0.0;
    }
    jobs as f64 * 3600.0 / span_secs
}

/// The soak figure of merit: jobs/hour *sustained at the SLO* — the
/// raw rate when the p95 slowdown meets `target_p95`, and 0 when the
/// tail blew through it (a stream that completes jobs arbitrarily late
/// sustains nothing).
pub fn sustained_jobs_per_hour(
    jobs: usize,
    span_secs: f64,
    p95_slowdown: f64,
    target_p95: f64,
) -> f64 {
    if p95_slowdown <= target_p95 {
        jobs_per_hour(jobs, span_secs)
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 95.0), 4.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn stats_shape() {
        let s = StreamStats::from_jobs(&[10.0, 20.0, 30.0], &[1.0, 2.0, 3.0]);
        assert_eq!(s.jobs, 3);
        assert_eq!(s.mean_jt, 20.0);
        assert_eq!(s.p50_jt, 20.0);
        assert_eq!(s.p95_jt, 30.0);
        assert_eq!(s.mean_slowdown, 2.0);
        assert_eq!(s.max_slowdown, 3.0);
        let empty = StreamStats::from_jobs(&[], &[]);
        assert_eq!(empty.jobs, 0);
        assert_eq!(empty.mean_slowdown, 1.0);
    }

    #[test]
    fn jain_index_shape() {
        // even allocation is perfectly fair
        assert_eq!(jain_index(&[2.0, 2.0, 2.0]), 1.0);
        // one tenant starved: (3)^2 / (2 * 9) = 0.5
        assert_eq!(jain_index(&[3.0, 0.0]), 0.5);
        // n tenants, one served: index -> 1/n
        assert!((jain_index(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // degenerate samples are fair by convention
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[5.0]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn tenant_stats_aggregate_slowdowns_and_slo() {
        let t = TenantStats::from_jobs("prod", 2.0, &[1.0, 2.0, 3.0], 1, 2, 4);
        assert_eq!(t.tenant, "prod");
        assert_eq!(t.weight, 2.0);
        assert_eq!(t.jobs, 4);
        assert_eq!(t.rejected, 1);
        assert_eq!(t.mean_slowdown, 2.0);
        assert_eq!(t.p95_slowdown, 3.0);
        assert_eq!(t.slo_attainment, 0.5);
        // no completed jobs, no deadlines: neutral aggregates
        let idle = TenantStats::from_jobs("batch", 1.0, &[], 0, 0, 0);
        assert_eq!(idle.jobs, 0);
        assert_eq!(idle.mean_slowdown, 1.0);
        assert_eq!(idle.p95_slowdown, 1.0);
        assert_eq!(idle.slo_attainment, 1.0);
    }

    #[test]
    fn sketch_is_bitwise_exact_below_capacity() {
        let vals = [4.0, 1.0, 3.5, 2.0, 9.25, 0.5, 7.125];
        let mut sk = QuantileSketch::new(8);
        for &v in &vals {
            sk.insert(v);
        }
        assert!(sk.is_exact());
        for p in [0.0, 25.0, 50.0, 75.0, 95.0, 100.0] {
            assert_eq!(sk.quantile(p).to_bits(), percentile(&vals, p).to_bits(), "p{p}");
        }
    }

    #[test]
    fn sketch_stays_bounded_and_close_past_capacity() {
        let n = 10_000usize;
        let mut sk = QuantileSketch::new(64);
        // deterministic scramble of 0..n so insertion order is not sorted
        for i in 0..n {
            sk.insert(((i * 7919) % n) as f64);
        }
        assert_eq!(sk.count(), n as u64);
        assert!(!sk.is_exact());
        assert!(sk.retained() <= 64, "retained {}", sk.retained());
        // rank error is bounded by the heaviest centroid; on this
        // uniform sample that translates to value error well under 5%
        assert!((sk.quantile(50.0) - 5000.0).abs() < 500.0, "p50 {}", sk.quantile(50.0));
        assert!((sk.quantile(95.0) - 9500.0).abs() < 500.0, "p95 {}", sk.quantile(95.0));
        assert!(sk.max_centroid_weight() > 0);
    }

    #[test]
    fn sketch_is_insertion_order_deterministic() {
        let mut a = QuantileSketch::new(16);
        let mut b = QuantileSketch::new(16);
        for i in 0..500u64 {
            let v = ((i * 31) % 97) as f64 * 1.375;
            a.insert(v);
            b.insert(v);
        }
        assert_eq!(a, b);
        assert_eq!(a.quantile(95.0).to_bits(), b.quantile(95.0).to_bits());
    }

    #[test]
    fn accumulator_matches_batch_stats_bitwise_on_small_streams() {
        let jts = [10.0, 33.5, 21.25, 8.0, 55.0];
        let slows = [1.0, 2.5, 1.75, 1.0, 4.0];
        let mut acc = StreamAccum::new(64);
        for (&j, &s) in jts.iter().zip(&slows) {
            acc.push(j, s);
        }
        let a = acc.stats();
        let b = StreamStats::from_jobs(&jts, &slows);
        assert_eq!(a.jobs, b.jobs);
        assert_eq!(a.mean_jt.to_bits(), b.mean_jt.to_bits());
        assert_eq!(a.p50_jt.to_bits(), b.p50_jt.to_bits());
        assert_eq!(a.p95_jt.to_bits(), b.p95_jt.to_bits());
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!(a.max_slowdown.to_bits(), b.max_slowdown.to_bits());
    }

    #[test]
    fn throughput_is_gated_on_the_slowdown_target() {
        assert_eq!(jobs_per_hour(100, 3600.0), 100.0);
        assert_eq!(jobs_per_hour(0, 0.0), 0.0);
        assert_eq!(sustained_jobs_per_hour(100, 3600.0, 2.0, 3.0), 100.0);
        assert_eq!(sustained_jobs_per_hour(100, 3600.0, 3.0, 3.0), 100.0);
        assert_eq!(sustained_jobs_per_hour(100, 3600.0, 3.1, 3.0), 0.0);
    }
}
