//! Job-level metrics, matching Table I's columns.

use crate::sim::TaskRecord;
use crate::util::Secs;

/// MT / RT / JT / LR for one executed job.
///
/// * `MT` — map-phase completion time: last map finish − submit.
/// * `RT` — reduce-phase completion time: last reduce finish − reduce
///   phase start (the slowstart gate), the paper's "reduce phase
///   completion time".
/// * `JT` — job completion time (make span): last task finish − submit.
/// * `LR` — data-locality ratio over map tasks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobMetrics {
    pub mt: f64,
    pub rt: f64,
    pub jt: f64,
    pub lr: f64,
}

impl JobMetrics {
    /// Derive from execution records. `submit` is the job submission
    /// time; `reduce_gate` the reduce-phase start (None = no reduces).
    pub fn from_records(records: &[TaskRecord], submit: Secs, reduce_gate: Option<Secs>) -> Self {
        if records.is_empty() {
            // degenerate (empty) task sets: all-zero metrics, full
            // locality — never NaN in aggregated/serialized output
            return Self { mt: 0.0, rt: 0.0, jt: 0.0, lr: 1.0 };
        }
        let maps: Vec<&TaskRecord> = records.iter().filter(|r| r.is_map).collect();
        let reduces: Vec<&TaskRecord> = records.iter().filter(|r| !r.is_map).collect();
        let map_end = maps.iter().map(|r| r.finish).fold(submit, Secs::max);
        let all_end = records.iter().map(|r| r.finish).fold(submit, Secs::max);
        let mt = (map_end - submit).0;
        let rt = if reduces.is_empty() {
            0.0
        } else {
            let red_end = reduces.iter().map(|r| r.finish).fold(submit, Secs::max);
            let start = reduce_gate.unwrap_or(submit);
            (red_end - start).0
        };
        let jt = (all_end - submit).0;
        let lr = if maps.is_empty() {
            1.0
        } else {
            maps.iter().filter(|r| r.is_local).count() as f64 / maps.len() as f64
        };
        Self { mt, rt, jt, lr }
    }
}

impl std::fmt::Display for JobMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MT={:.0}s RT={:.0}s JT={:.0}s LR={:.1}%",
            self.mt,
            self.rt,
            self.jt,
            self.lr * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::TaskId;
    use crate::topology::NodeId;

    fn rec(task: usize, finish: f64, is_map: bool, is_local: bool) -> TaskRecord {
        TaskRecord {
            task: TaskId(task),
            node: NodeId(0),
            picked_at: Secs::ZERO,
            input_ready: Secs::ZERO,
            compute_start: Secs::ZERO,
            finish: Secs(finish),
            source: None,
            is_local,
            is_map,
        }
    }

    #[test]
    fn metrics_shape() {
        let records = vec![
            rec(0, 10.0, true, true),
            rec(1, 14.0, true, false),
            rec(2, 30.0, false, false),
        ];
        let m = JobMetrics::from_records(&records, Secs::ZERO, Some(Secs(7.0)));
        assert_eq!(m.mt, 14.0);
        assert_eq!(m.rt, 23.0); // 30 - 7
        assert_eq!(m.jt, 30.0);
        assert_eq!(m.lr, 0.5);
    }

    #[test]
    fn map_only_job() {
        let records = vec![rec(0, 35.0, true, true)];
        let m = JobMetrics::from_records(&records, Secs::ZERO, None);
        assert_eq!(m.jt, 35.0);
        assert_eq!(m.rt, 0.0);
        assert_eq!(m.lr, 1.0);
    }

    #[test]
    fn empty_records_yield_zeroes_not_nan() {
        let m = JobMetrics::from_records(&[], Secs::ZERO, None);
        assert_eq!((m.mt, m.rt, m.jt, m.lr), (0.0, 0.0, 0.0, 1.0));
        assert!(!m.lr.is_nan());
    }

    #[test]
    fn submit_offset_subtracts() {
        let records = vec![rec(0, 35.0, true, true)];
        let m = JobMetrics::from_records(&records, Secs(5.0), None);
        assert_eq!(m.jt, 30.0);
    }
}
