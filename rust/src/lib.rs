//! # bass — Bandwidth-Aware Scheduling with SDN in Hadoop
//!
//! Production-quality reproduction of Qin et al., *"Bandwidth-Aware
//! Scheduling with SDN in Hadoop: A New Trend for Big Data"* (2014).
//!
//! The crate is the **L3 coordinator** of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`topology`] / [`sdn`] / [`hdfs`] / [`cluster`] / [`mapreduce`] /
//!   [`sim`] — the substrates the paper's evaluation depends on (network,
//!   OpenFlow-style controller with time-slot bandwidth calendars, HDFS
//!   block placement, task trackers, MapReduce job model, discrete-event
//!   simulator with flow-level bandwidth sharing).
//! * [`sched`] — the paper's contribution: the **BASS** scheduler
//!   (Algorithm 1) plus the baselines **HDS**, **BAR** and the **Pre-BASS**
//!   prefetching extension (Discussion 2).
//! * [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX/Pallas cost
//!   model (`artifacts/cost_*.hlo.txt`); Python never runs at request time.
//! * [`scenario`] — the construction layer: a declarative
//!   [`scenario::ScenarioSpec`] builds a [`scenario::SimSession`] owning
//!   every substrate object; all drivers construct clusters through it.
//! * [`coordinator`] — the leader event loop binding everything together.
//! * [`experiments`] — one driver per paper table/figure (Example 1-3,
//!   Table I(a)/(b), Fig 4, Fig 5), shared by `examples/` and `benches/`.
//!
//! Quickstart: see `examples/quickstart.rs`, or run
//! `cargo run --release -- example1`.

pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod hdfs;
pub mod mapreduce;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod sdn;
pub mod sim;
pub mod testkit;
pub mod topology;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
