//! Result writers: CSV and Markdown rows for EXPERIMENTS.md.

use crate::experiments::Table1Row;

/// Render Table I rows as the paper-shaped markdown table.
pub fn table1_markdown(rows: &[Table1Row]) -> String {
    let mut s = String::from(
        "| Data size | Sched | MT(s) | RT(s) | JT(s) | LR |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "| {} | {} | {:.0} | {:.0} | {:.0} | {:.1}% |\n",
            fmt_size(r.data_mb),
            r.scheduler,
            r.metrics.mt,
            r.metrics.rt,
            r.metrics.jt,
            r.metrics.lr * 100.0
        ));
    }
    s
}

/// CSV form of the same rows.
pub fn table1_csv(rows: &[Table1Row]) -> String {
    let mut s = String::from("data_mb,scheduler,mt_s,rt_s,jt_s,lr\n");
    for r in rows {
        s.push_str(&format!(
            "{},{},{:.2},{:.2},{:.2},{:.4}\n",
            r.data_mb, r.scheduler, r.metrics.mt, r.metrics.rt, r.metrics.jt, r.metrics.lr
        ));
    }
    s
}

/// Human data-size label (150M, 1G, ...).
pub fn fmt_size(mb: f64) -> String {
    if mb >= 1024.0 && (mb / 1024.0).fract() == 0.0 {
        format!("{}G", mb / 1024.0)
    } else {
        format!("{}M", mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::JobMetrics;

    fn row() -> Table1Row {
        Table1Row {
            scheduler: "BASS",
            data_mb: 1024.0,
            metrics: JobMetrics { mt: 10.0, rt: 20.0, jt: 25.0, lr: 0.75 },
        }
    }

    #[test]
    fn markdown_contains_row() {
        let md = table1_markdown(&[row()]);
        assert!(md.contains("| 1G | BASS | 10 | 20 | 25 | 75.0% |"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let csv = table1_csv(&[row()]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].split(',').count(), 6);
    }

    #[test]
    fn size_labels() {
        assert_eq!(fmt_size(150.0), "150M");
        assert_eq!(fmt_size(5120.0), "5G");
    }
}
