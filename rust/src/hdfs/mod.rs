//! HDFS substrate: blocks, replica placement, locality lookup.
//!
//! The schedulers only need the namenode's view: which task nodes hold a
//! replica of each input split (`data locality`), and which replica to
//! read from when going remote ("always moved from the least loaded node
//! storing the replica" — Discussion 2).

pub mod namenode;
pub mod placement;

pub use namenode::{BlockId, BlockInfo, Namenode};
pub use placement::PlacementPolicy;
