//! The namenode: block -> replica-set metadata.

use crate::topology::NodeId;

/// An HDFS block (one task input split in the paper's model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub usize);

/// Metadata for one block.
#[derive(Debug, Clone)]
pub struct BlockInfo {
    pub id: BlockId,
    pub size_mb: f64,
    /// Replica holders, distinct nodes.
    pub replicas: Vec<NodeId>,
}

/// Minimal namenode: the block map.
#[derive(Debug, Clone, Default)]
pub struct Namenode {
    blocks: Vec<BlockInfo>,
}

impl Namenode {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a block with an explicit replica set (used by the paper's
    /// Example 1 where placement is fixed) — replicas must be distinct.
    pub fn add_block(&mut self, size_mb: f64, replicas: Vec<NodeId>) -> BlockId {
        let mut sorted = replicas.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), replicas.len(), "replicas must be distinct nodes");
        assert!(!replicas.is_empty(), "a block needs at least one replica");
        let id = BlockId(self.blocks.len());
        self.blocks.push(BlockInfo { id, size_mb, replicas });
        id
    }

    pub fn block(&self, id: BlockId) -> &BlockInfo {
        &self.blocks[id.0]
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Does `node` hold a replica of `block`? (the locality test)
    pub fn is_local(&self, block: BlockId, node: NodeId) -> bool {
        self.block(block).replicas.contains(&node)
    }

    /// Replica holders restricted to an authorized node subset (the
    /// paper's Case 2 "locality-starvation" arises when this is empty).
    pub fn local_candidates<'a>(
        &'a self,
        block: BlockId,
        authorized: &'a [NodeId],
    ) -> impl Iterator<Item = NodeId> + 'a {
        self.block(block)
            .replicas
            .iter()
            .copied()
            .filter(move |r| authorized.contains(r))
    }

    /// Replica holders that can currently serve reads. Unlike
    /// [`Namenode::local_candidates`] this is *not* restricted to the
    /// compute-authorized subset — Case 2 reads from outside it — only to
    /// holders the caller deems alive (a crashed datanode's replicas are
    /// unreadable under `[dynamics]`).
    pub fn readable_replicas<'a>(
        &'a self,
        block: BlockId,
        readable: impl Fn(NodeId) -> bool + 'a,
    ) -> impl Iterator<Item = NodeId> + 'a {
        self.block(block).replicas.iter().copied().filter(move |&r| readable(r))
    }

    /// Can at least one replica of `block` serve reads right now?
    pub fn is_readable(&self, block: BlockId, readable: impl Fn(NodeId) -> bool) -> bool {
        self.readable_replicas(block, readable).next().is_some()
    }

    /// The replica to read from when transferring remotely under the
    /// legacy idle-only rule (Discussion 2: least loaded holder), over
    /// the *readable* holders only. `None` when every holder is down —
    /// the seed picked a crashed holder here, which the scheduling layer
    /// then "pulled" from; callers must treat `None` as block-unreadable.
    pub fn least_loaded_replica(
        &self,
        block: BlockId,
        readable: impl Fn(NodeId) -> bool,
        idle_of: impl Fn(NodeId) -> f64,
    ) -> Option<NodeId> {
        self.readable_replicas(block, readable)
            .min_by(|a, b| idle_of(*a).total_cmp(&idle_of(*b)))
    }

    /// Blocks with fewer readable replicas than stored replicas (some
    /// holder is down) — the namenode view a real HDFS would re-replicate
    /// from. Surfaced by the dynamics layer per scheduling round.
    pub fn under_replicated(&self, readable: impl Fn(NodeId) -> bool) -> Vec<BlockId> {
        self.blocks
            .iter()
            .filter(|b| b.replicas.iter().any(|&r| !readable(r)))
            .map(|b| b.id)
            .collect()
    }

    /// Blocks with *no* readable replica at all: tasks over these cannot
    /// be scheduled until a holder recovers.
    pub fn unreadable_blocks(&self, readable: impl Fn(NodeId) -> bool + Copy) -> Vec<BlockId> {
        self.blocks
            .iter()
            .filter(|b| !b.replicas.iter().any(|&r| readable(r)))
            .map(|b| b.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nn() -> Namenode {
        let mut n = Namenode::new();
        n.add_block(64.0, vec![NodeId(1), NodeId(2)]);
        n.add_block(64.0, vec![NodeId(0)]);
        n
    }

    #[test]
    fn locality_lookup() {
        let n = nn();
        assert!(n.is_local(BlockId(0), NodeId(1)));
        assert!(n.is_local(BlockId(0), NodeId(2)));
        assert!(!n.is_local(BlockId(0), NodeId(0)));
    }

    #[test]
    fn local_candidates_respects_authorization() {
        let n = nn();
        let auth = [NodeId(2), NodeId(3)];
        let c: Vec<_> = n.local_candidates(BlockId(0), &auth).collect();
        assert_eq!(c, vec![NodeId(2)]);
        // locality starvation: no authorized replica holder
        let auth2 = [NodeId(3)];
        assert_eq!(n.local_candidates(BlockId(0), &auth2).count(), 0);
    }

    #[test]
    fn least_loaded_replica_picks_min_idle_among_readable() {
        let n = nn();
        let idle = |nd: NodeId| [9.0, 3.0, 20.0][nd.0.min(2)];
        assert_eq!(n.least_loaded_replica(BlockId(0), |_| true, idle), Some(NodeId(1)));
        // the min-idle holder is down: the next healthy one wins
        assert_eq!(
            n.least_loaded_replica(BlockId(0), |nd| nd != NodeId(1), idle),
            Some(NodeId(2))
        );
        // every holder down: no source at all (the seed bug returned a
        // crashed node here)
        assert_eq!(n.least_loaded_replica(BlockId(0), |_| false, idle), None);
    }

    #[test]
    fn readability_and_under_replication_views() {
        let n = nn();
        let up = |nd: NodeId| nd != NodeId(1);
        assert!(n.is_readable(BlockId(0), up)); // NodeId(2) still serves
        assert!(n.is_readable(BlockId(1), up));
        assert_eq!(n.under_replicated(up), vec![BlockId(0)]);
        assert!(n.unreadable_blocks(up).is_empty());
        let only_zero_down = |nd: NodeId| nd != NodeId(0);
        assert_eq!(n.unreadable_blocks(only_zero_down), vec![BlockId(1)]);
        assert_eq!(n.under_replicated(|_| true), Vec::<BlockId>::new());
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn duplicate_replicas_rejected() {
        let mut n = Namenode::new();
        n.add_block(64.0, vec![NodeId(1), NodeId(1)]);
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn empty_replicas_rejected() {
        let mut n = Namenode::new();
        n.add_block(64.0, vec![]);
    }
}
