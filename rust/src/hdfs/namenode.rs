//! The namenode: block -> replica-set metadata.

use crate::topology::NodeId;

/// An HDFS block (one task input split in the paper's model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub usize);

/// Metadata for one block.
#[derive(Debug, Clone)]
pub struct BlockInfo {
    pub id: BlockId,
    pub size_mb: f64,
    /// Replica holders, distinct nodes.
    pub replicas: Vec<NodeId>,
}

/// Minimal namenode: the block map.
#[derive(Debug, Clone, Default)]
pub struct Namenode {
    blocks: Vec<BlockInfo>,
}

impl Namenode {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a block with an explicit replica set (used by the paper's
    /// Example 1 where placement is fixed) — replicas must be distinct.
    pub fn add_block(&mut self, size_mb: f64, replicas: Vec<NodeId>) -> BlockId {
        let mut sorted = replicas.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), replicas.len(), "replicas must be distinct nodes");
        assert!(!replicas.is_empty(), "a block needs at least one replica");
        let id = BlockId(self.blocks.len());
        self.blocks.push(BlockInfo { id, size_mb, replicas });
        id
    }

    pub fn block(&self, id: BlockId) -> &BlockInfo {
        &self.blocks[id.0]
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Does `node` hold a replica of `block`? (the locality test)
    pub fn is_local(&self, block: BlockId, node: NodeId) -> bool {
        self.block(block).replicas.contains(&node)
    }

    /// Replica holders restricted to an authorized node subset (the
    /// paper's Case 2 "locality-starvation" arises when this is empty).
    pub fn local_candidates<'a>(
        &'a self,
        block: BlockId,
        authorized: &'a [NodeId],
    ) -> impl Iterator<Item = NodeId> + 'a {
        self.block(block)
            .replicas
            .iter()
            .copied()
            .filter(move |r| authorized.contains(r))
    }

    /// The replica to read from when transferring remotely: the least
    /// loaded holder per the provided idle-time lookup (Discussion 2).
    pub fn least_loaded_replica(
        &self,
        block: BlockId,
        idle_of: impl Fn(NodeId) -> f64,
    ) -> NodeId {
        *self
            .block(block)
            .replicas
            .iter()
            .min_by(|a, b| idle_of(**a).total_cmp(&idle_of(**b)))
            .expect("non-empty replica set")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nn() -> Namenode {
        let mut n = Namenode::new();
        n.add_block(64.0, vec![NodeId(1), NodeId(2)]);
        n.add_block(64.0, vec![NodeId(0)]);
        n
    }

    #[test]
    fn locality_lookup() {
        let n = nn();
        assert!(n.is_local(BlockId(0), NodeId(1)));
        assert!(n.is_local(BlockId(0), NodeId(2)));
        assert!(!n.is_local(BlockId(0), NodeId(0)));
    }

    #[test]
    fn local_candidates_respects_authorization() {
        let n = nn();
        let auth = [NodeId(2), NodeId(3)];
        let c: Vec<_> = n.local_candidates(BlockId(0), &auth).collect();
        assert_eq!(c, vec![NodeId(2)]);
        // locality starvation: no authorized replica holder
        let auth2 = [NodeId(3)];
        assert_eq!(n.local_candidates(BlockId(0), &auth2).count(), 0);
    }

    #[test]
    fn least_loaded_replica_picks_min_idle() {
        let n = nn();
        let idle = |nd: NodeId| [9.0, 3.0, 20.0][nd.0.min(2)];
        assert_eq!(n.least_loaded_replica(BlockId(0), idle), NodeId(1));
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn duplicate_replicas_rejected() {
        let mut n = Namenode::new();
        n.add_block(64.0, vec![NodeId(1), NodeId(1)]);
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn empty_replicas_rejected() {
        let mut n = Namenode::new();
        n.add_block(64.0, vec![]);
    }
}
