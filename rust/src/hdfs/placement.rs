//! Replica placement policies for generated workloads.
//!
//! Table I runs with `dfs.replication = 3` on 6 nodes; placement there is
//! Hadoop's default (random distinct nodes, rack-unaware in a flat 6-node
//! cluster). The round-robin policy gives fully deterministic layouts for
//! calibration tests.

use crate::topology::NodeId;
use crate::util::XorShift;

use super::namenode::Namenode;

/// How generated blocks choose replica holders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// k distinct nodes uniformly at random (Hadoop default, flat cluster).
    RandomDistinct,
    /// Block b's replicas at nodes (b, b+1, ..., b+k-1) mod n.
    RoundRobin,
}

impl PlacementPolicy {
    /// Place `n_blocks` blocks of `size_mb` over `nodes`, `k` replicas each.
    pub fn place(
        &self,
        nn: &mut Namenode,
        nodes: &[NodeId],
        n_blocks: usize,
        size_mb: f64,
        k: usize,
        rng: &mut XorShift,
    ) -> Vec<super::BlockId> {
        assert!(k >= 1 && k <= nodes.len(), "replication {k} vs {} nodes", nodes.len());
        (0..n_blocks)
            .map(|b| {
                let replicas: Vec<NodeId> = match self {
                    PlacementPolicy::RandomDistinct => rng
                        .distinct(nodes.len(), k)
                        .into_iter()
                        .map(|i| nodes[i])
                        .collect(),
                    PlacementPolicy::RoundRobin => {
                        (0..k).map(|r| nodes[(b + r) % nodes.len()]).collect()
                    }
                };
                nn.add_block(size_mb, replicas)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::BlockId;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn round_robin_is_deterministic() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        let ids = PlacementPolicy::RoundRobin.place(&mut nn, &nodes(4), 5, 64.0, 2, &mut rng);
        assert_eq!(ids.len(), 5);
        assert_eq!(nn.block(BlockId(0)).replicas, vec![NodeId(0), NodeId(1)]);
        assert_eq!(nn.block(BlockId(3)).replicas, vec![NodeId(3), NodeId(0)]);
    }

    #[test]
    fn random_distinct_has_k_distinct_replicas() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(7);
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes(6), 50, 64.0, 3, &mut rng);
        for b in 0..50 {
            let r = &nn.block(BlockId(b)).replicas;
            assert_eq!(r.len(), 3);
            let mut s = r.clone();
            s.sort();
            s.dedup();
            assert_eq!(s.len(), 3);
        }
    }

    #[test]
    fn random_distinct_spreads_load() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(11);
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes(6), 600, 64.0, 3, &mut rng);
        let mut count = [0usize; 6];
        for b in 0..600 {
            for r in &nn.block(BlockId(b)).replicas {
                count[r.0] += 1;
            }
        }
        // 600*3/6 = 300 expected per node; allow generous slack
        for (i, &c) in count.iter().enumerate() {
            assert!((200..400).contains(&c), "node {i} has {c} replicas");
        }
    }

    #[test]
    #[should_panic(expected = "replication")]
    fn replication_beyond_cluster_rejected() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes(2), 1, 64.0, 3, &mut rng);
    }
}
