//! Replica placement policies for generated workloads.
//!
//! Table I runs with `dfs.replication = 3` on 6 nodes; placement there is
//! Hadoop's default (random distinct nodes, rack-unaware in a flat 6-node
//! cluster). The round-robin policy gives fully deterministic layouts for
//! calibration tests; [`PlacementPolicy::RackAware`] mirrors Hadoop's
//! rack-aware default on multi-switch clusters (BigDataSDNSim models the
//! same rule); [`PlacementPolicy::Hotspot`] concentrates primaries on a
//! few nodes so schedulers compete on skewed layouts; and
//! [`PlacementPolicy::Explicit`] replays a hand-written layout (the
//! Example 1 fixture).

use crate::topology::NodeId;
use crate::util::XorShift;

use super::namenode::Namenode;

/// How generated blocks choose replica holders.
#[derive(Debug, Clone, PartialEq)]
pub enum PlacementPolicy {
    /// k distinct nodes uniformly at random (Hadoop default, flat cluster).
    RandomDistinct,
    /// Block b's replicas at nodes (b, b+1, ..., b+k-1) mod n.
    RoundRobin,
    /// Hand-written layout: block b uses entry `b % len` — each entry is
    /// a list of distinct indices into the node slice, and the entry
    /// length (not the sweep's replication factor) sets that block's
    /// replica count. This is how Example 1's reverse-engineered layout
    /// is expressed.
    Explicit(Vec<Vec<usize>>),
    /// Hadoop's rack-aware default: first replica on a random node, the
    /// second in a *different* rack, the third in the second's rack,
    /// further replicas random. Falls back to random-distinct when the
    /// cluster has fewer than two racks (exactly Hadoop's flat-cluster
    /// behavior).
    RackAware,
    /// Skewed layout: with probability `bias` a block's primary replica
    /// lands on one of the first `hot` nodes; remaining replicas are
    /// random distinct. `bias = 0` degenerates to random-distinct.
    Hotspot { hot: usize, bias: f64 },
}

impl PlacementPolicy {
    /// Parse the config-file spelling (`[hdfs] placement = ...`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "random" | "random_distinct" => Some(PlacementPolicy::RandomDistinct),
            "round_robin" => Some(PlacementPolicy::RoundRobin),
            "rack_aware" => Some(PlacementPolicy::RackAware),
            "hotspot" => Some(PlacementPolicy::Hotspot { hot: 2, bias: 0.8 }),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::RandomDistinct => "random",
            PlacementPolicy::RoundRobin => "round_robin",
            PlacementPolicy::Explicit(_) => "explicit",
            PlacementPolicy::RackAware => "rack_aware",
            PlacementPolicy::Hotspot { .. } => "hotspot",
        }
    }

    /// Place `n_blocks` blocks of `size_mb` over `nodes`, `k` replicas
    /// each (`Explicit` entries carry their own count). `racks[i]` is the
    /// rack (edge switch) of `nodes[i]`; an empty slice means a flat
    /// cluster (see [`crate::topology::builders::host_racks`]).
    #[allow(clippy::too_many_arguments)] // flat layout args, one call shape
    pub fn place(
        &self,
        nn: &mut Namenode,
        nodes: &[NodeId],
        racks: &[usize],
        n_blocks: usize,
        size_mb: f64,
        k: usize,
        rng: &mut XorShift,
    ) -> Vec<super::BlockId> {
        let n = nodes.len();
        assert!(k >= 1 && k <= n, "replication {k} vs {n} nodes");
        assert!(racks.is_empty() || racks.len() == n, "racks must map the node slice");
        (0..n_blocks)
            .map(|b| {
                let replicas: Vec<NodeId> = match self {
                    PlacementPolicy::RandomDistinct => {
                        rng.distinct(n, k).into_iter().map(|i| nodes[i]).collect()
                    }
                    PlacementPolicy::RoundRobin => {
                        (0..k).map(|r| nodes[(b + r) % n]).collect()
                    }
                    PlacementPolicy::Explicit(lists) => {
                        assert!(!lists.is_empty(), "explicit placement needs entries");
                        lists[b % lists.len()].iter().map(|&i| nodes[i]).collect()
                    }
                    PlacementPolicy::RackAware => rack_aware(n, racks, k, rng)
                        .into_iter()
                        .map(|i| nodes[i])
                        .collect(),
                    PlacementPolicy::Hotspot { hot, bias } => {
                        hotspot(n, *hot, *bias, k, rng).into_iter().map(|i| nodes[i]).collect()
                    }
                };
                nn.add_block(size_mb, replicas)
            })
            .collect()
    }
}

/// Hadoop's rack rule over node *indices*; distinct by construction.
fn rack_aware(n: usize, racks: &[usize], k: usize, rng: &mut XorShift) -> Vec<usize> {
    let distinct_racks = {
        let mut rs: Vec<usize> = racks.to_vec();
        rs.sort_unstable();
        rs.dedup();
        rs.len()
    };
    if racks.is_empty() || distinct_racks < 2 {
        return rng.distinct(n, k);
    }
    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    // r0: the "writer" node
    chosen.push(rng.below(n));
    if k >= 2 {
        // r1: a node in a different rack
        let off_rack: Vec<usize> =
            (0..n).filter(|&i| racks[i] != racks[chosen[0]]).collect();
        chosen.push(off_rack[rng.below(off_rack.len())]);
    }
    if k >= 3 {
        // r2: another node in r1's rack, else anywhere distinct
        let same_rack: Vec<usize> = (0..n)
            .filter(|&i| racks[i] == racks[chosen[1]] && !chosen.contains(&i))
            .collect();
        if same_rack.is_empty() {
            push_distinct(n, &mut chosen, rng);
        } else {
            chosen.push(same_rack[rng.below(same_rack.len())]);
        }
    }
    while chosen.len() < k {
        push_distinct(n, &mut chosen, rng);
    }
    chosen
}

/// Hotspot rule over node indices.
fn hotspot(n: usize, hot: usize, bias: f64, k: usize, rng: &mut XorShift) -> Vec<usize> {
    let hot = hot.clamp(1, n);
    let mut chosen = Vec::with_capacity(k);
    chosen.push(if rng.chance(bias) { rng.below(hot) } else { rng.below(n) });
    while chosen.len() < k {
        push_distinct(n, &mut chosen, rng);
    }
    chosen
}

/// Append one uniformly random index not yet chosen (draws over the
/// complement, so one rng draw per replica — deterministic and bounded).
fn push_distinct(n: usize, chosen: &mut Vec<usize>, rng: &mut XorShift) {
    let rest: Vec<usize> = (0..n).filter(|i| !chosen.contains(i)).collect();
    chosen.push(rest[rng.below(rest.len())]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::BlockId;

    fn nodes(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn round_robin_is_deterministic() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        let ids =
            PlacementPolicy::RoundRobin.place(&mut nn, &nodes(4), &[], 5, 64.0, 2, &mut rng);
        assert_eq!(ids.len(), 5);
        assert_eq!(nn.block(BlockId(0)).replicas, vec![NodeId(0), NodeId(1)]);
        assert_eq!(nn.block(BlockId(3)).replicas, vec![NodeId(3), NodeId(0)]);
    }

    #[test]
    fn random_distinct_has_k_distinct_replicas() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(7);
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes(6), &[], 50, 64.0, 3, &mut rng);
        for b in 0..50 {
            let r = &nn.block(BlockId(b)).replicas;
            assert_eq!(r.len(), 3);
            let mut s = r.clone();
            s.sort();
            s.dedup();
            assert_eq!(s.len(), 3);
        }
    }

    #[test]
    fn random_distinct_spreads_load() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(11);
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes(6), &[], 600, 64.0, 3, &mut rng);
        let mut count = [0usize; 6];
        for b in 0..600 {
            for r in &nn.block(BlockId(b)).replicas {
                count[r.0] += 1;
            }
        }
        // 600*3/6 = 300 expected per node; allow generous slack
        for (i, &c) in count.iter().enumerate() {
            assert!((200..400).contains(&c), "node {i} has {c} replicas");
        }
    }

    #[test]
    fn explicit_replays_the_written_layout() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        let layout = PlacementPolicy::Explicit(vec![vec![1, 2], vec![0, 3]]);
        layout.place(&mut nn, &nodes(4), &[], 3, 64.0, 2, &mut rng);
        assert_eq!(nn.block(BlockId(0)).replicas, vec![NodeId(1), NodeId(2)]);
        assert_eq!(nn.block(BlockId(1)).replicas, vec![NodeId(0), NodeId(3)]);
        // cycles past the entry list
        assert_eq!(nn.block(BlockId(2)).replicas, vec![NodeId(1), NodeId(2)]);
    }

    #[test]
    fn rack_aware_crosses_racks_at_replication_3() {
        // 2 racks x 3 hosts: r0 anywhere, r1 off-rack, r2 in r1's rack
        let racks = [0, 0, 0, 1, 1, 1];
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(21);
        PlacementPolicy::RackAware.place(&mut nn, &nodes(6), &racks, 80, 64.0, 3, &mut rng);
        for b in 0..80 {
            let r = &nn.block(BlockId(b)).replicas;
            assert_eq!(r.len(), 3);
            let rk: Vec<usize> = r.iter().map(|nd| racks[nd.0]).collect();
            assert_ne!(rk[0], rk[1], "second replica must change racks: {r:?}");
            assert_eq!(rk[1], rk[2], "third replica shares the second's rack: {r:?}");
        }
    }

    #[test]
    fn rack_aware_flat_cluster_degenerates_to_random() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(5);
        PlacementPolicy::RackAware.place(&mut nn, &nodes(4), &[], 10, 64.0, 3, &mut rng);
        for b in 0..10 {
            assert_eq!(nn.block(BlockId(b)).replicas.len(), 3);
        }
    }

    #[test]
    fn hotspot_concentrates_primaries() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(13);
        PlacementPolicy::Hotspot { hot: 2, bias: 0.9 }
            .place(&mut nn, &nodes(8), &[], 400, 64.0, 2, &mut rng);
        let hot_primaries = (0..400)
            .filter(|&b| nn.block(BlockId(b)).replicas[0].0 < 2)
            .count();
        // bias 0.9 over 2-of-8 hot nodes: expect ~ 0.9 + 0.1*0.25 = 92.5%
        assert!(hot_primaries > 300, "only {hot_primaries}/400 primaries on hot nodes");
        // replicas stay distinct
        for b in 0..400 {
            let r = &nn.block(BlockId(b)).replicas;
            assert_ne!(r[0], r[1]);
        }
    }

    #[test]
    fn parse_covers_the_named_policies() {
        assert_eq!(PlacementPolicy::parse("random"), Some(PlacementPolicy::RandomDistinct));
        assert_eq!(PlacementPolicy::parse("round_robin"), Some(PlacementPolicy::RoundRobin));
        assert_eq!(PlacementPolicy::parse("rack_aware"), Some(PlacementPolicy::RackAware));
        assert!(matches!(
            PlacementPolicy::parse("hotspot"),
            Some(PlacementPolicy::Hotspot { .. })
        ));
        assert_eq!(PlacementPolicy::parse("roundrobin"), None);
    }

    #[test]
    #[should_panic(expected = "replication")]
    fn replication_beyond_cluster_rejected() {
        let mut nn = Namenode::new();
        let mut rng = XorShift::new(1);
        PlacementPolicy::RandomDistinct.place(&mut nn, &nodes(2), &[], 1, 64.0, 3, &mut rng);
    }
}
