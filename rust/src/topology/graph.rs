//! Core graph types: endpoints, links, and the [`Topology`] container.

/// A Hadoop task-tracker / datanode host (the paper's `ND_j`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// An OpenFlow switch (Open vSwitch in the paper's testbed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SwitchId(pub usize);

/// A physical link (the paper's `Link1..Link8`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub usize);

/// Anything a link can attach to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Endpoint {
    /// Task node / datanode.
    Host(NodeId),
    /// OpenFlow switch.
    Switch(SwitchId),
    /// The (single) core router of Fig. 2-style trees.
    Router(usize),
}

/// An undirected duplex link with a fixed line rate.
///
/// The paper treats each link's bandwidth as one shared resource that the
/// SDN controller slices into time slots, so we model capacity per link,
/// not per direction.
#[derive(Debug, Clone)]
pub struct Link {
    pub id: LinkId,
    pub a: Endpoint,
    pub b: Endpoint,
    /// Line rate in Mbps (the paper's 100 Mbps default).
    pub capacity_mbps: f64,
}

impl Link {
    /// The endpoint opposite to `e`, if `e` touches this link.
    pub fn other(&self, e: Endpoint) -> Option<Endpoint> {
        if self.a == e {
            Some(self.b)
        } else if self.b == e {
            Some(self.a)
        } else {
            None
        }
    }
}

/// The network: hosts, switches, router(s) and the links joining them.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    pub hosts: Vec<NodeId>,
    pub switches: Vec<SwitchId>,
    pub routers: Vec<usize>,
    pub links: Vec<Link>,
    /// adjacency: endpoint -> (link, neighbor endpoint)
    adj: std::collections::HashMap<Endpoint, Vec<(LinkId, Endpoint)>>,
}

impl Topology {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_host(&mut self) -> NodeId {
        let id = NodeId(self.hosts.len());
        self.hosts.push(id);
        self.adj.entry(Endpoint::Host(id)).or_default();
        id
    }

    pub fn add_switch(&mut self) -> SwitchId {
        let id = SwitchId(self.switches.len());
        self.switches.push(id);
        self.adj.entry(Endpoint::Switch(id)).or_default();
        id
    }

    pub fn add_router(&mut self) -> usize {
        let id = self.routers.len();
        self.routers.push(id);
        self.adj.entry(Endpoint::Router(id)).or_default();
        id
    }

    /// Connect two endpoints with a new link of the given rate.
    pub fn connect(&mut self, a: Endpoint, b: Endpoint, capacity_mbps: f64) -> LinkId {
        assert!(capacity_mbps > 0.0, "link rate must be positive");
        let id = LinkId(self.links.len());
        self.links.push(Link { id, a, b, capacity_mbps });
        self.adj.entry(a).or_default().push((id, b));
        self.adj.entry(b).or_default().push((id, a));
        id
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    pub fn neighbors(&self, e: Endpoint) -> &[(LinkId, Endpoint)] {
        self.adj.get(&e).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// BFS shortest path between two hosts, returned as the link sequence.
    /// `None` if disconnected; `Some(vec![])` if `src == dst`.
    pub fn route(&self, src: NodeId, dst: NodeId) -> Option<Vec<LinkId>> {
        use std::collections::{HashMap, VecDeque};
        if src == dst {
            return Some(Vec::new());
        }
        let start = Endpoint::Host(src);
        let goal = Endpoint::Host(dst);
        let mut prev: HashMap<Endpoint, (Endpoint, LinkId)> = HashMap::new();
        let mut q = VecDeque::new();
        q.push_back(start);
        while let Some(cur) = q.pop_front() {
            for &(lid, nxt) in self.neighbors(cur) {
                if nxt == start || prev.contains_key(&nxt) {
                    continue;
                }
                prev.insert(nxt, (cur, lid));
                if nxt == goal {
                    // reconstruct
                    let mut path = Vec::new();
                    let mut at = goal;
                    while at != start {
                        let (p, l) = prev[&at];
                        path.push(l);
                        at = p;
                    }
                    path.reverse();
                    return Some(path);
                }
                q.push_back(nxt);
            }
        }
        None
    }

    /// Single-source BFS: shortest link paths from `src` to **every**
    /// host, indexed by host id (`None` = disconnected, `Some(vec![])` at
    /// `src` itself). One sweep replaces `n_hosts` [`Topology::route`]
    /// calls, turning all-pairs cache construction from O(H²·E) into
    /// O(H·E) — the difference between seconds and minutes on
    /// thousand-host fat trees.
    ///
    /// `rot` rotates each expanded endpoint's neighbor order. On trees
    /// (unique shortest paths) it changes nothing; on multipath fabrics
    /// like [`super::builders::fat_tree`] passing the source host id
    /// spreads equal-length routes across the parallel core links
    /// deterministically (a static ECMP hash).
    pub fn routes_from(&self, src: NodeId, rot: usize) -> Vec<Option<Vec<LinkId>>> {
        use std::collections::{HashMap, VecDeque};
        let start = Endpoint::Host(src);
        let mut prev: HashMap<Endpoint, (Endpoint, LinkId)> = HashMap::new();
        let mut q = VecDeque::new();
        q.push_back(start);
        while let Some(cur) = q.pop_front() {
            let nbrs = self.neighbors(cur);
            let len = nbrs.len();
            for k in 0..len {
                let (lid, nxt) = nbrs[(k + rot) % len];
                if nxt == start || prev.contains_key(&nxt) {
                    continue;
                }
                prev.insert(nxt, (cur, lid));
                q.push_back(nxt);
            }
        }
        self.hosts
            .iter()
            .map(|&dst| {
                if dst == src {
                    return Some(Vec::new());
                }
                let goal = Endpoint::Host(dst);
                prev.contains_key(&goal).then(|| {
                    let mut path = Vec::new();
                    let mut at = goal;
                    while at != start {
                        let (p, l) = prev[&at];
                        path.push(l);
                        at = p;
                    }
                    path.reverse();
                    path
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line3() -> (Topology, NodeId, NodeId, NodeId) {
        // h0 - s0 - h1,  s0 - r0 - s1 - h2
        let mut t = Topology::new();
        let h0 = t.add_host();
        let h1 = t.add_host();
        let h2 = t.add_host();
        let s0 = t.add_switch();
        let s1 = t.add_switch();
        let r = t.add_router();
        t.connect(Endpoint::Host(h0), Endpoint::Switch(s0), 100.0);
        t.connect(Endpoint::Host(h1), Endpoint::Switch(s0), 100.0);
        t.connect(Endpoint::Host(h2), Endpoint::Switch(s1), 100.0);
        t.connect(Endpoint::Switch(s0), Endpoint::Router(r), 100.0);
        t.connect(Endpoint::Switch(s1), Endpoint::Router(r), 100.0);
        (t, h0, h1, h2)
    }

    #[test]
    fn route_same_switch_is_two_links() {
        let (t, h0, h1, _) = line3();
        let p = t.route(h0, h1).unwrap();
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn route_cross_switch_goes_via_router() {
        let (t, h0, _, h2) = line3();
        let p = t.route(h0, h2).unwrap();
        assert_eq!(p.len(), 4); // h0-s0, s0-r, r-s1, s1-h2
    }

    #[test]
    fn route_self_is_empty() {
        let (t, h0, _, _) = line3();
        assert_eq!(t.route(h0, h0).unwrap(), vec![]);
    }

    #[test]
    fn route_disconnected_is_none() {
        let mut t = Topology::new();
        let a = t.add_host();
        let b = t.add_host();
        assert!(t.route(a, b).is_none());
    }

    #[test]
    fn routes_from_matches_per_pair_bfs_on_trees() {
        let (t, h0, _, _) = line3();
        // trees have unique shortest paths: any rotation reproduces route()
        for rot in [0usize, 1, 7] {
            let all = t.routes_from(h0, rot);
            assert_eq!(all.len(), t.n_hosts());
            for (d, got) in all.iter().enumerate() {
                assert_eq!(got, &t.route(h0, NodeId(d)), "dst {d} rot {rot}");
            }
        }
    }

    #[test]
    fn routes_from_flags_disconnected_hosts() {
        let mut t = Topology::new();
        let a = t.add_host();
        let b = t.add_host();
        let all = t.routes_from(a, 0);
        assert_eq!(all[a.0], Some(vec![]));
        assert_eq!(all[b.0], None);
    }

    #[test]
    fn link_other_endpoint() {
        let (t, h0, h1, _) = line3();
        let l = t.link(LinkId(0));
        assert_eq!(l.other(Endpoint::Host(h0)), Some(Endpoint::Switch(SwitchId(0))));
        assert_eq!(l.other(Endpoint::Host(h1)), None);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_rate_link_rejected() {
        let mut t = Topology::new();
        let a = t.add_host();
        let b = t.add_host();
        t.connect(Endpoint::Host(a), Endpoint::Host(b), 0.0);
    }
}
