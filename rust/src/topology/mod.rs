//! Network topology substrate: hosts, switches, links, routing.
//!
//! The paper's testbed (Fig. 2) is a two-switch tree: task nodes hang off
//! two OpenFlow switches which connect through a router. [`Topology`] is a
//! general undirected multigraph of [`Endpoint`]s with BFS shortest-path
//! routing and an all-pairs path cache, plus builders for the paper's
//! Fig. 2 and for parameterized fat-tree-ish clusters used in Table I and
//! scale benches.

pub mod builders;
pub mod graph;
pub mod route;

pub use builders::{fat_tree, fig2, host_racks, tree_cluster, Fig2};
pub use graph::{Endpoint, Link, LinkId, NodeId, SwitchId, Topology};
pub use route::{PathCache, PathRef};
