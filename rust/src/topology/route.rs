//! All-pairs host path cache.
//!
//! The schedulers query `route(src, dst)` for every task x candidate-node
//! pair on the hot path; BFS per query is O(E) and shows up in profiles
//! (see EXPERIMENTS.md §Perf). [`PathCache`] precomputes all host-to-host
//! link paths once per topology change.

use super::graph::{LinkId, NodeId, Topology};

/// Immutable all-pairs path table over the task-node set.
#[derive(Debug, Clone)]
pub struct PathCache {
    n: usize,
    /// paths[src * n + dst] — `None` if disconnected.
    paths: Vec<Option<Vec<LinkId>>>,
}

impl PathCache {
    /// Build from a topology: one single-source BFS sweep per host
    /// (O(H·E) total; the seed ran a full BFS per *pair*, which priced
    /// thousand-host fat trees out entirely). Each source rotates its
    /// neighbor order by its own id, so multipath fabrics spread
    /// equal-length routes across parallel core links deterministically;
    /// trees are unaffected (unique shortest paths).
    pub fn build(topo: &Topology) -> Self {
        let n = topo.n_hosts();
        let mut paths = Vec::with_capacity(n * n);
        for s in 0..n {
            paths.extend(topo.routes_from(NodeId(s), s));
        }
        Self { n, paths }
    }

    /// Cached path; empty slice for src == dst.
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<&[LinkId]> {
        self.paths[src.0 * self.n + dst.0].as_deref()
    }

    pub fn n_hosts(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builders::fig2;

    #[test]
    fn cache_matches_bfs() {
        let f = fig2(100.0);
        let cache = PathCache::build(&f.topo);
        for s in 0..f.topo.n_hosts() {
            for d in 0..f.topo.n_hosts() {
                let want = f.topo.route(NodeId(s), NodeId(d));
                let got = cache.path(NodeId(s), NodeId(d)).map(|p| p.to_vec());
                // BFS may differ in path choice only if costs tie; Fig2 is
                // a tree so paths are unique.
                assert_eq!(got, want, "pair ({s},{d})");
            }
        }
    }

    #[test]
    fn self_path_is_empty() {
        let f = fig2(100.0);
        let cache = PathCache::build(&f.topo);
        assert_eq!(cache.path(NodeId(0), NodeId(0)).unwrap(), &[]);
    }
}
